"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/tile sizes; assert_allclose against ref.
This is the CORE correctness signal for the compute layer — everything the
rust coordinator executes was lowered from these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as kc
from compile.kernels import gmm as kg
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, dtype=jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-4, rtol=1e-4)


@st.composite
def conv_cases(draw):
    n = draw(st.sampled_from([1, 2]))
    ci = draw(st.sampled_from([1, 3, 8]))
    kh = draw(st.sampled_from([1, 3, 5]))
    kw = draw(st.sampled_from([1, 3]))
    stride = draw(st.sampled_from([1, 2]))
    ht = draw(st.sampled_from([1, 2, 4]))
    wt = draw(st.sampled_from([1, 2, 4]))
    hb = draw(st.integers(1, 3))
    wb = draw(st.integers(1, 3))
    ot = draw(st.sampled_from([2, 4, 8]))
    ob = draw(st.integers(1, 2))
    ho, wo, o = ht * hb, wt * wb, ot * ob
    h = (ho - 1) * stride + kh
    w = (wo - 1) * stride + kw
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    return dict(n=n, ci=ci, kh=kh, kw=kw, stride=stride,
                ht=ht, wt=wt, ot=ot, h=h, w=w, o=o, dtype=dtype)


@given(conv_cases())
@settings(**SETTINGS)
def test_conv2d_tiled_matches_ref(c):
    k1, k2 = jax.random.split(jax.random.PRNGKey(42))
    inp = _rand(k1, (c["n"], c["h"], c["w"], c["ci"]), c["dtype"])
    ker = _rand(k2, (c["kh"], c["kw"], c["ci"], c["o"]), c["dtype"])
    got = kc.conv2d_nhwo(inp, ker, stride=c["stride"],
                         ht=c["ht"], wt=c["wt"], ot=c["ot"])
    want = ref.conv2d_nhwi(inp.astype(jnp.float32),
                           ker.astype(jnp.float32), stride=c["stride"])
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(c["dtype"]))


@given(conv_cases())
@settings(**SETTINGS)
def test_conv2d_tiled_layout_is_tile_of_nhwo(c):
    """The tiled output must equal tile_nhwo(ref) — i.e. the kernel really
    produces the layout the primitive sequence specifies, not merely the
    right values in some order."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    inp = _rand(k1, (c["n"], c["h"], c["w"], c["ci"]), jnp.float32)
    ker = _rand(k2, (c["kh"], c["kw"], c["ci"], c["o"]), jnp.float32)
    tiled = kc.conv2d_tiled(inp, ker, None, stride=c["stride"],
                            ht=c["ht"], wt=c["wt"], ot=c["ot"])
    want = ref.tile_nhwo(ref.conv2d_nhwi(inp, ker, stride=c["stride"]),
                         c["ht"], c["wt"], c["ot"])
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@given(conv_cases())
@settings(max_examples=15, deadline=None)
def test_conv2d_fused_bias_relu(c):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    inp = _rand(k1, (c["n"], c["h"], c["w"], c["ci"]), jnp.float32)
    ker = _rand(k2, (c["kh"], c["kw"], c["ci"], c["o"]), jnp.float32)
    bias = _rand(k3, (c["o"],), jnp.float32)
    tiled = kc.conv2d_tiled(inp, ker, bias, stride=c["stride"],
                            ht=c["ht"], wt=c["wt"], ot=c["ot"],
                            fuse_bias_relu=True)
    want = ref.conv2d_bias_relu(inp, ker, bias, stride=c["stride"])
    np.testing.assert_allclose(np.asarray(ref.untile_nhwo(tiled)),
                               np.asarray(want), atol=1e-4, rtol=1e-4)


@st.composite
def gmm_cases(draw):
    mt = draw(st.sampled_from([1, 4, 8]))
    kt = draw(st.sampled_from([1, 4, 8]))
    nt = draw(st.sampled_from([2, 8, 16]))
    mb = draw(st.integers(1, 3))
    kb = draw(st.integers(1, 3))
    nb = draw(st.integers(1, 2))
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    return dict(m=mt * mb, k=kt * kb, n=nt * nb,
                mt=mt, kt=kt, nt=nt, dtype=dtype)


@given(gmm_cases())
@settings(**SETTINGS)
def test_gmm_tiled_matches_ref(c):
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    a = _rand(k1, (c["m"], c["k"]), c["dtype"])
    b = _rand(k2, (c["k"], c["n"]), c["dtype"])
    c_t = kg.gmm_tiled(kg.pack_a(a, c["mt"], c["kt"]),
                       kg.pack_b(b, c["kt"], c["nt"]))
    got = kg.untile_c(c_t)
    want = ref.gmm(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(c["dtype"]))


@given(gmm_cases())
@settings(**SETTINGS)
def test_gmm_store_at_matches_ref(c):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(13), 3)
    a = _rand(k1, (c["m"], c["k"]), jnp.float32)
    b = _rand(k2, (c["k"], c["n"]), jnp.float32)
    bias = _rand(k3, (c["n"],), jnp.float32)
    got = kg.gmm_store_at(a, kg.pack_store_at(b, bias),
                          mt=c["mt"], nt=c["nt"])
    want = ref.gmm_bias(a, b, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_pack_roundtrips():
    a = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    assert np.array_equal(
        np.asarray(kg.untile_c(kg.gmm_tiled(
            kg.pack_a(a, 4, 4), kg.pack_b(jnp.eye(8), 4, 4)))),
        np.asarray(a))


def test_tile_untile_roundtrip():
    x = jnp.arange(2 * 8 * 8 * 16, dtype=jnp.float32).reshape(2, 8, 8, 16)
    t = ref.tile_nhwo(x, 4, 2, 8)
    assert t.shape == (2, 2, 4, 2, 4, 2, 8)
    np.testing.assert_array_equal(np.asarray(ref.untile_nhwo(t)),
                                  np.asarray(x))


@pytest.mark.parametrize("size,stride,want", [
    (3, 2, [[1, 2, 3], [3, 4, 5]]),
    (2, 1, [[1, 2], [2, 3], [3, 4], [4, 5]]),
    (5, 5, [[1, 2, 3, 4, 5]]),
])
def test_unfold_paper_example(size, stride, want):
    x = jnp.array([1, 2, 3, 4, 5], dtype=jnp.float32)
    got = ref.unfold(x, 0, size, stride)
    np.testing.assert_array_equal(np.asarray(got), np.array(want, np.float32))


def test_unfold_shape_formula():
    # paper: new dims = (ceil((D - B)/S) + 1, B)
    x = jnp.zeros((17,))
    got = ref.unfold(x, 0, 6, 4)
    assert got.shape == (-(-(17 - 6) // 4) + 1, 6)
