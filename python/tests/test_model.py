"""L2 correctness: the graph variants agree with each other (same math,
different layouts) and with the oracle; AOT entries lower cleanly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def case_inputs():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    c = model.CASE
    inp = jax.random.normal(k1, (c["n"], c["h"], c["w"], c["i"]))
    ker = jax.random.normal(k2, (c["kh"], c["kw"], c["i"], c["o"])) * 0.1
    bias = jax.random.normal(k3, (c["o"],))
    return inp, ker, bias


def test_nhwo_vs_nohw_same_math(case_inputs):
    inp, ker, bias = case_inputs
    (nhwo,) = model.case_study_nhwo(inp, ker, bias)
    (nohw,) = model.case_study_nohw(inp.transpose(0, 3, 1, 2), ker, bias)
    np.testing.assert_allclose(np.asarray(nhwo),
                               np.asarray(nohw.transpose(0, 2, 3, 1)),
                               atol=1e-3, rtol=1e-3)


def test_tiled_vs_nhwo_same_math(case_inputs):
    inp, ker, bias = case_inputs
    (nhwo,) = model.case_study_nhwo(inp, ker, bias)
    (tiled,) = model.case_study_tiled(inp, ker, bias)
    t = model.TILE
    want = ref.tile_nhwo(nhwo, t["ht"], t["wt"], t["ot"])
    assert tiled.shape == want.shape
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_tiled_untile_path(case_inputs):
    inp, ker, bias = case_inputs
    (nhwo,) = model.case_study_nhwo(inp, ker, bias)
    (back,) = model.case_study_tiled_untile(inp, ker, bias)
    np.testing.assert_allclose(np.asarray(back), np.asarray(nhwo),
                               atol=1e-3, rtol=1e-3)


def test_case_output_shape(case_inputs):
    inp, ker, bias = case_inputs
    (nhwo,) = model.case_study_nhwo(inp, ker, bias)
    c = model.CASE
    ho = (c["h"] + 2 * c["pad"] - c["kh"]) // c["stride"] + 1
    assert nhwo.shape == (c["n"], ho, ho, c["o"])  # 112 for R18 layer 1
    assert ho == 112


def test_gmm_block_matches_ref():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    g = model.GMM
    a = jax.random.normal(k1, (g["m"], g["k"]))
    b = jax.random.normal(k2, (g["k"], g["n"]))
    bias = jax.random.normal(k3, (g["n"],))
    (got,) = model.gmm_block(a, b, bias)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.gmm_bias(a, b, bias)),
                               atol=1e-3, rtol=1e-3)
    (got2,) = model.gmm_tiled_block(a, b)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(ref.gmm(a, b)),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("name", sorted(model.ENTRIES))
def test_entries_trace(name):
    """Every AOT entry must at least abstractly evaluate (shape-level)."""
    fn, specs = model.ENTRIES[name]
    outs = jax.eval_shape(fn, *specs)
    assert len(outs) == 1
    assert all(d > 0 for d in outs[0].shape)
