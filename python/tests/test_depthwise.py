"""L1 correctness: depthwise Pallas kernel vs the lax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import depthwise as kd
from compile.kernels import ref


@st.composite
def dw_cases(draw):
    n = draw(st.sampled_from([1, 2]))
    k = draw(st.sampled_from([3, 5]))
    stride = draw(st.sampled_from([1, 2]))
    ht = draw(st.sampled_from([1, 2, 4]))
    wt = draw(st.sampled_from([2, 4]))
    hb = draw(st.integers(1, 2))
    wb = draw(st.integers(1, 2))
    ct = draw(st.sampled_from([4, 8]))
    cb = draw(st.integers(1, 2))
    ho, wo, c = ht * hb, wt * wb, ct * cb
    h = (ho - 1) * stride + k
    w = (wo - 1) * stride + k
    return dict(n=n, k=k, stride=stride, ht=ht, wt=wt, ct=ct,
                h=h, w=w, c=c)


@given(dw_cases())
@settings(max_examples=20, deadline=None)
def test_depthwise_matches_lax(c):
    k1, k2 = jax.random.split(jax.random.PRNGKey(21))
    inp = jax.random.normal(k1, (c["n"], c["h"], c["w"], c["c"]))
    ker = jax.random.normal(k2, (c["k"], c["k"], c["c"]))
    got = kd.depthwise2d_nhwc(inp, ker, stride=c["stride"],
                              ht=c["ht"], wt=c["wt"], ct=c["ct"])
    want = kd.ref_depthwise2d(inp, ker, stride=c["stride"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@given(dw_cases())
@settings(max_examples=10, deadline=None)
def test_depthwise_tiled_layout_exact(c):
    """The tiled output must equal tile_nhwo(oracle) exactly."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(8))
    inp = jax.random.normal(k1, (c["n"], c["h"], c["w"], c["c"]))
    ker = jax.random.normal(k2, (c["k"], c["k"], c["c"]))
    tiled = kd.depthwise2d_tiled(inp, ker, stride=c["stride"],
                                 ht=c["ht"], wt=c["wt"], ct=c["ct"])
    want = ref.tile_nhwo(kd.ref_depthwise2d(inp, ker, stride=c["stride"]),
                         c["ht"], c["wt"], c["ct"])
    assert tiled.shape == want.shape
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_depthwise_identity_filter():
    """A one-hot center filter with k=1 is the identity."""
    x = jnp.arange(2 * 4 * 4 * 8, dtype=jnp.float32).reshape(2, 4, 4, 8)
    ker = jnp.ones((1, 1, 8), dtype=jnp.float32)
    got = kd.depthwise2d_nhwc(x, ker, stride=1, ht=2, wt=2, ct=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)
