"""AOT pass: lower every L2 entry to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser on the rust side reassigns ids and round-trips cleanly.
See /opt/xla-example/gen_hlo.py.

Run once by ``make artifacts``; python is never on the rust request path.
Also writes ``artifacts/manifest.txt`` (name, arg arity + shapes/dtypes,
output shape) so the rust runtime can register executables generically.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_spec(s) -> str:
    return f"{s.dtype}[{','.join(str(d) for d in s.shape)}]"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the quickstart artifact; siblings are "
                         "written next to it")
    ap.add_argument("--only", default=None,
                    help="comma-separated entry names (default: all)")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)
    names = (args.only.split(",") if args.only else list(model.ENTRIES))

    manifest_lines = []
    for name in names:
        fn, specs = model.ENTRIES[name]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = (os.path.abspath(args.out) if name == "model"
                else os.path.join(out_dir, f"{name}.hlo.txt"))
        with open(path, "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *specs)
        outs = ";".join(_fmt_spec(s) for s in out_specs)
        ins = ";".join(_fmt_spec(s) for s in specs)
        manifest_lines.append(f"{name}\t{os.path.basename(path)}\t{ins}\t{outs}")
        print(f"[aot] {name}: {len(text)} chars -> {path}")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"[aot] manifest: {len(manifest_lines)} entries")


if __name__ == "__main__":
    main()
