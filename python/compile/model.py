"""L2: JAX compute graphs for the ALT reproduction, in concrete layouts.

The paper's case-study subgraph (§7.3.3 — the first layer of ResNet-18:
pad -> C2D(O=64, k=7, s=2) -> bias add -> ReLU) is expressed here in three
data layouts.  Each variant is a *whole-graph* function that the AOT pass
(`aot.py`) lowers once to HLO text; the rust runtime then measures them as
"the same graph under different layout decisions", which is exactly the
experiment ALT's tuner runs on the simulated device.

  * NHWO   — TensorFlow CPU default; the elementwise tail fuses trivially.
  * NOHW   — GPU/vendor default (Torch); channels-first.
  * TILED  — the ALT searched layout N (H/ht)(W/wt)(O/ot) ht wt ot with
             ht=4, wt=16, ot=16 (the §7.3.3 searched point), produced
             directly by the L1 Pallas kernel with bias+ReLU *fused into
             the tiled loop nest* — the layout-propagation win of Fig. 7.

Padding is an explicit graph op (the paper propagates layouts onto it so
it performs padding + conversion in one pass — Fig. 5b); here each variant
pads in its own layout, mirroring that behaviour.

Python in this package runs at *build time only*; the rust coordinator
never imports it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import conv2d as k_conv
from compile.kernels import gmm as k_gmm
from compile.kernels import ref

# Case-study configuration (R18 layer 1, paper §7.3.3).
CASE = dict(n=1, i=3, h=224, w=224, o=64, kh=7, kw=7, stride=2, pad=3)
TILE = dict(ht=4, wt=16, ot=16)
# GMM block configuration (BERT-tiny FFN-ish).
GMM = dict(m=128, k=128, n=512, mt=32, kt=32, nt=64)


def case_study_nhwo(inp, ker, bias):
    """pad -> C2D -> bias -> ReLU, everything NHWO/NHWI."""
    p = CASE["pad"]
    x = jnp.pad(inp, ((0, 0), (p, p), (p, p), (0, 0)))
    return (ref.conv2d_bias_relu(x, ker, bias, stride=CASE["stride"]),)


def case_study_nohw(inp_nohw, ker, bias):
    """Same graph, channels-first storage at every edge.

    The convolution itself consumes/produces channels-first tensors, as a
    vendor-library (Torch/cuDNN) build of the graph would.
    """
    p = CASE["pad"]
    x = jnp.pad(inp_nohw, ((0, 0), (0, 0), (p, p), (p, p)))
    out = jax.lax.conv_general_dilated(
        x, ker,
        window_strides=(CASE["stride"], CASE["stride"]),
        padding="VALID",
        dimension_numbers=("NCHW", "HWIO", "NCHW"),
    )
    return (jnp.maximum(out + bias[None, :, None, None], 0.0),)


def case_study_tiled(inp, ker, bias):
    """ALT layout: pad propagates the layout; the Pallas kernel emits the
    tiled output with the elementwise tail fused (Figs. 5b + 7)."""
    p = CASE["pad"]
    x = jnp.pad(inp, ((0, 0), (p, p), (p, p), (0, 0)))
    out = k_conv.conv2d_tiled(
        x, ker, bias, stride=CASE["stride"],
        ht=TILE["ht"], wt=TILE["wt"], ot=TILE["ot"], fuse_bias_relu=True)
    return (out,)


def case_study_tiled_untile(inp, ker, bias):
    """Tiled compute + fold back to NHWO at the graph boundary — the
    inverse-primitive path used when a consumer insists on NHWO."""
    (t,) = case_study_tiled(inp, ker, bias)
    return (ref.untile_nhwo(t),)


def gmm_block(a, b, bias):
    """GMM + bias via the store_at-packed Pallas kernel (offline packing
    of the constant operand happens inside the traced graph; XLA folds
    it into the weight at compile time)."""
    bp = k_gmm.pack_store_at(b, bias)
    out = k_gmm.gmm_store_at(a, bp, mt=GMM["mt"], nt=GMM["nt"])
    return (out,)


def gmm_tiled_block(a, b):
    """Fully tiled GMM: pack A and B, run the tiled kernel, un-tile C."""
    a_t = k_gmm.pack_a(a, GMM["mt"], GMM["kt"])
    b_t = k_gmm.pack_b(b, GMM["kt"], GMM["nt"])
    c_t = k_gmm.gmm_tiled(a_t, b_t)
    return (k_gmm.untile_c(c_t),)


def dep_block(inp, ker):
    """Depthwise conv in the ALT tiled layout, folded back to NHWC —
    the paper's memory-bound DEP family (Fig. 9) as an AOT artifact."""
    from compile.kernels import depthwise as k_dep

    out = k_dep.depthwise2d_nhwc(inp, ker, stride=1, ht=4, wt=8, ct=8)
    return (out,)


def _case_specs(channels_first: bool):
    n, i, h, w = CASE["n"], CASE["i"], CASE["h"], CASE["w"]
    o, kh, kw = CASE["o"], CASE["kh"], CASE["kw"]
    f32 = jnp.float32
    inp = jax.ShapeDtypeStruct((n, i, h, w) if channels_first
                               else (n, h, w, i), f32)
    ker = jax.ShapeDtypeStruct((kh, kw, i, o), f32)
    bias = jax.ShapeDtypeStruct((o,), f32)
    return (inp, ker, bias)


def _gmm_specs(with_bias: bool):
    f32 = jnp.float32
    a = jax.ShapeDtypeStruct((GMM["m"], GMM["k"]), f32)
    b = jax.ShapeDtypeStruct((GMM["k"], GMM["n"]), f32)
    if with_bias:
        return (a, b, jax.ShapeDtypeStruct((GMM["n"],), f32))
    return (a, b)


# name -> (fn, example_args). `aot.py` lowers every entry; `model` is the
# quickstart alias the Makefile keys on.
ENTRIES = {
    "model": (case_study_nhwo, _case_specs(False)),
    "case_nhwo": (case_study_nhwo, _case_specs(False)),
    "case_nohw": (case_study_nohw, _case_specs(True)),
    "case_tiled": (case_study_tiled, _case_specs(False)),
    "case_tiled_untile": (case_study_tiled_untile, _case_specs(False)),
    "gmm_store_at": (gmm_block, _gmm_specs(True)),
    "gmm_tiled": (gmm_tiled_block, _gmm_specs(False)),
    "dep_tiled": (
        dep_block,
        (
            jax.ShapeDtypeStruct((1, 34, 34, 32), jnp.float32),
            jax.ShapeDtypeStruct((3, 3, 32), jnp.float32),
        ),
    ),
}
