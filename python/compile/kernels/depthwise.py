"""L1 Pallas kernel: layout-tiled depthwise 2-D convolution (DEP).

The paper's Fig. 9 shows its largest single-op wins on depthwise and
dilated convolutions — the memory-bound families where layout tuning
pays most. This kernel is the depthwise counterpart of
:mod:`compile.kernels.conv2d`: each channel convolves with its own
filter (groups == channels), output produced directly in the ALT tiled
layout ``N (H/ht) (W/wt) (C/ct) ht wt ct``.

TPU note: depthwise convs cannot feed the MXU (no contraction over
channels); the kernel is VPU-element-wise over the window, which is why
the layout (VMEM residency + contiguous channel vectors) dominates its
performance — exactly the paper's memory-bound argument.

interpret=True as everywhere (see conv2d.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dw_tile_kernel(inp_ref, ker_ref, out_ref, *, stride: int,
                    ht: int, wt: int):
    """One grid step: output tile [N, 1, 1, 1, ht, wt, ct]."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    kh, kw, ct = ker_ref.shape
    n = inp_ref.shape[0]

    x = inp_ref[...]  # [N, H, W, ct] (C-blocked by BlockSpec)
    w = ker_ref[...]
    acc = jnp.zeros((n, ht, wt, ct), dtype=jnp.float32)
    span_h = (ht - 1) * stride + 1
    span_w = (wt - 1) * stride + 1
    for rh in range(kh):
        for rw in range(kw):
            xs = jax.lax.dynamic_slice(
                x,
                (0, i * ht * stride + rh, j * wt * stride + rw, 0),
                (n, span_h, span_w, ct),
            )[:, ::stride, ::stride, :]
            # per-channel multiply-accumulate (VPU, not MXU)
            acc += xs.astype(jnp.float32) * w[rh, rw].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)[:, None, None, None]


def depthwise2d_tiled(inp: jax.Array, ker: jax.Array, *, stride: int = 1,
                      ht: int, wt: int, ct: int,
                      out_dtype=None) -> jax.Array:
    """Tiled-layout depthwise C2D.

    inp: [N, H, W, C] (pre-padded); ker: [KH, KW, C];
    returns [N, HO/ht, WO/wt, C/ct, ht, wt, ct].
    """
    n, h, w, c = inp.shape
    kh, kw, c2 = ker.shape
    assert c == c2, (c, c2)
    ho = (h - kh) // stride + 1
    wo = (w - kw) // stride + 1
    assert ho % ht == 0 and wo % wt == 0 and c % ct == 0, (
        f"tiles must divide: {ho}%{ht}, {wo}%{wt}, {c}%{ct}")
    out_dtype = out_dtype or inp.dtype

    kernel = functools.partial(_dw_tile_kernel, stride=stride, ht=ht, wt=wt)
    return pl.pallas_call(
        kernel,
        grid=(ho // ht, wo // wt, c // ct),
        in_specs=[
            # channel-blocked input slab: only [N, H, W, ct] resident
            pl.BlockSpec((n, h, w, ct), lambda i, j, k: (0, 0, 0, k)),
            pl.BlockSpec((kh, kw, ct), lambda i, j, k: (0, 0, k)),
        ],
        out_specs=pl.BlockSpec(
            (n, 1, 1, 1, ht, wt, ct), lambda i, j, k: (0, i, j, k, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (n, ho // ht, wo // wt, c // ct, ht, wt, ct), out_dtype),
        interpret=True,
    )(inp, ker)


def depthwise2d_nhwc(inp: jax.Array, ker: jax.Array, *, stride: int = 1,
                     ht: int, wt: int, ct: int) -> jax.Array:
    """Tiled kernel + fold back to NHWC (for oracle comparison)."""
    t = depthwise2d_tiled(inp, ker, stride=stride, ht=ht, wt=wt, ct=ct)
    n, hb, wb, cb, ht_, wt_, ct_ = t.shape
    return t.transpose(0, 1, 4, 2, 5, 3, 6).reshape(
        n, hb * ht_, wb * wt_, cb * ct_)


def ref_depthwise2d(inp: jax.Array, ker: jax.Array, stride: int = 1) -> jax.Array:
    """Pure-lax oracle: depthwise conv via feature_group_count."""
    c = inp.shape[-1]
    # lax expects [KH, KW, 1, C] for depthwise with groups == C
    w4 = ker[:, :, None, :]
    return jax.lax.conv_general_dilated(
        inp,
        w4,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
