"""L1 Pallas kernels: layout-tiled GMM and the ``store_at`` fused GMM+bias.

The GMM layout template of the paper (§5.1) tiles all three matrices:
``C: (M/mt)(N/nt) mt nt``, ``A: (M/mt)(K/kt) mt kt``, ``B: (K/kt)(N/nt) kt nt``
with the tiled dims innermost.  The kernel below produces C directly in
the tiled layout; A and B arrive pre-packed in their tiled layouts (the
rust layout pass emits the packing as offline weight transforms).

``gmm_store_at`` realises the paper's ``store_at`` advanced primitive:
each element of the bias vector is attached to its column of the weight
matrix, so the inner product and the bias-add hit the same cache line /
VMEM slab.  The packed operand is ``[K+1, N]`` with the bias as row K.

interpret=True everywhere — see conv2d.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(a_ref, b_ref, out_ref):
    """One grid step: C tile [1, 1, mt, nt] from A row-slab and B col-slab."""
    a = a_ref[...]  # [1, KB, mt, kt]
    b = b_ref[...]  # [KB, 1, kt, nt]
    kb, mt, kt = a.shape[1], a.shape[2], a.shape[3]
    nt = b.shape[3]
    # Un-tile the K axis in-register and run one MXU contraction.
    a2 = a[0].transpose(1, 0, 2).reshape(mt, kb * kt)
    b2 = b[:, 0].reshape(kb * kt, nt)
    acc = jnp.dot(a2.astype(jnp.float32), b2.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)[None, None]


def gmm_tiled(a_t: jax.Array, b_t: jax.Array, *, out_dtype=None) -> jax.Array:
    """Tiled GMM.

    a_t: [M/mt, K/kt, mt, kt] (A in tiled layout)
    b_t: [K/kt, N/nt, kt, nt] (B in tiled layout)
    returns C in tiled layout [M/mt, N/nt, mt, nt].
    """
    mb, kb, mt, kt = a_t.shape
    kb2, nb, kt2, nt = b_t.shape
    assert kb == kb2 and kt == kt2, (a_t.shape, b_t.shape)
    out_dtype = out_dtype or a_t.dtype
    return pl.pallas_call(
        _gmm_kernel,
        grid=(mb, nb),
        in_specs=[
            pl.BlockSpec((1, kb, mt, kt), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((kb, 1, kt, nt), lambda i, j: (0, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, mt, nt), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((mb, nb, mt, nt), out_dtype),
        interpret=True,
    )(a_t, b_t)


def pack_a(a: jax.Array, mt: int, kt: int) -> jax.Array:
    """[M, K] -> [M/mt, K/kt, mt, kt] (offline layout transform for A)."""
    m, k = a.shape
    assert m % mt == 0 and k % kt == 0
    return a.reshape(m // mt, mt, k // kt, kt).transpose(0, 2, 1, 3)


def pack_b(b: jax.Array, kt: int, nt: int) -> jax.Array:
    """[K, N] -> [K/kt, N/nt, kt, nt] (offline layout transform for B)."""
    k, n = b.shape
    assert k % kt == 0 and n % nt == 0
    return b.reshape(k // kt, kt, n // nt, nt).transpose(0, 2, 1, 3)


def untile_c(c_t: jax.Array) -> jax.Array:
    """[M/mt, N/nt, mt, nt] -> [M, N] (inverse primitive sequence)."""
    mb, nb, mt, nt = c_t.shape
    return c_t.transpose(0, 2, 1, 3).reshape(mb * mt, nb * nt)


def _gmm_store_at_kernel(a_ref, bp_ref, out_ref):
    """GMM + bias with the bias stored at row K of the packed B operand."""
    a = a_ref[...]      # [mt, K]
    bp = bp_ref[...]    # [K+1, nt]
    acc = jnp.dot(a.astype(jnp.float32), bp[:-1].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    acc = acc + bp[-1].astype(jnp.float32)[None, :]
    out_ref[...] = acc.astype(out_ref.dtype)


def gmm_store_at(a: jax.Array, b_packed: jax.Array, *, mt: int, nt: int,
                 out_dtype=None) -> jax.Array:
    """Fused GMM+bias over a ``store_at``-packed weight.

    a: [M, K]; b_packed: [K+1, N] (row K is the bias); returns [M, N].
    """
    m, k = a.shape
    kp1, n = b_packed.shape
    assert kp1 == k + 1, (a.shape, b_packed.shape)
    assert m % mt == 0 and n % nt == 0
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        _gmm_store_at_kernel,
        grid=(m // mt, n // nt),
        in_specs=[
            pl.BlockSpec((mt, k), lambda i, j: (i, 0)),
            pl.BlockSpec((kp1, nt), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((mt, nt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,
    )(a, b_packed)


def pack_store_at(b: jax.Array, bias: jax.Array) -> jax.Array:
    """Offline ``store_at`` packing: attach bias as the last row of B."""
    return jnp.concatenate([b, bias[None, :]], axis=0)
