"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match the corresponding function here (pytest + hypothesis sweep in
``python/tests/test_kernel.py``). Keep them boring and obviously correct —
``jax.lax`` reference ops only, no tiling, no layout tricks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_nhwi(inp: jax.Array, ker: jax.Array, stride: int = 1) -> jax.Array:
    """2-D convolution, NHWI input, HWIO weight, NHWO output.

    inp: [N, H, W, I]; ker: [KH, KW, I, O]; returns [N, HO, WO, O] with
    HO = (H - KH)//stride + 1 (VALID padding — padding is an explicit
    graph-level op in ALT, never folded into the conv).
    """
    return jax.lax.conv_general_dilated(
        inp,
        ker,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_bias_relu(inp: jax.Array, ker: jax.Array, bias: jax.Array,
                     stride: int = 1) -> jax.Array:
    """The case-study subgraph body: C2D -> bias add -> ReLU (NHWO out)."""
    out = conv2d_nhwi(inp, ker, stride)
    return jnp.maximum(out + bias[None, None, None, :], 0.0)


def gmm(a: jax.Array, b: jax.Array) -> jax.Array:
    """General matrix multiply: [M, K] x [K, N] -> [M, N]."""
    return jnp.matmul(a, b)


def gmm_bias(a: jax.Array, b: jax.Array, bias: jax.Array) -> jax.Array:
    """GMM + bias (the ``store_at`` motivating op): [M,K]x[K,N]+[N]."""
    return jnp.matmul(a, b) + bias[None, :]


def tile_nhwo(out_nhwo: jax.Array, ht: int, wt: int, ot: int) -> jax.Array:
    """Repack NHWO -> N (H/ht) (W/wt) (O/ot) ht wt ot (ALT tiled layout).

    This is the pure data-movement semantics of the layout primitive
    sequence  split(H) . split(W) . split(O) . reorder  from the paper's
    C2D template (§5.1); used to check the tiled-layout kernels.
    """
    n, h, w, o = out_nhwo.shape
    assert h % ht == 0 and w % wt == 0 and o % ot == 0
    x = out_nhwo.reshape(n, h // ht, ht, w // wt, wt, o // ot, ot)
    return x.transpose(0, 1, 3, 5, 2, 4, 6)


def untile_nhwo(tiled: jax.Array) -> jax.Array:
    """Inverse of :func:`tile_nhwo` (the fold/inverse primitive sequence)."""
    n, hb, wb, ob, ht, wt, ot = tiled.shape
    x = tiled.transpose(0, 1, 4, 2, 5, 3, 6)
    return x.reshape(n, hb * ht, wb * wt, ob * ot)


def unfold(x: jax.Array, axis: int, size: int, stride: int) -> jax.Array:
    """Overlapped tiling of one dimension (the ``unfold`` primitive).

    A dimension of extent D becomes two dims [ceil((D-size)/stride)+1, size]
    (paper §4.1.2); e.g. [1,2,3,4,5] with size=3 stride=2 -> [[1,2,3],[3,4,5]].
    The last tile is right-clamped so it never reads out of bounds.
    """
    d = x.shape[axis]
    ntiles = -(-(d - size) // stride) + 1
    starts = jnp.minimum(jnp.arange(ntiles) * stride, d - size)
    idx = starts[:, None] + jnp.arange(size)[None, :]
    taken = jnp.take(x, idx.reshape(-1), axis=axis)
    new_shape = x.shape[:axis] + (ntiles, size) + x.shape[axis + 1:]
    return taken.reshape(new_shape)
