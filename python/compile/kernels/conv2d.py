"""L1 Pallas kernel: layout-tiled 2-D convolution.

This is the compute hot-spot of the paper's case study (§2, §7.3.3): a C2D
whose *output is produced directly in the ALT tiled layout*
``N (H/ht) (W/wt) (O/ot) ht wt ot`` so that no conversion operator is ever
needed downstream — the kernel is the codegen'd form of the layout
primitive sequence ``split(H,ht) . split(W,wt) . split(O,ot) . reorder``
applied to the output tensor, with the matching ``unfold`` on the input
tensor (overlapped input tiles, Fig. 2 of the paper).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's layout tiling
targets CPU cache lines / GPU shared memory; on TPU the same insight maps
to VMEM tiling — each grid step owns one (ht, wt, ot) output tile in VMEM,
weights are blocked over O so only an ``[KH, KW, I, ot]`` slab is resident,
and the MXU consumes ``[spatial, I] x [I, ot]`` contractions. BlockSpecs
express the HBM<->VMEM schedule that the paper expressed with loop tiling.

All kernels run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_tile_kernel(inp_ref, ker_ref, bias_ref, out_ref, *, stride: int,
                      ht: int, wt: int, fuse_bias_relu: bool):
    """One grid step: produce output tile [N, 1, 1, 1, ht, wt, ot].

    inp_ref holds the full [N, H, W, I] input (overlapped tiles cannot be
    expressed as disjoint BlockSpec blocks — this is exactly the paper's
    ``unfold`` data expansion, which we realise by slicing in-kernel).
    ker_ref holds the O-blocked weight slab [KH, KW, I, ot].
    """
    i = pl.program_id(0)  # H-tile index
    j = pl.program_id(1)  # W-tile index
    kh, kw, ci, ot = ker_ref.shape
    n = inp_ref.shape[0]

    x = inp_ref[...]
    w = ker_ref[...]
    acc = jnp.zeros((n, ht, wt, ot), dtype=jnp.float32)
    # Static python loops over the window: KH*KW MXU contractions of
    # [n*ht*wt, I] x [I, ot] each — the systolic-array-friendly shape.
    span_h = (ht - 1) * stride + 1
    span_w = (wt - 1) * stride + 1
    for rh in range(kh):
        for rw in range(kw):
            xs = jax.lax.dynamic_slice(
                x,
                (0, i * ht * stride + rh, j * wt * stride + rw, 0),
                (n, span_h, span_w, ci),
            )[:, ::stride, ::stride, :]
            acc += jnp.dot(
                xs.reshape(n * ht * wt, ci).astype(jnp.float32),
                w[rh, rw].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).reshape(n, ht, wt, ot)
    if fuse_bias_relu:
        # Layout propagation in action: bias-add + ReLU consume the tiled
        # layout in-register, so the elementwise tail is fused (Fig. 7).
        acc = jnp.maximum(acc + bias_ref[...][None, None, None, :], 0.0)
    out_ref[...] = acc.astype(out_ref.dtype)[:, None, None, None]


def conv2d_tiled(inp: jax.Array, ker: jax.Array, bias: jax.Array | None,
                 *, stride: int = 1, ht: int, wt: int, ot: int,
                 fuse_bias_relu: bool = False,
                 out_dtype=None) -> jax.Array:
    """Tiled-layout C2D.

    inp:  [N, H, W, I]   (NHWI; already padded by the graph-level pad op)
    ker:  [KH, KW, I, O] (HWIO)
    bias: [O] or None (required if fuse_bias_relu)
    returns [N, HO/ht, WO/wt, O/ot, ht, wt, ot] — the ALT tiled layout.
    """
    n, h, w, ci = inp.shape
    kh, kw, ci2, o = ker.shape
    assert ci == ci2, (ci, ci2)
    ho = (h - kh) // stride + 1
    wo = (w - kw) // stride + 1
    assert ho % ht == 0 and wo % wt == 0 and o % ot == 0, (
        f"tile sizes must divide output dims: {ho}%{ht}, {wo}%{wt}, {o}%{ot}")
    out_dtype = out_dtype or inp.dtype
    if bias is None:
        bias = jnp.zeros((o,), dtype=inp.dtype)

    grid = (ho // ht, wo // wt, o // ot)
    kernel = functools.partial(
        _conv_tile_kernel, stride=stride, ht=ht, wt=wt,
        fuse_bias_relu=fuse_bias_relu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Full input resident (unfold/overlap — see module docstring).
            pl.BlockSpec((n, h, w, ci), lambda i, j, k: (0, 0, 0, 0)),
            # Weight slab blocked over O: only [KH,KW,I,ot] in VMEM.
            pl.BlockSpec((kh, kw, ci, ot), lambda i, j, k: (0, 0, 0, k)),
            pl.BlockSpec((ot,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec(
            (n, 1, 1, 1, ht, wt, ot), lambda i, j, k: (0, i, j, k, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (n, ho // ht, wo // wt, o // ot, ht, wt, ot), out_dtype),
        interpret=True,
    )(inp, ker, bias)


def conv2d_nhwo(inp: jax.Array, ker: jax.Array, *, stride: int = 1,
                ht: int, wt: int, ot: int) -> jax.Array:
    """Convenience wrapper: tiled kernel + fold back to plain NHWO.

    Used by tests to compare against the oracle and by L2 graphs that need
    an NHWO tensor at a graph boundary (the inverse-primitive path).
    """
    tiled = conv2d_tiled(inp, ker, None, stride=stride, ht=ht, wt=wt, ot=ot)
    n, hb, wb, ob, ht_, wt_, ot_ = tiled.shape
    return tiled.transpose(0, 1, 4, 2, 5, 3, 6).reshape(
        n, hb * ht_, wb * wt_, ob * ot_)
