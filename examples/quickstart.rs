//! Quickstart: the whole ALT pipeline in one chain — tune a workload
//! jointly (layouts + loops), compile it for the native backend, and
//! run it end-to-end on real host buffers.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use alt::api::Session;
use alt::autotune::TuneOptions;
use alt::sim::HwProfile;

fn main() {
    let session = Session::for_model("case_study")
        .unwrap()
        .with_profile(HwProfile::intel())
        .with_options(TuneOptions { budget: 120, seed: 42, ..Default::default() });

    let tuned = session.tune(); // joint layout + loop search
    let sim_ms = tuned.report().unwrap().latency_ms();
    println!("tuned (simulated):  {sim_ms:.4} ms end-to-end");
    println!("searched layout:    {:?}", tuned.plan().ops[0].decision.out_seq.prims);

    let model = tuned.compile().expect("compile"); // weights packed once
    let (stats, out) = model.run_with_output(&model.seeded_inputs(7)).expect("run");
    println!(
        "native execution:   {:.3} ms for {} output values ({} repacks/run)",
        stats.latency_ms,
        out.len(),
        model.repacks_per_run()
    );

    model.save("target/quickstart_plan").expect("save");
    let reloaded = Session::load("target/quickstart_plan").expect("load");
    let again = reloaded.compile().expect("recompile").run(&model.seeded_inputs(7));
    println!("saved + reloaded:   {:.3} ms (no re-tuning)", again.unwrap().latency_ms);
}
