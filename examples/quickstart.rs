//! Quickstart: tune one convolution jointly (layouts + loops) and
//! compare against the untuned default and a loop-only baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use alt::autotune::tuner::{tune_op, TuneOptions};
use alt::codegen::{lower_complex, LayoutAssignment};
use alt::graph::models;
use alt::loops::LoopSchedule;
use alt::propagate::PropMode;
use alt::sim::{simulate_program, HwProfile};

fn main() {
    // The paper's case-study workload: ResNet-18's first layer
    // (pad -> C2D(O=64, k=7, s=2) -> bias -> ReLU on a 224x224 image).
    let g = models::case_study();
    let conv = g.complex_nodes()[0];
    let hw = HwProfile::intel();

    // Untuned: default NHWO layout, no tiling, scalar loops.
    let layouts = LayoutAssignment::identity(&g);
    let sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
    let p = lower_complex(&g, conv, &layouts, &sched, &[], hw.simd_lanes);
    let base = simulate_program(&p, &hw);
    println!("untuned:          {:.4} ms", base.latency_ms);

    // Loop-only tuning (what Ansor-style systems do).
    let mut lo = TuneOptions { budget: 120, ..Default::default() };
    lo.mode = PropMode::LoopOnly;
    let loop_only = tune_op(&g, conv, &hw, &lo);
    println!("loop-only tuned:  {:.4} ms", loop_only.best_ms);

    // Joint layout + loop tuning (ALT).
    let opts = TuneOptions { budget: 120, ..Default::default() };
    let joint = tune_op(&g, conv, &hw, &opts);
    println!("ALT joint tuned:  {:.4} ms", joint.best_ms);
    println!(
        "speedup vs untuned {:.1}x, vs loop-only {:.2}x",
        base.latency_ms / joint.best_ms,
        loop_only.best_ms / joint.best_ms
    );
    println!("\nsearched output layout primitives:");
    for prim in &joint.decision.out_seq.prims {
        println!("  {prim:?}");
    }
    println!("searched loop schedule: {:?}", joint.sched);
}
