//! End-to-end driver (the DESIGN.md validation run): exercise the full
//! three-layer stack on a real small workload.
//!
//! 1. **L3 tuner** — jointly tune ResNet-18 (and MobileNet-V2) on the
//!    simulated Intel profile, comparing ALT vs ALT-WP vs ALT-OL vs a
//!    vendor-style fixed build (the Fig. 10 experiment, scaled).
//! 2. **Runtime cross-check** — load the AOT HLO artifacts the Python
//!    layer produced for the case-study subgraph in three layouts
//!    (NHWO / NOHW / ALT-tiled with the Pallas kernel) and execute them
//!    for real on the PJRT CPU, verifying (a) the variants agree
//!    numerically and (b) the stack is runnable end to end with Python
//!    off the request path.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::collections::HashMap;

use alt::autotune::tuner::{tune_graph, TuneOptions};
use alt::bench::harness::Table;
use alt::graph::models;
use alt::propagate::{propagate, PropMode};
use alt::sim::netsim::simulate_graph;
use alt::sim::HwProfile;

fn main() {
    let hw = HwProfile::intel();
    let budget = std::env::var("ALT_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(240usize);

    // ---------- phase 1: end-to-end tuning on the simulated device ----
    let mut t = Table::new(
        &format!("end-to-end tuning ({}, budget {budget})", hw.name),
        &["network", "vendor ms", "ALT-OL ms", "ALT-WP ms", "ALT ms", "ALT speedup"],
    );
    for g in [models::resnet18(1), models::mobilenet_v2(1)] {
        // vendor-style fixed build
        let prop = propagate(&g, &[], PropMode::Alt);
        let vendor = simulate_graph(&g, &prop, &HashMap::new(), &hw).latency_ms();
        let run = |mode: PropMode| -> f64 {
            let opts = TuneOptions { budget, mode, seed: 42, ..Default::default() };
            tune_graph(&g, &hw, &opts).report.latency_ms()
        };
        let ol = run(PropMode::LoopOnly);
        let wp = run(PropMode::WithoutFusionProp);
        let alt = run(PropMode::Alt);
        t.row(&[
            g.name.clone(),
            format!("{vendor:.3}"),
            format!("{ol:.3}"),
            format!("{wp:.3}"),
            format!("{alt:.3}"),
            format!("{:.2}x", vendor / alt),
        ]);
    }
    t.print();

    // ---------- phase 2: real execution of the AOT artifacts ----------
    println!("\n== PJRT runtime cross-check (real host CPU) ==");
    let rt = match alt::runtime::Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!(
                "artifacts not built ({e}); run `make artifacts` first"
            );
            std::process::exit(1);
        }
    };
    println!("platform: {}, artifacts: {:?}", rt.platform(), rt.entries());

    // same logical input for every layout variant
    let nhwo = rt.load("case_nhwo").expect("load case_nhwo");
    let inputs_nhwo: Vec<Vec<f32>> = nhwo
        .spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, s)| alt::runtime::random_input(s, 100 + i as u64))
        .collect();

    let mut table = Table::new(
        "case-study variants on PJRT CPU",
        &["variant", "median ms", "out elems", "numerics"],
    );
    let base = nhwo.run(&inputs_nhwo).expect("run");
    let base_ms = nhwo.bench(&inputs_nhwo, 5).expect("bench");
    table.row(&[
        "case_nhwo".into(),
        format!("{base_ms:.3}"),
        base.output_elems.to_string(),
        "reference".into(),
    ]);

    // NOHW variant: transpose the input to channels-first
    let nohw = rt.load("case_nohw").expect("load case_nohw");
    let x = &inputs_nhwo[0];
    let (n, h, w, c) = (1usize, 224usize, 224usize, 3usize);
    let mut x_nohw = vec![0f32; x.len()];
    for b in 0..n {
        for i in 0..h {
            for j in 0..w {
                for ch in 0..c {
                    x_nohw[((b * c + ch) * h + i) * w + j] =
                        x[((b * h + i) * w + j) * c + ch];
                }
            }
        }
    }
    let in2 = vec![x_nohw, inputs_nhwo[1].clone(), inputs_nhwo[2].clone()];
    let r2 = nohw.run(&in2).expect("run nohw");
    let ms2 = nohw.bench(&in2, 5).expect("bench nohw");
    table.row(&[
        "case_nohw".into(),
        format!("{ms2:.3}"),
        r2.output_elems.to_string(),
        // same math, different storage: element counts must match
        if r2.output_elems == base.output_elems { "shape ok" } else { "MISMATCH" }
            .into(),
    ]);

    // ALT tiled variant (Pallas kernel with fused bias+ReLU), folded
    // back to NHWO so the numbers are directly comparable.
    let tiled = rt.load("case_tiled_untile").expect("load case_tiled_untile");
    let r3 = tiled.run(&inputs_nhwo).expect("run tiled");
    let ms3 = tiled.bench(&inputs_nhwo, 5).expect("bench tiled");
    let agree = base
        .sample
        .iter()
        .zip(&r3.sample)
        .all(|(a, b)| (a - b).abs() < 1e-2 * (1.0 + a.abs()));
    table.row(&[
        "case_tiled (pallas, fused)".into(),
        format!("{ms3:.3}"),
        r3.output_elems.to_string(),
        if agree { "matches nhwo" } else { "NUMERIC MISMATCH" }.into(),
    ]);
    table.print();
    if !agree {
        eprintln!("numeric mismatch between tiled and nhwo variants");
        std::process::exit(1);
    }
    println!("\nend_to_end: all layers compose; python stayed off the request path.");
}
