//! End-to-end driver (the DESIGN.md validation run): exercise the full
//! stack on a real small workload.
//!
//! 1. **L3 tuner** — jointly tune ResNet-18 (and MobileNet-V2) on the
//!    simulated Intel profile, comparing ALT vs ALT-WP vs ALT-OL vs a
//!    vendor-style fixed build (the Fig. 10 experiment, scaled).
//! 2. **Runtime cross-check** — execute the §7.3.3 case-study layout
//!    variants (NHWO / NOHW / ALT-tiled / ALT-tiled+unfold) for real on
//!    the native interpreter backend and verify (a) every variant
//!    computes the same values and (b) the measured latency ranking
//!    agrees with the simulator's preference order. No feature flags,
//!    no artifacts: the native backend executes the generated tensor
//!    programs directly. With `--features pjrt` and built artifacts,
//!    the PJRT leg additionally runs the AOT HLO variants.
//!
//! ```bash
//! cargo run --release --example end_to_end
//! ```

use std::collections::HashMap;

use alt::api::Session;
use alt::autotune::TuneOptions;
use alt::bench::harness::Table;
use alt::graph::models;
use alt::propagate::{propagate, PropMode};
use alt::runtime::variants::{cross_check, Scale};
use alt::sim::netsim::simulate_graph;
use alt::sim::HwProfile;

fn main() {
    let hw = HwProfile::intel();
    let budget = std::env::var("ALT_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(240usize);

    // ---------- phase 1: end-to-end tuning on the simulated device ----
    let mut t = Table::new(
        &format!("end-to-end tuning ({}, budget {budget})", hw.name),
        &["network", "vendor ms", "ALT-OL ms", "ALT-WP ms", "ALT ms", "ALT speedup"],
    );
    for name in ["resnet18", "mobilenet_v2"] {
        let g = models::by_name(name).unwrap();
        // vendor-style fixed build
        let prop = propagate(&g, &[], PropMode::Alt);
        let vendor = simulate_graph(&g, &prop, &HashMap::new(), &hw).latency_ms();
        let run = |mode: PropMode| -> f64 {
            let opts = TuneOptions { budget, mode, seed: 42, ..Default::default() };
            Session::new(g.clone())
                .with_profile(hw.clone())
                .with_options(opts)
                .tune()
                .report()
                .expect("tune() carries a report")
                .latency_ms()
        };
        let ol = run(PropMode::LoopOnly);
        let wp = run(PropMode::WithoutFusionProp);
        let alt = run(PropMode::Alt);
        t.row(&[
            g.name.clone(),
            format!("{vendor:.3}"),
            format!("{ol:.3}"),
            format!("{wp:.3}"),
            format!("{alt:.3}"),
            format!("{:.2}x", vendor / alt),
        ]);
    }
    t.print();

    // ---------- phase 1b: whole-model native execution ----------------
    // The Session pipeline end-to-end: tune bert_tiny, compile it for
    // the native backend (weights packed once), run the entire
    // transformer on host buffers, and round-trip the tuned plan
    // through disk without re-tuning.
    println!("\n== whole-model native execution (Session pipeline) ==");
    let session = Session::for_model("bert_tiny")
        .unwrap()
        .with_profile(hw.clone())
        .with_options(TuneOptions { budget, seed: 42, shards: 0, ..Default::default() });
    let tuned = session.tune();
    let model = tuned.compile().unwrap_or_else(|e| panic!("compile: {e}"));
    let inputs = model.seeded_inputs(100);
    let (stats, out) = model.run_with_output(&inputs).expect("run bert_tiny");
    println!(
        "bert_tiny: sim {:.3} ms | native {:.3} ms | {} outputs | \
         {} nests + {} simple ops | {} repacks/run | {}/{} weights packed",
        tuned.report().unwrap().latency_ms(),
        stats.latency_ms,
        out.len(),
        model.complex_steps(),
        model.simple_steps(),
        model.repacks_per_run(),
        model.weights_packed(),
        model.weights_total(),
    );
    let dir = "target/end_to_end_plan";
    model.save(dir).expect("save plan");
    let reloaded = Session::load(dir)
        .expect("load plan")
        .compile()
        .expect("recompile");
    let (_, again) = reloaded.run_with_output(&inputs).expect("run reloaded");
    if out.iter().zip(&again).any(|(a, b)| a.to_bits() != b.to_bits()) {
        eprintln!("save/load round trip changed the outputs");
        std::process::exit(1);
    }
    println!("save/load round trip -> {dir}: outputs bit-identical, no re-tuning");

    // ---------- phase 2: real execution on the native backend ---------
    println!("\n== native runtime cross-check (real host CPU) ==");
    let check = cross_check(Scale::Full, &hw, 0, 3, 100)
        .unwrap_or_else(|e| panic!("cross-check: {e}"));
    println!("threads: {}", check.threads);
    let mut table = Table::new(
        "case-study variants: simulated vs native execution",
        &["variant", "sim ms", "native ms", "numerics"],
    );
    for (i, name) in check.names.iter().enumerate() {
        table.row(&[
            name.clone(),
            format!("{:.4}", check.sim_ms[i]),
            format!("{:.3}", check.native_ms[i]),
            if check.numerics_ok { "agree" } else { "MISMATCH" }.into(),
        ]);
    }
    table.print();
    println!(
        "spearman(sim, native) = {:.3}; rank agreement: {}",
        check.spearman,
        if check.rank_agreement() { "yes" } else { "NO" }
    );
    for (a, b) in &check.strong_inversions {
        println!("  strong inversion: sim prefers {a} over {b}, native disagrees");
    }
    if !check.numerics_ok {
        eprintln!("numeric mismatch between layout variants");
        std::process::exit(1);
    }
    if !check.rank_agreement() {
        // the tuned variant's edge is its parallel schedule — a
        // single-core host cannot resolve the ranking, so only report
        if check.threads >= 2 {
            eprintln!("native latency ranking contradicts the simulator");
            std::process::exit(1);
        }
        eprintln!("note: single-core host, ranking not enforced");
    }

    // ---------- optional phase 3: PJRT leg over the AOT artifacts -----
    #[cfg(feature = "pjrt")]
    pjrt_leg();

    println!("\nend_to_end: all layers compose; python stayed off the request path.");
}

/// The original XLA-backed validation leg: load the AOT HLO artifacts
/// and execute them on the PJRT CPU client. Skips when `make
/// artifacts` has not run.
#[cfg(feature = "pjrt")]
fn pjrt_leg() {
    use alt::runtime::{random_input, Backend, Runtime};

    println!("\n== PJRT runtime cross-check (AOT HLO artifacts) ==");
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping PJRT leg: {e} (run `make artifacts`)");
            return;
        }
    };
    println!("platform: {}", Backend::platform(&rt));

    // same logical input for every layout variant
    let nhwo = rt.load("case_nhwo").expect("load case_nhwo");
    let inputs: Vec<Vec<f32>> = nhwo
        .spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, s)| random_input(s, 100 + i as u64))
        .collect();
    let base = nhwo.run(&inputs).expect("run nhwo");
    let base_ms = nhwo.bench(&inputs, 5).expect("bench nhwo");

    // ALT tiled variant (Pallas kernel with fused bias+ReLU), folded
    // back to NHWO so the numbers are directly comparable.
    let tiled = rt.load("case_tiled_untile").expect("load case_tiled_untile");
    let r3 = tiled.run(&inputs).expect("run tiled");
    let ms3 = tiled.bench(&inputs, 5).expect("bench tiled");
    let agree = base
        .sample
        .iter()
        .zip(&r3.sample)
        .all(|(a, b)| (a - b).abs() < 1e-2 * (1.0 + a.abs()));
    let mut table = Table::new(
        "case-study variants on PJRT CPU",
        &["variant", "median ms", "out elems", "numerics"],
    );
    table.row(&[
        "case_nhwo".into(),
        format!("{base_ms:.3}"),
        base.output_elems.to_string(),
        "reference".into(),
    ]);
    table.row(&[
        "case_tiled (pallas, fused)".into(),
        format!("{ms3:.3}"),
        r3.output_elems.to_string(),
        if agree { "matches nhwo" } else { "NUMERIC MISMATCH" }.into(),
    ]);
    table.print();
    if !agree {
        eprintln!("numeric mismatch between tiled and nhwo variants");
        std::process::exit(1);
    }
}
