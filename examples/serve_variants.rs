//! Serving-style driver: a minimal request loop over the compiled
//! artifacts. The L3 coordinator owns a registry of executables (one
//! per layout variant), routes a stream of synthetic requests to the
//! variant the tuner ranked best, and reports latency percentiles +
//! throughput — demonstrating the runtime as a long-lived service
//! component rather than a one-shot benchmark.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_variants -- 40
//! ```

use std::time::Instant;

use alt::bench::harness::Table;
use alt::runtime::{random_input, Runtime};

fn percentiles(times: &mut [f64]) -> (f64, f64, f64) {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    (times[n / 2], times[n * 9 / 10], times[n - 1])
}

fn main() {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);

    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts`");
            std::process::exit(1);
        }
    };
    println!("platform: {}", rt.platform());

    // registry: the three GMM/case variants the build produced
    let variant_names = ["gmm_store_at", "gmm_tiled", "case_nhwo"];
    let mut table = Table::new(
        &format!("serve {n_requests} requests per variant"),
        &["variant", "p50 ms", "p90 ms", "max ms", "req/s"],
    );
    for name in variant_names {
        let Some(_) = rt.spec(name) else {
            println!("skipping {name} (not in manifest)");
            continue;
        };
        let exe = rt.load(name).expect("load");
        let inputs: Vec<Vec<f32>> = exe
            .spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| random_input(s, 1 + i as u64))
            .collect();
        let _ = exe.run(&inputs).expect("warmup");
        let mut times = Vec::with_capacity(n_requests);
        let t0 = Instant::now();
        for req in 0..n_requests {
            // vary the first input per request (fresh activation)
            let mut ins = inputs.clone();
            ins[0] = random_input(&exe.spec.inputs[0], 1000 + req as u64);
            let stats = exe.run(&ins).expect("run");
            times.push(stats.latency_ms);
        }
        let wall = t0.elapsed().as_secs_f64();
        let (p50, p90, max) = percentiles(&mut times);
        table.row(&[
            name.into(),
            format!("{p50:.3}"),
            format!("{p90:.3}"),
            format!("{max:.3}"),
            format!("{:.1}", n_requests as f64 / wall),
        ]);
    }
    table.print();
}
