//! Serving-style driver: a minimal request loop over compiled layout
//! variants. The L3 coordinator owns a registry of executables (one
//! per variant) behind the backend-agnostic [`Backend`] trait, routes
//! a stream of synthetic requests to each variant, and reports latency
//! percentiles + throughput — demonstrating the runtime as a
//! long-lived service component rather than a one-shot benchmark.
//! A second loop serves *whole models* compiled through the Session
//! pipeline (`api::CompiledModel`), the multi-op successor of the
//! per-variant path.
//!
//! By default the zero-dependency native interpreter serves the
//! requests (compiled variants of the case-study conv and the GMM
//! pair); with `--features pjrt` and built artifacts, set
//! `ALT_SERVE_BACKEND=pjrt` to serve the AOT HLO artifacts instead.
//!
//! ```bash
//! cargo run --release --example serve_variants -- 40
//! ```

use std::time::Instant;

use alt::api::Session;
use alt::bench::harness::Table;
use alt::runtime::variants::{native_runtime, Scale};
use alt::runtime::{random_input, Backend};
use alt::sim::HwProfile;

fn percentiles(times: &mut [f64]) -> (f64, f64, f64) {
    times.sort_by(|a, b| a.total_cmp(b));
    let n = times.len();
    (times[n / 2], times[n * 9 / 10], times[n - 1])
}

fn backend() -> Box<dyn Backend> {
    #[cfg(feature = "pjrt")]
    if std::env::var("ALT_SERVE_BACKEND").as_deref() == Ok("pjrt") {
        match alt::runtime::Runtime::new("artifacts") {
            Ok(rt) => return Box::new(rt),
            Err(e) => {
                eprintln!("pjrt backend unavailable ({e}); using native");
            }
        }
    }
    let hw = HwProfile::intel();
    let rt = native_runtime(Scale::Full, &hw, 0)
        .unwrap_or_else(|e| panic!("native runtime: {e}"));
    Box::new(rt)
}

fn main() {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);

    let rt = backend();
    println!("backend: {} ({})", rt.backend_name(), rt.platform());

    let mut table = Table::new(
        &format!("serve {n_requests} requests per variant"),
        &["variant", "p50 ms", "p90 ms", "max ms", "req/s"],
    );
    for name in rt.entries() {
        // weights/bias generated once per variant; only the first
        // input (the activation) varies per request
        let specs = rt.input_specs(&name).expect("specs");
        let mut inputs: Vec<Vec<f32>> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| random_input(s, 1 + i as u64))
            .collect();
        let _ = rt.execute_with(&name, &inputs).expect("warmup");
        let mut times = Vec::with_capacity(n_requests);
        let t0 = Instant::now();
        for req in 0..n_requests {
            inputs[0] = random_input(&specs[0], 1000 + req as u64);
            let stats = rt.execute_with(&name, &inputs).expect("run");
            times.push(stats.latency_ms);
        }
        let wall = t0.elapsed().as_secs_f64();
        let (p50, p90, max) = percentiles(&mut times);
        table.row(&[
            name,
            format!("{p50:.3}"),
            format!("{p90:.3}"),
            format!("{max:.3}"),
            format!("{:.1}", n_requests as f64 / wall),
        ]);
    }
    table.print();

    // ---- whole-model serving over the Session pipeline ----
    let mut t2 = Table::new(
        &format!("serve {n_requests} whole-model requests"),
        &["model", "p50 ms", "p90 ms", "max ms", "inf/s", "repacks"],
    );
    for name in ["resnet18_small", "bert_tiny"] {
        let model = Session::for_model(name)
            .unwrap()
            .baseline() // identity plan: serving path, no tuning spend
            .compile()
            .unwrap_or_else(|e| panic!("compile {name}: {e}"));
        let specs = model.input_specs();
        let mut inputs = model.seeded_inputs(1);
        let _ = model.run(&inputs).expect("warmup");
        let mut times = Vec::with_capacity(n_requests);
        let t0 = Instant::now();
        for req in 0..n_requests {
            inputs[0] = random_input(&specs[0], 1000 + req as u64);
            times.push(model.run(&inputs).expect("run").latency_ms);
        }
        let wall = t0.elapsed().as_secs_f64();
        let (p50, p90, max) = percentiles(&mut times);
        t2.row(&[
            name.into(),
            format!("{p50:.3}"),
            format!("{p90:.3}"),
            format!("{max:.3}"),
            format!("{:.1}", n_requests as f64 / wall),
            model.repacks_per_run().to_string(),
        ]);
    }
    t2.print();
}
