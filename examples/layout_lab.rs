//! Layout laboratory: apply the paper's layout primitives by hand and
//! watch shapes, access expressions and simulated cache behaviour
//! change — the §4.1 walkthrough as runnable code.
//!
//! ```bash
//! cargo run --release --example layout_lab
//! ```

use alt::api::Session;
use alt::codegen::{lower_complex, LayoutAssignment};
use alt::expr::Var;
use alt::graph::models;
use alt::layout::{DimAccess, LayoutSeq, LayoutTransform, Primitive};
use alt::loops::LoopSchedule;
use alt::propagate::ComplexDecision;
use alt::sim::{simulate_program, HwProfile};

fn main() {
    // --- §4.1.1 paper example: NHWO -> N (O/4) (HW) 4 ---
    let (h, w, o) = (3i64, 5i64, 8i64);
    let mut seq = LayoutSeq::new();
    seq.push(Primitive::fuse(1, 3))
        .push(Primitive::split(1, &[o / 4, 4, h * w]))
        .push(Primitive::reorder(&[0, 1, 3, 2]));
    let tf = LayoutTransform::new(vec![2, h, w, o], &seq);
    println!("NHWO {:?} -> {:?}", [2, h, w, o], tf.final_shape());

    let acc: Vec<DimAccess> = (0..4).map(|i| DimAccess::Simple(Var(i))).collect();
    let rewritten = tf.rewrite_access(&acc);
    println!("access T[n][h][w][o] becomes:");
    for (d, a) in rewritten.iter().enumerate() {
        println!("  dim {d}: {}", a.to_expr());
    }

    // --- §4.1.2: unfold {1..5} with B=3, S=2 ---
    let useq = {
        let mut s = LayoutSeq::new();
        s.push(Primitive::unfold(0, 3, 2));
        s
    };
    let ut = LayoutTransform::new(vec![5], &useq);
    let packed = ut.repack(&[1.0, 2.0, 3.0, 4.0, 5.0], &[5], 0.0);
    println!("\nunfold([1,2,3,4,5], B=3, S=2) = {packed:?}");

    // --- layouts under the simulator: the Fig. 1 experiment in steps ---
    let g = models::case_study();
    let conv = g.complex_nodes()[0];
    let hw = HwProfile::intel();
    let out = g.node(conv).output;
    println!("\ncase-study conv under hand-picked layouts ({}):", hw.name);
    let candidates: Vec<(&str, LayoutSeq)> = vec![
        ("NHWO (default)", LayoutSeq::new()),
        ("NOHW", {
            let mut s = LayoutSeq::new();
            s.push(Primitive::reorder(&[0, 3, 1, 2]));
            s
        }),
        ("N(O/16)HW16", {
            let mut s = LayoutSeq::new();
            s.push(Primitive::split(3, &[4, 16]));
            s.push(Primitive::reorder(&[0, 3, 1, 2, 4]));
            s
        }),
        ("N(H/4)(W/16)(O/16)·4·16·16", {
            let mut s = LayoutSeq::new();
            s.push(Primitive::split(1, &[28, 4]));
            s.push(Primitive::split(3, &[7, 16]));
            s.push(Primitive::split(5, &[4, 16]));
            s.push(Primitive::reorder(&[0, 1, 3, 5, 2, 4, 6]));
            s
        }),
    ];
    for (name, seq) in candidates {
        let mut layouts = LayoutAssignment::identity(&g);
        let storage = seq.apply_shape(&g.tensor(out).shape);
        layouts.set(out, seq);
        let mut sched = LoopSchedule::identity(&storage, &[3, 7, 7]);
        sched.vectorize = true;
        sched.parallel = 2;
        let p = lower_complex(&g, conv, &layouts, &sched, &[], hw.simd_lanes);
        let r = simulate_program(&p, &hw);
        println!(
            "  {name:32} lat {:8.4} ms  L1mis {:10.0}  inst {:12.0}",
            r.latency_ms, r.l1_misses, r.instructions
        );
    }

    // --- the same hand-picked layout as a Session plan, run for real ---
    // `plan_with` turns explicit decisions into a compilable plan, so a
    // hand-authored layout goes through the exact pipeline a tuned one
    // does: compile (weights packed once) → whole-graph native run. A
    // shrunk two-conv chain keeps the interpreted run instant.
    let mut b = alt::graph::GraphBuilder::new("lab_chain");
    let x = b.input("x", &["N", "H", "W", "I"], &[1, 14, 14, 32]);
    let c1 = b.conv2d("c3x3", x, 32, 3, 1, 1);
    b.conv2d("c1x1", c1, 32, 1, 1, 0);
    let session = Session::new(b.finish()).with_profile(hw.clone());
    let convs = session.graph().complex_nodes();
    let mut tiled = LayoutSeq::new();
    tiled
        .push(Primitive::split(3, &[2, 16]))
        .push(Primitive::reorder(&[0, 3, 1, 2, 4]));
    let dec = ComplexDecision {
        node: convs[0],
        out_seq: tiled,
        ..Default::default()
    };
    let model = session
        .plan_with(vec![dec], Default::default())
        .and_then(|t| t.compile())
        .unwrap_or_else(|e| panic!("plan_with: {e}"));
    let stats = model.run(&model.seeded_inputs(9)).expect("run lab_chain");
    println!(
        "\nthe two-conv chain under the hand-picked N(O/16)HW16 layout, \
         executed natively end-to-end: {:.3} ms ({} repack{} inserted \
         where producer/consumer layouts disagree)",
        stats.latency_ms,
        model.repacks_per_run(),
        if model.repacks_per_run() == 1 { "" } else { "s" }
    );
}
