//! Bench: graph-tuning throughput — the sequential per-op walk vs the
//! sharded orchestrator (the §Perf acceptance measurement for the
//! multi-workload scheduler).
//!
//! Tunes a small fleet of figure workloads (§7.3 case study + the two
//! §7.3.1 propagation subgraphs) three ways at several thread counts:
//!
//! * `seq`      — `shards = 1`: the historical sequential walk;
//! * `sharded`  — `shards = 0, budget_realloc = false`: concurrent
//!   shards, historical budget split — must reproduce `seq` results
//!   bit-for-bit (sharding as a pure throughput knob);
//! * `adaptive` — `shards = 0, budget_realloc = true`: concurrent
//!   shards with adaptive budget reallocation — different (better or
//!   equal-quality) trajectory, checked for end-to-end latency parity
//!   and thread-count determinism.
//!
//! Results go to `BENCH_graph.json` (override with `BENCH_GRAPH_JSON`);
//! `scripts/bench_graph.sh` wraps this, CI enforces the hard floors
//! (sharded==sequential parity, thread-count determinism) and warns on
//! the speedup/latency ratios (shared runners are too noisy to gate).

use std::time::Instant;

use alt::autotune::tuner::{tune_graphs, GraphTuneResult, TuneOptions};
use alt::engine::Engine;
use alt::graph::{models, Graph};
use alt::sim::HwProfile;

const BUDGET: usize = 320;

fn opts(threads: usize, shards: usize, realloc: bool) -> TuneOptions {
    TuneOptions {
        budget: BUDGET,
        seed: 11,
        threads,
        shards,
        budget_realloc: realloc,
        ..Default::default()
    }
}

fn fleet() -> Vec<Graph> {
    ["case_study", "subgraph1", "subgraph2"]
        .iter()
        .map(|n| models::by_name(n).expect("zoo workload"))
        .collect()
}

/// Bit-level equality of everything the determinism contract covers.
fn same(a: &GraphTuneResult, b: &GraphTuneResult) -> bool {
    a.report.latency_ms().to_bits() == b.report.latency_ms().to_bits()
        && a.measurements == b.measurements
        && a.rounds == b.rounds
        && a.scheds == b.scheds
        && a.decisions == b.decisions
        && a.ops.len() == b.ops.len()
        && a.ops.iter().zip(&b.ops).all(|(x, y)| {
            x.best_ms.to_bits() == y.best_ms.to_bits()
                && x.history.len() == y.history.len()
                && x.history
                    .iter()
                    .zip(&y.history)
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

fn all_same(a: &[GraphTuneResult], b: &[GraphTuneResult]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| same(x, y))
}

fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

struct Run {
    wall_s: f64,
    results: Vec<GraphTuneResult>,
}

fn run(nets: &[Graph], hw: &HwProfile, o: &TuneOptions) -> Run {
    let t0 = Instant::now();
    let results = tune_graphs(nets, hw, o);
    Run { wall_s: t0.elapsed().as_secs_f64(), results }
}

fn main() {
    let nets = fleet();
    let hw = HwProfile::intel();
    let n_graphs = nets.len() as f64;

    // untimed warm-up: populates the process-global expr interner /
    // simplify memo over both trajectories so timed runs compare
    // threading + scheduling, not first-touch interning
    run(&nets, &hw, &opts(0, 1, false));
    run(&nets, &hw, &opts(0, 0, true));

    // single-thread references: the parity + determinism baselines
    let seq_ref = run(&nets, &hw, &opts(1, 1, false));
    let shard_ref = run(&nets, &hw, &opts(1, 0, false));
    let adapt_ref = run(&nets, &hw, &opts(1, 0, true));

    // parity: non-adaptive sharding must reproduce the sequential
    // results bit-for-bit (checked once against the 1-thread
    // references; the loop below checks thread-invariance separately
    // so a parity break is never misreported as a determinism break)
    let sharded_matches_sequential =
        all_same(&shard_ref.results, &seq_ref.results);

    println!("== graph orchestrator (budget {BUDGET}, {} workloads) ==", nets.len());
    println!(
        "sequential walk (1 thread):  {:.2} s  ({:.2} graphs/s)",
        seq_ref.wall_s,
        n_graphs / seq_ref.wall_s
    );

    let cores = Engine::new(0).threads();
    let mut thread_counts = vec![2usize, 4, 8];
    if !thread_counts.contains(&cores) {
        thread_counts.push(cores);
    }
    thread_counts.sort_unstable();
    thread_counts.dedup();

    let mut rows: Vec<String> = Vec::new();
    let mut deterministic = true;
    let mut speedup_best = 0.0f64;
    for &t in &thread_counts {
        let seq = run(&nets, &hw, &opts(t, 1, false));
        let sharded = run(&nets, &hw, &opts(t, 0, false));
        let adaptive = run(&nets, &hw, &opts(t, 0, true));
        // hard invariant: every mode is thread-invariant (each compared
        // against its own 1-thread reference)
        deterministic &= all_same(&seq.results, &seq_ref.results)
            && all_same(&sharded.results, &shard_ref.results)
            && all_same(&adaptive.results, &adapt_ref.results);
        let speedup = seq.wall_s / sharded.wall_s;
        speedup_best = speedup_best.max(speedup);
        println!(
            "threads {t:>2}: seq {:.2} s | sharded {:.2} s ({speedup:.2}x) | adaptive {:.2} s",
            seq.wall_s, sharded.wall_s, adaptive.wall_s
        );
        rows.push(format!(
            "    {{\"threads\": {t}, \"seq_wall_s\": {:.3}, \
             \"seq_graphs_per_sec\": {:.3}, \"sharded_wall_s\": {:.3}, \
             \"sharded_graphs_per_sec\": {:.3}, \"speedup\": {:.3}, \
             \"adaptive_wall_s\": {:.3}, \"adaptive_graphs_per_sec\": {:.3}}}",
            seq.wall_s,
            n_graphs / seq.wall_s,
            sharded.wall_s,
            n_graphs / sharded.wall_s,
            speedup,
            adaptive.wall_s,
            n_graphs / adaptive.wall_s,
        ));
    }

    // end-to-end latency parity of the adaptive trajectory (quality
    // guard: reallocation must not trade latency for throughput)
    let ratios: Vec<f64> = adapt_ref
        .results
        .iter()
        .zip(&seq_ref.results)
        .map(|(a, s)| a.report.latency_ms() / s.report.latency_ms())
        .collect();
    let latency_ratio = geomean(&ratios);
    let seq_meas: usize = seq_ref.results.iter().map(|r| r.measurements).sum();
    let adapt_meas: usize =
        adapt_ref.results.iter().map(|r| r.measurements).sum();
    println!("best sharded speedup:        {speedup_best:.2}x");
    println!("sharded == sequential:       {sharded_matches_sequential}");
    println!("thread-count determinism:    {deterministic}");
    println!(
        "adaptive latency ratio:      {latency_ratio:.3} (geomean vs sequential)"
    );
    println!(
        "adaptive measurements:       {adapt_meas} vs sequential {seq_meas}"
    );

    let path = std::env::var("BENCH_GRAPH_JSON")
        .unwrap_or_else(|_| "BENCH_graph.json".to_string());
    let json = format!(
        "{{\n  \"budget\": {BUDGET},\n  \"workloads\": {},\n  \
         \"serial\": {{\"threads\": 1, \"wall_s\": {:.3}, \
         \"graphs_per_sec\": {:.3}}},\n  \"runs\": [\n{}\n  ],\n  \
         \"speedup_best\": {:.3},\n  \
         \"sharded_matches_sequential\": {},\n  \
         \"deterministic\": {},\n  \
         \"adaptive_latency_ratio\": {:.4},\n  \
         \"adaptive_measurements\": {},\n  \
         \"sequential_measurements\": {}\n}}\n",
        nets.len(),
        seq_ref.wall_s,
        n_graphs / seq_ref.wall_s,
        rows.join(",\n"),
        speedup_best,
        sharded_matches_sequential,
        deterministic,
        latency_ratio,
        adapt_meas,
        seq_meas,
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("graph report -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
