//! Bench: native runtime cross-check — execute the full-scale §7.3.3
//! case-study layout variants on the host and compare the measured
//! latency ranking against the simulated device's preference order
//! (the real-host validation leg, tier-1 since the native backend).
//!
//! Reports per-variant native latency, sim-vs-native Spearman, the
//! tolerance-aware rank-agreement flag, cross-variant numeric
//! agreement, and thread-count determinism of native execution.
//!
//! Results go to `BENCH_runtime.json` (override with
//! `BENCH_RUNTIME_JSON`); `scripts/bench_runtime.sh` wraps this and CI
//! enforces the hard floors (rank agreement on multi-core runners,
//! numerics, determinism) while the Spearman value only warns.

use alt::runtime::variants::{case_executables, cross_check, Scale};
use alt::sim::HwProfile;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let hw = HwProfile::intel();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // thread-count determinism of native execution (bit-level)
    let mut thread_outputs: Vec<Vec<u32>> = Vec::new();
    for threads in [1usize, 2, cores.max(2)] {
        let exes = case_executables(Scale::Full, &hw, threads)
            .unwrap_or_else(|e| panic!("compile: {e}"));
        let tiled = exes
            .iter()
            .find(|e| e.name() == "case_tiled")
            .expect("case_tiled");
        let inputs = tiled.seeded_inputs(17);
        let (_, out) = tiled.run_with_output(&inputs).unwrap();
        thread_outputs.push(bits(&out));
    }
    let deterministic = thread_outputs.iter().all(|o| *o == thread_outputs[0]);

    let check = cross_check(Scale::Full, &hw, 0, 3, 17)
        .unwrap_or_else(|e| panic!("cross-check: {e}"));

    println!("== native runtime cross-check (full scale, {} threads, {cores} cores) ==", check.threads);
    for (i, name) in check.names.iter().enumerate() {
        println!(
            "{name:>20}: sim {:>9.4} ms | native {:>9.3} ms",
            check.sim_ms[i], check.native_ms[i]
        );
    }
    println!("spearman(sim, native):  {:.3}", check.spearman);
    println!("rank agreement:         {}", check.rank_agreement());
    println!("best agrees:            {}", check.best_agrees);
    println!("numerics agree:         {}", check.numerics_ok);
    println!("thread determinism:     {deterministic}");
    for (a, b) in &check.strong_inversions {
        println!("  strong inversion: sim prefers {a} over {b}, native disagrees");
    }

    let variants: Vec<String> = check
        .names
        .iter()
        .enumerate()
        .map(|(i, name)| {
            format!(
                "    {{\"name\": \"{name}\", \"sim_ms\": {:.6}, \
                 \"native_ms\": {:.6}}}",
                check.sim_ms[i], check.native_ms[i]
            )
        })
        .collect();
    let path = std::env::var("BENCH_RUNTIME_JSON")
        .unwrap_or_else(|_| "BENCH_runtime.json".to_string());
    let json = format!(
        "{{\n  \"cores\": {cores},\n  \"threads\": {},\n  \
         \"variants\": [\n{}\n  ],\n  \
         \"spearman\": {:.4},\n  \
         \"rank_agreement\": {},\n  \
         \"best_agrees\": {},\n  \
         \"numerics_ok\": {},\n  \
         \"deterministic\": {}\n}}\n",
        check.threads,
        variants.join(",\n"),
        check.spearman,
        check.rank_agreement(),
        check.best_agrees,
        check.numerics_ok,
        deterministic,
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("runtime report -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
