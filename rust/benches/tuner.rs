//! Bench: tuning-loop throughput — the serial walk vs the batched +
//! speculative joint stage (the §Perf acceptance measurement for this
//! subsystem).
//!
//! Times whole `tune_op` runs on the case-study C2D with a
//! joint-heavy budget split, comparing the serial walk (`threads = 1`,
//! `speculation = 1`) against the batched pipeline at several thread
//! counts with speculative joint-stage proposals (`speculation = 4`).
//! Reports measurements/sec and rounds/sec, re-checks the
//! speculative path's thread-count determinism, and verifies the memo
//! cache honours a small eviction cap. Results are written to
//! `BENCH_tuner.json` (override with `BENCH_TUNER_JSON`);
//! `scripts/bench_tuner.sh` wraps this.

use std::time::Instant;

use alt::autotune::tuner::{tune_op, tune_op_with, OpTuneResult, TuneOptions};
use alt::engine::Engine;
use alt::graph::models;
use alt::sim::HwProfile;

const SPECULATION: usize = 4;

fn opts(threads: usize, speculation: usize) -> TuneOptions {
    TuneOptions {
        budget: 192,
        joint_frac: 0.5, // joint-heavy: the stage this bench measures
        seed: 11,
        threads,
        speculation,
        ..Default::default()
    }
}

struct Run {
    threads: usize,
    speculation: usize,
    wall_s: f64,
    meas_per_sec: f64,
    rounds_per_sec: f64,
    result: OpTuneResult,
}

fn main() {
    let g = models::case_study();
    let conv = g.complex_nodes()[0];
    let hw = HwProfile::intel();

    // untimed warm-ups covering BOTH timed trajectories (the serial
    // walk and the spec=4 walk propose different layouts, so each
    // interns different expr shapes): populates the process-global
    // expr interner / simplify memo so every timed run below sees the
    // same warm global-cache state and the speedups isolate
    // threading + speculation. The engine memo is per-run (fresh
    // engine per tune_op), so that stays cold for each timed run.
    tune_op(&g, conv, &hw, &opts(0, 1));
    tune_op(&g, conv, &hw, &opts(0, SPECULATION));

    let time = |threads: usize, speculation: usize| -> Run {
        let o = opts(threads, speculation);
        let t0 = Instant::now();
        let result = tune_op(&g, conv, &hw, &o);
        let wall_s = t0.elapsed().as_secs_f64();
        Run {
            threads: if threads == 0 { Engine::new(0).threads() } else { threads },
            speculation,
            wall_s,
            meas_per_sec: result.measurements as f64 / wall_s,
            rounds_per_sec: result.rounds as f64 / wall_s,
            result,
        }
    };

    let serial = time(1, 1);
    println!("== tuner loop (budget 192, joint_frac 0.5) ==");
    println!(
        "serial walk (1 thread):      {:.2} s  ({:.0} meas/s, {:.1} rounds/s)",
        serial.wall_s, serial.meas_per_sec, serial.rounds_per_sec
    );

    let cores = Engine::new(0).threads();
    let mut thread_counts = vec![2usize, 4, 8];
    if !thread_counts.contains(&cores) {
        thread_counts.push(cores);
    }
    thread_counts.sort_unstable();
    thread_counts.dedup();

    let batched: Vec<Run> = thread_counts
        .iter()
        .map(|&t| {
            let r = time(t, SPECULATION);
            println!(
                "batched+spec (K={}, {:>2} thr): {:.2} s  ({:.0} meas/s, {:.1} rounds/s, {:.2}x)",
                SPECULATION,
                r.threads,
                r.wall_s,
                r.meas_per_sec,
                r.rounds_per_sec,
                r.meas_per_sec / serial.meas_per_sec
            );
            r
        })
        .collect();
    let best = batched
        .iter()
        .map(|r| r.meas_per_sec)
        .fold(0.0f64, f64::max);
    let speedup_best = best / serial.meas_per_sec;
    println!("best speedup vs serial walk: {speedup_best:.2}x");

    // determinism re-check on the bench config itself: the speculative
    // trajectory must not depend on thread count (the batched runs at
    // different thread counts must agree with a 1-thread replay)
    let replay = tune_op(&g, conv, &hw, &opts(1, SPECULATION));
    let deterministic = batched.iter().all(|r| {
        r.result.best_ms.to_bits() == replay.best_ms.to_bits()
            && r.result.measurements == replay.measurements
            && r.result.history.len() == replay.history.len()
            && r.result
                .history
                .iter()
                .zip(&replay.history)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    });
    println!("thread-count determinism:    {deterministic}");

    // memo-cache eviction bound: a tiny cap must hold under a real run
    let memo_cap = 256usize;
    let capped_engine = Engine::with_memo_cap(0, memo_cap);
    let capped = tune_op_with(&g, conv, &hw, &opts(0, SPECULATION), &capped_engine);
    let memo_len = capped_engine.memo_len();
    let cap_respected = memo_len <= memo_cap;
    println!(
        "memo cap {memo_cap}: {memo_len} entries after run, {} evictions (respected: {cap_respected})",
        capped.engine.evicted
    );

    // machine-readable report for scripts/bench_tuner.sh / CI trending
    let path = std::env::var("BENCH_TUNER_JSON")
        .unwrap_or_else(|_| "BENCH_tuner.json".to_string());
    let batched_json: Vec<String> = batched
        .iter()
        .map(|r| {
            format!(
                "    {{\"threads\": {}, \"speculation\": {}, \"wall_s\": {:.3}, \
                 \"meas_per_sec\": {:.1}, \"rounds_per_sec\": {:.2}}}",
                r.threads, r.speculation, r.wall_s, r.meas_per_sec, r.rounds_per_sec
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"budget\": {},\n  \"joint_frac\": {},\n  \
         \"speculation\": {},\n  \
         \"serial\": {{\"threads\": 1, \"wall_s\": {:.3}, \
         \"meas_per_sec\": {:.1}, \"rounds_per_sec\": {:.2}}},\n  \
         \"batched\": [\n{}\n  ],\n  \
         \"speedup_best\": {:.3},\n  \
         \"deterministic\": {},\n  \
         \"memo_cap\": {},\n  \"memo_len_after_capped_run\": {},\n  \
         \"memo_evictions\": {},\n  \"memo_cap_respected\": {}\n}}\n",
        opts(0, 1).budget,
        opts(0, 1).joint_frac,
        SPECULATION,
        serial.wall_s,
        serial.meas_per_sec,
        serial.rounds_per_sec,
        batched_json.join(",\n"),
        speedup_best,
        deterministic,
        memo_cap,
        memo_len,
        capped.engine.evicted,
        cap_respected,
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("tuner report -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
