//! Bench: regenerate Fig. 9 — single-operator comparison of
//! vendor/AutoTVM-like/FlexTensor-like/Ansor-like/ALT over the nine
//! operator families on the three hardware profiles.
//! Acceptance shape: ALT >= Ansor-like >= {AutoTVM, FlexTensor} >=
//! vendor on geomean; largest ALT margins on DEP/DIL.

use alt::bench::figures::{fig9, Scale};
use alt::bench::harness::time_fn;

fn main() {
    let scale = Scale::quick();
    let ms = time_fn(
        || {
            for t in fig9(&scale) {
                t.print();
                println!();
            }
        },
        1,
    );
    println!("[bench fig9] wall time {ms:.0} ms");
}
