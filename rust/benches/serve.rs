//! Bench: end-to-end graph serving through the Session pipeline —
//! tune → compile → run whole models on the native backend.
//!
//! For each serving workload (resnet18 at Small scale, bert_tiny) the
//! bench tunes once, compiles once (constant weights packed into their
//! tuned layouts at compile time), then measures end-to-end graph
//! inferences/sec, the per-inference repack count, and how quickly the
//! one-off compile-time weight packing amortizes against per-run
//! execution. Hard invariants checked on any machine: multi-op native
//! execution is bit-identical across thread counts, and the save/load
//! round trip reproduces the same outputs without re-tuning.
//!
//! Results go to `BENCH_serve.json` (override with `BENCH_SERVE_JSON`);
//! `scripts/bench_serve.sh` wraps this and CI enforces the hard floors
//! (determinism, round trip) while throughput only warns — shared
//! runners are too noisy for a required timing gate.

use std::time::Instant;

use alt::api::Session;
use alt::autotune::TuneOptions;
use alt::sim::HwProfile;

const BUDGET: usize = 200;
const REQUESTS: usize = 8;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn session(name: &str, threads: usize) -> Session {
    Session::for_model(name)
        .unwrap_or_else(|e| panic!("{e}"))
        .with_profile(HwProfile::intel())
        .with_options(TuneOptions {
            budget: BUDGET,
            seed: 17,
            shards: 0,
            ..Default::default()
        })
        .with_exec_threads(threads)
}

fn main() {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut rows: Vec<String> = Vec::new();
    let mut deterministic = true;
    let mut roundtrip_ok = true;

    println!("== whole-model serving (Session pipeline, budget {BUDGET}, {cores} cores) ==");
    for name in ["resnet18_small", "bert_tiny"] {
        let t_tune = Instant::now();
        let tuned = session(name, 0).tune();
        let tune_s = t_tune.elapsed().as_secs_f64();
        let sim_ms = tuned.report().expect("tuned").latency_ms();

        let model = tuned.compile().unwrap_or_else(|e| panic!("{name}: {e}"));
        let inputs = model.seeded_inputs(33);

        // serving loop: median per-inference latency + throughput
        let (_, reference) = model.run_with_output(&inputs).unwrap(); // warmup
        let mut times = Vec::with_capacity(REQUESTS);
        let t0 = Instant::now();
        for _ in 0..REQUESTS {
            times.push(model.run(&inputs).unwrap().latency_ms);
        }
        let wall = t0.elapsed().as_secs_f64();
        let native_ms = alt::util::stats::median(&mut times);
        let inf_per_sec = REQUESTS as f64 / wall;

        // compile-time weight packing amortization: packing is paid
        // once; this is how many inferences until the one-off cost is
        // below 1% of cumulative execution time
        let amortize_runs = if native_ms > 0.0 {
            (model.packing_ms() / (0.01 * native_ms)).ceil()
        } else {
            0.0
        };

        // hard floor 1: thread-count determinism of whole-model runs
        for threads in [1usize, 2] {
            let m = session(name, threads)
                .plan_with(
                    tuned.plan().decisions(),
                    tuned.plan().scheds(),
                )
                .unwrap()
                .compile()
                .unwrap();
            let (_, out) = m.run_with_output(&inputs).unwrap();
            if bits(&out) != bits(&reference) {
                deterministic = false;
                eprintln!("{name}: threads={threads} diverged");
            }
        }

        // hard floor 2: save/load round trip, no re-tuning
        let dir = std::env::temp_dir()
            .join(format!("alt_bench_serve_{}_{name}", std::process::id()));
        model.save(&dir).unwrap();
        let reloaded = Session::load(&dir)
            .and_then(|t| t.compile())
            .unwrap_or_else(|e| panic!("{name} reload: {e}"));
        let (_, out) = reloaded.run_with_output(&inputs).unwrap();
        if bits(&out) != bits(&reference) {
            roundtrip_ok = false;
            eprintln!("{name}: save/load round trip diverged");
        }
        std::fs::remove_dir_all(&dir).ok();

        println!(
            "{name:>15}: tune {tune_s:>6.1} s | sim {sim_ms:>8.3} ms | \
             native {native_ms:>8.3} ms ({inf_per_sec:.1} inf/s) | \
             {} nests + {} simple | {} repacks/run | \
             {}/{} weights packed in {:.1} ms (amortized in {amortize_runs:.0} runs)",
            model.complex_steps(),
            model.simple_steps(),
            model.repacks_per_run(),
            model.weights_packed(),
            model.weights_total(),
            model.packing_ms(),
        );
        rows.push(format!(
            "    {{\"name\": \"{name}\", \"tune_s\": {tune_s:.3}, \
             \"sim_ms\": {sim_ms:.4}, \"native_ms\": {native_ms:.4}, \
             \"inf_per_sec\": {inf_per_sec:.3}, \
             \"complex_steps\": {}, \"simple_steps\": {}, \
             \"repacks_per_run\": {}, \"weights_packed\": {}, \
             \"weights_total\": {}, \"packing_ms\": {:.3}, \
             \"compile_ms\": {:.3}, \"amortize_runs\": {amortize_runs:.0}}}",
            model.complex_steps(),
            model.simple_steps(),
            model.repacks_per_run(),
            model.weights_packed(),
            model.weights_total(),
            model.packing_ms(),
            model.compile_ms(),
        ));
    }

    println!("thread determinism:   {deterministic}");
    println!("save/load roundtrip:  {roundtrip_ok}");

    let path = std::env::var("BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let json = format!(
        "{{\n  \"cores\": {cores},\n  \"budget\": {BUDGET},\n  \
         \"requests\": {REQUESTS},\n  \"models\": [\n{}\n  ],\n  \
         \"deterministic\": {deterministic},\n  \
         \"roundtrip_ok\": {roundtrip_ok}\n}}\n",
        rows.join(",\n"),
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("serve report -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
