//! Bench: end-to-end graph serving through the Session pipeline —
//! tune → compile → run whole models on the native backend.
//!
//! For each serving workload (resnet18 at Small scale, bert_tiny) the
//! bench tunes once, compiles once (constant weights packed into their
//! tuned layouts at compile time), then measures end-to-end graph
//! inferences/sec, a per-phase breakdown (nest exec vs repack vs
//! boundary pack/unpack vs simple-op ms), and the within-run speedup of
//! the compiled fast path over the retained bytecode interpreter
//! (`ExecMode::Bytecode`), which doubles as a bit-identity oracle.
//! Hard invariants checked on any machine: multi-op native execution is
//! bit-identical across thread counts AND across executor modes, and
//! the save/load round trip reproduces the same outputs without
//! re-tuning. A dedicated fusion demo forces a Fig. 5a conversion onto
//! resnet18_small's stem conv and checks the fast path fuses it into
//! the nest's read-side gather (repack copy eliminated) bit-exactly.
//! A degradation demo forces one mid-model nest onto the bytecode
//! interpreter (the per-nest fault ladder's fallback) and reports the
//! within-run throughput ratio against all-fast, which CI gates ≥ 0.7
//! alongside bit-identity of the degraded output. A graph-rewrite
//! comparison compiles each zoo model twice from the same layout
//! decisions — rewrite stage on vs off — and reports plan-step counts
//! (ops_before/ops_after), bit-identity, and the within-run inf/s
//! ratio; CI gates strictly-fewer steps and bit-identity hard, the
//! speedup only warns.
//!
//! A second, serving-layer report measures the high-throughput path:
//! steady-state allocation of the reusable-scratch entry (counting
//! global allocator), dynamic-batching and intra-request-pipelining
//! bit-identity, deterministic typed backpressure, and a load
//! generator — closed-loop req/s with p50/p99 at 1/8/64 clients plus
//! an open-loop fixed-rate run with shed counting.
//!
//! Results go to `BENCH_serve.json` and `BENCH_throughput.json`
//! (override with `BENCH_SERVE_JSON` / `BENCH_THROUGHPUT_JSON`);
//! `scripts/bench_serve.sh` wraps this and CI enforces the hard floors
//! (determinism, round trip, fast-vs-interpreter ratio, fusion,
//! batching/pipelining identity, 8-client scaling on multi-core
//! runners) while absolute throughput only warns — shared runners are
//! too noisy for a required absolute-timing gate, but within-run
//! ratios are immune to machine speed.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use alt::analysis::ProofKind;
use alt::api::{
    BatchScratch, PipeScratch, RunScratch, ServeOptions, Server, Session,
};
use alt::autotune::TuneOptions;
use alt::error::ErrorKind;
use alt::layout::{LayoutSeq, Primitive};
use alt::propagate::ComplexDecision;
use alt::rewrite::RewriteMode;
use alt::runtime::{DegradeReason, ExecMode};
use alt::sim::HwProfile;

/// Counting allocator wrapping the system one — the instrument behind
/// the steady-state "reused scratch allocates (almost) nothing" block
/// in the throughput report.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new as u64, Ordering::Relaxed);
        System.realloc(p, l, new)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_snapshot() -> (u64, u64) {
    (ALLOC_CALLS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

const BUDGET: usize = 200;
const REQUESTS: usize = 8;
/// Bytecode-interpreter requests for the within-run ratio (fewer: the
/// interpreted path is the slow one being measured against).
const INTERP_REQUESTS: usize = 3;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn session(name: &str, threads: usize) -> Session {
    Session::for_model(name)
        .unwrap_or_else(|e| panic!("{e}"))
        .with_profile(HwProfile::intel())
        .with_options(TuneOptions {
            budget: BUDGET,
            seed: 17,
            shards: 0,
            ..Default::default()
        })
        .with_exec_threads(threads)
}

/// Force a conversion operator onto resnet18_small's stem conv input
/// (the graph input allocates identity, so a non-identity read layout
/// guarantees a Fig. 5a repack edge) and report whether the fast path
/// fused it away bit-exactly.
fn fusion_demo() -> String {
    let s = session("resnet18_small", 1);
    let conv1 = s.graph().complex_nodes()[0];
    let mut in_seq = LayoutSeq::new();
    in_seq.push(Primitive::reorder(&[0, 3, 1, 2])); // NHWC -> NCHW read
    let dec = ComplexDecision { node: conv1, in_seq, ..Default::default() };
    let tuned = s
        .plan_with(vec![dec], HashMap::new())
        .unwrap_or_else(|e| panic!("fusion plan: {e}"));
    let mut model = tuned.compile().unwrap_or_else(|e| panic!("{e}"));
    let conversions = model.conversions();
    let fused = model.fused_repacks();
    let materialized = model.materialized_repacks();
    let inputs = model.seeded_inputs(5);
    let (_, a) = model.run_with_output(&inputs).unwrap();
    model.set_exec_mode(ExecMode::Bytecode);
    let (_, b) = model.run_with_output(&inputs).unwrap();
    let identical = bits(&a) == bits(&b);
    println!(
        "fusion demo (resnet18_small stem): {conversions} conversions, \
         {fused} fused / {materialized} materialized, identical {identical}"
    );
    format!(
        "{{\"conversions\": {conversions}, \"fused\": {fused}, \
         \"materialized\": {materialized}, \"identical\": {identical}}}"
    )
}

/// Degradation-ladder overhead: force one mid-model nest of
/// resnet18_small onto the bytecode interpreter (public `degrade_nest`,
/// exactly what the per-nest compile ladder does on a fast-path
/// failure) and measure throughput against the all-fast and
/// all-bytecode endpoints of the ladder. Within-run ratios, so the
/// numbers are immune to runner speed; CI gates `degraded_vs_fast` and
/// `identical` hard.
fn degradation_overhead() -> String {
    let tuned = session("resnet18_small", 0).baseline();
    let mut model = tuned.compile().unwrap_or_else(|e| panic!("{e}"));
    let inputs = model.seeded_inputs(29);

    let (_, reference) = model.run_with_output(&inputs).unwrap(); // warmup
    let t0 = Instant::now();
    for _ in 0..REQUESTS {
        model.run(&inputs).unwrap();
    }
    let fast_inf_s = REQUESTS as f64 / t0.elapsed().as_secs_f64();

    let nests = model.health().nests.len();
    let victim = model.health().nests[nests / 2].node;
    assert!(
        model.degrade_nest(victim, DegradeReason::StreamAnalysis),
        "victim nest not found"
    );
    let (_, degraded_out) = model.run_with_output(&inputs).unwrap(); // warmup
    let identical = bits(&degraded_out) == bits(&reference);
    if !identical {
        eprintln!("degradation demo: degraded nest changed the output");
    }
    let t1 = Instant::now();
    for _ in 0..REQUESTS {
        model.run(&inputs).unwrap();
    }
    let degraded_inf_s = REQUESTS as f64 / t1.elapsed().as_secs_f64();

    model.set_exec_mode(ExecMode::Bytecode);
    model.run(&inputs).unwrap(); // warmup
    let t2 = Instant::now();
    for _ in 0..INTERP_REQUESTS {
        model.run(&inputs).unwrap();
    }
    let bytecode_inf_s = INTERP_REQUESTS as f64 / t2.elapsed().as_secs_f64();

    let ratio =
        if fast_inf_s > 0.0 { degraded_inf_s / fast_inf_s } else { 0.0 };
    println!(
        "degradation overhead (resnet18_small, 1/{nests} nests bytecode): \
         fast {fast_inf_s:.1} inf/s | degraded {degraded_inf_s:.1} inf/s \
         ({ratio:.2}x) | all-bytecode {bytecode_inf_s:.1} inf/s | \
         identical {identical}"
    );
    format!(
        "{{\"nests\": {nests}, \"degraded_nests\": 1, \
         \"fast_inf_per_sec\": {fast_inf_s:.3}, \
         \"degraded_inf_per_sec\": {degraded_inf_s:.3}, \
         \"bytecode_inf_per_sec\": {bytecode_inf_s:.3}, \
         \"degraded_vs_fast\": {ratio:.3}, \"identical\": {identical}}}"
    )
}

/// Graph-rewrite payoff, measured within one run: the same layout
/// decisions and schedules compiled twice — once with the rewrite
/// stage on (pad folds, constant folds, epilogue fusion annotated into
/// the plan) and once with it off. Every rewrite the zoo models admit
/// is bit-exact, so CI gates strictly-fewer plan steps AND bit-equal
/// outputs hard on both models; the rewritten-vs-unrewritten inf/s
/// ratio is reported but only warns (runner noise).
fn rewrite_comparison() -> String {
    let mut rows: Vec<String> = Vec::new();
    for name in ["resnet18_small", "bert_tiny"] {
        let rw_session = |mode: RewriteMode| {
            Session::for_model(name)
                .unwrap_or_else(|e| panic!("{e}"))
                .with_profile(HwProfile::intel())
                .with_options(TuneOptions {
                    budget: BUDGET,
                    seed: 17,
                    shards: 0,
                    rewrite: mode,
                    ..Default::default()
                })
                .with_exec_threads(1)
        };
        let on_tuned = rw_session(RewriteMode::On).baseline();
        let on_model = on_tuned
            .compile()
            .unwrap_or_else(|e| panic!("{name} rewrite-on compile: {e}"));
        // Same layouts/schedules, rewrite stage disabled: the
        // unrewritten twin for a within-run comparison.
        let off_model = rw_session(RewriteMode::Off)
            .plan_with(on_tuned.plan().decisions(), on_tuned.plan().scheds())
            .unwrap_or_else(|e| panic!("{name} rewrite-off plan: {e}"))
            .compile()
            .unwrap_or_else(|e| panic!("{name} rewrite-off compile: {e}"));
        let ops_after = on_model.complex_steps() + on_model.simple_steps();
        let ops_before = off_model.complex_steps() + off_model.simple_steps();
        let applied = on_model.rewrites_applied();
        let available = on_model.rewrites_available();

        let inputs = on_model.seeded_inputs(61);
        let (_, a) = on_model.run_with_output(&inputs).unwrap(); // warmup
        let (_, b) = off_model.run_with_output(&inputs).unwrap(); // warmup
        let identical = bits(&a) == bits(&b);
        if !identical {
            eprintln!("{name}: rewritten output diverged from unrewritten");
        }
        let t0 = Instant::now();
        for _ in 0..REQUESTS {
            on_model.run(&inputs).unwrap();
        }
        let on_inf_s = REQUESTS as f64 / t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        for _ in 0..REQUESTS {
            off_model.run(&inputs).unwrap();
        }
        let off_inf_s = REQUESTS as f64 / t1.elapsed().as_secs_f64();
        let speedup =
            if off_inf_s > 0.0 { on_inf_s / off_inf_s } else { 0.0 };

        println!(
            "rewrite {name:>15}: {applied}/{available} applied | \
             {ops_before} -> {ops_after} plan steps | \
             {on_inf_s:.1} vs {off_inf_s:.1} inf/s ({speedup:.2}x) | \
             identical {identical}"
        );
        rows.push(format!(
            "    {{\"name\": \"{name}\", \"ops_before\": {ops_before}, \
             \"ops_after\": {ops_after}, \
             \"rewrites_applied\": {applied}, \
             \"rewrites_available\": {available}, \
             \"rewritten_inf_per_sec\": {on_inf_s:.3}, \
             \"unrewritten_inf_per_sec\": {off_inf_s:.3}, \
             \"rewrite_speedup\": {speedup:.3}, \
             \"rewrite_identical\": {identical}}}"
        ));
    }
    rows.join(",\n")
}

fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| {
        a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
    });
    samples[((samples.len() - 1) as f64 * p).round() as usize]
}

/// Closed-loop load: `clients` threads each issue `per_client`
/// blocking requests back to back. Returns (req/s, p50 ms, p99 ms,
/// all-bit-identical).
fn closed_loop(
    server: &Server,
    clients: usize,
    per_client: usize,
    inputs: &[Vec<f32>],
    want: &[u32],
) -> (f64, f64, f64, bool) {
    let mut lat: Vec<f64> = Vec::with_capacity(clients * per_client);
    let mut identical = true;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let (srv, ins, w) = (server, inputs, want);
                s.spawn(move || {
                    let mut times = Vec::with_capacity(per_client);
                    let mut ok = true;
                    for _ in 0..per_client {
                        let t = Instant::now();
                        let reply = srv.infer(ins.to_vec()).unwrap();
                        times.push(t.elapsed().as_secs_f64() * 1e3);
                        ok &= bits(&reply.output) == w;
                    }
                    (times, ok)
                })
            })
            .collect();
        for h in handles {
            let (times, ok) = h.join().unwrap();
            lat.extend(times);
            identical &= ok;
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let rps = lat.len() as f64 / wall;
    (rps, percentile(&mut lat, 0.50), percentile(&mut lat, 0.99), identical)
}

/// The high-throughput serving report: steady-state allocation of the
/// reusable-scratch entry, dynamic-batching and pipelining bit-identity
/// (the CI hard gates), deterministic typed backpressure, and the load
/// generator — closed-loop req/s + p50/p99 at 1/8/64 clients plus an
/// open-loop fixed-rate run with shed counting. Scaling numbers are
/// within-run ratios; absolute latencies only warn in CI.
fn throughput_report(cores: usize) {
    let model = Arc::new(
        session("resnet18_small", 1)
            .baseline()
            .compile()
            .unwrap_or_else(|e| panic!("throughput compile: {e}")),
    );
    let inputs = model.seeded_inputs(41);
    let (_, reference) = model.run_with_output(&inputs).unwrap();
    let want = bits(&reference);

    // -- steady-state allocation: persistent scratch vs fresh per run --
    const ALLOC_RUNS: usize = 8;
    let mut scratch = RunScratch::default();
    for _ in 0..2 {
        model.run_in(&mut scratch, &inputs).unwrap(); // warm the pools
    }
    let (c0, b0) = alloc_snapshot();
    for _ in 0..ALLOC_RUNS {
        model.run_in(&mut scratch, &inputs).unwrap();
    }
    let (c1, b1) = alloc_snapshot();
    for _ in 0..ALLOC_RUNS {
        model.run(&inputs).unwrap(); // fresh scratch every request
    }
    let (c2, b2) = alloc_snapshot();
    let (reused_allocs, reused_bytes) = (c1 - c0, b1 - b0);
    let (fresh_allocs, fresh_bytes) = (c2 - c1, b2 - b1);
    let alloc_ratio = reused_bytes as f64 / fresh_bytes.max(1) as f64;

    // -- dynamic batching: bit-identity vs sequential (CI hard gate) --
    const LANES: usize = 5;
    let reqs: Vec<Vec<Vec<f32>>> =
        (0..LANES).map(|i| model.seeded_inputs(50 + i as u64)).collect();
    let seq: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| bits(&model.run_with_output(r).unwrap().1))
        .collect();
    let mut bscratch = BatchScratch::default();
    let lanes: Vec<&[Vec<f32>]> = reqs.iter().map(|r| r.as_slice()).collect();
    let batched_identical = model
        .run_batch_in(&mut bscratch, &lanes)
        .into_iter()
        .enumerate()
        .all(|(i, r)| match r {
            Ok((_, _, out)) => bits(&out) == seq[i],
            Err(e) => {
                eprintln!("batched lane {i} failed: {e}");
                false
            }
        });
    if !batched_identical {
        eprintln!("throughput: batched outputs diverged from sequential");
    }

    // -- intra-request pipelining: bit-identity + solo-latency ratio --
    let (waves, widest) = model.wave_shape();
    let mut pipe = PipeScratch::default();
    let mut pipelined_identical = true;
    for width in [2usize, 4] {
        let (_, _, out) = model
            .run_pipelined_in(&mut scratch, &mut pipe, width, &inputs)
            .unwrap();
        if bits(&out) != want {
            pipelined_identical = false;
            eprintln!("throughput: pipelined width {width} diverged");
        }
    }
    let mut serial_ms = Vec::with_capacity(ALLOC_RUNS);
    let mut piped_ms = Vec::with_capacity(ALLOC_RUNS);
    for _ in 0..ALLOC_RUNS {
        let t = Instant::now();
        model.run_pipelined_in(&mut scratch, &mut pipe, 1, &inputs).unwrap();
        serial_ms.push(t.elapsed().as_secs_f64() * 1e3);
        let t = Instant::now();
        model
            .run_pipelined_in(&mut scratch, &mut pipe, cores.max(2), &inputs)
            .unwrap();
        piped_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let serial_solo_ms = alt::util::stats::median(&mut serial_ms);
    let piped_solo_ms = alt::util::stats::median(&mut piped_ms);
    let pipeline_speedup =
        if piped_solo_ms > 0.0 { serial_solo_ms / piped_solo_ms } else { 0.0 };

    // -- deterministic typed backpressure (CI hard gate) --
    let overload_typed = {
        let srv = Server::start(
            Arc::clone(&model),
            ServeOptions {
                workers: 1,
                max_batch: 1,
                batch_window_us: 0,
                queue_cap: 1,
                pipeline_width: 1,
            },
        );
        srv.pause();
        let admitted = srv.submit(inputs.clone()).unwrap();
        let typed = matches!(
            srv.submit(inputs.clone()),
            Err(e) if e.kind() == ErrorKind::Overload
        );
        srv.resume();
        let drained = admitted.wait().is_ok();
        srv.shutdown();
        typed && drained
    };

    // -- closed-loop load generator --
    let server = Server::start(
        Arc::clone(&model),
        ServeOptions {
            workers: 0, // one per core
            max_batch: 8,
            batch_window_us: 200,
            queue_cap: 256,
            pipeline_width: 1,
        },
    );
    for _ in 0..2 {
        server.infer(inputs.clone()).unwrap(); // warmup
    }
    let mut closed_rows: Vec<String> = Vec::new();
    let mut rps_at: HashMap<usize, f64> = HashMap::new();
    let mut closed_identical = true;
    for (clients, per_client) in [(1usize, 24usize), (8, 12), (64, 2)] {
        let (rps, p50, p99, identical) =
            closed_loop(&server, clients, per_client, &inputs, &want);
        closed_identical &= identical;
        rps_at.insert(clients, rps);
        println!(
            "closed loop {clients:>3} clients: {rps:>7.1} req/s | \
             p50 {p50:.3} ms | p99 {p99:.3} ms | identical {identical}"
        );
        closed_rows.push(format!(
            "    {{\"clients\": {clients}, \"requests\": {}, \
             \"req_per_sec\": {rps:.3}, \"p50_ms\": {p50:.4}, \
             \"p99_ms\": {p99:.4}, \"identical\": {identical}}}",
            clients * per_client,
        ));
    }
    let rps_1 = rps_at.get(&1).copied().unwrap_or(0.0);
    let rps_8 = rps_at.get(&8).copied().unwrap_or(0.0);
    let scaling_8c = if rps_1 > 0.0 { rps_8 / rps_1 } else { 0.0 };

    // -- open-loop load generator: fixed submit rate, shed counting --
    const OPEN_SUBMITS: usize = 48;
    let target_rps = (2.0 * rps_1).max(1.0);
    let interval = Duration::from_secs_f64(1.0 / target_rps);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(OPEN_SUBMITS);
    let mut dropped = 0usize;
    for i in 0..OPEN_SUBMITS {
        match server.submit(inputs.clone()) {
            Ok(p) => pending.push((Instant::now(), p)),
            Err(e) => {
                assert_eq!(e.kind(), ErrorKind::Overload, "{e}");
                dropped += 1;
            }
        }
        if i + 1 < OPEN_SUBMITS {
            std::thread::sleep(interval);
        }
    }
    let mut open_lat: Vec<f64> = Vec::with_capacity(pending.len());
    let mut completed = 0usize;
    for (submitted, p) in pending {
        let reply = p.wait().unwrap();
        open_lat.push(submitted.elapsed().as_secs_f64() * 1e3);
        completed += 1;
        assert!(reply.phases.queue_ms >= 0.0);
    }
    let open_wall = t0.elapsed().as_secs_f64();
    let open_rps = completed as f64 / open_wall;
    let open_p50 = percentile(&mut open_lat, 0.50);
    let open_p99 = percentile(&mut open_lat, 0.99);
    let final_stats = server.stats();
    server.shutdown();

    println!(
        "steady-state alloc: reused {reused_bytes} B / fresh {fresh_bytes} B \
         (ratio {alloc_ratio:.4}) over {ALLOC_RUNS} runs"
    );
    println!(
        "pipelining: {waves} waves (widest {widest}), solo \
         {serial_solo_ms:.3} -> {piped_solo_ms:.3} ms \
         ({pipeline_speedup:.2}x), identical {pipelined_identical}"
    );
    println!(
        "open loop: target {target_rps:.1} req/s -> {open_rps:.1} req/s, \
         {completed}/{OPEN_SUBMITS} completed, {dropped} shed | \
         p50 {open_p50:.3} ms | p99 {open_p99:.3} ms"
    );
    println!(
        "scaling 8 clients vs 1: {scaling_8c:.2}x on {cores} cores | \
         batched identical {batched_identical} | overload typed \
         {overload_typed} | served {} batches {}",
        final_stats.served, final_stats.batches,
    );

    let path = std::env::var("BENCH_THROUGHPUT_JSON")
        .unwrap_or_else(|_| "BENCH_throughput.json".to_string());
    let json = format!(
        "{{\n  \"cores\": {cores},\n  \"model\": \"resnet18_small\",\n  \
         \"exec_threads\": 1,\n  \"workers\": {workers},\n  \
         \"alloc_steady_state\": {{\"runs\": {ALLOC_RUNS}, \
         \"reused_allocs\": {reused_allocs}, \
         \"reused_bytes\": {reused_bytes}, \
         \"fresh_allocs\": {fresh_allocs}, \
         \"fresh_bytes\": {fresh_bytes}, \
         \"ratio\": {alloc_ratio:.6}}},\n  \
         \"batched_lanes\": {LANES},\n  \
         \"batched_identical\": {batched_identical},\n  \
         \"waves\": {waves},\n  \"widest_wave\": {widest},\n  \
         \"pipelined_identical\": {pipelined_identical},\n  \
         \"serial_solo_ms\": {serial_solo_ms:.4},\n  \
         \"piped_solo_ms\": {piped_solo_ms:.4},\n  \
         \"pipeline_speedup\": {pipeline_speedup:.3},\n  \
         \"overload_typed\": {overload_typed},\n  \
         \"closed_identical\": {closed_identical},\n  \
         \"closed_loop\": [\n{closed}\n  ],\n  \
         \"scaling_8c\": {scaling_8c:.3},\n  \
         \"open_loop\": {{\"target_req_per_sec\": {target_rps:.3}, \
         \"submitted\": {OPEN_SUBMITS}, \"completed\": {completed}, \
         \"dropped\": {dropped}, \"req_per_sec\": {open_rps:.3}, \
         \"p50_ms\": {open_p50:.4}, \"p99_ms\": {open_p99:.4}}},\n  \
         \"served\": {served},\n  \"batches\": {batches}\n}}\n",
        workers = ServeOptions::default().resolved_workers(),
        closed = closed_rows.join(",\n"),
        served = final_stats.served,
        batches = final_stats.batches,
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("throughput report -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut rows: Vec<String> = Vec::new();
    let mut deterministic = true;
    let mut roundtrip_ok = true;

    println!("== whole-model serving (Session pipeline, budget {BUDGET}, {cores} cores) ==");
    for name in ["resnet18_small", "bert_tiny"] {
        let t_tune = Instant::now();
        let tuned = session(name, 0).tune();
        let tune_s = t_tune.elapsed().as_secs_f64();
        let sim_ms = tuned.report().expect("tuned").latency_ms();

        let mut model =
            tuned.compile().unwrap_or_else(|e| panic!("{name}: {e}"));
        let inputs = model.seeded_inputs(33);

        // serving loop: median per-inference latency + throughput,
        // with the per-phase breakdown from the same profiled runs
        let (_, reference) = model.run_with_output(&inputs).unwrap(); // warmup
        let mut times = Vec::with_capacity(REQUESTS);
        let (mut nest, mut repack, mut boundary, mut simple) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        let t0 = Instant::now();
        for _ in 0..REQUESTS {
            let (stats, ph, _) = model.run_profiled(&inputs).unwrap();
            times.push(stats.latency_ms);
            nest.push(ph.nest_ms);
            repack.push(ph.repack_ms);
            boundary.push(ph.boundary_ms);
            simple.push(ph.simple_ms);
        }
        let wall = t0.elapsed().as_secs_f64();
        let native_ms = alt::util::stats::median(&mut times);
        let inf_per_sec = REQUESTS as f64 / wall;
        let nest_ms = alt::util::stats::median(&mut nest);
        let repack_ms = alt::util::stats::median(&mut repack);
        let boundary_ms = alt::util::stats::median(&mut boundary);
        let simple_ms = alt::util::stats::median(&mut simple);

        // within-run fast-vs-interpreter ratio on the SAME compiled
        // model: flip the executor mode, re-measure, flip back. The
        // interpreter run is also the fast path's bit-identity oracle.
        model.set_exec_mode(ExecMode::Bytecode);
        let (_, interp_out) = model.run_with_output(&inputs).unwrap(); // warmup
        let fastpath_identical = bits(&interp_out) == bits(&reference);
        if !fastpath_identical {
            eprintln!("{name}: fast path diverged from bytecode oracle");
        }
        let mut itimes = Vec::with_capacity(INTERP_REQUESTS);
        for _ in 0..INTERP_REQUESTS {
            itimes.push(model.run(&inputs).unwrap().latency_ms);
        }
        let interp_ms = alt::util::stats::median(&mut itimes);
        model.set_exec_mode(ExecMode::Fast);
        let fast_vs_interp =
            if native_ms > 0.0 { interp_ms / native_ms } else { 0.0 };

        // compile-time weight packing amortization: packing is paid
        // once; this is how many inferences until the one-off cost is
        // below 1% of cumulative execution time
        let amortize_runs = if native_ms > 0.0 {
            (model.packing_ms() / (0.01 * native_ms)).ceil()
        } else {
            0.0
        };

        // static-analyzer coverage: how each nest's write map was
        // certified and how many runtime checks the certificates elide
        // — tracked release over release via the JSON report
        let health = model.health();
        let count = |k: ProofKind| {
            health.nests.iter().filter(|n| n.write_proof == k).count()
        };
        let proof_symbolic = count(ProofKind::Symbolic);
        let proof_enumerated = count(ProofKind::Enumerated);
        let proof_unproven = count(ProofKind::Unproven);
        let race_free = health.nests.iter().filter(|n| n.race_free).count();
        let reads_bounded =
            health.nests.iter().filter(|n| n.reads_bounded).count();

        // hard floor 1: thread-count determinism of whole-model runs
        for threads in [1usize, 2] {
            let m = session(name, threads)
                .plan_with(
                    tuned.plan().decisions(),
                    tuned.plan().scheds(),
                )
                .unwrap()
                .compile()
                .unwrap();
            let (_, out) = m.run_with_output(&inputs).unwrap();
            if bits(&out) != bits(&reference) {
                deterministic = false;
                eprintln!("{name}: threads={threads} diverged");
            }
        }

        // hard floor 2: save/load round trip, no re-tuning
        let dir = std::env::temp_dir()
            .join(format!("alt_bench_serve_{}_{name}", std::process::id()));
        model.save(&dir).unwrap();
        let reloaded = Session::load(&dir)
            .and_then(|t| t.compile())
            .unwrap_or_else(|e| panic!("{name} reload: {e}"));
        let (_, out) = reloaded.run_with_output(&inputs).unwrap();
        if bits(&out) != bits(&reference) {
            roundtrip_ok = false;
            eprintln!("{name}: save/load round trip diverged");
        }
        std::fs::remove_dir_all(&dir).ok();

        println!(
            "{name:>15}: tune {tune_s:>6.1} s | sim {sim_ms:>8.3} ms | \
             native {native_ms:>8.3} ms ({inf_per_sec:.1} inf/s, \
             {fast_vs_interp:.1}x vs interp {interp_ms:.3} ms) | \
             phases nest {nest_ms:.3} + repack {repack_ms:.3} + \
             boundary {boundary_ms:.3} + simple {simple_ms:.3} ms | \
             {} nests + {} simple | {} fused + {} materialized repacks/run | \
             proofs {proof_symbolic} symbolic / {proof_enumerated} enumerated \
             / {proof_unproven} unproven ({race_free} race-free, \
             {reads_bounded} reads bounded) | \
             {}/{} weights packed in {:.1} ms (amortized in {amortize_runs:.0} runs)",
            model.complex_steps(),
            model.simple_steps(),
            model.fused_repacks(),
            model.materialized_repacks(),
            model.weights_packed(),
            model.weights_total(),
            model.packing_ms(),
        );
        rows.push(format!(
            "    {{\"name\": \"{name}\", \"tune_s\": {tune_s:.3}, \
             \"sim_ms\": {sim_ms:.4}, \"native_ms\": {native_ms:.4}, \
             \"interp_ms\": {interp_ms:.4}, \
             \"fast_vs_interp\": {fast_vs_interp:.3}, \
             \"fastpath_identical\": {fastpath_identical}, \
             \"all_fast_paths\": {}, \
             \"inf_per_sec\": {inf_per_sec:.3}, \
             \"nest_ms\": {nest_ms:.4}, \"repack_ms\": {repack_ms:.4}, \
             \"boundary_ms\": {boundary_ms:.4}, \"simple_ms\": {simple_ms:.4}, \
             \"complex_steps\": {}, \"simple_steps\": {}, \
             \"repacks_per_run\": {}, \"repacks_fused\": {}, \
             \"repacks_materialized\": {}, \
             \"proof\": {{\"symbolic\": {proof_symbolic}, \
             \"enumerated\": {proof_enumerated}, \
             \"unproven\": {proof_unproven}, \
             \"race_free\": {race_free}, \
             \"reads_bounded\": {reads_bounded}}}, \
             \"weights_packed\": {}, \
             \"weights_total\": {}, \"packing_ms\": {:.3}, \
             \"compile_ms\": {:.3}, \"amortize_runs\": {amortize_runs:.0}}}",
            model.all_fast_paths(),
            model.complex_steps(),
            model.simple_steps(),
            model.repacks_per_run(),
            model.fused_repacks(),
            model.materialized_repacks(),
            model.weights_packed(),
            model.weights_total(),
            model.packing_ms(),
            model.compile_ms(),
        ));
    }

    let fusion = fusion_demo();
    let degradation = degradation_overhead();
    let rewrite = rewrite_comparison();

    println!("thread determinism:   {deterministic}");
    println!("save/load roundtrip:  {roundtrip_ok}");

    let path = std::env::var("BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let json = format!(
        "{{\n  \"cores\": {cores},\n  \"budget\": {BUDGET},\n  \
         \"requests\": {REQUESTS},\n  \
         \"interp_requests\": {INTERP_REQUESTS},\n  \"models\": [\n{}\n  ],\n  \
         \"fusion_demo\": {fusion},\n  \
         \"degradation_overhead\": {degradation},\n  \
         \"rewrite\": [\n{rewrite}\n  ],\n  \
         \"deterministic\": {deterministic},\n  \
         \"roundtrip_ok\": {roundtrip_ok}\n}}\n",
        rows.join(",\n"),
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("serve report -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    println!("== high-throughput serving (shared model, {cores} cores) ==");
    throughput_report(cores);
}
