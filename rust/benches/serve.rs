//! Bench: end-to-end graph serving through the Session pipeline —
//! tune → compile → run whole models on the native backend.
//!
//! For each serving workload (resnet18 at Small scale, bert_tiny) the
//! bench tunes once, compiles once (constant weights packed into their
//! tuned layouts at compile time), then measures end-to-end graph
//! inferences/sec, a per-phase breakdown (nest exec vs repack vs
//! boundary pack/unpack vs simple-op ms), and the within-run speedup of
//! the compiled fast path over the retained bytecode interpreter
//! (`ExecMode::Bytecode`), which doubles as a bit-identity oracle.
//! Hard invariants checked on any machine: multi-op native execution is
//! bit-identical across thread counts AND across executor modes, and
//! the save/load round trip reproduces the same outputs without
//! re-tuning. A dedicated fusion demo forces a Fig. 5a conversion onto
//! resnet18_small's stem conv and checks the fast path fuses it into
//! the nest's read-side gather (repack copy eliminated) bit-exactly.
//! A degradation demo forces one mid-model nest onto the bytecode
//! interpreter (the per-nest fault ladder's fallback) and reports the
//! within-run throughput ratio against all-fast, which CI gates ≥ 0.7
//! alongside bit-identity of the degraded output.
//!
//! Results go to `BENCH_serve.json` (override with `BENCH_SERVE_JSON`);
//! `scripts/bench_serve.sh` wraps this and CI enforces the hard floors
//! (determinism, round trip, fast-vs-interpreter ratio, fusion) while
//! absolute throughput only warns — shared runners are too noisy for a
//! required absolute-timing gate, but the within-run ratio is immune to
//! machine speed.

use std::collections::HashMap;
use std::time::Instant;

use alt::api::Session;
use alt::autotune::TuneOptions;
use alt::layout::{LayoutSeq, Primitive};
use alt::propagate::ComplexDecision;
use alt::runtime::{DegradeReason, ExecMode};
use alt::sim::HwProfile;

const BUDGET: usize = 200;
const REQUESTS: usize = 8;
/// Bytecode-interpreter requests for the within-run ratio (fewer: the
/// interpreted path is the slow one being measured against).
const INTERP_REQUESTS: usize = 3;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn session(name: &str, threads: usize) -> Session {
    Session::for_model(name)
        .unwrap_or_else(|e| panic!("{e}"))
        .with_profile(HwProfile::intel())
        .with_options(TuneOptions {
            budget: BUDGET,
            seed: 17,
            shards: 0,
            ..Default::default()
        })
        .with_exec_threads(threads)
}

/// Force a conversion operator onto resnet18_small's stem conv input
/// (the graph input allocates identity, so a non-identity read layout
/// guarantees a Fig. 5a repack edge) and report whether the fast path
/// fused it away bit-exactly.
fn fusion_demo() -> String {
    let s = session("resnet18_small", 1);
    let conv1 = s.graph().complex_nodes()[0];
    let mut in_seq = LayoutSeq::new();
    in_seq.push(Primitive::reorder(&[0, 3, 1, 2])); // NHWC -> NCHW read
    let dec = ComplexDecision { node: conv1, in_seq, ..Default::default() };
    let tuned = s
        .plan_with(vec![dec], HashMap::new())
        .unwrap_or_else(|e| panic!("fusion plan: {e}"));
    let mut model = tuned.compile().unwrap_or_else(|e| panic!("{e}"));
    let conversions = model.conversions();
    let fused = model.fused_repacks();
    let materialized = model.materialized_repacks();
    let inputs = model.seeded_inputs(5);
    let (_, a) = model.run_with_output(&inputs).unwrap();
    model.set_exec_mode(ExecMode::Bytecode);
    let (_, b) = model.run_with_output(&inputs).unwrap();
    let identical = bits(&a) == bits(&b);
    println!(
        "fusion demo (resnet18_small stem): {conversions} conversions, \
         {fused} fused / {materialized} materialized, identical {identical}"
    );
    format!(
        "{{\"conversions\": {conversions}, \"fused\": {fused}, \
         \"materialized\": {materialized}, \"identical\": {identical}}}"
    )
}

/// Degradation-ladder overhead: force one mid-model nest of
/// resnet18_small onto the bytecode interpreter (public `degrade_nest`,
/// exactly what the per-nest compile ladder does on a fast-path
/// failure) and measure throughput against the all-fast and
/// all-bytecode endpoints of the ladder. Within-run ratios, so the
/// numbers are immune to runner speed; CI gates `degraded_vs_fast` and
/// `identical` hard.
fn degradation_overhead() -> String {
    let tuned = session("resnet18_small", 0).baseline();
    let mut model = tuned.compile().unwrap_or_else(|e| panic!("{e}"));
    let inputs = model.seeded_inputs(29);

    let (_, reference) = model.run_with_output(&inputs).unwrap(); // warmup
    let t0 = Instant::now();
    for _ in 0..REQUESTS {
        model.run(&inputs).unwrap();
    }
    let fast_inf_s = REQUESTS as f64 / t0.elapsed().as_secs_f64();

    let nests = model.health().nests.len();
    let victim = model.health().nests[nests / 2].node;
    assert!(
        model.degrade_nest(victim, DegradeReason::StreamAnalysis),
        "victim nest not found"
    );
    let (_, degraded_out) = model.run_with_output(&inputs).unwrap(); // warmup
    let identical = bits(&degraded_out) == bits(&reference);
    if !identical {
        eprintln!("degradation demo: degraded nest changed the output");
    }
    let t1 = Instant::now();
    for _ in 0..REQUESTS {
        model.run(&inputs).unwrap();
    }
    let degraded_inf_s = REQUESTS as f64 / t1.elapsed().as_secs_f64();

    model.set_exec_mode(ExecMode::Bytecode);
    model.run(&inputs).unwrap(); // warmup
    let t2 = Instant::now();
    for _ in 0..INTERP_REQUESTS {
        model.run(&inputs).unwrap();
    }
    let bytecode_inf_s = INTERP_REQUESTS as f64 / t2.elapsed().as_secs_f64();

    let ratio =
        if fast_inf_s > 0.0 { degraded_inf_s / fast_inf_s } else { 0.0 };
    println!(
        "degradation overhead (resnet18_small, 1/{nests} nests bytecode): \
         fast {fast_inf_s:.1} inf/s | degraded {degraded_inf_s:.1} inf/s \
         ({ratio:.2}x) | all-bytecode {bytecode_inf_s:.1} inf/s | \
         identical {identical}"
    );
    format!(
        "{{\"nests\": {nests}, \"degraded_nests\": 1, \
         \"fast_inf_per_sec\": {fast_inf_s:.3}, \
         \"degraded_inf_per_sec\": {degraded_inf_s:.3}, \
         \"bytecode_inf_per_sec\": {bytecode_inf_s:.3}, \
         \"degraded_vs_fast\": {ratio:.3}, \"identical\": {identical}}}"
    )
}

fn main() {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut rows: Vec<String> = Vec::new();
    let mut deterministic = true;
    let mut roundtrip_ok = true;

    println!("== whole-model serving (Session pipeline, budget {BUDGET}, {cores} cores) ==");
    for name in ["resnet18_small", "bert_tiny"] {
        let t_tune = Instant::now();
        let tuned = session(name, 0).tune();
        let tune_s = t_tune.elapsed().as_secs_f64();
        let sim_ms = tuned.report().expect("tuned").latency_ms();

        let mut model =
            tuned.compile().unwrap_or_else(|e| panic!("{name}: {e}"));
        let inputs = model.seeded_inputs(33);

        // serving loop: median per-inference latency + throughput,
        // with the per-phase breakdown from the same profiled runs
        let (_, reference) = model.run_with_output(&inputs).unwrap(); // warmup
        let mut times = Vec::with_capacity(REQUESTS);
        let (mut nest, mut repack, mut boundary, mut simple) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        let t0 = Instant::now();
        for _ in 0..REQUESTS {
            let (stats, ph, _) = model.run_profiled(&inputs).unwrap();
            times.push(stats.latency_ms);
            nest.push(ph.nest_ms);
            repack.push(ph.repack_ms);
            boundary.push(ph.boundary_ms);
            simple.push(ph.simple_ms);
        }
        let wall = t0.elapsed().as_secs_f64();
        let native_ms = alt::util::stats::median(&mut times);
        let inf_per_sec = REQUESTS as f64 / wall;
        let nest_ms = alt::util::stats::median(&mut nest);
        let repack_ms = alt::util::stats::median(&mut repack);
        let boundary_ms = alt::util::stats::median(&mut boundary);
        let simple_ms = alt::util::stats::median(&mut simple);

        // within-run fast-vs-interpreter ratio on the SAME compiled
        // model: flip the executor mode, re-measure, flip back. The
        // interpreter run is also the fast path's bit-identity oracle.
        model.set_exec_mode(ExecMode::Bytecode);
        let (_, interp_out) = model.run_with_output(&inputs).unwrap(); // warmup
        let fastpath_identical = bits(&interp_out) == bits(&reference);
        if !fastpath_identical {
            eprintln!("{name}: fast path diverged from bytecode oracle");
        }
        let mut itimes = Vec::with_capacity(INTERP_REQUESTS);
        for _ in 0..INTERP_REQUESTS {
            itimes.push(model.run(&inputs).unwrap().latency_ms);
        }
        let interp_ms = alt::util::stats::median(&mut itimes);
        model.set_exec_mode(ExecMode::Fast);
        let fast_vs_interp =
            if native_ms > 0.0 { interp_ms / native_ms } else { 0.0 };

        // compile-time weight packing amortization: packing is paid
        // once; this is how many inferences until the one-off cost is
        // below 1% of cumulative execution time
        let amortize_runs = if native_ms > 0.0 {
            (model.packing_ms() / (0.01 * native_ms)).ceil()
        } else {
            0.0
        };

        // hard floor 1: thread-count determinism of whole-model runs
        for threads in [1usize, 2] {
            let m = session(name, threads)
                .plan_with(
                    tuned.plan().decisions(),
                    tuned.plan().scheds(),
                )
                .unwrap()
                .compile()
                .unwrap();
            let (_, out) = m.run_with_output(&inputs).unwrap();
            if bits(&out) != bits(&reference) {
                deterministic = false;
                eprintln!("{name}: threads={threads} diverged");
            }
        }

        // hard floor 2: save/load round trip, no re-tuning
        let dir = std::env::temp_dir()
            .join(format!("alt_bench_serve_{}_{name}", std::process::id()));
        model.save(&dir).unwrap();
        let reloaded = Session::load(&dir)
            .and_then(|t| t.compile())
            .unwrap_or_else(|e| panic!("{name} reload: {e}"));
        let (_, out) = reloaded.run_with_output(&inputs).unwrap();
        if bits(&out) != bits(&reference) {
            roundtrip_ok = false;
            eprintln!("{name}: save/load round trip diverged");
        }
        std::fs::remove_dir_all(&dir).ok();

        println!(
            "{name:>15}: tune {tune_s:>6.1} s | sim {sim_ms:>8.3} ms | \
             native {native_ms:>8.3} ms ({inf_per_sec:.1} inf/s, \
             {fast_vs_interp:.1}x vs interp {interp_ms:.3} ms) | \
             phases nest {nest_ms:.3} + repack {repack_ms:.3} + \
             boundary {boundary_ms:.3} + simple {simple_ms:.3} ms | \
             {} nests + {} simple | {} fused + {} materialized repacks/run | \
             {}/{} weights packed in {:.1} ms (amortized in {amortize_runs:.0} runs)",
            model.complex_steps(),
            model.simple_steps(),
            model.fused_repacks(),
            model.materialized_repacks(),
            model.weights_packed(),
            model.weights_total(),
            model.packing_ms(),
        );
        rows.push(format!(
            "    {{\"name\": \"{name}\", \"tune_s\": {tune_s:.3}, \
             \"sim_ms\": {sim_ms:.4}, \"native_ms\": {native_ms:.4}, \
             \"interp_ms\": {interp_ms:.4}, \
             \"fast_vs_interp\": {fast_vs_interp:.3}, \
             \"fastpath_identical\": {fastpath_identical}, \
             \"all_fast_paths\": {}, \
             \"inf_per_sec\": {inf_per_sec:.3}, \
             \"nest_ms\": {nest_ms:.4}, \"repack_ms\": {repack_ms:.4}, \
             \"boundary_ms\": {boundary_ms:.4}, \"simple_ms\": {simple_ms:.4}, \
             \"complex_steps\": {}, \"simple_steps\": {}, \
             \"repacks_per_run\": {}, \"repacks_fused\": {}, \
             \"repacks_materialized\": {}, \"weights_packed\": {}, \
             \"weights_total\": {}, \"packing_ms\": {:.3}, \
             \"compile_ms\": {:.3}, \"amortize_runs\": {amortize_runs:.0}}}",
            model.all_fast_paths(),
            model.complex_steps(),
            model.simple_steps(),
            model.repacks_per_run(),
            model.fused_repacks(),
            model.materialized_repacks(),
            model.weights_packed(),
            model.weights_total(),
            model.packing_ms(),
            model.compile_ms(),
        ));
    }

    let fusion = fusion_demo();
    let degradation = degradation_overhead();

    println!("thread determinism:   {deterministic}");
    println!("save/load roundtrip:  {roundtrip_ok}");

    let path = std::env::var("BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let json = format!(
        "{{\n  \"cores\": {cores},\n  \"budget\": {BUDGET},\n  \
         \"requests\": {REQUESTS},\n  \
         \"interp_requests\": {INTERP_REQUESTS},\n  \"models\": [\n{}\n  ],\n  \
         \"fusion_demo\": {fusion},\n  \
         \"degradation_overhead\": {degradation},\n  \
         \"deterministic\": {deterministic},\n  \
         \"roundtrip_ok\": {roundtrip_ok}\n}}\n",
        rows.join(",\n"),
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("serve report -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
