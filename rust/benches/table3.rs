//! Bench: regenerate Table 3 — simulated hardware counters (#inst,
//! L1 loads/misses/stores, latency) of the §7.3.3 case study under
//! NHWO / NOHW / N(O/ot)HWot / searched-tiled layouts.
//! Acceptance shape: tiled layout has the fewest misses + lowest
//! latency; NOHW has the most instructions.

use alt::bench::figures::{table3, Scale};
use alt::bench::harness::time_fn;

fn main() {
    let scale = Scale::quick();
    let ms = time_fn(|| table3(&scale).print(), 1);
    println!("[bench table3] wall time {ms:.0} ms");
}
