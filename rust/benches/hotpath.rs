//! Bench: tuner hot-path microbenchmarks (the §Perf targets).
//!
//! Measures the three dominant costs of one "measurement" unit:
//! program lowering (codegen), simulation (device model), and
//! cost-model feature extraction + prediction — plus the end-to-end
//! measurements/second the tuner achieves. EXPERIMENTS.md §Perf
//! tracks these numbers before/after optimization.

use alt::bench::harness::time_fn;
use alt::codegen::{lower_complex, LayoutAssignment};
use alt::cost::CostModel;
use alt::graph::models;
use alt::loops::LoopSchedule;
use alt::sim::{simulate_program, HwProfile};

fn main() {
    let g = models::case_study();
    let conv = g.complex_nodes()[0];
    let hw = HwProfile::intel();
    let layouts = LayoutAssignment::identity(&g);
    let mut sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
    sched.spatial_tiles = vec![1, 4, 16, 16];
    sched.vectorize = true;
    sched.parallel = 2;

    const N: usize = 200;
    let lower_ms = time_fn(
        || {
            for _ in 0..N {
                std::hint::black_box(lower_complex(
                    &g, conv, &layouts, &sched, &[], hw.simd_lanes,
                ));
            }
        },
        5,
    ) / N as f64;

    let p = lower_complex(&g, conv, &layouts, &sched, &[], hw.simd_lanes);
    let sim_ms = time_fn(
        || {
            for _ in 0..N {
                std::hint::black_box(simulate_program(&p, &hw));
            }
        },
        5,
    ) / N as f64;

    let mut cm = CostModel::new();
    for i in 0..64 {
        cm.observe(&p, 1.0 + (i % 7) as f64 * 0.1);
    }
    cm.retrain();
    let predict_ms = time_fn(
        || {
            for _ in 0..N {
                std::hint::black_box(cm.predict(&p));
            }
        },
        5,
    ) / N as f64;

    let per_meas = lower_ms + sim_ms + predict_ms;
    println!("== hotpath (per-unit costs) ==");
    println!("lower_complex:   {:.3} ms", lower_ms);
    println!("simulate:        {:.3} ms", sim_ms);
    println!("cost predict:    {:.3} ms", predict_ms);
    println!("per-measurement: {:.3} ms  ({:.0} measurements/s)",
        per_meas, 1000.0 / per_meas);

    // end-to-end: one tuning round of the real tuner
    let t0 = std::time::Instant::now();
    let opts = alt::autotune::TuneOptions {
        budget: 48,
        ..Default::default()
    };
    let r = alt::autotune::tuner::tune_op(&g, conv, &hw, &opts);
    let el = t0.elapsed().as_secs_f64();
    println!(
        "tune_op(48 measurements): {:.2} s  ({:.0} meas/s), best {:.4} ms",
        el,
        r.measurements as f64 / el,
        r.best_ms
    );
}
