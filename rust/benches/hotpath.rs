//! Bench: tuner hot-path microbenchmarks (the §Perf targets).
//!
//! Measures the three dominant costs of one "measurement" unit:
//! program lowering (codegen), simulation (device model), and
//! cost-model feature extraction + prediction — plus the throughput of
//! the candidate-evaluation engine (candidates/sec for the full
//! `lower → featurize → predict → simulate` pipeline, serial vs
//! parallel vs memo-warm) and the end-to-end measurements/second the
//! tuner achieves. Engine numbers are also written to
//! `BENCH_engine.json` (override the path with `BENCH_ENGINE_JSON`);
//! `scripts/bench_engine.sh` wraps this.

use std::collections::HashSet;
use std::time::Instant;

use alt::autotune::LoopSpace;
use alt::bench::harness::time_fn;
use alt::codegen::{lower_complex, LayoutAssignment};
use alt::cost::CostModel;
use alt::engine::{Engine, EvalContext};
use alt::graph::models;
use alt::loops::LoopSchedule;
use alt::propagate::{propagate, PropMode};
use alt::sim::{simulate_program, HwProfile};
use alt::util::Rng;

fn main() {
    let g = models::case_study();
    let conv = g.complex_nodes()[0];
    let hw = HwProfile::intel();
    let layouts = LayoutAssignment::identity(&g);
    let mut sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
    sched.spatial_tiles = vec![1, 4, 16, 16];
    sched.vectorize = true;
    sched.parallel = 2;

    const N: usize = 200;
    let lower_ms = time_fn(
        || {
            for _ in 0..N {
                std::hint::black_box(lower_complex(
                    &g, conv, &layouts, &sched, &[], hw.simd_lanes,
                ));
            }
        },
        5,
    ) / N as f64;

    let p = lower_complex(&g, conv, &layouts, &sched, &[], hw.simd_lanes);
    let sim_ms = time_fn(
        || {
            for _ in 0..N {
                std::hint::black_box(simulate_program(&p, &hw));
            }
        },
        5,
    ) / N as f64;

    let mut cm = CostModel::new();
    for i in 0..64 {
        cm.observe(&p, 1.0 + (i % 7) as f64 * 0.1);
    }
    cm.retrain();
    let predict_ms = time_fn(
        || {
            for _ in 0..N {
                std::hint::black_box(cm.predict(&p));
            }
        },
        5,
    ) / N as f64;

    let per_meas = lower_ms + sim_ms + predict_ms;
    println!("== hotpath (per-unit costs) ==");
    println!("lower_complex:   {:.3} ms", lower_ms);
    println!("simulate:        {:.3} ms", sim_ms);
    println!("cost predict:    {:.3} ms", predict_ms);
    println!("per-measurement: {:.3} ms  ({:.0} measurements/s)",
        per_meas, 1000.0 / per_meas);

    // --- engine throughput: candidates/sec through the full pipeline ---
    // distinct candidates so cold runs contain no accidental memo hits
    let prop = propagate(&g, &[], PropMode::Alt);
    let space = LoopSpace::new(&[1, 112, 112, 64], &[3, 7, 7]);
    let mut rng = Rng::new(7);
    let mut seen = HashSet::new();
    let mut cands: Vec<LoopSchedule> = Vec::new();
    while cands.len() < 256 {
        let pt = space.random_point(&mut rng);
        if seen.insert(pt.clone()) {
            cands.push(space.decode(&pt));
        }
    }
    let ctx = EvalContext::new(&g, conv, &prop, &hw);
    let n = cands.len() as f64;

    let bench_engine = |engine: &Engine| -> f64 {
        let t0 = Instant::now();
        std::hint::black_box(engine.pipeline_batch(&ctx, &cands, &cm));
        n / t0.elapsed().as_secs_f64()
    };

    // untimed warm-up pass on a throwaway engine: populates the
    // process-global expr interner / simplify memo so the timed serial
    // and parallel runs see identical global-cache state — the
    // speedup then isolates threading, not cache warmth. Each timed
    // engine still starts with a cold candidate memo of its own.
    Engine::serial().pipeline_batch(&ctx, &cands, &cm);

    let serial = Engine::serial();
    let serial_cps = bench_engine(&serial);
    let parallel = Engine::new(0);
    let parallel_cps = bench_engine(&parallel);
    let before_warm = parallel.stats();
    let warm_cps = bench_engine(&parallel); // same engine: 100% memo hits
    let speedup = parallel_cps / serial_cps;
    let warm_stats = parallel.stats().since(&before_warm); // warm-run delta

    println!("\n== engine (candidates/sec, {} candidates) ==", cands.len());
    println!("serial (1 thread):      {:.0} cand/s", serial_cps);
    println!(
        "parallel ({} threads):  {:.0} cand/s  ({:.2}x)",
        parallel.threads(),
        parallel_cps,
        speedup
    );
    println!(
        "memo-warm re-run:       {:.0} cand/s  (hit rate {:.0}%)",
        warm_cps,
        warm_stats.hit_rate() * 100.0
    );

    // end-to-end: one tuning run of the real tuner (parallel engine;
    // the serial-walk vs speculative comparison lives in the `tuner`
    // bench — scripts/bench_tuner.sh)
    let t0 = std::time::Instant::now();
    let opts = alt::autotune::TuneOptions {
        budget: 48,
        ..Default::default()
    };
    let r = alt::autotune::tuner::tune_op(&g, conv, &hw, &opts);
    let el = t0.elapsed().as_secs_f64();
    let tune_meas_per_s = r.measurements as f64 / el;
    let tune_rounds_per_s = r.rounds as f64 / el;
    println!(
        "\ntune_op(48 measurements): {:.2} s  ({:.0} meas/s, {:.1} rounds/s), \
         best {:.4} ms, memo hit rate {:.0}%",
        el,
        tune_meas_per_s,
        tune_rounds_per_s,
        r.best_ms,
        r.engine.hit_rate() * 100.0
    );

    // machine-readable report for scripts/bench_engine.sh / CI trending
    let path = std::env::var("BENCH_ENGINE_JSON")
        .unwrap_or_else(|_| "BENCH_engine.json".to_string());
    let json = format!(
        "{{\n  \"candidates\": {},\n  \"threads\": {},\n  \
         \"serial_cand_per_sec\": {:.1},\n  \
         \"parallel_cand_per_sec\": {:.1},\n  \
         \"parallel_speedup\": {:.3},\n  \
         \"memo_warm_cand_per_sec\": {:.1},\n  \
         \"memo_hit_rate\": {:.4},\n  \
         \"tune_op_meas_per_sec\": {:.1},\n  \
         \"tune_op_rounds_per_sec\": {:.2},\n  \
         \"tune_op_memo_hit_rate\": {:.4},\n  \
         \"lower_ms\": {:.4},\n  \"simulate_ms\": {:.4},\n  \
         \"predict_ms\": {:.4}\n}}\n",
        cands.len(),
        parallel.threads(),
        serial_cps,
        parallel_cps,
        speedup,
        warm_cps,
        warm_stats.hit_rate(),
        tune_meas_per_s,
        tune_rounds_per_s,
        r.engine.hit_rate(),
        lower_ms,
        sim_ms,
        predict_ms,
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("engine report -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
