//! Bench: regenerate Fig. 1 — C2D latency under NOHW / NHWO / HWON
//! fixed layouts, loop-tuned, on every hardware profile.
//! Acceptance shape (DESIGN.md): best layout beats worst by >30% on
//! average; no layout wins everywhere.

use alt::bench::figures::{fig1, Scale};
use alt::bench::harness::time_fn;

fn main() {
    let scale = Scale::quick();
    let ms = time_fn(
        || {
            for t in fig1(&scale) {
                t.print();
                println!();
            }
        },
        1,
    );
    println!("[bench fig1] wall time {ms:.0} ms");
}
