//! Bench: regenerate Fig. 11 — layout-propagation overhead ablation
//! (Ansor / ALT-FP / ALT-BP / ALT on the two §7.3.1 subgraphs).
//! Acceptance shape: ALT beats both forced-sharing variants; the
//! standalone conversion cost stays small relative to the gains.

use alt::bench::figures::{fig11, Scale};
use alt::bench::harness::time_fn;

fn main() {
    let scale = Scale::quick();
    let ms = time_fn(|| fig11(&scale).print(), 1);
    println!("[bench fig11] wall time {ms:.0} ms");
}
