//! Bench: regenerate Fig. 12 — parameter sensitivity of template
//! tiling levels vs budget.
//! Acceptance shape: 1-level@B >= 2-level@B; 2-level@1.5B >= 1-level@B.

use alt::bench::figures::{fig12, Scale};
use alt::bench::harness::time_fn;

fn main() {
    let scale = Scale::quick();
    let ms = time_fn(|| fig12(&scale).print(), 1);
    println!("[bench fig12] wall time {ms:.0} ms");
}
