//! Bench: regenerate Table 2 — exact cache-simulator L1 miss counts of
//! layout tiling vs loop tiling under a Cortex-A76-like prefetcher.
//! Acceptance shape: layout-tiled misses ≈ size/(line·prefetch) and
//! never exceed the loop-tiled misses.

use alt::bench::figures::table2;
use alt::bench::harness::time_fn;

fn main() {
    let ms = time_fn(|| table2().print(), 3);
    println!("[bench table2] wall time {ms:.2} ms");
}
