//! Bench: regenerate Fig. 10 — end-to-end network latency for
//! vendor / Ansor-like / ALT-OL / ALT-WP / ALT.
//! Acceptance shape: ALT > ALT-WP > ALT-OL ≈ Ansor-like geomean;
//! smallest margin on compute-bound R3D, largest on MV2.

use alt::bench::figures::{fig10, Scale};
use alt::bench::harness::time_fn;

fn main() {
    let scale = Scale::quick();
    let ms = time_fn(
        || {
            for t in fig10(&scale, true) {
                t.print();
                println!();
            }
        },
        1,
    );
    println!("[bench fig10] wall time {ms:.0} ms");
}
