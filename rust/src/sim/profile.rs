//! Hardware profiles for the simulated device.
//!
//! Parameterised after the paper's three platforms (§7): a many-core
//! Intel Xeon-class server CPU, an NVIDIA V100-class GPU, and a Kirin
//! 990-class ARM SoC. Numbers are order-of-magnitude calibrations — the
//! tuner only compares candidates *within* one profile, and the figures
//! report speedup ratios, so only relative structure matters
//! (lane width, cache sizes, prefetch depth, core count).

/// A simulated device description.
#[derive(Clone, Debug)]
pub struct HwProfile {
    pub name: &'static str,
    /// f32 SIMD lanes (AVX-512: 16, warp: 32, NEON: 4).
    pub simd_lanes: i64,
    /// Parallel hardware units (cores / SMs / big cores).
    pub cores: i64,
    /// FMA issue ports per core (each retiring `simd_lanes` MACs).
    pub fma_ports: f64,
    pub freq_ghz: f64,
    pub l1_bytes: i64,
    pub l2_bytes: i64,
    pub line_bytes: i64,
    /// Contiguous lines fetched per demand miss (hardware prefetch /
    /// coalescing depth). Table 2 measures 4 on Cortex-A76.
    pub prefetch_lines: i64,
    /// Average cost (cycles) of an L1 hit per SIMD bundle.
    pub l1_cost: f64,
    /// L2 hit latency in cycles (per line).
    pub l2_latency: f64,
    /// DRAM latency per line in cycles (before prefetch amortization).
    pub mem_latency: f64,
    /// Fraction of DRAM latency exposed for streaming (overlap factor).
    pub mem_overlap: f64,
    /// Memory parallelism cap: cores beyond this do not add bandwidth.
    pub bw_saturation_cores: f64,
    /// Fixed per-program launch overhead (kernel launch / loop setup).
    pub launch_overhead_ms: f64,
}

impl HwProfile {
    /// Effective per-line DRAM cost after overlap.
    pub fn mem_latency_eff(&self) -> f64 {
        self.mem_latency * self.mem_overlap
    }

    /// 40-core Intel Xeon Gold-class (AVX-512).
    pub fn intel() -> Self {
        Self {
            name: "intel",
            simd_lanes: 16,
            cores: 40,
            fma_ports: 2.0,
            freq_ghz: 2.5,
            l1_bytes: 32 * 1024,
            l2_bytes: 1024 * 1024,
            line_bytes: 64,
            prefetch_lines: 4,
            l1_cost: 0.5,
            l2_latency: 14.0,
            mem_latency: 80.0,
            mem_overlap: 0.35,
            bw_saturation_cores: 8.0,
            launch_overhead_ms: 2e-3,
        }
    }

    /// NVIDIA V100-class: 80 SMs modeled as cores, 32-lane warps,
    /// coalescing modeled as deep prefetch over 128B lines.
    pub fn gpu() -> Self {
        Self {
            name: "gpu",
            simd_lanes: 32,
            cores: 80,
            fma_ports: 2.0,
            freq_ghz: 1.4,
            l1_bytes: 96 * 1024,  // shared memory + L1
            l2_bytes: 6 * 1024 * 1024,
            line_bytes: 128,
            prefetch_lines: 8,
            l1_cost: 0.25,
            l2_latency: 30.0,
            mem_latency: 120.0,
            mem_overlap: 0.15, // deep memory-level parallelism
            bw_saturation_cores: 40.0,
            launch_overhead_ms: 5e-3,
        }
    }

    /// Kirin 990-class ARM big cores (Cortex-A76, NEON).
    pub fn arm() -> Self {
        Self {
            name: "arm",
            simd_lanes: 4,
            cores: 4,
            fma_ports: 2.0,
            freq_ghz: 2.6,
            l1_bytes: 64 * 1024,
            l2_bytes: 512 * 1024,
            line_bytes: 64,
            prefetch_lines: 4, // measured in the paper's Table 2
            l1_cost: 0.5,
            l2_latency: 12.0,
            mem_latency: 100.0,
            mem_overlap: 0.5,
            bw_saturation_cores: 2.0,
            launch_overhead_ms: 1e-3,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "intel" => Some(Self::intel()),
            "gpu" => Some(Self::gpu()),
            "arm" => Some(Self::arm()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::intel(), Self::gpu(), Self::arm()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_roundtrip() {
        for hw in HwProfile::all() {
            let again = HwProfile::by_name(hw.name).unwrap();
            assert_eq!(again.simd_lanes, hw.simd_lanes);
        }
        assert!(HwProfile::by_name("tpu").is_none());
    }

    #[test]
    fn profiles_are_distinct() {
        let i = HwProfile::intel();
        let g = HwProfile::gpu();
        let a = HwProfile::arm();
        assert!(i.simd_lanes != g.simd_lanes && g.simd_lanes != a.simd_lanes);
        assert!(a.cores < i.cores);
    }
}
