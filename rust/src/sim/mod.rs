//! The simulated device (DESIGN.md §Hardware-Adaptation).
//!
//! The paper measures candidate programs on Intel Xeon, NVIDIA GPU and
//! Kirin ARM hardware. This sandbox has none of those, so "on-device
//! measurement" is replaced by an analytic machine model that captures
//! the mechanisms the paper's layout tuning works through:
//!
//! * **cache behaviour** — per-loop-level footprint analysis finds the
//!   reuse level of every operand; distinct-line counts model L1/L2
//!   misses (Table 3 counters fall out of this directly);
//! * **hardware prefetching** — sequential runs of cache lines amortize
//!   misses by the prefetch depth (the Table 2 experiment: layout-tiled
//!   contiguous blocks beat loop-tiled strided blocks);
//! * **SIMD bundling** — the vectorized innermost loop only pays off
//!   when the accesses it drives are unit-stride;
//! * **parallelism** — `parallel`-annotated loops scale compute up to
//!   the core count, memory up to the bandwidth saturation point.
//!
//! The model is *relative-accuracy* oriented: the tuner only ever
//! compares candidates, so what must be right is the ranking and the
//! rough magnitude of ratios — exactly the acceptance criteria listed in
//! DESIGN.md. The [`cache`] submodule additionally provides an *exact*
//! trace-driven cache+prefetch simulator used by the Table 2
//! reproduction and as a golden reference for the analytic line counts.

pub mod cache;
pub mod netsim;
pub mod profile;

pub use profile::HwProfile;

use crate::codegen::Program;
use crate::loops::{Annotation, LoopKind};

/// Simulated execution report (raw counts; latency in milliseconds).
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub latency_ms: f64,
    pub cycles_compute: f64,
    pub cycles_mem: f64,
    pub instructions: f64,
    pub l1_loads: f64,
    pub l1_stores: f64,
    pub l1_misses: f64,
    pub l2_misses: f64,
    pub flops: f64,
    pub parallel_speedup: f64,
}

impl SimReport {
    /// Combine sequential stages (graph-level summation).
    pub fn accumulate(&mut self, other: &SimReport) {
        self.latency_ms += other.latency_ms;
        self.cycles_compute += other.cycles_compute;
        self.cycles_mem += other.cycles_mem;
        self.instructions += other.instructions;
        self.l1_loads += other.l1_loads;
        self.l1_stores += other.l1_stores;
        self.l1_misses += other.l1_misses;
        self.l2_misses += other.l2_misses;
        self.flops += other.flops;
    }
}

/// Per-(access, loop) dependence info, evaluated numerically from the
/// flattened address expression.
#[derive(Clone, Debug)]
struct VarDep {
    /// Representative address delta for a unit step of the loop var.
    stride: i64,
    /// Distinct address values over the loop's full extent.
    distinct: i64,
}

fn analyze_access(flat: &crate::expr::Expr, extents: &[i64]) -> Vec<VarDep> {
    // Midpoint environment avoids clamp boundaries (min-exprs).
    let mid: Vec<i64> = extents.iter().map(|&e| (e - 1) / 2).collect();
    let deps = flat.vars();
    (0..extents.len())
        .map(|v| {
            if !deps.contains(&v) || extents[v] <= 1 {
                return VarDep { stride: 0, distinct: 1 };
            }
            let mut env = mid.clone();
            env[v] = 0;
            let at0 = flat.eval(&env);
            env[v] = 1;
            let at1 = flat.eval(&env);
            env[v] = extents[v] - 1;
            let atn = flat.eval(&env);
            let step = (at1 - at0).abs();
            let total = (atn - at0).abs();
            if total == 0 {
                VarDep { stride: 0, distinct: 1 }
            } else if step == 0 {
                // div-pattern: the address moves once every k steps
                let distinct = (total + 1).min(extents[v]);
                let eff =
                    (total as f64 / (extents[v] - 1) as f64).ceil() as i64;
                VarDep { stride: eff.max(1), distinct }
            } else {
                let distinct = (total / step + 1).min(extents[v]);
                VarDep { stride: step, distinct }
            }
        })
        .collect()
}

/// Footprint of one access over the inner loop suffix `order[from..]`:
/// `(distinct elements, contiguous run length in elements)`.
fn footprint(deps: &[VarDep], order: &[usize], from: usize) -> (f64, f64) {
    let mut elems = 1.0;
    for &l in &order[from..] {
        elems *= deps[l].distinct as f64;
    }
    // Contiguous run: grow the run by absorbing loops whose stride fits
    // inside the current run length (densest-chain heuristic).
    let mut chain: Vec<&VarDep> = order[from..]
        .iter()
        .map(|&l| &deps[l])
        .filter(|d| d.stride > 0 && d.distinct > 1)
        .collect();
    chain.sort_by_key(|d| d.stride);
    let mut run = 1.0;
    for d in chain {
        if d.stride as f64 <= run {
            run = run.max(d.stride as f64 * d.distinct as f64);
        }
    }
    (elems, run.min(elems))
}

/// Analytic simulation of one generated tensor program.
pub fn simulate_program(p: &Program, hw: &HwProfile) -> SimReport {
    let extents: Vec<i64> = p.loops.iter().map(|l| l.extent).collect();
    let n_loops = extents.len();
    let order: Vec<usize> = (0..n_loops).collect();
    let total_iters = p.total_iters();

    // --- vectorization ---
    let vec_loop = p.loops.iter().position(|l| l.ann == Annotation::Vectorize);
    let lane_elems = hw.simd_lanes;
    let mut vec_eff = 1.0; // 1.0 == scalar
    struct Acc {
        deps: Vec<VarDep>,
        bytes: i64,
        is_write: bool,
        gather: bool,
    }
    let mut accs: Vec<Acc> = p
        .accesses
        .iter()
        .map(|a| Acc {
            deps: analyze_access(&a.flat(), &extents),
            bytes: a.elem_bytes,
            is_write: a.is_write,
            gather: false,
        })
        .collect();
    if let Some(vl) = vec_loop {
        let e = extents[vl] as f64;
        let lanes = lane_elems as f64;
        let util = e / (lanes * (e / lanes).ceil());
        // Writes must be unit-stride along the vector loop to vectorize.
        let w_ok = accs
            .iter()
            .filter(|a| a.is_write)
            .all(|a| a.deps[vl].stride <= 1);
        if w_ok {
            vec_eff = (lanes * util).max(1.0);
            for a in &mut accs {
                a.gather = a.deps[vl].stride > 1;
            }
        }
    }

    // --- cache-level reuse: find fit level for L1 and L2 ---
    let fit_level = |cap_bytes: i64| -> usize {
        for l in 0..n_loops {
            let total: f64 = accs
                .iter()
                .map(|a| {
                    let (e, _) = footprint(&a.deps, &order, l);
                    e * a.bytes as f64
                })
                .sum();
            if total <= cap_bytes as f64 {
                return l;
            }
        }
        n_loops
    };
    let l1_level = fit_level(hw.l1_bytes);
    let l2_level = fit_level(hw.l2_bytes);

    // --- misses per access at a given fit level ---
    let line = hw.line_bytes as f64;
    let misses_at = |a: &Acc, level: usize, prefetch: i64| -> f64 {
        let (elems, run) = footprint(&a.deps, &order, level);
        let lines_per_run = ((run * a.bytes as f64) / line).ceil().max(1.0);
        let runs = (elems / run).max(1.0);
        // Sequential prefetchers need a sustained stream to train; a
        // run must span several prefetch windows before misses amortize
        // fully (this is the Table 2 effect: strided short rows defeat
        // the prefetcher even when each row covers a few lines).
        let pf = prefetch as f64;
        let pf_eff = if lines_per_run >= 2.0 * pf {
            pf
        } else if lines_per_run >= pf {
            (pf / 2.0).max(1.0)
        } else {
            1.0
        };
        let demand = runs * (lines_per_run / pf_eff).ceil().max(1.0);
        // Outer *dependent* trips re-stream the footprint.
        let outer: f64 = order[..level]
            .iter()
            .map(|&l| a.deps[l].distinct as f64)
            .product();
        outer * demand
    };

    let mut l1_misses = 0.0;
    let mut l2_misses = 0.0;
    for a in &accs {
        let pf = if a.gather { 1 } else { hw.prefetch_lines };
        l1_misses += misses_at(a, l1_level, pf);
        l2_misses += misses_at(a, l2_level.max(l1_level), pf);
    }
    l2_misses = l2_misses.min(l1_misses);

    // --- instruction / load-store counts ---
    // Each access issues one op per iteration of the loops it actually
    // depends on (loop-invariant operands are register-hoisted — this
    // is what makes compute_at fusion profitable: the fused tail's
    // operands depend only on the spatial loops, not the reductions).
    // SIMD bundles unit-stride accesses along the vectorized loop;
    // gathers fall back to per-lane scalar loads.
    let mut l1_loads = 0.0;
    let mut l1_stores = 0.0;
    for a in &accs {
        let mut dep_iters = 1.0;
        let mut vec_bundle = 1.0;
        for (v, d) in a.deps.iter().enumerate() {
            if d.stride != 0 || d.distinct > 1 {
                dep_iters *= extents[v] as f64;
                if Some(v) == vec_loop && d.stride <= 1 {
                    vec_bundle = vec_eff;
                }
            }
        }
        let ops = dep_iters / vec_bundle;
        if a.is_write {
            l1_stores += ops;
        } else {
            l1_loads += ops;
        }
    }
    let flops = p.total_flops();
    let compute_insts = total_iters * p.flops_per_iter / 2.0 / vec_eff;
    let unrolled: f64 = p
        .loops
        .iter()
        .filter(|l| l.ann == Annotation::Unroll)
        .map(|l| l.extent as f64)
        .product();
    let loop_overhead = 0.15 * total_iters / vec_eff / unrolled.max(1.0);
    let instructions = compute_insts + l1_loads + l1_stores + loop_overhead;

    // --- cycle model ---
    let cycles_compute =
        (total_iters * p.flops_per_iter) / (2.0 * vec_eff * hw.fma_ports);
    let cycles_l1 = (l1_loads + l1_stores) * hw.l1_cost;
    let cycles_l1_miss = (l1_misses - l2_misses).max(0.0) * hw.l2_latency;
    let cycles_dram = l2_misses * hw.mem_latency_eff();
    let mem_total = cycles_l1 + cycles_l1_miss + cycles_dram;

    // --- parallel scaling ---
    let par_extent: f64 = p
        .loops
        .iter()
        .filter(|l| l.ann == Annotation::Parallel && l.kind == LoopKind::Spatial)
        .map(|l| l.extent as f64)
        .product();
    let cores = hw.cores as f64;
    let comp_speedup = if par_extent > 1.0 {
        let used = par_extent.min(cores);
        // imbalance when the parallel extent doesn't divide the cores
        used * (par_extent / (used * (par_extent / used).ceil()))
    } else {
        1.0
    };
    let mem_speedup = comp_speedup.min(hw.bw_saturation_cores);

    let cycles = (cycles_compute / comp_speedup).max(mem_total / mem_speedup)
        + 0.1 * (cycles_compute / comp_speedup + mem_total / mem_speedup);
    let latency_ms = cycles / (hw.freq_ghz * 1e9) * 1e3 + hw.launch_overhead_ms;

    SimReport {
        latency_ms,
        cycles_compute,
        cycles_mem: mem_total,
        instructions,
        l1_loads,
        l1_stores,
        l1_misses,
        l2_misses,
        flops,
        parallel_speedup: comp_speedup,
    }
}

/// Streaming cost for non-complex ops (elementwise not fused, padding,
/// pooling, softmax, layout conversions): one pass of reads + writes at
/// (possibly strided) streaming bandwidth.
pub fn simulate_streaming(
    bytes_read: f64,
    bytes_written: f64,
    contiguous: bool,
    hw: &HwProfile,
) -> SimReport {
    let line = hw.line_bytes as f64;
    let pf = if contiguous { hw.prefetch_lines as f64 } else { 1.0 };
    let lines = (bytes_read + bytes_written) / line;
    let misses = (lines / pf).max(1.0);
    let mem_cycles = misses * hw.mem_latency_eff();
    let elems = (bytes_read + bytes_written) / 4.0;
    let compute_cycles = elems / hw.simd_lanes as f64;
    let speedup = hw.bw_saturation_cores;
    let cycles = (mem_cycles / speedup).max(compute_cycles / hw.cores as f64);
    SimReport {
        latency_ms: cycles / (hw.freq_ghz * 1e9) * 1e3 + hw.launch_overhead_ms,
        cycles_compute: compute_cycles,
        cycles_mem: mem_cycles,
        instructions: elems / hw.simd_lanes as f64 * 2.0,
        l1_loads: bytes_read / 4.0 / hw.simd_lanes as f64,
        l1_stores: bytes_written / 4.0 / hw.simd_lanes as f64,
        l1_misses: lines,
        l2_misses: misses,
        flops: elems,
        parallel_speedup: speedup,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{lower_complex, LayoutAssignment};
    use crate::graph::models;
    use crate::layout::{LayoutSeq, Primitive};
    use crate::loops::LoopSchedule;

    fn case_program(
        layouts: &LayoutAssignment,
        sched: &LoopSchedule,
        hw: &HwProfile,
    ) -> Program {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        lower_complex(&g, conv, layouts, sched, &[], hw.simd_lanes)
    }

    #[test]
    fn vectorized_beats_scalar() {
        let g = models::case_study();
        let hw = HwProfile::intel();
        let layouts = LayoutAssignment::identity(&g);
        let mut scalar = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        scalar.spatial_tiles = vec![1, 4, 4, 16];
        let mut vect = scalar.clone();
        vect.vectorize = true;
        let sp = simulate_program(&case_program(&layouts, &scalar, &hw), &hw);
        let sv = simulate_program(&case_program(&layouts, &vect, &hw), &hw);
        assert!(
            sv.latency_ms < sp.latency_ms * 0.5,
            "vectorize speedup too small: {} vs {}",
            sv.latency_ms,
            sp.latency_ms
        );
        let _ = g;
    }

    #[test]
    fn parallel_scales() {
        let hw = HwProfile::intel();
        let g = models::case_study();
        let layouts = LayoutAssignment::identity(&g);
        let mut s = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        s.spatial_tiles = vec![1, 4, 112, 64];
        s.vectorize = true;
        let base = simulate_program(&case_program(&layouts, &s, &hw), &hw);
        let mut p = s.clone();
        p.parallel = 2; // N.o (1) x H.o (28)
        let par = simulate_program(&case_program(&layouts, &p, &hw), &hw);
        assert!(
            par.latency_ms < base.latency_ms / 3.0,
            "parallel gave only {:.2}x",
            base.latency_ms / par.latency_ms
        );
        assert!(par.parallel_speedup <= hw.cores as f64);
    }

    #[test]
    fn tiling_reduces_misses() {
        let g = models::case_study();
        let hw = HwProfile::intel();
        let layouts = LayoutAssignment::identity(&g);
        let untiled = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        let mut tiled = untiled.clone();
        tiled.spatial_tiles = vec![1, 4, 16, 16];
        let su = simulate_program(&case_program(&layouts, &untiled, &hw), &hw);
        let st = simulate_program(&case_program(&layouts, &tiled, &hw), &hw);
        assert!(
            st.l1_misses < su.l1_misses,
            "tiled {} vs untiled {}",
            st.l1_misses,
            su.l1_misses
        );
    }

    #[test]
    fn layout_tiled_output_fewer_misses_than_loop_tiled() {
        // The §2/§7.3.3 claim: layout tiling (contiguous tiles in
        // storage) beats loop tiling alone on cache behaviour.
        let g = models::case_study();
        let hw = HwProfile::intel();
        let conv = g.complex_nodes()[0];
        let out = g.node(conv).output;

        let mut sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        sched.spatial_tiles = vec![1, 4, 16, 16];
        sched.vectorize = true;
        let plain = LayoutAssignment::identity(&g);
        let s_loop = simulate_program(&case_program(&plain, &sched, &hw), &hw);

        let mut tl = LayoutAssignment::identity(&g);
        let mut seq = LayoutSeq::new();
        seq.push(Primitive::split(1, &[28, 4]))
            .push(Primitive::split(3, &[7, 16]))
            .push(Primitive::split(5, &[4, 16]))
            .push(Primitive::reorder(&[0, 1, 3, 5, 2, 4, 6]));
        tl.set(out, seq);
        let mut sched_t =
            LoopSchedule::identity(&[1, 28, 7, 4, 4, 16, 16], &[3, 7, 7]);
        sched_t.vectorize = true;
        let s_layout = simulate_program(&case_program(&tl, &sched_t, &hw), &hw);
        assert!(
            s_layout.l1_misses < s_loop.l1_misses,
            "layout-tiled {} vs loop-tiled {}",
            s_layout.l1_misses,
            s_loop.l1_misses
        );
    }

    #[test]
    fn streaming_scales_with_bytes() {
        let hw = HwProfile::intel();
        let a = simulate_streaming(1e6, 1e6, true, &hw);
        let b = simulate_streaming(4e6, 4e6, true, &hw);
        assert!(b.latency_ms > a.latency_ms * 2.0);
        let c = simulate_streaming(1e6, 1e6, false, &hw);
        assert!(c.latency_ms > a.latency_ms, "strided stream must cost more");
    }

    #[test]
    fn report_counters_positive_and_consistent() {
        let g = models::case_study();
        let hw = HwProfile::arm();
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        let r = simulate_program(&case_program(&layouts, &sched, &hw), &hw);
        assert!(r.latency_ms > 0.0);
        assert!(r.instructions > 0.0);
        assert!(r.l1_misses > 0.0 && r.l1_misses <= r.l1_loads + r.l1_stores);
        assert!(r.l2_misses <= r.l1_misses);
        assert!((r.flops - 2.0 * 112.0 * 112.0 * 64.0 * 147.0).abs() < 1.0);
    }

    #[test]
    fn accumulate_sums() {
        let mut a = SimReport { latency_ms: 1.0, flops: 10.0, ..Default::default() };
        let b = SimReport { latency_ms: 2.0, flops: 5.0, ..Default::default() };
        a.accumulate(&b);
        assert_eq!(a.latency_ms, 3.0);
        assert_eq!(a.flops, 15.0);
    }
}
