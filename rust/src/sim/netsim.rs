//! Whole-graph simulation: sequential execution of a network under a
//! layout assignment (propagation result) and per-operator loop
//! schedules — the "end-to-end inference" measurement of §7.2.
//!
//! [`simulate_graph_with`] evaluates the complex-operator nests on the
//! candidate-evaluation engine's worker pool (and through its memo
//! cache, so a graph simulation following a tuning run re-uses the
//! programs tuning already lowered); reports are accumulated in graph
//! order, so the totals are identical for any pool size.

use std::collections::HashMap;

use crate::codegen::{lower_complex, Program};
use crate::engine::Engine;
use crate::graph::{Graph, NodeId, OpKind};
use crate::layout::LayoutTransform;
use crate::loops::LoopSchedule;
use crate::propagate::PropagationResult;
use crate::sim::{simulate_program, simulate_streaming, HwProfile, SimReport};

/// Per-node simulated latency breakdown.
#[derive(Clone, Debug)]
pub struct NodeCost {
    pub node: Option<NodeId>,
    pub label: String,
    pub report: SimReport,
}

/// End-to-end simulation result.
#[derive(Clone, Debug, Default)]
pub struct GraphReport {
    pub total: SimReport,
    pub per_node: Vec<NodeCost>,
}

impl GraphReport {
    pub fn latency_ms(&self) -> f64 {
        self.total.latency_ms
    }
}

fn tensor_bytes(graph: &Graph, t: usize) -> f64 {
    graph.tensor(t).bytes() as f64
}

/// Storage bytes of a tensor after its layout sequence (unfold/pad
/// expand the allocation).
fn storage_bytes(graph: &Graph, t: usize, prop: &PropagationResult) -> f64 {
    let ten = graph.tensor(t);
    let seq = prop.layouts.get(t);
    if seq.is_identity() {
        return ten.bytes() as f64;
    }
    // layouts are built against the logical shape the consumer reads
    // (expanded for transposed-conv inputs)
    let base = crate::codegen::layout_base_shape(graph, t);
    let tf = LayoutTransform::new(base, &seq);
    tf.final_shape().iter().product::<i64>() as f64 * ten.dtype.bytes() as f64
}

/// Simulate the whole graph. `scheds` maps complex nodes to their loop
/// schedules (identity when missing). Serial convenience wrapper over
/// [`simulate_graph_with`].
pub fn simulate_graph(
    graph: &Graph,
    prop: &PropagationResult,
    scheds: &HashMap<NodeId, LoopSchedule>,
    hw: &HwProfile,
) -> GraphReport {
    simulate_graph_with(graph, prop, scheds, hw, &Engine::serial())
}

/// One pending row of the graph report: either a cheap streaming cost
/// (computed inline) or a complex nest evaluated on the engine pool.
enum Row {
    Ready(Option<NodeId>, String, SimReport),
    Complex(NodeId, String, usize), // index into the engine job list
}

/// Simulate the whole graph, evaluating complex-operator nests on
/// `engine`'s worker pool (memoized — a run right after tuning hits
/// the tuner's cache). Accumulation order matches the serial path, so
/// the report is identical for any engine size.
pub fn simulate_graph_with(
    graph: &Graph,
    prop: &PropagationResult,
    scheds: &HashMap<NodeId, LoopSchedule>,
    hw: &HwProfile,
    engine: &Engine,
) -> GraphReport {
    let mut rows: Vec<Row> = Vec::new();
    let mut jobs: Vec<(NodeId, LoopSchedule)> = Vec::new();

    // Standalone layout conversions (Fig. 5a): strided repack through
    // memory — read the tensor, write the consumer-side (possibly
    // expanded) layout.
    for c in &prop.conversions {
        if c.absorbed_by.is_none() {
            let read = tensor_bytes(graph, c.tensor);
            let base = crate::codegen::layout_base_shape(graph, c.tensor);
            let tf = LayoutTransform::new(base, &c.to);
            let written = tf.final_shape().iter().product::<i64>() as f64
                * graph.tensor(c.tensor).dtype.bytes() as f64;
            // run-based repack: bandwidth-bound (see engine conversion
            // accounting)
            let r = simulate_streaming(read, written, true, hw);
            rows.push(Row::Ready(None, format!("convert(t{})", c.tensor), r));
        }
    }

    for node in &graph.nodes {
        if prop.fused_nodes.contains(&node.id) {
            continue; // cost carried by the producing complex op's nest
        }
        match &node.kind {
            OpKind::Conv { .. } | OpKind::Matmul | OpKind::Dense => {
                let sched = scheds.get(&node.id).cloned().unwrap_or_else(|| {
                    LoopSchedule::identity(
                        &graph.tensor(node.output).shape,
                        &[1],
                    )
                });
                rows.push(Row::Complex(node.id, node.name.clone(), jobs.len()));
                jobs.push((node.id, sched));
            }
            OpKind::Reshape { .. } => { /* metadata only */ }
            OpKind::Eltwise { .. } | OpKind::BiasAdd => {
                let read: f64 =
                    node.inputs.iter().map(|&t| tensor_bytes(graph, t)).sum();
                let written = tensor_bytes(graph, node.output);
                let contiguous = prop.layouts.is_identity(node.output);
                let r = simulate_streaming(read, written, contiguous, hw);
                rows.push(Row::Ready(Some(node.id), node.name.clone(), r));
            }
            OpKind::PadOp { .. } => {
                let read = tensor_bytes(graph, node.inputs[0]);
                // absorbed conversion (Fig. 5b): the pad writes the
                // transformed (possibly expanded) layout in one pass —
                // strided writes, but no extra traversal.
                // an absorbed conversion only changes the write
                // volume (expanded layout); runs stay long, so the
                // pass remains bandwidth-bound
                let written = storage_bytes(graph, node.output, prop);
                let r = simulate_streaming(read, written, true, hw);
                rows.push(Row::Ready(Some(node.id), node.name.clone(), r));
            }
            OpKind::Pool { .. }
            | OpKind::Softmax { .. }
            | OpKind::LayerNorm { .. }
            | OpKind::BatchNorm
            | OpKind::Reduce { .. }
            | OpKind::LayoutConvert => {
                let read: f64 =
                    node.inputs.iter().map(|&t| tensor_bytes(graph, t)).sum();
                let written = tensor_bytes(graph, node.output);
                let r = simulate_streaming(read, written, true, hw);
                rows.push(Row::Ready(Some(node.id), node.name.clone(), r));
            }
        }
    }

    // Evaluate every complex nest in parallel, then fold the report in
    // the original (serial) order.
    let reports = engine.simulate_nodes(graph, prop, hw, &jobs);
    let mut rep = GraphReport::default();
    for row in rows {
        let (node, label, r) = match row {
            Row::Ready(node, label, r) => (node, label, r),
            Row::Complex(node, label, j) => {
                (Some(node), label, reports[j].clone())
            }
        };
        rep.total.accumulate(&r);
        rep.per_node.push(NodeCost { node, label, report: r });
    }
    rep
}

/// Convenience: lower + simulate one complex node in isolation (the
/// single-operator benchmark path, §7.1).
pub fn simulate_single_op(
    graph: &Graph,
    node: NodeId,
    prop: &PropagationResult,
    sched: &LoopSchedule,
    hw: &HwProfile,
) -> (Program, SimReport) {
    let tail = prop.fused_tails.get(&node).cloned().unwrap_or_default();
    let p = lower_complex(graph, node, &prop.layouts, sched, &tail, hw.simd_lanes);
    let r = simulate_program(&p, hw);
    (p, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::propagate::{propagate, PropMode};

    #[test]
    fn case_study_simulates_end_to_end() {
        let g = models::case_study();
        let prop = propagate(&g, &[], PropMode::Alt);
        let rep = simulate_graph(&g, &prop, &HashMap::new(), &HwProfile::intel());
        assert!(rep.latency_ms() > 0.0);
        // pad + conv nest (+ fused bias/relu skipped)
        assert!(rep.per_node.len() >= 2);
    }

    #[test]
    fn whole_resnet_simulates() {
        let g = models::resnet18(1);
        let prop = propagate(&g, &[], PropMode::Alt);
        let rep = simulate_graph(&g, &prop, &HashMap::new(), &HwProfile::intel());
        assert!(rep.latency_ms() > 0.0);
        assert!(rep.total.flops > 1e9, "R18 must exceed 1 GFLOP");
    }

    #[test]
    fn conversion_costs_latency() {
        use crate::layout::{LayoutSeq, Primitive};
        use crate::propagate::ComplexDecision;
        let g = models::prop_subgraph(7);
        let convs = g.complex_nodes();
        let mut in_seq = LayoutSeq::new();
        in_seq.push(Primitive::split(3, &[32, 16]));
        let decs = vec![ComplexDecision {
            node: convs[1],
            in_seq,
            ..Default::default()
        }];
        let with_conv = propagate(&g, &decs, PropMode::Alt);
        let without = propagate(&g, &[], PropMode::Alt);
        let hw = HwProfile::intel();
        let a = simulate_graph(&g, &with_conv, &HashMap::new(), &hw);
        let b = simulate_graph(&g, &without, &HashMap::new(), &hw);
        let conv_rows =
            a.per_node.iter().filter(|n| n.label.starts_with("convert")).count();
        assert_eq!(conv_rows, 1);
        assert!(a.latency_ms() > b.latency_ms());
    }

    #[test]
    fn parallel_graph_sim_matches_serial() {
        let g = models::resnet18(1);
        let prop = propagate(&g, &[], PropMode::Alt);
        let hw = HwProfile::intel();
        let serial = simulate_graph(&g, &prop, &HashMap::new(), &hw);
        let parallel = simulate_graph_with(
            &g,
            &prop,
            &HashMap::new(),
            &hw,
            &Engine::new(4),
        );
        assert_eq!(
            serial.latency_ms().to_bits(),
            parallel.latency_ms().to_bits(),
            "pool size must not change the report"
        );
        assert_eq!(serial.per_node.len(), parallel.per_node.len());
    }

    #[test]
    fn bert_and_r3d_simulate() {
        for g in [models::bert_tiny(), models::resnet3d_18(1)] {
            let prop = propagate(&g, &[], PropMode::Alt);
            let rep =
                simulate_graph(&g, &prop, &HashMap::new(), &HwProfile::gpu());
            assert!(rep.latency_ms() > 0.0, "{} failed", g.name);
        }
    }
}
