//! Exact trace-driven cache simulator with hardware prefetch.
//!
//! Used by the Table 2 reproduction (layout tiling vs loop tiling on a
//! Cortex-A76-like L1) and as a golden reference for the analytic line
//! counts of the parent module. Set-associative, LRU, with a next-N-line
//! sequential prefetcher: a demand miss on line `L` also fills
//! `L+1..L+N` (the behaviour the paper infers from its measurements:
//! "the CPU is very likely to fetch four contiguous cache lines when a
//! miss event is triggered").

/// Set-associative LRU cache with sequential prefetch.
pub struct CacheSim {
    sets: Vec<Vec<(u64, u64)>>, // per set: (tag, last-use tick)
    n_sets: u64,
    assoc: usize,
    line_bytes: u64,
    prefetch_lines: u64,
    tick: u64,
    /// Demand misses (prefetched fills do not count — matching perf
    /// counters, which report demand L1D misses).
    pub misses: u64,
    /// Demand accesses.
    pub accesses: u64,
}

impl CacheSim {
    pub fn new(capacity_bytes: u64, assoc: usize, line_bytes: u64, prefetch_lines: u64) -> Self {
        let n_lines = capacity_bytes / line_bytes;
        let n_sets = (n_lines / assoc as u64).max(1);
        Self {
            sets: vec![Vec::new(); n_sets as usize],
            n_sets,
            assoc,
            line_bytes,
            prefetch_lines,
            tick: 0,
            misses: 0,
            accesses: 0,
        }
    }

    /// Cortex-A76-like L1D: 64 KiB, 4-way, 64 B lines, 4-line prefetch
    /// (the configuration behind the paper's Table 2 predictions).
    pub fn cortex_a76_l1() -> Self {
        Self::new(64 * 1024, 4, 64, 4)
    }

    fn line_of(&self, addr: u64) -> u64 {
        addr / self.line_bytes
    }

    fn touch_line(&mut self, line: u64, demand: bool) -> bool {
        self.tick += 1;
        let set = (line % self.n_sets) as usize;
        let tag = line / self.n_sets;
        let ways = &mut self.sets[set];
        if let Some(w) = ways.iter_mut().find(|(t, _)| *t == tag) {
            if demand {
                w.1 = self.tick;
            }
            return true;
        }
        // fill
        if ways.len() >= self.assoc {
            // evict LRU
            let (idx, _) = ways
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, used))| *used)
                .unwrap();
            ways.remove(idx);
        }
        ways.push((tag, self.tick));
        false
    }

    /// One demand access at byte address `addr`.
    pub fn access(&mut self, addr: u64) {
        self.accesses += 1;
        let line = self.line_of(addr);
        let hit = self.touch_line(line, true);
        if !hit {
            self.misses += 1;
            // sequential prefetch of the next lines
            for i in 1..self.prefetch_lines {
                self.touch_line(line + i, false);
            }
        }
    }

    /// Stream a whole byte range (e.g. a SIMD load loop).
    pub fn access_range(&mut self, start: u64, bytes: u64, step: u64) {
        let mut a = start;
        while a < start + bytes {
            self.access(a);
            a += step;
        }
    }

    pub fn reset_counters(&mut self) {
        self.misses = 0;
        self.accesses = 0;
    }
}

/// The paper's Table 2 experiment, first function: a `rows x cols` f32
/// block stored **contiguously** (layout tiling), loaded once with
/// 16-element NEON loads. Returns demand misses.
pub fn table2_layout_tiled(rows: u64, cols: u64) -> u64 {
    let mut c = CacheSim::cortex_a76_l1();
    let bytes = rows * cols * 4;
    c.access_range(0, bytes, 64); // one access per line touched
    c.misses
}

/// Second function: the same block stored **row by row** inside a larger
/// array of `row_stride` f32 per row (loop tiling without data
/// movement). Each row is `cols` elements at stride `row_stride`.
pub fn table2_loop_tiled(rows: u64, cols: u64, row_stride: u64) -> u64 {
    let mut c = CacheSim::cortex_a76_l1();
    for r in 0..rows {
        let start = r * row_stride * 4;
        c.access_range(start, cols * 4, 64.min(cols * 4));
    }
    c.misses
}

/// Analytic prediction from the paper: `rows*cols/(line_elems *
/// prefetch)` for the contiguous case (float32x16 lines, 4-line
/// prefetch).
pub fn table2_prediction(rows: u64, cols: u64) -> u64 {
    rows * cols / (16 * 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_hits_after_fill() {
        let mut c = CacheSim::new(1024, 4, 64, 1);
        c.access(0);
        assert_eq!(c.misses, 1);
        c.access(4);
        c.access(63);
        assert_eq!(c.misses, 1, "same line must hit");
        c.access(64);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn eviction_when_over_capacity() {
        // 2 sets x 2 ways of 64B = 256B cache
        let mut c = CacheSim::new(256, 2, 64, 1);
        // lines 0,2,4 map to set 0; 3 lines > 2 ways -> evicts line 0
        c.access(0);
        c.access(2 * 64);
        c.access(4 * 64);
        assert_eq!(c.misses, 3);
        c.access(0); // line 0 was evicted
        assert_eq!(c.misses, 4);
    }

    #[test]
    fn prefetch_hides_sequential_misses() {
        let mut c = CacheSim::new(64 * 1024, 4, 64, 4);
        c.access_range(0, 64 * 64, 64); // 64 lines sequential
        // with 4-line prefetch only every 4th line demand-misses
        assert_eq!(c.misses, 16);
    }

    #[test]
    fn table2_matches_paper_predictions() {
        // Paper Table 2: predictions 32 / 128 / 512 / 2048 for
        // 512x{4,16,64,256}; measured demand misses were 32/96/501/2037.
        for (cols, pred) in [(4u64, 32u64), (16, 128), (64, 512), (256, 2048)] {
            assert_eq!(table2_prediction(512, cols), pred);
            let got = table2_layout_tiled(512, cols);
            // simulator sits within ~0..25% of the analytic prediction,
            // like the measured numbers in the paper
            assert!(
                got <= pred && got * 4 >= pred * 3,
                "cols={cols}: got {got}, pred {pred}"
            );
        }
    }

    #[test]
    fn table2_layout_beats_loop_tiling() {
        // Paper Table 2, second column vs third: loop tiling (strided
        // rows) always misses at least as much as layout tiling
        // (contiguous), strictly more while a row underfills the
        // prefetch span (row bytes < prefetch * line).
        for cols in [4u64, 16, 64, 256] {
            let lt = table2_layout_tiled(512, cols);
            let lp = table2_loop_tiled(512, cols, 512);
            if cols * 4 < 4 * 64 {
                assert!(lp > lt, "cols={cols}: loop {lp} <= layout {lt}");
            } else {
                assert!(lp >= lt, "cols={cols}: loop {lp} < layout {lt}");
            }
        }
    }
}
