//! Index-expression IR.
//!
//! Layout primitives rewrite *tensor accessing expressions* (Table 1 of
//! the paper and the `unfold` rule of Eq. (1)). Those rewrites bottom out
//! in the small expression language here: integer affine arithmetic plus
//! floor-division, modulo and min — exactly the operator set the paper's
//! rules need.
//!
//! Expressions reference loop variables by numeric id ([`Expr::Var`]).
//! The simulator never interprets them symbolically: it evaluates
//! concrete points (base + unit steps) to derive address strides, so the
//! IR only needs `eval`, `subst` and a light constant-folding `simplify`.
//!
//! ## Interning
//!
//! Sub-expressions are **hash-consed**: constructors route children
//! through a process-wide arena of `Arc` nodes, so structurally equal
//! subtrees share one allocation and the whole IR is `Send + Sync` —
//! the property the parallel candidate-evaluation engine
//! ([`crate::engine`]) relies on to lower and simulate candidates
//! across worker threads. The arena never evicts (pointer identity of a
//! canonical node is stable for the process lifetime), which makes the
//! memoized-`simplify` table sound: it is keyed by the canonical child
//! pointers, and structurally equal children always intern to the same
//! pointer. The same invariant lets `Eq`/`Hash` compare children by
//! pointer identity, so interning is O(1) per node rather than a
//! structural re-walk of the subtree. Layout rewrites re-derive the
//! same handful of index shapes for every candidate in a tuning run,
//! so the arena stays small while the constructor fast path skips
//! re-simplification entirely.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex, OnceLock};

/// An integer index expression over loop variables.
#[derive(Clone, Debug)]
pub enum Expr {
    /// Loop variable by id.
    Var(usize),
    /// Integer constant.
    Const(i64),
    Add(Arc<Expr>, Arc<Expr>),
    Sub(Arc<Expr>, Arc<Expr>),
    Mul(Arc<Expr>, Arc<Expr>),
    /// Floor division (both operands non-negative in all generated code).
    Div(Arc<Expr>, Arc<Expr>),
    /// Modulo (non-negative operands).
    Mod(Arc<Expr>, Arc<Expr>),
    Min(Arc<Expr>, Arc<Expr>),
}

pub use Expr::{Const, Var};

// Equality and hashing are *semantically structural* but implemented
// shallowly: composite nodes compare children by `Arc` pointer
// identity. This is sound because every composite `Expr` in the crate
// is built through the constructors below, which intern children into
// the canonical arena — so for children, pointer equality ⟺
// structural equality. The payoff is O(1) hashing/interning per node
// on codegen's hottest path (a derived structural Hash would re-walk
// whole subtrees at every constructor call). Do NOT build composite
// variants directly with un-interned children.
impl PartialEq for Expr {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Var(a), Var(b)) => a == b,
            (Const(a), Const(b)) => a == b,
            (Expr::Add(a1, b1), Expr::Add(a2, b2))
            | (Expr::Sub(a1, b1), Expr::Sub(a2, b2))
            | (Expr::Mul(a1, b1), Expr::Mul(a2, b2))
            | (Expr::Div(a1, b1), Expr::Div(a2, b2))
            | (Expr::Mod(a1, b1), Expr::Mod(a2, b2))
            | (Expr::Min(a1, b1), Expr::Min(a2, b2)) => {
                Arc::ptr_eq(a1, a2) && Arc::ptr_eq(b1, b2)
            }
            _ => false,
        }
    }
}

impl Eq for Expr {}

impl Hash for Expr {
    fn hash<H: Hasher>(&self, state: &mut H) {
        std::mem::discriminant(self).hash(state);
        match self {
            Var(i) => i.hash(state),
            Const(c) => c.hash(state),
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Mod(a, b)
            | Expr::Min(a, b) => {
                (Arc::as_ptr(a) as usize).hash(state);
                (Arc::as_ptr(b) as usize).hash(state);
            }
        }
    }
}

/// Binary operator tags for the simplify-memo key.
const OP_ADD: u8 = 0;
const OP_SUB: u8 = 1;
const OP_MUL: u8 = 2;
const OP_DIV: u8 = 3;
const OP_MOD: u8 = 4;
const OP_MIN: u8 = 5;

const SHARDS: usize = 16;

/// Process-wide hash-consing arena + memoized-simplify table, sharded
/// to keep lock contention negligible under the parallel engine.
struct Interner {
    nodes: Vec<Mutex<HashSet<Arc<Expr>>>>,
    simplify_memo: Vec<Mutex<HashMap<(u8, usize, usize), Expr>>>,
}

fn interner() -> &'static Interner {
    static INTERNER: OnceLock<Interner> = OnceLock::new();
    INTERNER.get_or_init(|| Interner {
        nodes: (0..SHARDS).map(|_| Mutex::new(HashSet::new())).collect(),
        simplify_memo: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
    })
}

fn shard_of<T: Hash>(v: &T) -> usize {
    // DefaultHasher::new() uses fixed keys — deterministic per process.
    let mut h = std::collections::hash_map::DefaultHasher::new();
    v.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

/// Intern an expression node, returning its canonical shared `Arc`.
/// Structurally equal inputs always return pointer-identical nodes.
pub fn intern(e: Expr) -> Arc<Expr> {
    let it = interner();
    let mut set = it.nodes[shard_of(&e)].lock().unwrap();
    if let Some(a) = set.get(&e) {
        return a.clone();
    }
    let a = Arc::new(e);
    set.insert(a.clone());
    a
}

/// Number of distinct nodes in the interning arena (diagnostics).
pub fn intern_len() -> usize {
    interner().nodes.iter().map(|s| s.lock().unwrap().len()).sum()
}

/// Build a binary node from canonical children with memoized simplify.
/// Keying by child pointers is sound because `intern` is canonical and
/// the arena never evicts.
fn binop(op: u8, a: Arc<Expr>, b: Arc<Expr>) -> Expr {
    let key = (op, Arc::as_ptr(&a) as usize, Arc::as_ptr(&b) as usize);
    let it = interner();
    let shard = (key.1 ^ key.2.rotate_left(17) ^ ((op as usize) << 3)) % SHARDS;
    if let Some(r) = it.simplify_memo[shard].lock().unwrap().get(&key) {
        return r.clone();
    }
    let raw = match op {
        OP_ADD => Expr::Add(a, b),
        OP_SUB => Expr::Sub(a, b),
        OP_MUL => Expr::Mul(a, b),
        OP_DIV => Expr::Div(a, b),
        OP_MOD => Expr::Mod(a, b),
        _ => Expr::Min(a, b),
    };
    let r = raw.simplify();
    it.simplify_memo[shard].lock().unwrap().insert(key, r.clone());
    r
}

impl Expr {
    pub fn add(a: Expr, b: Expr) -> Expr {
        binop(OP_ADD, intern(a), intern(b))
    }
    pub fn sub(a: Expr, b: Expr) -> Expr {
        binop(OP_SUB, intern(a), intern(b))
    }
    pub fn mul(a: Expr, b: Expr) -> Expr {
        binop(OP_MUL, intern(a), intern(b))
    }
    pub fn div(a: Expr, b: Expr) -> Expr {
        binop(OP_DIV, intern(a), intern(b))
    }
    pub fn rem(a: Expr, b: Expr) -> Expr {
        binop(OP_MOD, intern(a), intern(b))
    }
    pub fn min(a: Expr, b: Expr) -> Expr {
        binop(OP_MIN, intern(a), intern(b))
    }

    /// Evaluate with `env[var_id]` giving each variable's value.
    /// Out-of-range variables are an error in codegen; panic loudly.
    pub fn eval(&self, env: &[i64]) -> i64 {
        match self {
            Var(i) => env[*i],
            Const(c) => *c,
            Expr::Add(a, b) => a.eval(env) + b.eval(env),
            Expr::Sub(a, b) => a.eval(env) - b.eval(env),
            Expr::Mul(a, b) => a.eval(env) * b.eval(env),
            Expr::Div(a, b) => {
                let (x, y) = (a.eval(env), b.eval(env));
                debug_assert!(y != 0, "division by zero in index expr");
                x.div_euclid(y)
            }
            Expr::Mod(a, b) => {
                let (x, y) = (a.eval(env), b.eval(env));
                debug_assert!(y != 0, "mod by zero in index expr");
                x.rem_euclid(y)
            }
            Expr::Min(a, b) => a.eval(env).min(b.eval(env)),
        }
    }

    /// Substitute each variable with the given expression
    /// (`subs[var_id]`); variables without a mapping (`None`) stay.
    pub fn subst(&self, subs: &[Option<Expr>]) -> Expr {
        match self {
            Var(i) => match subs.get(*i) {
                Some(Some(e)) => e.clone(),
                _ => self.clone(),
            },
            Const(_) => self.clone(),
            Expr::Add(a, b) => Expr::add(a.subst(subs), b.subst(subs)),
            Expr::Sub(a, b) => Expr::sub(a.subst(subs), b.subst(subs)),
            Expr::Mul(a, b) => Expr::mul(a.subst(subs), b.subst(subs)),
            Expr::Div(a, b) => Expr::div(a.subst(subs), b.subst(subs)),
            Expr::Mod(a, b) => Expr::rem(a.subst(subs), b.subst(subs)),
            Expr::Min(a, b) => Expr::min(a.subst(subs), b.subst(subs)),
        }
    }

    /// Set of variable ids mentioned.
    pub fn vars(&self) -> BTreeSet<usize> {
        let mut out = BTreeSet::new();
        self.collect_vars(&mut out);
        out
    }

    fn collect_vars(&self, out: &mut BTreeSet<usize>) {
        match self {
            Var(i) => {
                out.insert(*i);
            }
            Const(_) => {}
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Mod(a, b)
            | Expr::Min(a, b) => {
                a.collect_vars(out);
                b.collect_vars(out);
            }
        }
    }

    /// Light constant folding + algebraic identities. One level deep —
    /// constructors call it bottom-up so trees stay folded.
    pub fn simplify(&self) -> Expr {
        match self {
            Expr::Add(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x + y),
                (Const(0), e) | (e, Const(0)) => e.clone(),
                _ => self.clone(),
            },
            Expr::Sub(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x - y),
                (e, Const(0)) => e.clone(),
                (x, y) if x == y => Const(0),
                _ => self.clone(),
            },
            Expr::Mul(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x * y),
                (Const(0), _) | (_, Const(0)) => Const(0),
                (Const(1), e) | (e, Const(1)) => e.clone(),
                _ => self.clone(),
            },
            Expr::Div(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) if *y != 0 => Const(x.div_euclid(*y)),
                (e, Const(1)) => e.clone(),
                (Const(0), _) => Const(0),
                _ => self.clone(),
            },
            Expr::Mod(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) if *y != 0 => Const(x.rem_euclid(*y)),
                (_, Const(1)) => Const(0),
                (Const(0), _) => Const(0),
                _ => self.clone(),
            },
            Expr::Min(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(*x.min(y)),
                (x, y) if x == y => x.clone(),
                _ => self.clone(),
            },
            _ => self.clone(),
        }
    }

    /// Linearize a multi-dim access: `sum(idx[d] * stride[d])` where
    /// strides are row-major over `shape`. This is the flat address the
    /// simulator samples.
    pub fn flatten(idx: &[Expr], shape: &[i64]) -> Expr {
        assert_eq!(idx.len(), shape.len());
        let mut strides = vec![1i64; shape.len()];
        for d in (0..shape.len().saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        let mut acc = Const(0);
        for (e, s) in idx.iter().zip(&strides) {
            acc = Expr::add(acc, Expr::mul(e.clone(), Const(*s)));
        }
        acc
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Var(i) => write!(f, "v{i}"),
            Const(c) => write!(f, "{c}"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
            Expr::Sub(a, b) => write!(f, "({a} - {b})"),
            Expr::Mul(a, b) => write!(f, "({a}*{b})"),
            Expr::Div(a, b) => write!(f, "({a}//{b})"),
            Expr::Mod(a, b) => write!(f, "({a}%{b})"),
            Expr::Min(a, b) => write!(f, "min({a},{b})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_affine() {
        // 3*v0 + v1 - 2
        let e = Expr::sub(
            Expr::add(Expr::mul(Const(3), Var(0)), Var(1)),
            Const(2),
        );
        assert_eq!(e.eval(&[4, 5]), 15);
    }

    #[test]
    fn eval_div_mod_euclid() {
        let e = Expr::div(Var(0), Const(4));
        assert_eq!(e.eval(&[11]), 2);
        let m = Expr::rem(Var(0), Const(4));
        assert_eq!(m.eval(&[11]), 3);
    }

    #[test]
    fn simplify_identities() {
        assert_eq!(Expr::add(Var(0), Const(0)), Var(0));
        assert_eq!(Expr::mul(Var(0), Const(1)), Var(0));
        assert_eq!(Expr::mul(Var(0), Const(0)), Const(0));
        assert_eq!(Expr::div(Var(0), Const(1)), Var(0));
        assert_eq!(Expr::rem(Var(0), Const(1)), Const(0));
        assert_eq!(Expr::add(Const(2), Const(3)), Const(5));
    }

    #[test]
    fn subst_replaces_vars() {
        // v0 + 2*v1 with v0 := v2//3
        let e = Expr::add(Var(0), Expr::mul(Const(2), Var(1)));
        let s = e.subst(&[Some(Expr::div(Var(2), Const(3))), None]);
        assert_eq!(s.eval(&[0, 10, 9]), 3 + 20);
    }

    #[test]
    fn flatten_row_major() {
        // idx (v0, v1) over shape [4, 8] -> v0*8 + v1
        let e = Expr::flatten(&[Var(0), Var(1)], &[4, 8]);
        assert_eq!(e.eval(&[2, 3]), 19);
    }

    #[test]
    fn vars_collects() {
        let e = Expr::add(Var(3), Expr::mul(Var(1), Const(2)));
        let v: Vec<usize> = e.vars().into_iter().collect();
        assert_eq!(v, vec![1, 3]);
    }

    #[test]
    fn interning_is_canonical() {
        // structurally equal nodes intern to the same allocation
        let a = intern(Expr::add(Var(0), Const(7)));
        let b = intern(Expr::add(Var(0), Const(7)));
        assert!(Arc::ptr_eq(&a, &b));
        // equal subtrees built via constructors share children
        let e1 = Expr::mul(Expr::add(Var(1), Const(2)), Var(3));
        let e2 = Expr::mul(Expr::add(Var(1), Const(2)), Var(3));
        assert_eq!(e1, e2);
        if let (Expr::Mul(x, _), Expr::Mul(y, _)) = (&e1, &e2) {
            assert!(Arc::ptr_eq(x, y), "hash-consed children must share");
        } else {
            panic!("expected Mul nodes");
        }
    }

    #[test]
    fn interned_exprs_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Expr>();
        assert_send_sync::<Arc<Expr>>();
    }

    #[test]
    fn memoized_simplify_matches_fresh_simplify() {
        // first call populates the memo, second must return the same value
        let a = Expr::mul(Var(2), Const(1));
        let b = Expr::mul(Var(2), Const(1));
        assert_eq!(a, b);
        assert_eq!(a, Var(2));
        assert!(intern_len() > 0);
    }
}
