//! Index-expression IR.
//!
//! Layout primitives rewrite *tensor accessing expressions* (Table 1 of
//! the paper and the `unfold` rule of Eq. (1)). Those rewrites bottom out
//! in the small expression language here: integer affine arithmetic plus
//! floor-division, modulo and min — exactly the operator set the paper's
//! rules need.
//!
//! Expressions reference loop variables by numeric id ([`Expr::Var`]).
//! The simulator never interprets them symbolically: it evaluates
//! concrete points (base + unit steps) to derive address strides, so the
//! IR only needs `eval`, `subst` and a light constant-folding `simplify`.
//!
//! ## Interning
//!
//! Sub-expressions are **hash-consed**: constructors route children
//! through a process-wide arena of `Arc` nodes, so structurally equal
//! subtrees share one allocation and the whole IR is `Send + Sync` —
//! the property the parallel candidate-evaluation engine
//! ([`crate::engine`]) relies on to lower and simulate candidates
//! across worker threads. `Eq`/`Hash` compare children by pointer
//! identity, so interning is O(1) per node rather than a structural
//! re-walk of the subtree. Layout rewrites re-derive the same handful
//! of index shapes for every candidate in a tuning run, so the arena
//! stays small while the constructor fast path skips re-simplification
//! entirely.
//!
//! ## Eviction & the pointer-stability invariant
//!
//! Long-running services must not grow the arena monotonically, so it
//! is size-capped ([`set_arena_cap`]): when the cap is exceeded a
//! sweep ([`sweep_arena`]) drops every node whose *only* strong
//! reference is the arena itself. That criterion is what keeps
//! pointer-identity comparison sound across evictions:
//!
//! * a node is evicted only when **no live `Expr` anywhere references
//!   it** — neither as an `Arc` child (every live composite value
//!   pins its children) nor from the arena (interned parents pin
//!   their children too, so sweeps iterate to a fixpoint, leaves
//!   last). Any two live expressions with structurally equal children
//!   therefore still share canonical child pointers, and a fresh
//!   construction of an evicted shape simply re-interns it as a new
//!   canonical node.
//! * the memoized-`simplify` table is keyed by child *addresses*;
//!   every entry pins its two operand `Arc`s (plus its result's
//!   children), so an address in a live key always denotes a live
//!   node — stale-address (ABA) lookups are structurally impossible.
//!   Sweeps clear the table first, which both unpins that garbage and
//!   bounds the table; entries are pure, so a clear only costs
//!   re-simplification.
//!
//! Eviction is thus invisible to results: it changes when work is
//! recomputed, never what any expression evaluates to — pinned by the
//! eviction property test in `tests/batched_tuner.rs`.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// An integer index expression over loop variables.
#[derive(Clone, Debug)]
pub enum Expr {
    /// Loop variable by id.
    Var(usize),
    /// Integer constant.
    Const(i64),
    Add(Arc<Expr>, Arc<Expr>),
    Sub(Arc<Expr>, Arc<Expr>),
    Mul(Arc<Expr>, Arc<Expr>),
    /// Floor division (both operands non-negative in all generated code).
    Div(Arc<Expr>, Arc<Expr>),
    /// Modulo (non-negative operands).
    Mod(Arc<Expr>, Arc<Expr>),
    Min(Arc<Expr>, Arc<Expr>),
}

pub use Expr::{Const, Var};

// Equality and hashing are *semantically structural* but implemented
// shallowly: composite nodes compare children by `Arc` pointer
// identity. This is sound because every composite `Expr` in the crate
// is built through the constructors below, which intern children into
// the canonical arena — so for children, pointer equality ⟺
// structural equality. The payoff is O(1) hashing/interning per node
// on codegen's hottest path (a derived structural Hash would re-walk
// whole subtrees at every constructor call). Do NOT build composite
// variants directly with un-interned children.
impl PartialEq for Expr {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Var(a), Var(b)) => a == b,
            (Const(a), Const(b)) => a == b,
            (Expr::Add(a1, b1), Expr::Add(a2, b2))
            | (Expr::Sub(a1, b1), Expr::Sub(a2, b2))
            | (Expr::Mul(a1, b1), Expr::Mul(a2, b2))
            | (Expr::Div(a1, b1), Expr::Div(a2, b2))
            | (Expr::Mod(a1, b1), Expr::Mod(a2, b2))
            | (Expr::Min(a1, b1), Expr::Min(a2, b2)) => {
                Arc::ptr_eq(a1, a2) && Arc::ptr_eq(b1, b2)
            }
            _ => false,
        }
    }
}

impl Eq for Expr {}

impl Hash for Expr {
    fn hash<H: Hasher>(&self, state: &mut H) {
        std::mem::discriminant(self).hash(state);
        match self {
            Var(i) => i.hash(state),
            Const(c) => c.hash(state),
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Mod(a, b)
            | Expr::Min(a, b) => {
                (Arc::as_ptr(a) as usize).hash(state);
                (Arc::as_ptr(b) as usize).hash(state);
            }
        }
    }
}

/// Binary operator tags for the simplify-memo key.
const OP_ADD: u8 = 0;
const OP_SUB: u8 = 1;
const OP_MUL: u8 = 2;
const OP_DIV: u8 = 3;
const OP_MOD: u8 = 4;
const OP_MIN: u8 = 5;

const SHARDS: usize = 16;

/// A memoized-simplify entry: the result plus the two operand `Arc`s
/// of its key. Pinning the operands is load-bearing: a node whose
/// address appears in a live memo key can never drop to a strong count
/// of 1, so a sweep can never evict it and the key can never dangle —
/// even if an insert races with a sweep's memo clear.
type SimplifyEntry = (Arc<Expr>, Arc<Expr>, Expr);

/// Process-wide hash-consing arena + memoized-simplify table, sharded
/// to keep lock contention negligible under the parallel engine.
struct Interner {
    nodes: Vec<Mutex<HashSet<Arc<Expr>>>>,
    simplify_memo: Vec<Mutex<HashMap<(u8, usize, usize), SimplifyEntry>>>,
}

fn interner() -> &'static Interner {
    static INTERNER: OnceLock<Interner> = OnceLock::new();
    INTERNER.get_or_init(|| Interner {
        nodes: (0..SHARDS).map(|_| Mutex::new(HashSet::new())).collect(),
        simplify_memo: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
    })
}

fn shard_of<T: Hash>(v: &T) -> usize {
    // DefaultHasher::new() uses fixed keys — deterministic per process.
    let mut h = std::collections::hash_map::DefaultHasher::new();
    v.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

/// Default node cap for the interning arena. Typical tuning runs stay
/// two orders of magnitude below this; the cap exists so long-running
/// services are bounded.
pub const DEFAULT_ARENA_CAP: usize = 1 << 18;

/// Approximate live-node count (exact after each sweep).
static ARENA_LEN: AtomicUsize = AtomicUsize::new(0);
static ARENA_CAP: AtomicUsize = AtomicUsize::new(DEFAULT_ARENA_CAP);
/// Node count at which the next automatic sweep fires (0 ⇒ the cap).
/// Raised above the cap after a sweep that could not get under it —
/// everything still referenced — so pinned-full arenas don't sweep on
/// every insert.
static NEXT_SWEEP: AtomicUsize = AtomicUsize::new(0);
static SWEEPING: AtomicBool = AtomicBool::new(false);

/// Current arena node cap.
pub fn arena_cap() -> usize {
    ARENA_CAP.load(Ordering::Relaxed)
}

/// Set the arena node cap (min 1). Lowering it takes effect at the
/// next intern; eviction never changes what expressions evaluate to.
pub fn set_arena_cap(cap: usize) {
    ARENA_CAP.store(cap.max(1), Ordering::Relaxed);
    NEXT_SWEEP.store(0, Ordering::Relaxed);
}

/// Intern an expression node, returning its canonical shared `Arc`.
/// Structurally equal inputs always return pointer-identical nodes
/// (for as long as either lives — see the module docs on eviction).
pub fn intern(e: Expr) -> Arc<Expr> {
    let it = interner();
    let a = {
        let mut set = it.nodes[shard_of(&e)].lock().unwrap();
        if let Some(a) = set.get(&e) {
            return a.clone();
        }
        let a = Arc::new(e);
        set.insert(a.clone());
        a
    };
    ARENA_LEN.fetch_add(1, Ordering::Relaxed);
    maybe_sweep(it);
    a
}

/// Trigger a sweep when the arena outgrows its cap (and the post-sweep
/// hysteresis gate). Runs after the shard lock is released; a single
/// sweeper at a time.
fn maybe_sweep(it: &Interner) {
    let cap = ARENA_CAP.load(Ordering::Relaxed);
    let len = ARENA_LEN.load(Ordering::Relaxed);
    if len <= cap.max(NEXT_SWEEP.load(Ordering::Relaxed)) {
        return;
    }
    if SWEEPING.swap(true, Ordering::SeqCst) {
        return; // another thread is already sweeping
    }
    sweep(it);
    let live = ARENA_LEN.load(Ordering::Relaxed);
    let gate = if live > cap { live + (cap / 2).max(1) } else { 0 };
    NEXT_SWEEP.store(gate, Ordering::Relaxed);
    SWEEPING.store(false, Ordering::SeqCst);
}

/// Evict every node whose only strong reference is the arena itself;
/// returns the number of nodes dropped. Safe at any time from any
/// thread — live expressions keep their children pinned (the count is
/// inspected under the owning shard's lock, so no new reference can
/// appear mid-check), and the simplify memo is cleared first so its
/// child-address keys can never dangle.
pub fn sweep_arena() -> usize {
    sweep(interner())
}

fn sweep(it: &Interner) -> usize {
    // 1) drop the simplify memo: its values pin their children, and
    //    its keys are child addresses that must not outlive the nodes.
    for m in &it.simplify_memo {
        m.lock().unwrap().clear();
    }
    // 2) drop unreferenced nodes. Parents pin children, so each pass
    //    unpins the next layer down — iterate to a fixpoint.
    let mut evicted_total = 0;
    loop {
        let mut evicted = 0;
        for shard in &it.nodes {
            let mut set = shard.lock().unwrap();
            let before = set.len();
            set.retain(|a| Arc::strong_count(a) > 1);
            evicted += before - set.len();
        }
        if evicted == 0 {
            break;
        }
        evicted_total += evicted;
    }
    let live: usize = it.nodes.iter().map(|s| s.lock().unwrap().len()).sum();
    ARENA_LEN.store(live, Ordering::Relaxed);
    evicted_total
}

/// Number of distinct nodes in the interning arena (diagnostics).
pub fn intern_len() -> usize {
    interner().nodes.iter().map(|s| s.lock().unwrap().len()).sum()
}

/// Build a binary node from canonical children with memoized simplify.
/// Keying by child pointers is sound because `intern` is canonical and
/// every memo entry pins its operand `Arc`s (see [`SimplifyEntry`]) —
/// an address in a live key is always an address of a live node.
fn binop(op: u8, a: Arc<Expr>, b: Arc<Expr>) -> Expr {
    let key = (op, Arc::as_ptr(&a) as usize, Arc::as_ptr(&b) as usize);
    let it = interner();
    let shard = (key.1 ^ key.2.rotate_left(17) ^ ((op as usize) << 3)) % SHARDS;
    if let Some((_, _, r)) = it.simplify_memo[shard].lock().unwrap().get(&key) {
        return r.clone();
    }
    let (ka, kb) = (a.clone(), b.clone());
    let raw = match op {
        OP_ADD => Expr::Add(a, b),
        OP_SUB => Expr::Sub(a, b),
        OP_MUL => Expr::Mul(a, b),
        OP_DIV => Expr::Div(a, b),
        OP_MOD => Expr::Mod(a, b),
        _ => Expr::Min(a, b),
    };
    let r = raw.simplify();
    it.simplify_memo[shard].lock().unwrap().insert(key, (ka, kb, r.clone()));
    r
}

impl Expr {
    pub fn add(a: Expr, b: Expr) -> Expr {
        binop(OP_ADD, intern(a), intern(b))
    }
    pub fn sub(a: Expr, b: Expr) -> Expr {
        binop(OP_SUB, intern(a), intern(b))
    }
    pub fn mul(a: Expr, b: Expr) -> Expr {
        binop(OP_MUL, intern(a), intern(b))
    }
    pub fn div(a: Expr, b: Expr) -> Expr {
        binop(OP_DIV, intern(a), intern(b))
    }
    pub fn rem(a: Expr, b: Expr) -> Expr {
        binop(OP_MOD, intern(a), intern(b))
    }
    pub fn min(a: Expr, b: Expr) -> Expr {
        binop(OP_MIN, intern(a), intern(b))
    }

    /// Evaluate with `env[var_id]` giving each variable's value.
    /// Out-of-range variables are an error in codegen; panic loudly.
    pub fn eval(&self, env: &[i64]) -> i64 {
        match self {
            Var(i) => env[*i],
            Const(c) => *c,
            Expr::Add(a, b) => a.eval(env) + b.eval(env),
            Expr::Sub(a, b) => a.eval(env) - b.eval(env),
            Expr::Mul(a, b) => a.eval(env) * b.eval(env),
            Expr::Div(a, b) => {
                let (x, y) = (a.eval(env), b.eval(env));
                debug_assert!(y != 0, "division by zero in index expr");
                x.div_euclid(y)
            }
            Expr::Mod(a, b) => {
                let (x, y) = (a.eval(env), b.eval(env));
                debug_assert!(y != 0, "mod by zero in index expr");
                x.rem_euclid(y)
            }
            Expr::Min(a, b) => a.eval(env).min(b.eval(env)),
        }
    }

    /// Substitute each variable with the given expression
    /// (`subs[var_id]`); variables without a mapping (`None`) stay.
    pub fn subst(&self, subs: &[Option<Expr>]) -> Expr {
        match self {
            Var(i) => match subs.get(*i) {
                Some(Some(e)) => e.clone(),
                _ => self.clone(),
            },
            Const(_) => self.clone(),
            Expr::Add(a, b) => Expr::add(a.subst(subs), b.subst(subs)),
            Expr::Sub(a, b) => Expr::sub(a.subst(subs), b.subst(subs)),
            Expr::Mul(a, b) => Expr::mul(a.subst(subs), b.subst(subs)),
            Expr::Div(a, b) => Expr::div(a.subst(subs), b.subst(subs)),
            Expr::Mod(a, b) => Expr::rem(a.subst(subs), b.subst(subs)),
            Expr::Min(a, b) => Expr::min(a.subst(subs), b.subst(subs)),
        }
    }

    /// Set of variable ids mentioned.
    pub fn vars(&self) -> BTreeSet<usize> {
        let mut out = BTreeSet::new();
        self.collect_vars(&mut out);
        out
    }

    fn collect_vars(&self, out: &mut BTreeSet<usize>) {
        match self {
            Var(i) => {
                out.insert(*i);
            }
            Const(_) => {}
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Mod(a, b)
            | Expr::Min(a, b) => {
                a.collect_vars(out);
                b.collect_vars(out);
            }
        }
    }

    /// Light constant folding + algebraic identities. One level deep —
    /// constructors call it bottom-up so trees stay folded.
    pub fn simplify(&self) -> Expr {
        match self {
            Expr::Add(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x + y),
                (Const(0), e) | (e, Const(0)) => e.clone(),
                _ => self.clone(),
            },
            Expr::Sub(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x - y),
                (e, Const(0)) => e.clone(),
                (x, y) if x == y => Const(0),
                _ => self.clone(),
            },
            Expr::Mul(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x * y),
                (Const(0), _) | (_, Const(0)) => Const(0),
                (Const(1), e) | (e, Const(1)) => e.clone(),
                _ => self.clone(),
            },
            Expr::Div(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) if *y != 0 => Const(x.div_euclid(*y)),
                (e, Const(1)) => e.clone(),
                (Const(0), _) => Const(0),
                _ => self.clone(),
            },
            Expr::Mod(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) if *y != 0 => Const(x.rem_euclid(*y)),
                (_, Const(1)) => Const(0),
                (Const(0), _) => Const(0),
                _ => self.clone(),
            },
            Expr::Min(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(*x.min(y)),
                (x, y) if x == y => x.clone(),
                _ => self.clone(),
            },
            _ => self.clone(),
        }
    }

    /// Linearize a multi-dim access: `sum(idx[d] * stride[d])` where
    /// strides are row-major over `shape`. This is the flat address the
    /// simulator samples.
    pub fn flatten(idx: &[Expr], shape: &[i64]) -> Expr {
        assert_eq!(idx.len(), shape.len());
        let mut strides = vec![1i64; shape.len()];
        for d in (0..shape.len().saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        let mut acc = Const(0);
        for (e, s) in idx.iter().zip(&strides) {
            acc = Expr::add(acc, Expr::mul(e.clone(), Const(*s)));
        }
        acc
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Var(i) => write!(f, "v{i}"),
            Const(c) => write!(f, "{c}"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
            Expr::Sub(a, b) => write!(f, "({a} - {b})"),
            Expr::Mul(a, b) => write!(f, "({a}*{b})"),
            Expr::Div(a, b) => write!(f, "({a}//{b})"),
            Expr::Mod(a, b) => write!(f, "({a}%{b})"),
            Expr::Min(a, b) => write!(f, "min({a},{b})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_affine() {
        // 3*v0 + v1 - 2
        let e = Expr::sub(
            Expr::add(Expr::mul(Const(3), Var(0)), Var(1)),
            Const(2),
        );
        assert_eq!(e.eval(&[4, 5]), 15);
    }

    #[test]
    fn eval_div_mod_euclid() {
        let e = Expr::div(Var(0), Const(4));
        assert_eq!(e.eval(&[11]), 2);
        let m = Expr::rem(Var(0), Const(4));
        assert_eq!(m.eval(&[11]), 3);
    }

    #[test]
    fn simplify_identities() {
        assert_eq!(Expr::add(Var(0), Const(0)), Var(0));
        assert_eq!(Expr::mul(Var(0), Const(1)), Var(0));
        assert_eq!(Expr::mul(Var(0), Const(0)), Const(0));
        assert_eq!(Expr::div(Var(0), Const(1)), Var(0));
        assert_eq!(Expr::rem(Var(0), Const(1)), Const(0));
        assert_eq!(Expr::add(Const(2), Const(3)), Const(5));
    }

    #[test]
    fn subst_replaces_vars() {
        // v0 + 2*v1 with v0 := v2//3
        let e = Expr::add(Var(0), Expr::mul(Const(2), Var(1)));
        let s = e.subst(&[Some(Expr::div(Var(2), Const(3))), None]);
        assert_eq!(s.eval(&[0, 10, 9]), 3 + 20);
    }

    #[test]
    fn flatten_row_major() {
        // idx (v0, v1) over shape [4, 8] -> v0*8 + v1
        let e = Expr::flatten(&[Var(0), Var(1)], &[4, 8]);
        assert_eq!(e.eval(&[2, 3]), 19);
    }

    #[test]
    fn vars_collects() {
        let e = Expr::add(Var(3), Expr::mul(Var(1), Const(2)));
        let v: Vec<usize> = e.vars().into_iter().collect();
        assert_eq!(v, vec![1, 3]);
    }

    #[test]
    fn interning_is_canonical() {
        // structurally equal nodes intern to the same allocation
        let a = intern(Expr::add(Var(0), Const(7)));
        let b = intern(Expr::add(Var(0), Const(7)));
        assert!(Arc::ptr_eq(&a, &b));
        // equal subtrees built via constructors share children
        let e1 = Expr::mul(Expr::add(Var(1), Const(2)), Var(3));
        let e2 = Expr::mul(Expr::add(Var(1), Const(2)), Var(3));
        assert_eq!(e1, e2);
        if let (Expr::Mul(x, _), Expr::Mul(y, _)) = (&e1, &e2) {
            assert!(Arc::ptr_eq(x, y), "hash-consed children must share");
        } else {
            panic!("expected Mul nodes");
        }
    }

    #[test]
    fn interned_exprs_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Expr>();
        assert_send_sync::<Arc<Expr>>();
    }

    #[test]
    fn memoized_simplify_matches_fresh_simplify() {
        // first call populates the memo, second must return the same value
        let a = Expr::mul(Var(2), Const(1));
        let b = Expr::mul(Var(2), Const(1));
        assert_eq!(a, b);
        assert_eq!(a, Var(2));
        assert!(intern_len() > 0);
    }

    #[test]
    fn sweep_evicts_only_unreferenced_nodes() {
        // var ids far outside anything other tests intern, so the
        // nodes built here are provably garbage once dropped
        const BASE: usize = 900_100;
        let held = Expr::add(Var(BASE), Const(41));
        let garbage: Vec<Expr> = (0..64)
            .map(|i| Expr::add(Var(BASE + 1 + i), Const(43)))
            .collect();
        drop(garbage);
        let evicted = sweep_arena();
        assert!(evicted >= 64, "sweep dropped only {evicted} nodes");
        // the held value survives and stays canonical: a fresh build of
        // the same shape must compare equal (shared child pointers)
        let rebuilt = Expr::add(Var(BASE), Const(41));
        assert_eq!(held, rebuilt);
        // an evicted shape re-interns cleanly and is canonical again
        let again = Expr::add(Var(BASE + 1), Const(43));
        let again2 = Expr::add(Var(BASE + 1), Const(43));
        assert_eq!(again, again2);
    }

    #[test]
    fn eviction_is_invisible_to_evaluation() {
        const BASE: usize = 910_000;
        // same expression built before and after a sweep that evicts
        // the first copy must evaluate identically
        let mk = || {
            Expr::add(
                Expr::mul(Var(0), Const(7)),
                Expr::rem(Var(1), Const(5)),
            )
        };
        let before = mk().eval(&[3, 13]);
        let garbage: Vec<Expr> =
            (0..32).map(|i| Expr::mul(Var(BASE + i), Const(9))).collect();
        drop(garbage);
        sweep_arena();
        assert_eq!(mk().eval(&[3, 13]), before);
        assert_eq!(before, 3 * 7 + 13 % 5);
    }

    #[test]
    fn arena_cap_roundtrips() {
        let old = arena_cap();
        set_arena_cap(12_345);
        assert_eq!(arena_cap(), 12_345);
        set_arena_cap(old);
        assert_eq!(arena_cap(), old);
    }
}
