//! Index-expression IR.
//!
//! Layout primitives rewrite *tensor accessing expressions* (Table 1 of
//! the paper and the `unfold` rule of Eq. (1)). Those rewrites bottom out
//! in the small expression language here: integer affine arithmetic plus
//! floor-division, modulo and min — exactly the operator set the paper's
//! rules need.
//!
//! Expressions reference loop variables by numeric id ([`Expr::Var`]).
//! The simulator never interprets them symbolically: it evaluates
//! concrete points (base + unit steps) to derive address strides, so the
//! IR only needs `eval`, `subst` and a light constant-folding `simplify`.

use std::collections::BTreeSet;
use std::fmt;
use std::rc::Rc;

/// An integer index expression over loop variables.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Expr {
    /// Loop variable by id.
    Var(usize),
    /// Integer constant.
    Const(i64),
    Add(Rc<Expr>, Rc<Expr>),
    Sub(Rc<Expr>, Rc<Expr>),
    Mul(Rc<Expr>, Rc<Expr>),
    /// Floor division (both operands non-negative in all generated code).
    Div(Rc<Expr>, Rc<Expr>),
    /// Modulo (non-negative operands).
    Mod(Rc<Expr>, Rc<Expr>),
    Min(Rc<Expr>, Rc<Expr>),
}

pub use Expr::{Const, Var};

impl Expr {
    pub fn add(a: Expr, b: Expr) -> Expr {
        Expr::Add(Rc::new(a), Rc::new(b)).simplify()
    }
    pub fn sub(a: Expr, b: Expr) -> Expr {
        Expr::Sub(Rc::new(a), Rc::new(b)).simplify()
    }
    pub fn mul(a: Expr, b: Expr) -> Expr {
        Expr::Mul(Rc::new(a), Rc::new(b)).simplify()
    }
    pub fn div(a: Expr, b: Expr) -> Expr {
        Expr::Div(Rc::new(a), Rc::new(b)).simplify()
    }
    pub fn rem(a: Expr, b: Expr) -> Expr {
        Expr::Mod(Rc::new(a), Rc::new(b)).simplify()
    }
    pub fn min(a: Expr, b: Expr) -> Expr {
        Expr::Min(Rc::new(a), Rc::new(b)).simplify()
    }

    /// Evaluate with `env[var_id]` giving each variable's value.
    /// Out-of-range variables are an error in codegen; panic loudly.
    pub fn eval(&self, env: &[i64]) -> i64 {
        match self {
            Var(i) => env[*i],
            Const(c) => *c,
            Expr::Add(a, b) => a.eval(env) + b.eval(env),
            Expr::Sub(a, b) => a.eval(env) - b.eval(env),
            Expr::Mul(a, b) => a.eval(env) * b.eval(env),
            Expr::Div(a, b) => {
                let (x, y) = (a.eval(env), b.eval(env));
                debug_assert!(y != 0, "division by zero in index expr");
                x.div_euclid(y)
            }
            Expr::Mod(a, b) => {
                let (x, y) = (a.eval(env), b.eval(env));
                debug_assert!(y != 0, "mod by zero in index expr");
                x.rem_euclid(y)
            }
            Expr::Min(a, b) => a.eval(env).min(b.eval(env)),
        }
    }

    /// Substitute each variable with the given expression
    /// (`subs[var_id]`); variables without a mapping (`None`) stay.
    pub fn subst(&self, subs: &[Option<Expr>]) -> Expr {
        match self {
            Var(i) => match subs.get(*i) {
                Some(Some(e)) => e.clone(),
                _ => self.clone(),
            },
            Const(_) => self.clone(),
            Expr::Add(a, b) => Expr::add(a.subst(subs), b.subst(subs)),
            Expr::Sub(a, b) => Expr::sub(a.subst(subs), b.subst(subs)),
            Expr::Mul(a, b) => Expr::mul(a.subst(subs), b.subst(subs)),
            Expr::Div(a, b) => Expr::div(a.subst(subs), b.subst(subs)),
            Expr::Mod(a, b) => Expr::rem(a.subst(subs), b.subst(subs)),
            Expr::Min(a, b) => Expr::min(a.subst(subs), b.subst(subs)),
        }
    }

    /// Set of variable ids mentioned.
    pub fn vars(&self) -> BTreeSet<usize> {
        let mut out = BTreeSet::new();
        self.collect_vars(&mut out);
        out
    }

    fn collect_vars(&self, out: &mut BTreeSet<usize>) {
        match self {
            Var(i) => {
                out.insert(*i);
            }
            Const(_) => {}
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Mod(a, b)
            | Expr::Min(a, b) => {
                a.collect_vars(out);
                b.collect_vars(out);
            }
        }
    }

    /// Light constant folding + algebraic identities. One level deep —
    /// constructors call it bottom-up so trees stay folded.
    pub fn simplify(&self) -> Expr {
        match self {
            Expr::Add(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x + y),
                (Const(0), e) | (e, Const(0)) => e.clone(),
                _ => self.clone(),
            },
            Expr::Sub(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x - y),
                (e, Const(0)) => e.clone(),
                (x, y) if x == y => Const(0),
                _ => self.clone(),
            },
            Expr::Mul(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(x * y),
                (Const(0), _) | (_, Const(0)) => Const(0),
                (Const(1), e) | (e, Const(1)) => e.clone(),
                _ => self.clone(),
            },
            Expr::Div(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) if *y != 0 => Const(x.div_euclid(*y)),
                (e, Const(1)) => e.clone(),
                (Const(0), _) => Const(0),
                _ => self.clone(),
            },
            Expr::Mod(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) if *y != 0 => Const(x.rem_euclid(*y)),
                (_, Const(1)) => Const(0),
                (Const(0), _) => Const(0),
                _ => self.clone(),
            },
            Expr::Min(a, b) => match (&**a, &**b) {
                (Const(x), Const(y)) => Const(*x.min(y)),
                (x, y) if x == y => x.clone(),
                _ => self.clone(),
            },
            _ => self.clone(),
        }
    }

    /// Linearize a multi-dim access: `sum(idx[d] * stride[d])` where
    /// strides are row-major over `shape`. This is the flat address the
    /// simulator samples.
    pub fn flatten(idx: &[Expr], shape: &[i64]) -> Expr {
        assert_eq!(idx.len(), shape.len());
        let mut strides = vec![1i64; shape.len()];
        for d in (0..shape.len().saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        let mut acc = Const(0);
        for (e, s) in idx.iter().zip(&strides) {
            acc = Expr::add(acc, Expr::mul(e.clone(), Const(*s)));
        }
        acc
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Var(i) => write!(f, "v{i}"),
            Const(c) => write!(f, "{c}"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
            Expr::Sub(a, b) => write!(f, "({a} - {b})"),
            Expr::Mul(a, b) => write!(f, "({a}*{b})"),
            Expr::Div(a, b) => write!(f, "({a}//{b})"),
            Expr::Mod(a, b) => write!(f, "({a}%{b})"),
            Expr::Min(a, b) => write!(f, "min({a},{b})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_affine() {
        // 3*v0 + v1 - 2
        let e = Expr::sub(
            Expr::add(Expr::mul(Const(3), Var(0)), Var(1)),
            Const(2),
        );
        assert_eq!(e.eval(&[4, 5]), 15);
    }

    #[test]
    fn eval_div_mod_euclid() {
        let e = Expr::div(Var(0), Const(4));
        assert_eq!(e.eval(&[11]), 2);
        let m = Expr::rem(Var(0), Const(4));
        assert_eq!(m.eval(&[11]), 3);
    }

    #[test]
    fn simplify_identities() {
        assert_eq!(Expr::add(Var(0), Const(0)), Var(0));
        assert_eq!(Expr::mul(Var(0), Const(1)), Var(0));
        assert_eq!(Expr::mul(Var(0), Const(0)), Const(0));
        assert_eq!(Expr::div(Var(0), Const(1)), Var(0));
        assert_eq!(Expr::rem(Var(0), Const(1)), Const(0));
        assert_eq!(Expr::add(Const(2), Const(3)), Const(5));
    }

    #[test]
    fn subst_replaces_vars() {
        // v0 + 2*v1 with v0 := v2//3
        let e = Expr::add(Var(0), Expr::mul(Const(2), Var(1)));
        let s = e.subst(&[Some(Expr::div(Var(2), Const(3))), None]);
        assert_eq!(s.eval(&[0, 10, 9]), 3 + 20);
    }

    #[test]
    fn flatten_row_major() {
        // idx (v0, v1) over shape [4, 8] -> v0*8 + v1
        let e = Expr::flatten(&[Var(0), Var(1)], &[4, 8]);
        assert_eq!(e.eval(&[2, 3]), 19);
    }

    #[test]
    fn vars_collects() {
        let e = Expr::add(Var(3), Expr::mul(Var(1), Const(2)));
        let v: Vec<usize> = e.vars().into_iter().collect();
        assert_eq!(v, vec![1, 3]);
    }
}
