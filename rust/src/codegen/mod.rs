//! Program generation (paper §6).
//!
//! Lowers one complex operator (plus its fused elementwise tail) to a
//! *tensor program*: an ordered loop nest whose body carries the storage
//! access expression of every operand. The pass follows §6 exactly:
//!
//! 1. deduce the output tensor's final layout by applying its primitive
//!    sequence `S_Y`; the loop nest is reconstructed with one spatial
//!    loop per storage dim (`L' = S_Y(L)`);
//! 2. remap every other operand: replace the logical loop variables `L`
//!    by `S_Y⁻¹(L')` in its access indices;
//! 3. apply each operand's own sequence `S_X` to its accesses —
//!    `S_X(S_Y⁻¹(L'))`.
//!
//! The resulting [`Program`] is what the device simulator executes and
//! what cost-model features are extracted from.

use crate::expr::{Const, Expr};
use crate::graph::{Graph, Node, NodeId, OpKind};
use crate::layout::{DimAccess, LayoutSeq, LayoutTransform};
use crate::loops::{build_nest, Annotation, Loop, LoopSchedule};
use crate::tensor::TensorId;

/// One operand access inside the generated loop nest.
#[derive(Clone, Debug)]
pub struct TensorAccess {
    pub tensor: TensorId,
    /// Storage shape after the tensor's layout sequence.
    pub storage_shape: Vec<i64>,
    /// Storage index expression per storage dim, over loop-var ids.
    pub idx: Vec<Expr>,
    pub is_write: bool,
    pub elem_bytes: i64,
}

impl TensorAccess {
    /// Flattened (row-major) address expression in elements.
    pub fn flat(&self) -> Expr {
        Expr::flatten(&self.idx, &self.storage_shape)
    }
}

/// A generated tensor program for one (possibly fused) loop nest.
#[derive(Clone, Debug)]
pub struct Program {
    pub node: NodeId,
    /// Loops, outermost first.
    pub loops: Vec<Loop>,
    pub accesses: Vec<TensorAccess>,
    /// MAC-equivalent floating ops per innermost iteration.
    pub flops_per_iter: f64,
    /// Ids of elementwise nodes fused into this nest (compute_at).
    pub fused: Vec<NodeId>,
}

impl Program {
    pub fn total_iters(&self) -> f64 {
        self.loops.iter().map(|l| l.extent as f64).product()
    }

    pub fn total_flops(&self) -> f64 {
        self.total_iters() * self.flops_per_iter
    }

    pub fn innermost(&self) -> &Loop {
        self.loops.last().expect("empty nest")
    }

    pub fn vectorized_loop(&self) -> Option<&Loop> {
        self.loops.iter().find(|l| l.ann == Annotation::Vectorize)
    }
}

/// Layout decisions for all tensors of a graph (produced by the
/// propagation pass; identity when absent).
///
/// A tensor normally has one storage layout (`set`/`get`). When a
/// runtime conversion sits between producer and consumer (Fig. 5a),
/// the *consumer* reads a different layout than the producer wrote:
/// that consumer-side view is a read override keyed by
/// `(consumer node, tensor)`.
#[derive(Clone, Debug, Default)]
pub struct LayoutAssignment {
    seqs: Vec<Option<LayoutSeq>>,
    read_overrides: std::collections::HashMap<(NodeId, TensorId), LayoutSeq>,
}

impl LayoutAssignment {
    pub fn identity(graph: &Graph) -> Self {
        Self {
            seqs: vec![None; graph.tensors.len()],
            read_overrides: Default::default(),
        }
    }

    pub fn set(&mut self, t: TensorId, seq: LayoutSeq) {
        if t >= self.seqs.len() {
            self.seqs.resize(t + 1, None);
        }
        self.seqs[t] = Some(seq);
    }

    /// The layout the producer writes (allocation layout).
    pub fn get(&self, t: TensorId) -> LayoutSeq {
        self.seqs.get(t).cloned().flatten().unwrap_or_default()
    }

    /// Register the layout `node` reads `t` in, when it differs from
    /// the producer's (a conversion op materializes the repack).
    pub fn set_read_override(&mut self, node: NodeId, t: TensorId, seq: LayoutSeq) {
        self.read_overrides.insert((node, t), seq);
    }

    /// The layout `node` observes when reading `t`.
    pub fn get_for(&self, node: NodeId, t: TensorId) -> LayoutSeq {
        self.read_overrides
            .get(&(node, t))
            .cloned()
            .unwrap_or_else(|| self.get(t))
    }

    pub fn is_identity(&self, t: TensorId) -> bool {
        self.get(t).is_identity()
    }

    /// Deterministic content hash over all non-identity sequences and
    /// read overrides — the layout component of the candidate-eval
    /// engine's memoization key. Two assignments that lower every node
    /// identically hash equal regardless of construction order.
    pub fn content_hash(&self) -> u64 {
        use std::hash::Hash;
        let mut h = crate::util::StableHasher::new();
        for (t, s) in self.seqs.iter().enumerate() {
            if let Some(s) = s {
                if !s.is_identity() {
                    (t, s).hash(&mut h);
                }
            }
        }
        let mut ov: Vec<(&(NodeId, TensorId), &LayoutSeq)> =
            self.read_overrides.iter().collect();
        ov.sort_by_key(|(k, _)| **k);
        for (k, s) in ov {
            (k, s).hash(&mut h);
        }
        std::hash::Hasher::finish(&h)
    }
}

/// The logical iteration structure of a complex op before layout
/// reconstruction: spatial dims (== logical output dims) and reduction
/// dims, plus functions producing the operands' logical accesses.
struct LogicalOp {
    spatial: Vec<i64>,
    reduction: Vec<i64>,
    reduction_names: Vec<String>,
    flops_per_iter: f64,
}

fn logical_op(graph: &Graph, node: &Node) -> LogicalOp {
    let out = graph.tensor(node.output);
    match &node.kind {
        OpKind::Conv { kernel, groups, .. } => {
            let x = graph.tensor(node.inputs[0]);
            let ci = *x.shape.last().unwrap();
            let mut reduction = vec![ci / groups];
            let mut rnames = vec!["ri".to_string()];
            for (d, &k) in kernel.iter().enumerate() {
                reduction.push(k);
                rnames.push(format!("r{}", out.dim_names[1 + d].to_lowercase()));
            }
            LogicalOp {
                spatial: out.shape.clone(),
                reduction,
                reduction_names: rnames,
                flops_per_iter: 2.0,
            }
        }
        OpKind::Matmul | OpKind::Dense => {
            let a = graph.tensor(node.inputs[0]);
            let k = *a.shape.last().unwrap();
            LogicalOp {
                spatial: out.shape.clone(),
                reduction: vec![k],
                reduction_names: vec!["rk".to_string()],
                flops_per_iter: 2.0,
            }
        }
        other => panic!("logical_op on non-complex node {other:?}"),
    }
}

/// The *effective* logical input shape a conv reads — transposed convs
/// read a zero-expanded input (see DESIGN.md); everything else reads the
/// producer's logical shape.
pub fn conv_input_logical_shape(graph: &Graph, node: &Node) -> Vec<i64> {
    let x = graph.tensor(node.inputs[0]);
    match &node.kind {
        OpKind::Conv { spatial, stride, kernel, transposed: true, .. } => {
            let mut s = vec![x.shape[0]];
            for d in 0..*spatial {
                s.push((x.shape[1 + d] - 1) * stride[d] + 1 + 2 * (kernel[d] - 1));
            }
            s.push(*x.shape.last().unwrap());
            s
        }
        _ => x.shape.clone(),
    }
}

/// The logical shape a tensor's layout sequence was built against.
/// Normally the tensor's own shape; for the input of a *transposed*
/// convolution it is the zero-expanded shape the conv reads (templates
/// build their `unfold`s against that).
pub fn layout_base_shape(graph: &Graph, tensor: TensorId) -> Vec<i64> {
    for n in &graph.nodes {
        if let OpKind::Conv { transposed: true, .. } = &n.kind {
            if n.inputs[0] == tensor {
                return conv_input_logical_shape(graph, n);
            }
        }
    }
    graph.tensor(tensor).shape.clone()
}

/// Lower one complex node plus fused elementwise tail to a [`Program`].
///
/// `fused_tail` lists elementwise nodes (in topo order) whose compute is
/// inlined into the tile body; the propagation pass guarantees their
/// layouts match the output layout when it requests fusion.
pub fn lower_complex(
    graph: &Graph,
    node_id: NodeId,
    layouts: &LayoutAssignment,
    sched: &LoopSchedule,
    fused_tail: &[NodeId],
    simd_lanes: i64,
) -> Program {
    let node = graph.node(node_id);
    let lop = logical_op(graph, node);
    let out_seq = layouts.get(node.output);
    let out_tf = LayoutTransform::new(lop.spatial.clone(), &out_seq);
    let storage_shape = out_tf.final_shape().to_vec();

    // Reconstructed loop nest: one spatial loop per storage dim (§6).
    let storage_names: Vec<String> =
        (0..storage_shape.len()).map(|d| format!("s{d}")).collect();
    let mut sched = sched.clone();
    sched.repair(&storage_shape, &lop.reduction);
    let nest = build_nest(
        &storage_shape,
        &storage_names,
        &lop.reduction,
        &lop.reduction_names,
        &sched,
        simd_lanes,
    );

    // Storage index expr per storage dim: outer*tile + inner.
    let storage_idx: Vec<Expr> = nest
        .spatial_pairs
        .iter()
        .zip(&sched.spatial_tiles)
        .map(|(&(o, i), &t)| {
            Expr::add(Expr::mul(Expr::Var(o), Const(t)), Expr::Var(i))
        })
        .collect();
    // Reduction var exprs.
    let red_idx: Vec<Expr> = nest
        .reduction_pairs
        .iter()
        .zip(&sched.reduction_tiles)
        .map(|(&(o, i), &t)| {
            Expr::add(Expr::mul(Expr::Var(o), Const(t)), Expr::Var(i))
        })
        .collect();

    // Logical output coordinates: L = S_Y^{-1}(L').
    let logical = out_tf.backward(&storage_idx);

    let mut accesses = Vec::new();
    // Output write in storage coordinates (identity over storage idx).
    let out_t = graph.tensor(node.output);
    accesses.push(TensorAccess {
        tensor: node.output,
        storage_shape: storage_shape.clone(),
        idx: storage_idx.clone(),
        is_write: fused_tail.is_empty(),
        elem_bytes: out_t.dtype.bytes(),
    });

    // Operand accesses: logical access -> operand's own layout seq.
    match &node.kind {
        OpKind::Conv { spatial, stride, dilation, groups, transposed, kernel } => {
            let x_id = node.inputs[0];
            let w_id = node.inputs[1];
            let x = graph.tensor(x_id);
            let sp = *spatial;
            let o_expr = logical[sp + 1].clone();
            let co = *graph.tensor(node.output).shape.last().unwrap();
            let ci = *x.shape.last().unwrap();
            let cig = ci / groups;
            // input channel = group(o) * (I/groups) + ri
            let ci_expr = if *groups == 1 {
                red_idx[0].clone()
            } else {
                let per_group_o = co / groups;
                Expr::add(
                    Expr::mul(
                        Expr::div(o_expr.clone(), Const(per_group_o)),
                        Const(cig),
                    ),
                    red_idx[0].clone(),
                )
            };
            // input spatial: sliding pattern per dim
            let mut x_acc: Vec<DimAccess> =
                vec![DimAccess::Simple(logical[0].clone())];
            for d in 0..sp {
                let (v, win_mul) = if *transposed {
                    (1, 1) // expanded-input equivalence: stride-1 window
                } else {
                    (stride[d], dilation[d])
                };
                x_acc.push(DimAccess::Sliding {
                    stride: v,
                    outer: logical[1 + d].clone(),
                    window: Expr::mul(Const(win_mul), red_idx[1 + d].clone()),
                    win_lo: 0,
                    win_size: win_mul * (kernel[d] - 1) + 1,
                });
            }
            x_acc.push(DimAccess::Simple(ci_expr));
            let x_shape = conv_input_logical_shape(graph, node);
            push_access(&mut accesses, graph, node_id, x_id, &x_shape, &x_acc, layouts);

            // weight access [K1..Kn, ri, o]
            let mut w_acc: Vec<DimAccess> = (0..sp)
                .map(|d| DimAccess::Simple(red_idx[1 + d].clone()))
                .collect();
            w_acc.push(DimAccess::Simple(red_idx[0].clone()));
            w_acc.push(DimAccess::Simple(o_expr.clone()));
            let w_shape = graph.tensor(w_id).shape.clone();
            push_access(&mut accesses, graph, node_id, w_id, &w_shape, &w_acc, layouts);
        }
        OpKind::Matmul | OpKind::Dense => {
            let a_id = node.inputs[0];
            let b_id = node.inputs[1];
            let rank = logical.len();
            // A: [B.., M, K]
            let mut a_acc: Vec<DimAccess> = logical[..rank - 1]
                .iter()
                .map(|e| DimAccess::Simple(e.clone()))
                .collect();
            a_acc.push(DimAccess::Simple(red_idx[0].clone()));
            let a_shape = graph.tensor(a_id).shape.clone();
            push_access(&mut accesses, graph, node_id, a_id, &a_shape, &a_acc, layouts);
            // B: [K, N]
            let b_acc = vec![
                DimAccess::Simple(red_idx[0].clone()),
                DimAccess::Simple(logical[rank - 1].clone()),
            ];
            let b_shape = graph.tensor(b_id).shape.clone();
            push_access(&mut accesses, graph, node_id, b_id, &b_shape, &b_acc, layouts);
        }
        _ => unreachable!(),
    }

    // Tensors the weight's `store_at` primitives attached into its own
    // storage: their reads ride the weight slab (same cache line /
    // VMEM block — §4.1.2), so no separate access is emitted.
    let stored_at: Vec<TensorId> = node
        .inputs
        .get(1)
        .map(|&w| {
            layouts
                .get(w)
                .prims
                .iter()
                .filter_map(|p| match p {
                    crate::layout::Primitive::StoreAt { other, .. } => {
                        Some(*other)
                    }
                    _ => None,
                })
                .collect()
        })
        .unwrap_or_default();

    // Fused elementwise tail: extra operands read at the same logical
    // coordinates; the final tensor is the nest's real write.
    let mut extra_flops = 0.0;
    for &tail_id in fused_tail {
        let tail = graph.node(tail_id);
        extra_flops += 1.0;
        for &inp in &tail.inputs {
            let it = graph.tensor(inp);
            // skip the intermediate produced inside this fusion group
            if it.producer == Some(node_id)
                || fused_tail.contains(&it.producer.unwrap_or(usize::MAX))
            {
                continue;
            }
            if stored_at.contains(&inp) {
                continue; // packed into the weight slab by store_at
            }
            let acc: Vec<DimAccess> = if it.rank() == 1 {
                // bias along last logical dim
                vec![DimAccess::Simple(logical.last().unwrap().clone())]
            } else {
                logical.iter().map(|e| DimAccess::Simple(e.clone())).collect()
            };
            let shape = it.shape.clone();
            push_access(&mut accesses, graph, node_id, inp, &shape, &acc, layouts);
        }
    }
    if let Some(&last) = fused_tail.last() {
        let fin = graph.node(last).output;
        let fin_t = graph.tensor(fin);
        let fin_tf = LayoutTransform::new(fin_t.shape.clone(), &layouts.get(fin));
        accesses.push(TensorAccess {
            tensor: fin,
            storage_shape: fin_tf.final_shape().to_vec(),
            idx: fin_tf
                .rewrite_access(
                    &logical
                        .iter()
                        .map(|e| DimAccess::Simple(e.clone()))
                        .collect::<Vec<_>>(),
                )
                .iter()
                .map(|a| a.to_expr())
                .collect(),
            is_write: true,
            elem_bytes: fin_t.dtype.bytes(),
        });
    }

    // Elementwise flops amortize over reduction iterations.
    let red_total: f64 = lop.reduction.iter().map(|&r| r as f64).product();
    Program {
        node: node_id,
        loops: nest.loops,
        accesses,
        flops_per_iter: lop.flops_per_iter + extra_flops / red_total.max(1.0),
        fused: fused_tail.to_vec(),
    }
}

fn push_access(
    accesses: &mut Vec<TensorAccess>,
    graph: &Graph,
    reader: NodeId,
    tensor: TensorId,
    logical_shape: &[i64],
    logical_acc: &[DimAccess],
    layouts: &LayoutAssignment,
) {
    // consumer-side layout: differs from the allocation layout when a
    // conversion op sits on this edge (Fig. 5a)
    let seq = layouts.get_for(reader, tensor);
    let tf = LayoutTransform::new(logical_shape.to_vec(), &seq);
    let idx: Vec<Expr> = tf
        .rewrite_access(logical_acc)
        .iter()
        .map(|a| a.to_expr())
        .collect();
    accesses.push(TensorAccess {
        tensor,
        storage_shape: tf.final_shape().to_vec(),
        idx,
        is_write: false,
        elem_bytes: graph.tensor(tensor).dtype.bytes(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::layout::Primitive;

    fn case_conv(graph: &Graph) -> NodeId {
        graph.complex_nodes()[0]
    }

    fn check_program_addresses_in_bounds(p: &Program) {
        // walk a pseudo-random sample of the iteration space; every
        // access must stay inside its storage shape.
        let extents: Vec<i64> = p.loops.iter().map(|l| l.extent).collect();
        let mut rng = crate::util::Rng::new(99);
        for _ in 0..200 {
            let env: Vec<i64> = extents
                .iter()
                .map(|&e| rng.below(e as usize) as i64)
                .collect();
            for a in &p.accesses {
                let total: i64 = a.storage_shape.iter().product();
                let f = a.flat().eval(&env);
                assert!(
                    f >= 0 && f < total,
                    "access to t{} out of bounds: {f} not in [0,{total})",
                    a.tensor
                );
            }
        }
    }

    #[test]
    fn identity_layout_conv_program() {
        let g = models::case_study();
        let conv = case_conv(&g);
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        let p = lower_complex(&g, conv, &layouts, &sched, &[], 16);
        // 4 spatial + 3 reduction dims, two loops each
        assert_eq!(p.loops.len(), 14);
        assert_eq!(p.accesses.len(), 3); // out, in, weight
        assert!((p.total_flops()
            - 2.0 * (112.0 * 112.0 * 64.0) * (3.0 * 49.0))
            .abs()
            < 1.0);
        check_program_addresses_in_bounds(&p);
    }

    #[test]
    fn tiled_output_layout_reconstructs_nest() {
        let g = models::case_study();
        let conv = case_conv(&g);
        let out = g.node(conv).output;
        let mut layouts = LayoutAssignment::identity(&g);
        // N (H/4) (W/16) (O/16) 4 16 16
        let mut seq = LayoutSeq::new();
        seq.push(Primitive::split(1, &[28, 4]))
            .push(Primitive::split(3, &[7, 16]))
            .push(Primitive::split(5, &[4, 16]))
            .push(Primitive::reorder(&[0, 1, 3, 5, 2, 4, 6]));
        layouts.set(out, seq);
        let sched = LoopSchedule::identity(&[1, 28, 7, 4, 4, 16, 16], &[3, 7, 7]);
        let p = lower_complex(&g, conv, &layouts, &sched, &[], 16);
        // 7 storage dims -> 7 spatial loop pairs + 3 reduction pairs
        assert_eq!(p.loops.len(), 20);
        check_program_addresses_in_bounds(&p);
    }

    #[test]
    fn unfolded_input_layout_in_bounds() {
        let g = models::case_study();
        let conv = case_conv(&g);
        let node = g.node(conv);
        let out = node.output;
        let inp = node.inputs[0]; // padded 230x230x3
        let mut layouts = LayoutAssignment::identity(&g);
        let (ht, wt) = (4i64, 16i64);
        let mut out_seq = LayoutSeq::new();
        out_seq
            .push(Primitive::split(1, &[112 / ht, ht]))
            .push(Primitive::split(3, &[112 / wt, wt]))
            .push(Primitive::split(5, &[4, 16]))
            .push(Primitive::reorder(&[0, 1, 3, 5, 2, 4, 6]));
        layouts.set(out, out_seq);
        // matching unfold on the input: B = V*(ht-1)+M, S = V*ht
        let (v, m) = (2i64, 7i64);
        let mut in_seq = LayoutSeq::new();
        in_seq
            .push(Primitive::unfold(1, v * (ht - 1) + m, v * ht))
            .push(Primitive::unfold(3, v * (wt - 1) + m, v * wt));
        layouts.set(inp, in_seq);
        let sched =
            LoopSchedule::identity(&[1, 28, 7, 4, 4, 16, 16], &[3, 7, 7]);
        let p = lower_complex(&g, conv, &layouts, &sched, &[], 16);
        check_program_addresses_in_bounds(&p);
    }

    #[test]
    fn fused_tail_reads_bias_and_writes_final() {
        let g = models::case_study();
        let conv = case_conv(&g);
        // tail: bias, relu
        let bias_node = conv + 1;
        let relu_node = conv + 2;
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        let p = lower_complex(
            &g,
            conv,
            &layouts,
            &sched,
            &[bias_node, relu_node],
            16,
        );
        // out(non-write), in, weight, bias, final(write)
        assert_eq!(p.accesses.len(), 5);
        let writes: Vec<_> = p.accesses.iter().filter(|a| a.is_write).collect();
        assert_eq!(writes.len(), 1);
        assert_eq!(writes[0].tensor, g.node(relu_node).output);
        check_program_addresses_in_bounds(&p);
    }

    #[test]
    fn store_at_packs_bias_into_weight_slab() {
        // dense + bias: with store_at on the weight, the bias loses its
        // separate access and the weight storage grows by one K-row
        let mut b = crate::graph::GraphBuilder::new("t");
        let x = b.input("x", &["M", "K"], &[8, 16]);
        let _y = b.dense("fc", x, 32);
        let g = b.finish();
        let dense = g.complex_nodes()[0];
        let node = g.node(dense);
        let (w, bias) = (node.inputs[1], node.inputs[1] + 2);
        assert_eq!(g.tensor(bias).shape, vec![32], "bias tensor id");
        let bias_node = dense + 1;

        let sched = LoopSchedule::identity(&[8, 32], &[16]);
        let plain = LayoutAssignment::identity(&g);
        let p0 = lower_complex(&g, dense, &plain, &sched, &[bias_node], 16);

        let mut packed = LayoutAssignment::identity(&g);
        let mut seq = LayoutSeq::new();
        seq.push(crate::layout::Primitive::StoreAt { other: bias, dim: 0 });
        packed.set(w, seq);
        let p1 = lower_complex(&g, dense, &packed, &sched, &[bias_node], 16);

        assert_eq!(p1.accesses.len(), p0.accesses.len() - 1);
        let w_acc = p1.accesses.iter().find(|a| a.tensor == w).unwrap();
        assert_eq!(w_acc.storage_shape, vec![17, 32]); // K+1 rows
        // reads stay within the original K rows
        let extents: Vec<i64> = p1.loops.iter().map(|l| l.extent).collect();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..50 {
            let env: Vec<i64> = extents
                .iter()
                .map(|&e| rng.below(e as usize) as i64)
                .collect();
            let f = w_acc.flat().eval(&env);
            assert!(f >= 0 && f < 17 * 32);
        }
    }

    #[test]
    fn gmm_program() {
        let mut rng = crate::util::Rng::new(4);
        let cfg = models::random_op_config("GMM", &mut rng);
        let gmm = cfg.graph.complex_nodes()[0];
        let layouts = LayoutAssignment::identity(&cfg.graph);
        let out_shape = cfg.graph.tensor(cfg.graph.node(gmm).output).shape.clone();
        let k = *cfg.graph.tensor(cfg.graph.node(gmm).inputs[0]).shape.last().unwrap();
        let sched = LoopSchedule::identity(&out_shape, &[k]);
        let p = lower_complex(&cfg.graph, gmm, &layouts, &sched, &[], 16);
        assert_eq!(p.accesses.len(), 3);
        check_program_addresses_in_bounds(&p);
    }

    #[test]
    fn grouped_conv_channel_mapping_in_bounds() {
        let mut rng = crate::util::Rng::new(7);
        for fam in ["GRP", "DEP", "DIL", "T2D", "C1D", "C3D", "T3D"] {
            let cfg = models::random_op_config(fam, &mut rng);
            let id = cfg.graph.complex_nodes()[0];
            let layouts = LayoutAssignment::identity(&cfg.graph);
            let out_shape =
                cfg.graph.tensor(cfg.graph.node(id).output).shape.clone();
            let sched = LoopSchedule::identity(&out_shape, &[1]);
            // reduction arity fixed by repair()
            let p = lower_complex(&cfg.graph, id, &layouts, &sched, &[], 16);
            check_program_addresses_in_bounds(&p);
        }
    }
}
