//! `alt` — the ALT compiler/auto-tuner launcher (Layer-3 leader).
//!
//! Subcommands:
//!   tune     — joint layout+loop tuning of a network or single op,
//!              through the staged Session pipeline; `--save DIR`
//!              compiles the winner and persists the tuned plan
//!   graph    — print a workload's computational graph
//!   sim      — simulate a network under default layouts/schedules
//!   propagate— show the layout-propagation result of a tuned network
//!   run      — execute for real on the native backend: `--load DIR`
//!              runs a whole saved model end-to-end (no re-tuning);
//!              otherwise a compiled layout variant (the native
//!              interpreter by default, or the PJRT CPU runtime over
//!              AOT HLO artifacts with `--backend pjrt`)
//!   check    — static verification of a saved plan: load + compile,
//!              print per-nest proof certificates (injectivity, bounds,
//!              race-freedom) and lint findings; exit non-zero on
//!              error/warning findings
//!   figures  — regenerate a paper table/figure (also: `figures` binary)
//!
//! Configuration: `--config file.conf` (key = value, see
//! rust/src/config) with `--set key=value` overrides.

use std::collections::HashMap;

use alt::api::Session;
use alt::autotune::tuner::{tune_graph, tune_graphs, tune_op};
use alt::bench::figures;
use alt::bench::harness::Table;
use alt::config::Config;
use alt::graph::models;
use alt::graph::Graph;
use alt::propagate::{propagate, PropMode};
use alt::sim::netsim::simulate_graph;
use alt::sim::HwProfile;

fn usage() -> ! {
    eprintln!(
        "usage: alt <tune|graph|sim|propagate|run|check|figures> [args]
  alt tune --workload r18 [--hw intel|gpu|arm] [--budget N] [--mode alt|wp|ol]
           [--threads N] [--speculation K] [--memo_cap N]
           [--shards N(1=sequential,0=auto)] [--budget_realloc true|false]
           [--rewrite off|on|joint] [--no-rewrite]
           [--save DIR] [--config f.conf] [--set k=v,...] [--op N]
           (--workload a,b,c tunes a whole fleet via the sharded
            multi-workload scheduler; --save compiles the tuned model
            and writes the durable plan + manifest into DIR)
  alt graph --workload mv2
  alt sim --workload bt [--hw gpu]
  alt propagate --workload case_study [--budget N]
  alt run --load DIR [--iters N] [--seed S] [--threads N]
          (whole-model native execution of a saved tuned plan)
  alt run [--backend native|pjrt] [--artifact case_tiled] [--iters N]
          [--scale full|small] [--threads N] [--seed S]
          (--backend pjrt additionally takes --dir artifacts and needs
           the `pjrt` feature; native is the default and needs nothing)
  alt check DIR (or --load DIR)
          (static verification of a saved tuned plan: per-nest
           injectivity/bounds/race-freedom certificates + plan lints;
           exit 0 clean, 1 on error/warning findings, 2 on load errors)
  alt figures <fig1|fig9|fig10|fig11|fig12|table2|table3|motivating|observations|all> [--full]"
    );
    std::process::exit(2);
}

/// Minimal flag parser: --key value / --flag.
fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    m
}

/// Print a launcher-level error and exit — user-facing input problems
/// (bad flags, malformed config files) are refusals, not panics.
fn fatal(msg: impl std::fmt::Display) -> ! {
    eprintln!("alt: {msg}");
    std::process::exit(2);
}

fn build_config(flags: &HashMap<String, String>) -> Config {
    let mut cfg = flags
        .get("config")
        .map(|p| Config::from_file(p).unwrap_or_else(|e| fatal(e)))
        .unwrap_or_default();
    for (k, v) in flags {
        if k != "config" && k != "set" {
            cfg.set(k, v);
        }
    }
    if let Some(sets) = flags.get("set") {
        for kv in sets.split(',') {
            if let Some((k, v)) = kv.split_once('=') {
                cfg.set(k.trim(), v.trim());
            }
        }
    }
    // `--no-rewrite` is the escape hatch: it beats a `rewrite =` value
    // from the config file, a `--rewrite` flag and `--set rewrite=...`
    if flags.contains_key("no-rewrite") {
        cfg.set("rewrite", "off");
    }
    cfg
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    let cfg = build_config(&flags);
    let hw = HwProfile::by_name(cfg.get("hw").unwrap_or("intel"))
        .unwrap_or_else(|| fatal("unknown hw profile"));

    match cmd.as_str() {
        "tune" => {
            let wname = cfg.get("workload").unwrap_or("case_study");
            let opts = cfg.tune_options().unwrap_or_else(|e| fatal(e));
            if wname.contains(',') && cfg.get("op").is_some() {
                eprintln!("--op is not supported with a workload fleet");
                std::process::exit(2);
            }
            if wname.contains(',') {
                // fleet tuning: every workload's shards share one
                // scheduler and engine. Auto-shard unless the user
                // pinned a shard count explicitly (the advertised
                // default for the fleet path).
                let mut opts = opts;
                if cfg.get("shards").is_none() {
                    opts.shards = 0;
                }
                let graphs: Vec<Graph> = wname
                    .split(',')
                    .map(|n| {
                        models::by_name(n.trim())
                            .unwrap_or_else(|| panic!("unknown workload {n}"))
                    })
                    .collect();
                let results = tune_graphs(&graphs, &hw, &opts);
                let mut t = Table::new(
                    "fleet tuning",
                    &["network", "ms", "measurements", "shards", "overshoot"],
                );
                for (g, r) in graphs.iter().zip(&results) {
                    t.row(&[
                        g.name.clone(),
                        format!("{:.4}", r.report.latency_ms()),
                        r.measurements.to_string(),
                        r.shards.to_string(),
                        r.budget_overshoot.to_string(),
                    ]);
                }
                t.print();
                return;
            }
            let g = models::by_name(wname).unwrap_or_else(|| panic!("unknown workload {wname}"));
            if let Some(op) = cfg.get("op") {
                let idx: usize = op
                    .parse()
                    .unwrap_or_else(|e| fatal(format!("--op '{op}': {e}")));
                let complex = g.complex_nodes();
                let Some(&node) = complex.get(idx) else {
                    fatal(format!(
                        "--op {idx} out of range: {} has {} complex ops",
                        g.name,
                        complex.len()
                    ))
                };
                let r = tune_op(&g, node, &hw, &opts);
                println!(
                    "tuned {} op#{node}: {:.4} ms after {} measurements",
                    g.name, r.best_ms, r.measurements
                );
                println!("layout: {:?}", r.decision.out_seq);
                println!("schedule: {:?}", r.sched);
                // optional tuning-curve dump (CSV: measurement, best_ms)
                if let Some(path) = cfg.get("curve") {
                    let mut csv = String::from("measurement,best_ms\n");
                    for (i, ms) in r.history.iter().enumerate() {
                        csv.push_str(&format!("{},{ms}\n", i + 1));
                    }
                    std::fs::write(path, csv).unwrap_or_else(|e| {
                        fatal(format!("write curve {path}: {e}"))
                    });
                    println!("tuning curve -> {path}");
                }
            } else {
                // the staged pipeline: tune → (optionally) compile+save
                let session = Session::new(g)
                    .with_profile(hw.clone())
                    .with_options(opts)
                    .with_exec_threads(cfg.get_usize("exec_threads", 0));
                let tuned = session.tune();
                let Some(r) = tuned.result() else {
                    fatal("tune() returned no result")
                };
                println!(
                    "tuned {} on {}: {:.4} ms end-to-end ({} measurements)",
                    tuned.graph().name,
                    hw.name,
                    r.report.latency_ms(),
                    r.measurements
                );
                let mut t = Table::new("per-op latency", &["node", "label", "ms"]);
                for n in &r.report.per_node {
                    t.row(&[
                        n.node.map(|i| i.to_string()).unwrap_or_default(),
                        n.label.clone(),
                        format!("{:.4}", n.report.latency_ms),
                    ]);
                }
                t.print();
                if let Some(dir) = cfg.get("save").or_else(|| cfg.save_dir()) {
                    let model = tuned
                        .compile()
                        .unwrap_or_else(|e| panic!("compile: {e}"));
                    model
                        .save(dir)
                        .unwrap_or_else(|e| panic!("save {dir}: {e}"));
                    println!(
                        "compiled ({} nests, {} weights packed, {}/{} \
                         rewrites applied, {:.1} ms) \
                         and saved tuned plan + manifest -> {dir}",
                        model.complex_steps(),
                        model.weights_packed(),
                        model.rewrites_applied(),
                        model.rewrites_available(),
                        model.compile_ms()
                    );
                }
            }
        }
        "graph" => {
            let wname = cfg.get("workload").unwrap_or("case_study");
            let g = models::by_name(wname).unwrap_or_else(|| panic!("unknown workload {wname}"));
            println!(
                "{}: {} nodes, {} tensors, {} complex ops, {:.2} GFLOPs",
                g.name,
                g.nodes.len(),
                g.tensors.len(),
                g.complex_nodes().len(),
                g.total_flops() / 1e9
            );
            for n in &g.nodes {
                println!("  {}", g.describe(n.id));
            }
        }
        "sim" => {
            let wname = cfg.get("workload").unwrap_or("case_study");
            let g = models::by_name(wname).unwrap_or_else(|| panic!("unknown workload {wname}"));
            let prop = propagate(&g, &[], PropMode::Alt);
            let rep = simulate_graph(&g, &prop, &HashMap::new(), &hw);
            println!(
                "{} on {} (default layouts/schedules): {:.4} ms, {:.2} GFLOPs",
                g.name,
                hw.name,
                rep.latency_ms(),
                rep.total.flops / 1e9
            );
        }
        "propagate" => {
            let wname = cfg.get("workload").unwrap_or("case_study");
            let g = models::by_name(wname).unwrap_or_else(|| panic!("unknown workload {wname}"));
            let opts = cfg.tune_options().unwrap_or_else(|e| fatal(e));
            let r = tune_graph(&g, &hw, &opts);
            let prop = propagate(&g, &r.decisions, opts.mode);
            println!(
                "{}: {} conversions, {} fusion groups",
                g.name,
                prop.conversions.len(),
                prop.fused_tails.len()
            );
            for c in &prop.conversions {
                println!(
                    "  convert t{} ({}) absorbed_by={:?}",
                    c.tensor,
                    g.tensor(c.tensor).name,
                    c.absorbed_by
                );
            }
        }
        "run" => {
            use alt::runtime::Backend;
            let backend = cfg.backend();
            let iters = cfg.get_usize("iters", 5);
            let seed = cfg.get_u64("seed", 7);
            // whole-model execution of a saved tuned plan (no
            // re-tuning). Only an explicit --load triggers this path:
            // a config file's `save_dir` must not hijack variant runs
            // that pass --backend/--artifact.
            if let Some(dir) = cfg.get("load") {
                let mut tuned = Session::load(dir)
                    .unwrap_or_else(|e| panic!("load {dir}: {e}"));
                // --threads overrides the plan's saved execution width
                // (pure throughput; the plan's value is kept otherwise)
                if cfg.get("threads").is_some() {
                    tuned = tuned.with_exec_threads(cfg.get_usize("threads", 0));
                }
                let model = tuned
                    .compile()
                    .unwrap_or_else(|e| panic!("compile: {e}"));
                println!(
                    "{}: {} complex nests + {} simple ops, {} repacks/run, \
                     {}/{} weights packed at compile ({:.1} ms)",
                    model.graph().name,
                    model.complex_steps(),
                    model.simple_steps(),
                    model.repacks_per_run(),
                    model.weights_packed(),
                    model.weights_total(),
                    model.packing_ms()
                );
                let inputs = model.seeded_inputs(seed);
                let ms = model
                    .bench(&inputs, iters)
                    .unwrap_or_else(|e| panic!("run: {e}"));
                println!(
                    "end-to-end native: median {ms:.3} ms over {iters} runs \
                     ({:.1} inf/s)",
                    1e3 / ms
                );
                return;
            }
            match backend {
                "native" => {
                    let scale = alt::runtime::variants::Scale::from_name(
                        cfg.get("scale").unwrap_or("full"),
                    )
                    .unwrap_or_else(|| panic!("--scale must be small|full"));
                    let threads = cfg.get_usize("threads", 0);
                    let rt = alt::runtime::variants::native_runtime(
                        scale, &hw, threads,
                    )
                    .unwrap_or_else(|e| panic!("native runtime: {e}"));
                    println!("platform: {}", rt.platform());
                    let name =
                        cfg.get("artifact").unwrap_or("case_tiled");
                    let ms = rt
                        .bench_variant(name, seed, iters)
                        .unwrap_or_else(|e| {
                            panic!("{e} (have: {:?})", rt.entries())
                        });
                    println!("{name}: median {ms:.3} ms over {iters} runs");
                }
                "pjrt" => {
                    #[cfg(feature = "pjrt")]
                    {
                        let dir = cfg.get("dir").unwrap_or("artifacts");
                        let name = cfg.get("artifact").unwrap_or("model");
                        let rt = alt::runtime::Runtime::new(dir)
                            .unwrap_or_else(|e| panic!("runtime: {e}"));
                        println!("platform: {}", Backend::platform(&rt));
                        let ms = rt
                            .bench_variant(name, seed, iters)
                            .unwrap_or_else(|e| panic!("{e}"));
                        println!(
                            "{name}: median {ms:.3} ms over {iters} runs"
                        );
                    }
                    #[cfg(not(feature = "pjrt"))]
                    {
                        eprintln!(
                            "`alt run --backend pjrt` needs the PJRT \
                             runtime: rebuild with `--features pjrt` \
                             (requires the xla crate); the default \
                             `--backend native` works without it"
                        );
                        std::process::exit(2);
                    }
                }
                other => {
                    eprintln!("unknown backend '{other}' (native|pjrt)");
                    std::process::exit(2);
                }
            }
        }
        "check" => {
            use alt::analysis::Severity;
            // plan dir: first positional arg, or --load like `run`
            let dir = args
                .get(1)
                .filter(|a| !a.starts_with("--"))
                .map(String::as_str)
                .or_else(|| cfg.get("load"))
                .unwrap_or_else(|| {
                    fatal("check: pass a plan directory (`alt check DIR`)")
                });
            // load/compile failures are exit 2 (input problem), lint
            // findings are exit 1 — CI distinguishes "plan is broken"
            // from "plan compiled but the analyzer objects".
            let tuned =
                Session::load(dir).unwrap_or_else(|e| fatal(format!("load {dir}: {e}")));
            let model = tuned
                .compile()
                .unwrap_or_else(|e| fatal(format!("compile {dir}: {e}")));
            let health = model.health();
            println!(
                "{}: {} complex nests, {} degraded, {} forced repacks, \
                 {}/{} rewrites applied",
                model.graph().name,
                health.nests.len(),
                health.degraded_nests,
                health.forced_repacks,
                health.rewrites_applied,
                health.rewrites_available
            );
            let mut t = Table::new(
                "nest certificates",
                &[
                    "node", "name", "fast", "parallel", "direct",
                    "proof", "race-free", "reads-bounded",
                ],
            );
            for n in &health.nests {
                t.row(&[
                    n.node.to_string(),
                    n.name.clone(),
                    n.fast.to_string(),
                    n.parallel.to_string(),
                    n.writes_direct.to_string(),
                    n.write_proof.to_string(),
                    n.race_free.to_string(),
                    n.reads_bounded.to_string(),
                ]);
            }
            t.print();
            let findings = model.diagnostics();
            for d in &findings {
                println!("{d}");
            }
            // Severity orders Error < Warning < Perf: anything at
            // Warning or stronger fails the check; Perf is advisory.
            let failing = findings
                .iter()
                .filter(|d| d.severity <= Severity::Warning)
                .count();
            if failing > 0 {
                eprintln!(
                    "check: {failing} error/warning finding(s) \
                     ({} total incl. perf advisories)",
                    findings.len()
                );
                std::process::exit(1);
            }
            println!(
                "check: OK — all certificates hold \
                 ({} perf advisories)",
                findings.len()
            );
        }
        "figures" => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or("all");
            let scale = if flags.contains_key("full") {
                figures::Scale::full()
            } else {
                figures::Scale::quick()
            };
            run_figures(which, &scale);
        }
        _ => usage(),
    }
}

fn run_figures(which: &str, scale: &figures::Scale) {
    let print_all = |ts: Vec<Table>| {
        for t in ts {
            t.print();
            println!();
        }
    };
    match which {
        "fig1" => print_all(figures::fig1(scale)),
        "motivating" => figures::motivating(scale).print(),
        "table2" => figures::table2().print(),
        "fig9" => print_all(figures::fig9(scale)),
        "fig10" => print_all(figures::fig10(scale, true)),
        "fig10-full" => print_all(figures::fig10(scale, false)),
        "fig11" => figures::fig11(scale).print(),
        "fig12" => figures::fig12(scale).print(),
        "table3" => figures::table3(scale).print(),
        "observations" => figures::observations(scale).print(),
        "ablations" => print_all(figures::ablations(scale)),
        "all" => {
            figures::table2().print();
            println!();
            figures::motivating(scale).print();
            println!();
            print_all(figures::fig1(scale));
            print_all(figures::fig9(scale));
            print_all(figures::fig10(scale, true));
            figures::fig11(scale).print();
            println!();
            figures::fig12(scale).print();
            println!();
            figures::table3(scale).print();
            println!();
            figures::observations(scale).print();
        }
        _ => {
            eprintln!("unknown figure '{which}'");
            std::process::exit(2);
        }
    }
}
