//! Graph-rewrite subsystem — the pipeline stage between graph
//! construction and tuning.
//!
//! ALT breaks the graph/operator wall for *layouts*; this module breaks
//! it for *graph rewrites* too. Three pieces:
//!
//! 1. **Folding rules** over [`crate::graph::ops`]: constant folding
//!    (simple ops whose inputs are all weights collapse to compile-time
//!    constants), pad-into-conv folding (a single-consumer `PadOp`
//!    feeding a convolution disappears into the consumer's read gather —
//!    the `-1 → 0.0` fill the Fig. 5a fused-conversion machinery already
//!    speaks), and BatchNorm-into-Conv folding (the `torch.jit.freeze`
//!    recipe: scale folds into the packed weights, the residual shift
//!    becomes a per-channel epilogue).
//! 2. **Pattern matcher + rule registry**: the executable rules above
//!    plus epilogue fusion of `Softmax`/`LayerNorm` tails into their
//!    producing complex nest, covering the IPEX production patterns
//!    that map onto the zoo (Conv+Add+ReLU residual joins in
//!    `resnet18_small` — already absorbed by elementwise-tail fusion,
//!    reported by the matcher; Div/Add+Softmax and Add+LayerNorm in
//!    `bert_tiny` — captured by [`RewriteKind::FuseEpilogue`]).
//! 3. **Joint-search integration**: an *anchored* rewrite (epilogue or
//!    BN fold) applies only when its anchor nest's output layout is the
//!    identity — fusing the tail constrains the producer's layout. In
//!    [`RewriteMode::On`] the tuner clamps anchor output layouts so
//!    every anchored rewrite applies; in [`RewriteMode::Joint`] the
//!    clamp is a discrete decision sampled alongside layout proposals,
//!    with a fusion credit in the comparison, so the fuse-or-layout
//!    trade falls out of the joint search instead of a fixed pre-pass.
//!
//! Rewrites are **plan annotations, not graph mutations**: node and
//! tensor ids stay stable, `rewrite = off` is bit-for-bit today's
//! behavior, and a saved plan's `rewrite =` line re-derives the
//! rewritten execution plan exactly on load.

use std::collections::{HashMap, HashSet};

use crate::error::{Error, ErrorKind, Result};
use crate::graph::{EltKind, Graph, NodeId, OpKind};
use crate::propagate::{propagate, ComplexDecision, PropMode};
use crate::tensor::{Role, TensorId};

/// When (and how) the rewrite stage participates in tuning.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RewriteMode {
    /// No rewriting — today's behavior, bit-for-bit.
    #[default]
    Off,
    /// Apply every applicable rewrite; the tuner clamps anchor output
    /// layouts to the identity so anchored rewrites always fire.
    On,
    /// Anchored rewrites are discrete decisions the joint stage samples
    /// alongside layout proposals (with a fusion credit); unanchored
    /// folds always apply.
    Joint,
}

impl RewriteMode {
    /// Canonical spelling — what config files and CLI flags write.
    pub fn name(self) -> &'static str {
        match self {
            RewriteMode::Off => "off",
            RewriteMode::On => "on",
            RewriteMode::Joint => "joint",
        }
    }

    /// Parse the canonical spelling.
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "off" => Some(RewriteMode::Off),
            "on" => Some(RewriteMode::On),
            "joint" => Some(RewriteMode::Joint),
            _ => None,
        }
    }
}

/// Executable rewrite rules — each one changes what the compiled plan
/// executes (and is therefore serialized into `plan.txt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RewriteKind {
    /// A simple op whose inputs are all compile-time constants is
    /// evaluated at compile time.
    FoldConstant,
    /// A single-consumer `PadOp` feeding a complex nest folds into the
    /// consumer's read gather (`-1` slots read `0.0`).
    FoldPad,
    /// `BatchNorm` directly after a convolution folds into the packed
    /// weights (scale) plus a per-channel epilogue shift.
    FoldBatchNorm,
    /// A sole-consumer `Softmax`/`LayerNorm` of a complex nest's
    /// (tail-)output fuses as an in-buffer epilogue of that nest.
    FuseEpilogue,
}

impl RewriteKind {
    pub fn name(self) -> &'static str {
        match self {
            RewriteKind::FoldConstant => "fold_const",
            RewriteKind::FoldPad => "fold_pad",
            RewriteKind::FoldBatchNorm => "fold_bn",
            RewriteKind::FuseEpilogue => "fuse_epilogue",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "fold_const" => Some(RewriteKind::FoldConstant),
            "fold_pad" => Some(RewriteKind::FoldPad),
            "fold_bn" => Some(RewriteKind::FoldBatchNorm),
            "fuse_epilogue" => Some(RewriteKind::FuseEpilogue),
            _ => None,
        }
    }

    /// Anchored rules only apply when the anchor's output layout is the
    /// identity (the rewrite↔layout interaction the joint stage tunes).
    pub fn anchored(self) -> bool {
        matches!(self, RewriteKind::FoldBatchNorm | RewriteKind::FuseEpilogue)
    }
}

/// One chosen rewrite, serialized into plans as `kind:node:anchor`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RewriteDecision {
    pub kind: RewriteKind,
    /// The folded / absorbed node.
    pub node: NodeId,
    /// The complex node absorbing it (== `node` for unanchored folds
    /// with no complex consumer, i.e. `FoldConstant`).
    pub anchor: NodeId,
}

impl RewriteDecision {
    /// Plan-file spelling.
    pub fn fmt(&self) -> String {
        format!("{}:{}:{}", self.kind.name(), self.node, self.anchor)
    }

    /// Parse the plan-file spelling.
    pub fn parse(s: &str) -> Option<Self> {
        let mut it = s.split(':');
        let kind = RewriteKind::from_name(it.next()?)?;
        let node = it.next()?.parse().ok()?;
        let anchor = it.next()?.parse().ok()?;
        if it.next().is_some() {
            return None;
        }
        Some(Self { kind, node, anchor })
    }
}

/// A rewrite the registry matched on this graph. Whether it is *chosen*
/// depends on the mode and (for anchored rules) on the anchor's tuned
/// output layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub kind: RewriteKind,
    pub node: NodeId,
    pub anchor: NodeId,
}

impl Candidate {
    pub fn decision(&self) -> RewriteDecision {
        RewriteDecision { kind: self.kind, node: self.node, anchor: self.anchor }
    }
}

/// A report-only pattern match — production fusion patterns the stack
/// already covers through elementwise-tail fusion (or a named rule),
/// surfaced for diagnostics.
#[derive(Clone, Debug)]
pub struct PatternMatch {
    pub pattern: &'static str,
    pub node: NodeId,
}

/// Everything the matcher found on one graph.
#[derive(Clone, Debug, Default)]
pub struct Analysis {
    /// Executable rewrite candidates, ascending by folded node id.
    pub candidates: Vec<Candidate>,
    /// Report-only pattern matches (IPEX production list).
    pub patterns: Vec<PatternMatch>,
}

impl Analysis {
    /// Complex nodes that anchor at least one anchored candidate — the
    /// nodes whose output-layout choice the joint stage couples with a
    /// fuse-or-not decision.
    pub fn anchors(&self) -> HashSet<NodeId> {
        self.candidates
            .iter()
            .filter(|c| c.kind.anchored())
            .map(|c| c.anchor)
            .collect()
    }

    /// The candidate folding `node`, if any.
    pub fn candidate_for(&self, node: NodeId) -> Option<&Candidate> {
        self.candidates.iter().find(|c| c.node == node)
    }
}

/// Trace `t` upstream through single-input elementwise producers to the
/// nearest complex producer (for the report-only pattern matcher).
fn complex_source(graph: &Graph, mut t: TensorId) -> Option<NodeId> {
    loop {
        let p = graph.producer(t)?;
        let node = graph.node(p);
        if node.is_complex() {
            return Some(p);
        }
        if !node.is_elementwise() {
            return None;
        }
        t = node.inputs[0];
    }
}

/// The effective written tensor of each complex node under structural
/// (empty-decision) propagation, after the same last-claimant tail
/// dedup the model compiler applies: chains that merge at residual
/// joins are owned by the LAST topological claimant, earlier claimants
/// truncate before the shared suffix.
fn effective_outputs(graph: &Graph) -> HashMap<NodeId, TensorId> {
    let prop = propagate(graph, &[], PropMode::Alt);
    let mut tail_owner: HashMap<NodeId, NodeId> = HashMap::new();
    for node in &graph.nodes {
        if let Some(tail) = prop.fused_tails.get(&node.id) {
            for &t in tail {
                tail_owner.insert(t, node.id);
            }
        }
    }
    let mut out = HashMap::new();
    for node in &graph.nodes {
        if !node.is_complex() {
            continue;
        }
        let mut tail =
            prop.fused_tails.get(&node.id).cloned().unwrap_or_default();
        if let Some(cut) =
            tail.iter().position(|t| tail_owner.get(t) != Some(&node.id))
        {
            tail.truncate(cut);
        }
        let written = tail
            .last()
            .map(|&t| graph.node(t).output)
            .unwrap_or(node.output);
        out.insert(node.id, written);
    }
    out
}

/// Run the rewrite rule registry over `graph`. Deterministic, layout-
/// independent: the same candidates come out at tune time, at plan
/// validation, and after `save`/`load`.
pub fn analyze(graph: &Graph) -> Analysis {
    let mut analysis = Analysis::default();
    let output_id = match graph.nodes.last() {
        Some(n) => n.output,
        None => return analysis,
    };

    // ---- constant folding (cascades in topological order) ----
    let mut folded: HashSet<TensorId> = HashSet::new();
    for node in &graph.nodes {
        if node.is_complex() || matches!(node.kind, OpKind::LayoutConvert) {
            continue;
        }
        let all_const = !node.inputs.is_empty()
            && node.inputs.iter().all(|&t| {
                graph.tensor(t).role == Role::Weight || folded.contains(&t)
            });
        if all_const && node.output != output_id {
            folded.insert(node.output);
            analysis.candidates.push(Candidate {
                kind: RewriteKind::FoldConstant,
                node: node.id,
                anchor: node.id,
            });
        }
    }

    // ---- pad-into-conv folding ----
    for node in &graph.nodes {
        let OpKind::PadOp { .. } = node.kind else { continue };
        if folded.contains(&node.output)
            || graph.tensor(node.inputs[0]).role == Role::Weight
        {
            continue;
        }
        let consumers = graph.consumers(node.output);
        let [c] = consumers.as_slice() else { continue };
        let consumer = graph.node(*c);
        if consumer.is_complex() && consumer.inputs[0] == node.output {
            analysis.candidates.push(Candidate {
                kind: RewriteKind::FoldPad,
                node: node.id,
                anchor: *c,
            });
        }
    }

    // ---- BN-into-Conv folding + epilogue fusion (anchored) ----
    let written = effective_outputs(graph);
    let anchor_of: HashMap<TensorId, NodeId> =
        written.iter().map(|(&n, &t)| (t, n)).collect();
    for node in &graph.nodes {
        let fusable = match node.kind {
            // BN folds only through a convolution's linear output —
            // never through a fused nonlinear tail.
            OpKind::BatchNorm => {
                matches!(
                    graph.producer(node.inputs[0]).map(|p| &graph.node(p).kind),
                    Some(OpKind::Conv { .. })
                ) && node.inputs[1..]
                    .iter()
                    .all(|&t| graph.tensor(t).role == Role::Weight)
            }
            OpKind::Softmax { .. } | OpKind::LayerNorm { .. } => true,
            _ => false,
        };
        if !fusable {
            continue;
        }
        let t = node.inputs[0];
        let Some(&anchor) = anchor_of.get(&t) else { continue };
        if graph.consumers(t).len() != 1 {
            continue;
        }
        // BN additionally requires the *direct* conv output (no tail).
        let kind = match node.kind {
            OpKind::BatchNorm => {
                if t != graph.node(anchor).output {
                    continue;
                }
                RewriteKind::FoldBatchNorm
            }
            _ => RewriteKind::FuseEpilogue,
        };
        analysis.candidates.push(Candidate { kind, node: node.id, anchor });
    }
    analysis.candidates.sort_by_key(|c| (c.node, c.anchor, c.kind));

    // ---- report-only IPEX pattern matches ----
    for node in &graph.nodes {
        match &node.kind {
            OpKind::Eltwise { kind: EltKind::Add, arity: 2 } => {
                let joins_conv = node
                    .inputs
                    .iter()
                    .any(|&t| complex_source(graph, t).is_some());
                let relu_next = graph
                    .consumers(node.output)
                    .iter()
                    .any(|&c| {
                        matches!(
                            graph.node(c).kind,
                            OpKind::Eltwise { kind: EltKind::Relu, .. }
                        )
                    });
                if joins_conv && relu_next {
                    analysis.patterns.push(PatternMatch {
                        pattern: "conv_add_relu",
                        node: node.id,
                    });
                }
            }
            OpKind::Eltwise { kind: EltKind::Gelu, .. } => {
                if matches!(
                    complex_source(graph, node.inputs[0])
                        .map(|p| &graph.node(p).kind),
                    Some(OpKind::Dense | OpKind::Matmul)
                ) {
                    analysis.patterns.push(PatternMatch {
                        pattern: "linear_gelu",
                        node: node.id,
                    });
                }
            }
            OpKind::LayerNorm { .. } => {
                if matches!(
                    graph.producer(node.inputs[0]).map(|p| &graph.node(p).kind),
                    Some(OpKind::Eltwise { kind: EltKind::Add, .. })
                ) {
                    analysis.patterns.push(PatternMatch {
                        pattern: "add_layernorm",
                        node: node.id,
                    });
                }
            }
            OpKind::Softmax { .. } => {
                if complex_source(graph, node.inputs[0]).is_some() {
                    analysis.patterns.push(PatternMatch {
                        pattern: "div_add_softmax",
                        node: node.id,
                    });
                }
            }
            _ => {}
        }
    }
    analysis
}

/// Whether `decisions` leaves `anchor`'s output layout at the identity
/// (complex nodes absent from the decision list default to identity).
fn identity_out(decisions: &[ComplexDecision], anchor: NodeId) -> bool {
    decisions
        .iter()
        .find(|d| d.node == anchor)
        .map_or(true, |d| d.out_seq.is_identity())
}

/// Select the rewrites that apply for one set of layout decisions:
/// unanchored folds always apply (when rewriting is enabled at all);
/// anchored ones only when the anchor's chosen output layout is the
/// identity — and only under full ALT propagation, since the ablation
/// modes rewrite decisions behind the tuner's back.
pub fn select(
    analysis: &Analysis,
    mode: RewriteMode,
    prop_mode: PropMode,
    decisions: &[ComplexDecision],
) -> Vec<RewriteDecision> {
    if mode == RewriteMode::Off {
        return Vec::new();
    }
    analysis
        .candidates
        .iter()
        .filter(|c| {
            !c.kind.anchored()
                || (prop_mode == PropMode::Alt
                    && identity_out(decisions, c.anchor))
        })
        .map(Candidate::decision)
        .collect()
}

/// Validate a plan's rewrite list against a fresh analysis of `graph`
/// (hand-edited or corrupt plans get a typed `Compile` refusal), and
/// return the analysis for the compiler to key off.
pub fn validate(
    graph: &Graph,
    rewrites: &[RewriteDecision],
    decisions: &[ComplexDecision],
) -> Result<Analysis> {
    let analysis = analyze(graph);
    let mut seen: HashSet<NodeId> = HashSet::new();
    for r in rewrites {
        if !seen.insert(r.node) {
            return Err(Error::with_kind(
                ErrorKind::Compile,
                format!("{}: node {} rewritten twice", graph.name, r.node),
            ));
        }
        let ok = analysis
            .candidates
            .iter()
            .any(|c| c.decision() == *r);
        if !ok {
            return Err(Error::with_kind(
                ErrorKind::Compile,
                format!(
                    "{}: rewrite {} does not match any candidate on this \
                     graph",
                    graph.name,
                    r.fmt()
                ),
            ));
        }
        if r.kind.anchored() && !identity_out(decisions, r.anchor) {
            return Err(Error::with_kind(
                ErrorKind::Compile,
                format!(
                    "{}: anchored rewrite {} requires the identity output \
                     layout on node {}",
                    graph.name,
                    r.fmt(),
                    r.anchor
                ),
            ));
        }
    }
    Ok(analysis)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::layout::{LayoutSeq, Primitive};

    #[test]
    fn mode_and_kind_names_round_trip() {
        for m in [RewriteMode::Off, RewriteMode::On, RewriteMode::Joint] {
            assert_eq!(RewriteMode::from_name(m.name()), Some(m));
        }
        for k in [
            RewriteKind::FoldConstant,
            RewriteKind::FoldPad,
            RewriteKind::FoldBatchNorm,
            RewriteKind::FuseEpilogue,
        ] {
            assert_eq!(RewriteKind::from_name(k.name()), Some(k));
        }
        assert!(RewriteMode::from_name("maybe").is_none());
        let d = RewriteDecision {
            kind: RewriteKind::FoldPad,
            node: 3,
            anchor: 4,
        };
        assert_eq!(RewriteDecision::parse(&d.fmt()), Some(d));
        assert!(RewriteDecision::parse("fold_pad:3").is_none());
        assert!(RewriteDecision::parse("fold_pad:3:4:5").is_none());
    }

    #[test]
    fn resnet18_small_folds_every_conv_pad() {
        let g = models::resnet18_small();
        let a = analyze(&g);
        let pads: Vec<_> = a
            .candidates
            .iter()
            .filter(|c| c.kind == RewriteKind::FoldPad)
            .collect();
        // conv1 + 8 blocks x (c1, c2) pads; the pool pad must NOT fold
        assert_eq!(pads.len(), 17, "{pads:?}");
        let pool_pad = g
            .nodes
            .iter()
            .find(|n| n.name == "pool1.pad")
            .map(|n| n.id)
            .unwrap();
        assert!(pads.iter().all(|c| c.node != pool_pad));
        // the residual joins match the production pattern list
        let joins = a
            .patterns
            .iter()
            .filter(|p| p.pattern == "conv_add_relu")
            .count();
        assert_eq!(joins, 8);
    }

    #[test]
    fn bert_tiny_fuses_softmax_and_layernorm_epilogues() {
        let g = models::bert_tiny();
        let a = analyze(&g);
        let epis: Vec<_> = a
            .candidates
            .iter()
            .filter(|c| c.kind == RewriteKind::FuseEpilogue)
            .collect();
        // per layer: softmax (anchored at scores), ln1 (at o-proj's
        // res1 tail), ln2 (at ffn2's res2 tail)
        assert_eq!(epis.len(), 6, "{epis:?}");
        for c in &epis {
            assert!(g.node(c.anchor).is_complex());
        }
        assert!(a.patterns.iter().any(|p| p.pattern == "linear_gelu"));
        assert!(a.patterns.iter().any(|p| p.pattern == "add_layernorm"));
        assert!(a.patterns.iter().any(|p| p.pattern == "div_add_softmax"));
    }

    #[test]
    fn anchored_rewrites_require_identity_output_layout() {
        let g = models::bert_tiny();
        let a = analyze(&g);
        let epi = a
            .candidates
            .iter()
            .find(|c| c.kind == RewriteKind::FuseEpilogue)
            .copied()
            .unwrap();
        let all = select(&a, RewriteMode::On, PropMode::Alt, &[]);
        assert!(all.contains(&epi.decision()));
        // a non-identity output layout on the anchor blocks it
        let mut seq = LayoutSeq::new();
        seq.push(Primitive::reorder(&[1, 0]));
        let dec = ComplexDecision {
            node: epi.anchor,
            out_seq: seq,
            ..Default::default()
        };
        let constrained =
            select(&a, RewriteMode::On, PropMode::Alt, &[dec.clone()]);
        assert!(!constrained.contains(&epi.decision()));
        // and validate() refuses the inconsistent pairing
        assert!(validate(&g, &[epi.decision()], &[dec]).is_err());
        // off mode selects nothing at all
        assert!(select(&a, RewriteMode::Off, PropMode::Alt, &[]).is_empty());
    }

    #[test]
    fn validate_rejects_foreign_rewrites() {
        let g = models::case_study_small(); // pad-free: no candidates
        let a = analyze(&g);
        assert!(a.candidates.is_empty());
        let bogus = RewriteDecision {
            kind: RewriteKind::FoldPad,
            node: 0,
            anchor: 1,
        };
        assert!(validate(&g, &[bogus], &[]).is_err());
    }
}
