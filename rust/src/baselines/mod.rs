//! Baseline tuners/compilers (paper §7 comparators).
//!
//! * [`vendor`] — a vendor-library stand-in (Torch/MKL-DNN/cuDNN/
//!   XNNPACK): one fixed hand-written schedule on the platform's default
//!   layout, no search.
//! * [`autotvm_like`] — template-based tuning over a *small* predefined
//!   space with simulated annealing (AutoTVM's limitation: small space).
//! * [`flextensor_like`] — schedule-space random walk with **no cost
//!   model** (every candidate is measured).
//! * [`ansor_like`] — loop-only tuning with sketch sampling + evolution
//!   + the GBT cost model; layouts stay at the framework default
//!   (NHWO-family), like Ansor without NeoCPU layout packing.
//!
//! All baselines consume the same budget unit as ALT: one simulated
//! measurement. This is the §7 "search budget" metric.

use crate::autotune::space::LoopSpace;
use crate::codegen::lower_complex;
use crate::cost::CostModel;
use crate::graph::{Graph, NodeId};
use crate::loops::LoopSchedule;
use crate::propagate::{propagate, PropMode, PropagationResult};
use crate::sim::{simulate_program, HwProfile};
use crate::util::Rng;

/// Outcome of a baseline run on one operator.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    pub name: &'static str,
    pub best_ms: f64,
    pub measurements: usize,
}

fn nest_dims(graph: &Graph, node: NodeId) -> (Vec<i64>, Vec<i64>) {
    let n = graph.node(node);
    let storage = graph.tensor(n.output).shape.clone();
    let reduction = match &n.kind {
        crate::graph::OpKind::Conv { kernel, groups, .. } => {
            let ci = *graph.tensor(n.inputs[0]).shape.last().unwrap();
            let mut r = vec![ci / groups];
            r.extend(kernel.iter().copied());
            r
        }
        crate::graph::OpKind::Matmul | crate::graph::OpKind::Dense => {
            vec![*graph.tensor(n.inputs[0]).shape.last().unwrap()]
        }
        _ => vec![1],
    };
    (storage, reduction)
}

fn measure(
    graph: &Graph,
    node: NodeId,
    prop: &PropagationResult,
    sched: &LoopSchedule,
    hw: &HwProfile,
) -> f64 {
    let tail = prop.fused_tails.get(&node).cloned().unwrap_or_default();
    let p = lower_complex(graph, node, &prop.layouts, sched, &tail, hw.simd_lanes);
    simulate_program(&p, hw).latency_ms
}

/// Vendor library: one heuristic schedule, channels-last default layout,
/// no search. (Vendor kernels are hand-tuned for *common* shapes; the
/// heuristic mirrors that: tile to lanes, vectorize, parallel outer.)
pub fn vendor(graph: &Graph, node: NodeId, hw: &HwProfile) -> BaselineResult {
    let prop = propagate(graph, &[], PropMode::Alt);
    let (sp, rd) = nest_dims(graph, node);
    let mut sched = LoopSchedule::identity(&sp, &rd);
    // classic fixed recipe: tile last dim to lanes, spatial rows by 4
    for (i, t) in sched.spatial_tiles.iter_mut().enumerate() {
        let e = sp[i];
        *t = if i + 1 == sp.len() {
            crate::util::round_to_divisor(e, hw.simd_lanes as f64)
        } else {
            crate::util::round_to_divisor(e, 4.0)
        };
    }
    sched.vectorize = true;
    sched.parallel = 2;
    sched.unroll = 4;
    let ms = measure(graph, node, &prop, &sched, hw);
    BaselineResult { name: "vendor", best_ms: ms, measurements: 1 }
}

/// AutoTVM-like: simulated annealing over a small hand-template space
/// (tiles restricted to powers of two ≤ 64, fixed annotations).
pub fn autotvm_like(
    graph: &Graph,
    node: NodeId,
    hw: &HwProfile,
    budget: usize,
    seed: u64,
) -> BaselineResult {
    let mut rng = Rng::new(seed ^ 0xA7);
    let prop = propagate(graph, &[], PropMode::Alt);
    let (sp, rd) = nest_dims(graph, node);
    let pow2 = |e: i64, rng: &mut Rng| -> i64 {
        let opts: Vec<i64> = [1i64, 2, 4, 8, 16, 32, 64]
            .into_iter()
            .filter(|f| e % f == 0)
            .collect();
        *rng.choose(&opts)
    };
    let sample = |rng: &mut Rng| -> LoopSchedule {
        let mut s = LoopSchedule::identity(&sp, &rd);
        s.spatial_tiles = sp.iter().map(|&e| pow2(e, rng)).collect();
        s.reduction_tiles = rd.iter().map(|&e| pow2(e, rng)).collect();
        s.vectorize = true;
        s.parallel = 2;
        s
    };
    let mut cur = sample(&mut rng);
    let mut cur_ms = measure(graph, node, &prop, &cur, hw);
    let mut best_ms = cur_ms;
    let mut temp = 1.0;
    for i in 1..budget {
        // mutate one dimension
        let mut cand = cur.clone();
        let d = rng.below(sp.len() + rd.len());
        if d < sp.len() {
            cand.spatial_tiles[d] = pow2(sp[d], &mut rng);
        } else {
            cand.reduction_tiles[d - sp.len()] = pow2(rd[d - sp.len()], &mut rng);
        }
        let ms = measure(graph, node, &prop, &cand, hw);
        let accept = ms < cur_ms
            || rng.uniform() < (-(ms - cur_ms) / (cur_ms * temp)).exp();
        if accept {
            cur = cand;
            cur_ms = ms;
        }
        best_ms = best_ms.min(ms);
        temp = (1.0 - i as f64 / budget as f64).max(0.05);
    }
    BaselineResult { name: "autotvm", best_ms, measurements: budget }
}

/// FlexTensor-like: random walk over the full loop space, no cost model
/// — every candidate costs one measurement.
pub fn flextensor_like(
    graph: &Graph,
    node: NodeId,
    hw: &HwProfile,
    budget: usize,
    seed: u64,
) -> BaselineResult {
    let mut rng = Rng::new(seed ^ 0xF1E);
    let prop = propagate(graph, &[], PropMode::Alt);
    let (sp, rd) = nest_dims(graph, node);
    let space = LoopSpace::new(&sp, &rd);
    let mut best_point = space.default_point();
    let mut best_ms =
        measure(graph, node, &prop, &space.decode(&best_point), hw);
    for i in 1..budget {
        let cand = if i % 5 == 0 {
            space.random_point(&mut rng)
        } else {
            let dim = rng.below(space.n_dims());
            let dir = if rng.uniform() < 0.5 { 1 } else { -1 };
            space.neighbor(&best_point, dim, dir)
        };
        let ms = measure(graph, node, &prop, &space.decode(&cand), hw);
        if ms < best_ms {
            best_ms = ms;
            best_point = cand;
        }
    }
    BaselineResult { name: "flextensor", best_ms, measurements: budget }
}

/// Ansor-like: loop-only tuning with batch sampling + mutation guided by
/// the GBT cost model; only top-k per batch are measured. Layouts stay
/// at the framework default.
pub fn ansor_like(
    graph: &Graph,
    node: NodeId,
    hw: &HwProfile,
    budget: usize,
    seed: u64,
) -> BaselineResult {
    let mut rng = Rng::new(seed ^ 0xA502);
    let prop = propagate(graph, &[], PropMode::Alt);
    let (sp, rd) = nest_dims(graph, node);
    let space = LoopSpace::new(&sp, &rd);
    let mut cost = CostModel::new();
    let tail = prop.fused_tails.get(&node).cloned().unwrap_or_default();

    let mut best_point = space.default_point();
    let mut best_ms = f64::INFINITY;
    let mut used = 0usize;
    let (batch, top_k) = (16usize, 4usize);
    while used < budget {
        let mut cands = Vec::with_capacity(batch);
        for b in 0..batch {
            if b % 2 == 0 || !best_ms.is_finite() {
                cands.push(space.random_point(&mut rng));
            } else {
                // evolutionary mutation of the incumbent
                let mut p = best_point.clone();
                for _ in 0..(1 + rng.below(2)) {
                    let dim = rng.below(space.n_dims());
                    let dir = if rng.uniform() < 0.5 { 1 } else { -1 };
                    p = space.neighbor(&p, dim, dir);
                }
                cands.push(p);
            }
        }
        let mut scored: Vec<(usize, f64)> = cands
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let prog = lower_complex(
                    graph,
                    node,
                    &prop.layouts,
                    &space.decode(p),
                    &tail,
                    hw.simd_lanes,
                );
                (i, cost.predict(&prog))
            })
            .collect();
        // NaN-safe, NaN predictions rank last
        scored.sort_by(|a, b| crate::util::stats::nan_last_cmp(a.1, b.1));
        for &(i, _) in scored.iter().take(top_k.min(budget - used)) {
            let sched = space.decode(&cands[i]);
            let prog = lower_complex(
                graph, node, &prop.layouts, &sched, &tail, hw.simd_lanes,
            );
            let ms = simulate_program(&prog, hw).latency_ms;
            cost.observe(&prog, ms);
            used += 1;
            if ms < best_ms {
                best_ms = ms;
                best_point = cands[i].clone();
            }
        }
    }
    BaselineResult { name: "ansor", best_ms, measurements: used }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn all_baselines_run_on_case_study() {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let hw = HwProfile::intel();
        let v = vendor(&g, conv, &hw);
        let a = autotvm_like(&g, conv, &hw, 20, 1);
        let f = flextensor_like(&g, conv, &hw, 20, 1);
        let n = ansor_like(&g, conv, &hw, 20, 1);
        for r in [&v, &a, &f, &n] {
            assert!(r.best_ms.is_finite() && r.best_ms > 0.0, "{}", r.name);
        }
    }

    /// Structural sanity: with equal budgets, the cost-model-guided
    /// searcher should not lose badly to the blind random walk.
    #[test]
    fn ansor_not_worse_than_flextensor() {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let hw = HwProfile::intel();
        let mut wins = 0;
        for seed in 0..3 {
            let a = ansor_like(&g, conv, &hw, 40, seed);
            let f = flextensor_like(&g, conv, &hw, 40, seed);
            if a.best_ms <= f.best_ms * 1.1 {
                wins += 1;
            }
        }
        assert!(wins >= 2, "ansor lost to flextensor in {}/3 seeds", 3 - wins);
    }

    #[test]
    fn budget_accounting_exact() {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let hw = HwProfile::arm();
        let a = autotvm_like(&g, conv, &hw, 15, 7);
        assert_eq!(a.measurements, 15);
        let n = ansor_like(&g, conv, &hw, 17, 7);
        assert!(n.measurements >= 17 && n.measurements <= 17 + 4);
    }
}
