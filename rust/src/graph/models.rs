//! Builders for the paper's evaluation workloads (§7): ResNet-18,
//! MobileNet-V2, BERT-base/tiny, ResNet3D-18, the micro-benchmark
//! subgraphs of §7.3, and the randomized single-operator configurations
//! of §7.1.

use crate::graph::{EltKind, Graph, GraphBuilder, OpKind, PoolKind};
use crate::util::Rng;

/// The model zoo: every workload name the launcher, the figure
/// harnesses and the serving plans accept, with its aliases. This is
/// the single name→graph mapping — `main.rs`, the figures binary, the
/// bench harness and `api::Session::load` all resolve through it, and
/// a saved plan's `model` key must be one of the canonical names.
pub fn by_name(name: &str) -> Option<Graph> {
    match name {
        "resnet18" | "r18" => Some(resnet18(1)),
        "resnet18-b16" => Some(resnet18(16)),
        "resnet18_small" | "r18s" => Some(resnet18_small()),
        "mobilenet_v2" | "mv2" => Some(mobilenet_v2(1)),
        "bert_base" | "bb" => Some(bert_base()),
        "bert_tiny" | "bt" => Some(bert_tiny()),
        "resnet3d_18" | "r3d" => Some(resnet3d_18(1)),
        "case_study" | "case" => Some(case_study()),
        "case_study_small" | "cs" => Some(case_study_small()),
        "subgraph1" => Some(prop_subgraph(7)),
        "subgraph2" => Some(prop_subgraph(14)),
        _ => None,
    }
}

/// Canonical zoo names (the strings a graph's `name` field carries, so
/// `by_name(g.name)` round-trips for every zoo member).
pub const MODEL_NAMES: [&str; 11] = [
    "resnet18",
    "resnet18-b16",
    "resnet18_small",
    "mobilenet_v2",
    "bert_base",
    "bert_tiny",
    "resnet3d_18",
    "case_study",
    "case_study_small",
    "subgraph1",
    "subgraph2",
];

/// ResNet-18 (image, NHWI 224²). `batch` is the paper's b1/b16 knob.
pub fn resnet18(batch: i64) -> Graph {
    let name =
        if batch == 1 { "resnet18".to_string() } else { format!("resnet18-b{batch}") };
    let mut b = GraphBuilder::new(&name);
    let x = b.input("x", &["N", "H", "W", "I"], &[batch, 224, 224, 3]);
    let mut t = b.conv_bias_relu("conv1", x, 64, 7, 2, 3);
    // maxpool with pad 1 (112 -> 56)
    let pooled_pad = b.op(
        "pool1.pad",
        OpKind::PadOp { before: vec![0, 1, 1, 0], after: vec![0, 1, 1, 0] },
        &[t],
    );
    t = b.op(
        "pool1",
        OpKind::Pool { kind: PoolKind::Max, kernel: vec![3, 3], stride: vec![2, 2] },
        &[pooled_pad],
    );
    let stages: [(i64, i64, usize); 4] =
        [(64, 1, 2), (128, 2, 2), (256, 2, 2), (512, 2, 2)];
    for (si, (ch, first_stride, blocks)) in stages.iter().enumerate() {
        for blk in 0..*blocks {
            let stride = if blk == 0 { *first_stride } else { 1 };
            let name = format!("s{si}b{blk}");
            let shortcut = if stride != 1
                || b.graph.tensor(t).shape.last() != Some(ch)
            {
                b.conv2d(&format!("{name}.down"), t, *ch, 1, stride, 0)
            } else {
                t
            };
            let c1 = b.conv_bias_relu(&format!("{name}.c1"), t, *ch, 3, stride, 1);
            let c2 = b.conv2d(&format!("{name}.c2"), c1, *ch, 3, 1, 1);
            let bias = b.weight(&format!("{name}.c2.b"), &["O"], &[*ch]);
            let c2b = b.op(&format!("{name}.c2.bias"), OpKind::BiasAdd, &[c2, bias]);
            let sum = b.add(&format!("{name}.add"), c2b, shortcut);
            t = b.relu(&format!("{name}.relu"), sum);
        }
    }
    t = b.op("gap", OpKind::Reduce { keep_last: true }, &[t]);
    b.dense("fc", t, 1000);
    b.finish()
}

/// ResNet-18 at "Small" scale: the full 18-layer topology (stem conv,
/// max-pool, four residual stages with downsample shortcuts, global
/// average pool, classifier) on a 56² input with quarter-width
/// channels. Small enough that the whole network *executes* on the
/// native interpreter backend in well under a second, so the Session
/// tune→compile→run pipeline can be exercised end-to-end in tier-1
/// tests and the serving bench.
pub fn resnet18_small() -> Graph {
    let mut b = GraphBuilder::new("resnet18_small");
    let x = b.input("x", &["N", "H", "W", "I"], &[1, 56, 56, 3]);
    let mut t = b.conv_bias_relu("conv1", x, 16, 7, 2, 3);
    // maxpool with pad 1 (28 -> 14)
    let pooled_pad = b.op(
        "pool1.pad",
        OpKind::PadOp { before: vec![0, 1, 1, 0], after: vec![0, 1, 1, 0] },
        &[t],
    );
    t = b.op(
        "pool1",
        OpKind::Pool { kind: PoolKind::Max, kernel: vec![3, 3], stride: vec![2, 2] },
        &[pooled_pad],
    );
    let stages: [(i64, i64, usize); 4] =
        [(16, 1, 2), (32, 2, 2), (64, 2, 2), (128, 2, 2)];
    for (si, (ch, first_stride, blocks)) in stages.iter().enumerate() {
        for blk in 0..*blocks {
            let stride = if blk == 0 { *first_stride } else { 1 };
            let name = format!("s{si}b{blk}");
            let shortcut = if stride != 1
                || b.graph.tensor(t).shape.last() != Some(ch)
            {
                b.conv2d(&format!("{name}.down"), t, *ch, 1, stride, 0)
            } else {
                t
            };
            let c1 = b.conv_bias_relu(&format!("{name}.c1"), t, *ch, 3, stride, 1);
            let c2 = b.conv2d(&format!("{name}.c2"), c1, *ch, 3, 1, 1);
            let bias = b.weight(&format!("{name}.c2.b"), &["O"], &[*ch]);
            let c2b = b.op(&format!("{name}.c2.bias"), OpKind::BiasAdd, &[c2, bias]);
            let sum = b.add(&format!("{name}.add"), c2b, shortcut);
            t = b.relu(&format!("{name}.relu"), sum);
        }
    }
    t = b.op("gap", OpKind::Reduce { keep_last: true }, &[t]);
    b.dense("fc", t, 100);
    b.finish()
}

/// MobileNet-V2 (lightweight; depthwise-heavy — the paper's
/// memory-bound showcase in Fig. 10).
pub fn mobilenet_v2(batch: i64) -> Graph {
    let mut b = GraphBuilder::new("mobilenet_v2");
    let x = b.input("x", &["N", "H", "W", "I"], &[batch, 224, 224, 3]);
    let mut t = b.conv_bias_relu("conv1", x, 32, 3, 2, 1);

    // (expansion, out channels, repeats, stride) per the MV2 paper.
    let cfg: [(i64, i64, usize, i64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut block_idx = 0;
    for (exp, out_ch, repeats, first_stride) in cfg {
        for r in 0..repeats {
            let stride = if r == 0 { first_stride } else { 1 };
            let name = format!("ir{block_idx}");
            block_idx += 1;
            let in_ch = *b.graph.tensor(t).shape.last().unwrap();
            let hidden = in_ch * exp;
            let mut y = t;
            if exp != 1 {
                y = b.conv_bias_relu(&format!("{name}.expand"), y, hidden, 1, 1, 0);
            }
            // depthwise 3x3 (groups == channels)
            y = b.conv2d_full(&format!("{name}.dw"), y, hidden, 3, stride, 1, 1, hidden);
            y = b.relu(&format!("{name}.dw.relu"), y);
            // linear projection (no activation)
            y = b.conv2d(&format!("{name}.project"), y, out_ch, 1, 1, 0);
            if stride == 1 && in_ch == out_ch {
                y = b.add(&format!("{name}.res"), y, t);
            }
            t = y;
        }
    }
    t = b.conv_bias_relu("conv_last", t, 1280, 1, 1, 0);
    t = b.op("gap", OpKind::Reduce { keep_last: true }, &[t]);
    b.dense("fc", t, 1000);
    b.finish()
}

/// One transformer encoder layer; `seq` tokens, `hidden` width.
fn bert_layer(b: &mut GraphBuilder, t_in: usize, name: &str, seq: i64, hidden: i64, heads: i64) -> usize {
    let t_in = t_in as crate::tensor::TensorId;
    let _ = heads; // head split is folded into the fused contractions
    // QKV projections (three GMMs).
    let q = b.dense(&format!("{name}.q"), t_in, hidden);
    let k = b.dense(&format!("{name}.k"), t_in, hidden);
    let v = b.dense(&format!("{name}.v"), t_in, hidden);
    // Attention modeled as two fused contractions with exactly the
    // multi-head MAC count (heads * seq^2 * head_dim == seq^2 * hidden):
    //   scores: [seq, hidden] x [hidden, seq] -> [seq, seq]
    //   ctx:    [seq, seq]    x [seq, hidden] -> [seq, hidden]
    let kt = b.op(
        &format!("{name}.k.t"),
        OpKind::Reshape { shape: vec![hidden, seq] },
        &[k],
    );
    let scores = b.op(&format!("{name}.scores"), OpKind::Matmul, &[q, kt]);
    let probs = b.op(
        &format!("{name}.softmax"),
        OpKind::Softmax { axis: 1 },
        &[scores],
    );
    let ctx_full = b.op(&format!("{name}.ctx"), OpKind::Matmul, &[probs, v]);
    // project back up to hidden and add residual
    let ow = b.weight(&format!("{name}.o.w"), &["K", "N"], &[hidden, hidden]);
    let proj = b.op(&format!("{name}.o"), OpKind::Dense, &[ctx_full, ow]);
    let res1 = b.add(&format!("{name}.res1"), proj, t_in);
    let ln1 = b.op(
        &format!("{name}.ln1"),
        OpKind::LayerNorm { axis: 1 },
        &[res1],
    );
    // FFN
    let f1 = b.dense(&format!("{name}.ffn1"), ln1, hidden * 4);
    let g = b.op(
        &format!("{name}.gelu"),
        OpKind::Eltwise { kind: EltKind::Gelu, arity: 1 },
        &[f1],
    );
    let f2 = b.dense(&format!("{name}.ffn2"), g, hidden);
    let res2 = b.add(&format!("{name}.res2"), f2, ln1);
    b.op(&format!("{name}.ln2"), OpKind::LayerNorm { axis: 1 }, &[res2])
}

/// BERT encoder stack at batch 1 (paper input `N x 128` tokens; we model
/// the post-embedding sequence `[seq, hidden]`).
pub fn bert(layers: usize, hidden: i64, heads: i64, seq: i64) -> Graph {
    let mut b = GraphBuilder::new(if hidden >= 768 { "bert_base" } else { "bert_tiny" });
    let mut t = b.input("tokens", &["M", "K"], &[seq, hidden]);
    for l in 0..layers {
        t = bert_layer(&mut b, t, &format!("l{l}"), seq, hidden, heads);
    }
    b.finish()
}

pub fn bert_base() -> Graph {
    bert(12, 768, 12, 128)
}

pub fn bert_tiny() -> Graph {
    bert(2, 128, 2, 128)
}

/// ResNet3D-18 (video; input `N x 16 x 112 x 112 x 3` channels-last).
pub fn resnet3d_18(batch: i64) -> Graph {
    let mut b = GraphBuilder::new("resnet3d_18");
    let x = b.input("x", &["N", "D", "H", "W", "I"], &[batch, 16, 112, 112, 3]);

    let conv3 = |b: &mut GraphBuilder, name: &str, x, o, k: i64, stride: i64, pad: i64| {
        let x = if pad > 0 {
            b.op(
                &format!("{name}.pad"),
                OpKind::PadOp {
                    before: vec![0, pad, pad, pad, 0],
                    after: vec![0, pad, pad, pad, 0],
                },
                &[x],
            )
        } else {
            x
        };
        let ci = *b.graph.tensor(x).shape.last().unwrap();
        let w = b.weight(
            &format!("{name}.w"),
            &["KD", "KH", "KW", "I", "O"],
            &[k, k, k, ci, o],
        );
        b.op(
            name,
            OpKind::Conv {
                spatial: 3,
                stride: vec![stride, stride, stride],
                dilation: vec![1, 1, 1],
                groups: 1,
                transposed: false,
                kernel: vec![k, k, k],
            },
            &[x, w],
        )
    };

    let mut t = conv3(&mut b, "conv1", x, 64, 3, 2, 1);
    t = b.relu("conv1.relu", t);
    let stages: [(i64, i64, usize); 3] = [(64, 1, 2), (128, 2, 2), (256, 2, 2)];
    for (si, (ch, first_stride, blocks)) in stages.iter().enumerate() {
        for blk in 0..*blocks {
            let stride = if blk == 0 { *first_stride } else { 1 };
            let name = format!("r3d.s{si}b{blk}");
            let shortcut = if stride != 1
                || b.graph.tensor(t).shape.last() != Some(ch)
            {
                conv3(&mut b, &format!("{name}.down"), t, *ch, 1, stride, 0)
            } else {
                t
            };
            let c1 = conv3(&mut b, &format!("{name}.c1"), t, *ch, 3, stride, 1);
            let c1r = b.relu(&format!("{name}.c1.relu"), c1);
            let c2 = conv3(&mut b, &format!("{name}.c2"), c1r, *ch, 3, 1, 1);
            let sum = b.add(&format!("{name}.add"), c2, shortcut);
            t = b.relu(&format!("{name}.relu"), sum);
        }
    }
    t = b.op("gap", OpKind::Reduce { keep_last: true }, &[t]);
    b.dense("fc", t, 400);
    b.finish()
}

/// The §7.3.3 case-study graph: pad -> C2D(O=64, k=7, s=2) -> bias ->
/// ReLU on a 224² input (R18 layer 1, N=1, I=3 -> padded 230²).
pub fn case_study() -> Graph {
    let mut b = GraphBuilder::new("case_study");
    let x = b.input("x", &["N", "H", "W", "I"], &[1, 224, 224, 3]);
    b.conv_bias_relu("conv1", x, 64, 7, 2, 3);
    b.finish()
}

/// The case study at the runtime's Small scale (pre-padded 30²×8 input
/// → 28²×16, 3×3 kernel — the same problem size
/// `runtime::variants::case_graph(Scale::Small)` compiles): one
/// complex op, sub-millisecond native runs, so it is the zoo's
/// cheapest save/load round-trip workload.
pub fn case_study_small() -> Graph {
    let mut b = GraphBuilder::new("case_study_small");
    let x = b.input("x", &["N", "H", "W", "I"], &[1, 30, 30, 8]);
    b.conv_bias_relu("conv1", x, 16, 3, 1, 0);
    b.finish()
}

/// §7.3.1 propagation-overhead subgraphs: padding(1) -> C2D(3x3, s=1)
/// -> C2D(1x1, s=1). `hw` is 7 (subgraph#1) or 14 (subgraph#2);
/// channels 512, and subgraph#2's last conv emits 2048.
pub fn prop_subgraph(hw: i64) -> Graph {
    let mut b = GraphBuilder::new(if hw == 7 { "subgraph1" } else { "subgraph2" });
    let x = b.input("x", &["N", "H", "W", "I"], &[1, hw, hw, 512]);
    let c1 = b.conv2d("c3x3", x, 512, 3, 1, 1);
    let last_o = if hw == 7 { 512 } else { 2048 };
    b.conv2d("c1x1", c1, last_o, 1, 1, 0);
    b.finish()
}

/// A single-operator graph for the Fig. 9 suite.
#[derive(Clone, Debug)]
pub struct OpConfig {
    pub op: &'static str,
    pub graph: Graph,
}

/// The paper's nine single-operator families.
pub const OP_FAMILIES: [&str; 9] =
    ["C2D", "GRP", "DEP", "DIL", "C3D", "C1D", "GMM", "T2D", "T3D"];

/// Random configuration generator for §7.1 (batch from [1,16], channels
/// from the paper's sample set, etc.). Deterministic per (family, seed).
pub fn random_op_config(family: &'static str, rng: &mut Rng) -> OpConfig {
    let batches = [1i64, 16];
    let chans = [3i64, 16, 32, 64, 512, 960, 1280];
    let n = *rng.choose(&batches);
    let ci = *rng.choose(&chans);
    // keep spatial extents divisible-friendly and small enough to tune
    let hw = *rng.choose(&[14i64, 28, 56]);
    let co = *rng.choose(&[16i64, 32, 64, 128]);
    let k = *rng.choose(&[1i64, 3, 5]);
    let stride = *rng.choose(&[1i64, 2]);
    let pad = k / 2;

    let mut b = GraphBuilder::new(family);
    match family {
        "C2D" => {
            let x = b.input("x", &["N", "H", "W", "I"], &[n, hw, hw, ci]);
            b.conv2d("c2d", x, co, k, stride, pad);
        }
        "GRP" => {
            let ci = ci.max(16) / 4 * 4;
            let co = co.max(16);
            let x = b.input("x", &["N", "H", "W", "I"], &[n, hw, hw, ci]);
            b.conv2d_full("grp", x, co, k.max(3), stride, pad.max(1), 1, 4);
        }
        "DEP" => {
            let ci = ci.max(16);
            let x = b.input("x", &["N", "H", "W", "I"], &[n, hw, hw, ci]);
            b.conv2d_full("dep", x, ci, k.max(3), stride, (k.max(3)) / 2, 1, ci);
        }
        "DIL" => {
            let x = b.input("x", &["N", "H", "W", "I"], &[n, hw, hw, ci]);
            let dil = 2;
            let keff = dil * (k.max(3) - 1) + 1;
            b.conv2d_full("dil", x, co, k.max(3), 1, keff / 2, dil, 1);
        }
        "C3D" => {
            let d = *rng.choose(&[8i64, 16]);
            let hw3 = *rng.choose(&[14i64, 28]);
            let ci3 = *rng.choose(&[3i64, 16, 32]);
            let x = b.input("x", &["N", "D", "H", "W", "I"], &[n.min(4), d, hw3, hw3, ci3]);
            let xp = b.op(
                "pad",
                OpKind::PadOp { before: vec![0, 1, 1, 1, 0], after: vec![0, 1, 1, 1, 0] },
                &[x],
            );
            let w = b.weight("w", &["KD", "KH", "KW", "I", "O"], &[3, 3, 3, ci3, co]);
            b.op(
                "c3d",
                OpKind::Conv {
                    spatial: 3,
                    stride: vec![stride, stride, stride],
                    dilation: vec![1, 1, 1],
                    groups: 1,
                    transposed: false,
                    kernel: vec![3, 3, 3],
                },
                &[xp, w],
            );
        }
        "C1D" => {
            let len = *rng.choose(&[128i64, 256]);
            let x = b.input("x", &["N", "W", "I"], &[n, len, ci]);
            let xp = b.op(
                "pad",
                OpKind::PadOp { before: vec![0, k / 2, 0], after: vec![0, k / 2, 0] },
                &[x],
            );
            let w = b.weight("w", &["KW", "I", "O"], &[k, ci, co]);
            b.op(
                "c1d",
                OpKind::Conv {
                    spatial: 1,
                    stride: vec![stride],
                    dilation: vec![1],
                    groups: 1,
                    transposed: false,
                    kernel: vec![k],
                },
                &[xp, w],
            );
        }
        "GMM" => {
            let m = *rng.choose(&[64i64, 128, 512]);
            let kk = *rng.choose(&[64i64, 256, 768]);
            let nn = *rng.choose(&[64i64, 256, 768]);
            let a = b.input("a", &["M", "K"], &[m, kk]);
            let w = b.weight("b", &["K", "N"], &[kk, nn]);
            b.op("gmm", OpKind::Matmul, &[a, w]);
        }
        "T2D" => {
            let x = b.input("x", &["N", "H", "W", "I"], &[n.min(4), hw / 2, hw / 2, ci]);
            let w = b.weight("w", &["KH", "KW", "I", "O"], &[4, 4, ci, co]);
            b.op(
                "t2d",
                OpKind::Conv {
                    spatial: 2,
                    stride: vec![2, 2],
                    dilation: vec![1, 1],
                    groups: 1,
                    transposed: true,
                    kernel: vec![4, 4],
                },
                &[x, w],
            );
        }
        "T3D" => {
            let x = b.input(
                "x",
                &["N", "D", "H", "W", "I"],
                &[1, 8, hw / 2, hw / 2, ci.min(64)],
            );
            let w = b.weight("w", &["KD", "KH", "KW", "I", "O"], &[4, 4, 4, ci.min(64), co]);
            b.op(
                "t3d",
                OpKind::Conv {
                    spatial: 3,
                    stride: vec![2, 2, 2],
                    dilation: vec![1, 1, 1],
                    groups: 1,
                    transposed: true,
                    kernel: vec![4, 4, 4],
                },
                &[x, w],
            );
        }
        other => panic!("unknown op family {other}"),
    }
    OpConfig { op: family, graph: b.finish() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_structure() {
        let g = resnet18(1);
        // 1 stem + 8 blocks x 2 convs + 3 downsamples + fc = 20+ complex
        let complex = g.complex_nodes().len();
        assert!(complex >= 20, "complex ops {complex}");
        // final fc output is 1000-wide
        let last = g.nodes.last().unwrap();
        assert_eq!(*g.tensor(last.output).shape.last().unwrap(), 1000);
    }

    #[test]
    fn resnet18_small_structure() {
        let g = resnet18_small();
        // same topology as resnet18: stem + 8 blocks x 2 convs + 3
        // downsamples + fc
        assert_eq!(g.complex_nodes().len(), resnet18(1).complex_nodes().len());
        let last = g.nodes.last().unwrap();
        assert_eq!(*g.tensor(last.output).shape.last().unwrap(), 100);
        // small enough to execute natively in tests
        assert!(g.total_flops() < 0.1e9, "flops {}", g.total_flops());
    }

    #[test]
    fn by_name_covers_the_zoo_and_roundtrips_names() {
        for name in MODEL_NAMES {
            let g = by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(g.name, name, "canonical name must round-trip");
        }
        // aliases resolve to the same graphs
        for (alias, canon) in
            [("r18", "resnet18"), ("bt", "bert_tiny"), ("r18s", "resnet18_small")]
        {
            assert_eq!(by_name(alias).unwrap().name, canon);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn mobilenet_depthwise_present() {
        let g = mobilenet_v2(1);
        let has_dw = g.nodes.iter().any(|n| {
            matches!(&n.kind, OpKind::Conv { groups, .. } if *groups > 1)
        });
        assert!(has_dw);
    }

    #[test]
    fn bert_tiny_builds() {
        let g = bert_tiny();
        assert!(g.complex_nodes().len() >= 2 * 7); // >= 7 GMMs per layer
        let g2 = bert_base();
        assert!(g2.complex_nodes().len() > g.complex_nodes().len());
    }

    #[test]
    fn r3d_builds() {
        let g = resnet3d_18(1);
        assert!(g.complex_nodes().len() >= 13);
    }

    #[test]
    fn case_study_shapes() {
        let g = case_study();
        let conv = g
            .nodes
            .iter()
            .find(|n| matches!(n.kind, OpKind::Conv { .. }))
            .unwrap();
        assert_eq!(g.tensor(conv.output).shape, vec![1, 112, 112, 64]);
        // padded input is 230x230
        assert_eq!(g.tensor(conv.inputs[0]).shape, vec![1, 230, 230, 3]);
    }

    #[test]
    fn all_families_generate() {
        let mut rng = Rng::new(1);
        for fam in OP_FAMILIES {
            for _ in 0..3 {
                let cfg = random_op_config(fam, &mut rng);
                assert!(
                    !cfg.graph.complex_nodes().is_empty(),
                    "{fam} lacks complex op"
                );
            }
        }
    }

    #[test]
    fn prop_subgraphs_match_paper() {
        let g1 = prop_subgraph(7);
        assert_eq!(g1.complex_nodes().len(), 2);
        let g2 = prop_subgraph(14);
        let last = *g2.complex_nodes().last().unwrap();
        assert_eq!(*g2.tensor(g2.node(last).output).shape.last().unwrap(), 2048);
    }
}
