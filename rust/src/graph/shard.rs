//! Shard analysis: partition a graph's complex operators into
//! independently tunable shards (paper §4.2 + ROADMAP "multi-graph
//! sharding").
//!
//! §4.2 constraint 3 makes each complex operator's layout decision
//! independent, so per-op tuning runs are already side-effect-free —
//! what couples two ops is *propagation reachability*: op A's output
//! sequence is replicated down its single-consumer element-wise chain
//! (the fused tail, Figs. 6–7), and op B's input conversion may be
//! absorbed by an element-wise producer on that same chain (Fig. 5b).
//! When A's chain reaches a tensor B reads, the two decisions touch
//! the same element-wise nodes; the analysis keeps such ops in one
//! shard so their tuning stays sequential in topological order (§6),
//! while ops separated by a **non-propagatable boundary** — a direct
//! complex→complex edge (constraint 3 inserts a conversion there), a
//! non-element-wise op (pool, reshape, softmax, …), or a
//! multi-consumer fan-out (which stops the chain walk) — always land
//! in different shards and may tune concurrently.
//!
//! The orchestrator ([`crate::autotune::orchestrator`]) schedules the
//! resulting groups over one shared engine; because the partition is a
//! pure function of the graph, it never depends on thread count.

use std::collections::HashMap;

use crate::graph::{Graph, NodeId};
use crate::propagate::eltwise_chain;

/// The independence groups of a graph's complex ops: a partition —
/// every complex op appears in exactly one group — in topological
/// order (groups ordered by their first member; members in graph
/// order).
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub groups: Vec<Vec<NodeId>>,
}

impl ShardPlan {
    /// Total complex ops covered by the partition.
    pub fn n_ops(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }
}

fn find(parent: &mut [usize], mut i: usize) -> usize {
    while parent[i] != i {
        parent[i] = parent[parent[i]];
        i = parent[i];
    }
    i
}

fn union(parent: &mut [usize], a: usize, b: usize) {
    let (ra, rb) = (find(parent, a), find(parent, b));
    if ra != rb {
        // root at the smaller index so group identity is stable
        let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
        parent[hi] = lo;
    }
}

/// Compute the independence groups of `graph`'s complex operators.
pub fn analyze(graph: &Graph) -> ShardPlan {
    let complex = graph.complex_nodes();
    let mut parent: Vec<usize> = (0..complex.len()).collect();
    for (i, &a) in complex.iter().enumerate() {
        // Tensors written by propagatable element-wise nodes below `a`
        // — exactly the nodes a's output sequence is replicated onto.
        // a's own output is deliberately NOT in this set: a direct
        // complex→complex edge is a conversion boundary, not a
        // propagation path.
        let chain = eltwise_chain(graph, graph.node(a).output);
        if chain.is_empty() {
            continue;
        }
        let reach: Vec<usize> =
            chain.iter().map(|&c| graph.node(c).output).collect();
        for (j, &b) in complex.iter().enumerate() {
            if i != j && graph.node(b).inputs.iter().any(|t| reach.contains(t)) {
                union(&mut parent, i, j);
            }
        }
    }
    let mut by_root: HashMap<usize, Vec<NodeId>> = HashMap::new();
    let mut order: Vec<usize> = Vec::new();
    for (i, &n) in complex.iter().enumerate() {
        let r = find(&mut parent, i);
        if !by_root.contains_key(&r) {
            order.push(r);
        }
        by_root.entry(r).or_default().push(n);
    }
    ShardPlan {
        groups: order.into_iter().map(|r| by_root.remove(&r).unwrap()).collect(),
    }
}

/// Pack independence groups into at most `shards` scheduling units:
/// `0` keeps one unit per group (auto), otherwise groups are assigned
/// greedily — in topological order, each to the currently lightest
/// unit (ties to the lowest index) — so the packing is balanced by op
/// count and deterministic. Groups are never split: the §6 sequential
/// order inside a group is preserved.
pub fn pack(plan: &ShardPlan, shards: usize) -> Vec<Vec<NodeId>> {
    if shards == 0 || shards >= plan.groups.len() {
        return plan.groups.clone();
    }
    let mut out: Vec<Vec<NodeId>> = vec![Vec::new(); shards.max(1)];
    for g in &plan.groups {
        let lightest = (0..out.len())
            .min_by_key(|&i| (out[i].len(), i))
            .expect("at least one unit");
        out[lightest].extend(g.iter().copied());
    }
    out.retain(|u| !u.is_empty());
    out
}

/// Execution wavefronts: partition **all** nodes by dataflow depth.
/// Wave `w` holds the nodes whose produced inputs all come from waves
/// `< w`; nodes reading only graph inputs / weights are wave 0. Nodes
/// inside one wave are mutually data-independent, so a runtime may
/// execute them concurrently and still commit results in graph order.
///
/// This is deliberately *not* [`analyze`]: shard groups encode layout-
/// propagation coupling for the tuner (a direct complex→complex edge
/// is a group boundary yet strictly data-DEpendent), while waves encode
/// run-time data independence for intra-request pipelining.
pub fn exec_waves(graph: &Graph) -> Vec<Vec<NodeId>> {
    // tensor -> wave of its producing node; absent = graph input/weight
    let mut tensor_wave: HashMap<usize, usize> = HashMap::new();
    let mut waves: Vec<Vec<NodeId>> = Vec::new();
    for n in &graph.nodes {
        let w = n
            .inputs
            .iter()
            .filter_map(|t| tensor_wave.get(t))
            .map(|&pw| pw + 1)
            .max()
            .unwrap_or(0);
        if waves.len() <= w {
            waves.resize_with(w + 1, Vec::new);
        }
        waves[w].push(n.id);
        tensor_wave.insert(n.output, w);
    }
    waves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn covered(plan: &ShardPlan, graph: &Graph) -> bool {
        let mut all: Vec<NodeId> = plan.groups.iter().flatten().copied().collect();
        all.sort_unstable();
        let mut complex = graph.complex_nodes();
        complex.sort_unstable();
        all == complex
    }

    #[test]
    fn partition_covers_every_model() {
        for g in [
            models::case_study(),
            models::prop_subgraph(7),
            models::prop_subgraph(14),
            models::resnet18(1),
            models::mobilenet_v2(1),
            models::bert_tiny(),
        ] {
            let plan = analyze(&g);
            assert!(covered(&plan, &g), "{}: bad partition", g.name);
            assert_eq!(plan.n_ops(), g.complex_nodes().len());
        }
    }

    #[test]
    fn direct_complex_edge_is_a_boundary() {
        // prop_subgraph: pad -> c3x3 -> c1x1, the two convs adjacent
        // with no element-wise op between them — constraint 3 inserts a
        // conversion there, so they tune independently.
        let g = models::prop_subgraph(7);
        let plan = analyze(&g);
        assert_eq!(plan.groups.len(), 2);
        assert!(plan.groups.iter().all(|grp| grp.len() == 1));
    }

    #[test]
    fn pool_boundary_isolates_resnet_stem() {
        // conv1's chain (bias, relu) ends at the maxpool — nothing
        // downstream may share its shard.
        let g = models::resnet18(1);
        let plan = analyze(&g);
        let conv1 = g.complex_nodes()[0];
        let stem = plan
            .groups
            .iter()
            .find(|grp| grp.contains(&conv1))
            .expect("conv1 covered");
        assert_eq!(stem.as_slice(), &[conv1][..], "stem group {stem:?}");
        assert!(plan.groups.len() > 1, "resnet18 must shard");
    }

    #[test]
    fn eltwise_chain_merges_coupled_convs() {
        // s0b1.c2 -> bias -> add -> relu -> s1b0.down: the downsample
        // conv consumes the residual relu directly (no pad between),
        // so propagation crosses the element-wise chain and the two
        // convs share a shard. s0b0.c1 and s0b0.c2, by contrast, are
        // separated by c2's padding op (shape changes stop the chain)
        // and must stay apart.
        let g = models::resnet18(1);
        let plan = analyze(&g);
        let by_name = |name: &str| {
            g.nodes.iter().find(|n| n.name == name).map(|n| n.id).unwrap()
        };
        let (c2, down) = (by_name("s0b1.c2"), by_name("s1b0.down"));
        let grp = plan.groups.iter().find(|grp| grp.contains(&c2)).unwrap();
        assert!(grp.contains(&down), "c2/down split across {grp:?}");
        let (a, b) = (by_name("s0b0.c1"), by_name("s0b0.c2"));
        let ga = plan.groups.iter().position(|grp| grp.contains(&a)).unwrap();
        let gb = plan.groups.iter().position(|grp| grp.contains(&b)).unwrap();
        assert_ne!(ga, gb, "padding boundary must split c1/c2");
    }

    #[test]
    fn reshape_boundary_splits_bert_attention() {
        // k-projection feeds the scores matmul through a reshape —
        // a non-propagatable boundary.
        let g = models::bert_tiny();
        let plan = analyze(&g);
        let by_name = |name: &str| {
            g.nodes.iter().find(|n| n.name == name).map(|n| n.id).unwrap()
        };
        let (k, scores) = (by_name("l0.k"), by_name("l0.scores"));
        let gk = plan.groups.iter().position(|grp| grp.contains(&k)).unwrap();
        let gs =
            plan.groups.iter().position(|grp| grp.contains(&scores)).unwrap();
        assert_ne!(gk, gs, "reshape boundary must split k/scores");
        // while q feeds scores through a bias chain — same shard
        let q = by_name("l0.q");
        let gq = plan.groups.iter().position(|grp| grp.contains(&q)).unwrap();
        assert_eq!(gq, gs, "q couples to scores through its bias chain");
    }

    #[test]
    fn exec_waves_cover_all_nodes_exactly_once() {
        for g in [
            models::case_study(),
            models::resnet18(1),
            models::bert_tiny(),
        ] {
            let waves = exec_waves(&g);
            let mut all: Vec<NodeId> = waves.iter().flatten().copied().collect();
            all.sort_unstable();
            let mut ids: Vec<NodeId> = g.nodes.iter().map(|n| n.id).collect();
            ids.sort_unstable();
            assert_eq!(all, ids, "{}: waves must partition the nodes", g.name);
        }
    }
    #[test]
    fn exec_waves_respect_dataflow_order() {
        // every produced input of a node must sit in a strictly earlier
        // wave — the property pipelined execution relies on
        for g in [models::resnet18(1), models::bert_tiny()] {
            let waves = exec_waves(&g);
            let mut wave_of: HashMap<NodeId, usize> = HashMap::new();
            for (w, ns) in waves.iter().enumerate() {
                for &n in ns {
                    wave_of.insert(n, w);
                }
            }
            let producer: HashMap<usize, NodeId> =
                g.nodes.iter().map(|n| (n.output, n.id)).collect();
            for n in &g.nodes {
                for t in &n.inputs {
                    if let Some(&p) = producer.get(t) {
                        assert!(
                            wave_of[&p] < wave_of[&n.id],
                            "{}: {} reads {} from a later-or-equal wave",
                            g.name,
                            n.name,
                            g.node(p).name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bert_qkv_projections_share_a_wave() {
        // q/k/v all read the same embedded input — data-independent,
        // so they pipeline onto different cores of one request
        let g = models::bert_tiny();
        let waves = exec_waves(&g);
        let by_name = |name: &str| {
            g.nodes.iter().find(|n| n.name == name).map(|n| n.id).unwrap()
        };
        let wave_of = |id: NodeId| {
            waves.iter().position(|w| w.contains(&id)).unwrap()
        };
        let (q, k, v) = (by_name("l0.q"), by_name("l0.k"), by_name("l0.v"));
        assert_eq!(wave_of(q), wave_of(k));
        assert_eq!(wave_of(k), wave_of(v));
        // while the scores matmul depends on q and k — strictly later
        assert!(wave_of(by_name("l0.scores")) > wave_of(q));
    }

    #[test]
    fn chain_nodes_land_in_successive_waves() {
        // prop_subgraph is a straight pipe: every wave is a singleton
        let g = models::prop_subgraph(7);
        let waves = exec_waves(&g);
        assert_eq!(waves.len(), g.nodes.len());
        assert!(waves.iter().all(|w| w.len() == 1));
    }

    #[test]
    fn pack_balances_and_preserves_coverage() {
        let g = models::resnet18(1);
        let plan = analyze(&g);
        let n_ops = plan.n_ops();
        for k in [0usize, 1, 2, 3, 7, 64] {
            let units = pack(&plan, k);
            if k > 0 {
                assert!(units.len() <= k.max(1));
            }
            let mut all: Vec<NodeId> =
                units.iter().flatten().copied().collect();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), n_ops, "pack({k}) lost or duplicated ops");
        }
        // balanced within one group's weight
        let units = pack(&plan, 3);
        let max = units.iter().map(|u| u.len()).max().unwrap();
        let min = units.iter().map(|u| u.len()).min().unwrap();
        let biggest_group = plan.groups.iter().map(|g| g.len()).max().unwrap();
        assert!(max - min <= biggest_group, "pack imbalance {min}..{max}");
    }
}
