//! Operator kinds and shape inference.
//!
//! Logical layouts are fixed per op (channels-last: NWO/NHWO/NDHWO for
//! convs, MK/KN/MN for GMM); *storage* layouts are what the tuner
//! manipulates via primitive sequences, so the logical convention here
//! is just the coordinate system the primitives start from.

/// Elementwise op flavours (all cost-equivalent in the simulator except
/// for operand arity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EltKind {
    Relu,
    Relu6,
    Add,
    Mul,
    Sigmoid,
    Gelu,
    Tanh,
    Identity,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Operator vocabulary — every op the paper's five networks need, plus
/// the layout-conversion op the propagation pass inserts.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// N-d convolution over channels-last input `[N, S1..Sn, I]` with
    /// weight `[K1..Kn, I/groups, O]`, output `[N, S1'..Sn', O]`.
    /// Covers C1D/C2D/C3D, grouped (GRP), depthwise (DEP: groups == I),
    /// dilated (DIL) and transposed (T2D/T3D) variants.
    Conv {
        spatial: usize,
        stride: Vec<i64>,
        dilation: Vec<i64>,
        groups: i64,
        transposed: bool,
        kernel: Vec<i64>,
    },
    /// `[.., M, K] x [K, N] -> [.., M, N]` (batched over leading dims of
    /// the first operand).
    Matmul,
    /// Dense layer: same contraction as Matmul; kept distinct because
    /// vendor baselines schedule it differently.
    Dense,
    /// Elementwise with `arity` tensor operands of identical shape
    /// (broadcast handled by BiasAdd).
    Eltwise { kind: EltKind, arity: usize },
    /// `x + bias` with bias along the last dim.
    BiasAdd,
    /// Zero padding per dimension.
    PadOp { before: Vec<i64>, after: Vec<i64> },
    /// Spatial pooling over channels-last input.
    Pool { kind: PoolKind, kernel: Vec<i64>, stride: Vec<i64> },
    /// Softmax along `axis`.
    Softmax { axis: usize },
    /// LayerNorm along the last dim.
    LayerNorm { axis: usize },
    /// Inference-mode batch normalization along the last (channel) dim:
    /// `(x - mean) / sqrt(var + eps) * gamma + beta` with per-channel
    /// `gamma, beta, mean, var` operands and eps fixed at `1e-5`
    /// (mirroring LayerNorm). Exists so the rewrite pass has a real
    /// BN-into-Conv folding target; inference graphs that keep it
    /// unfused execute it as a plain simple op.
    BatchNorm,
    /// Reduce spatial dims to 1 (global average pool).
    Reduce { keep_last: bool },
    /// Pure metadata reshape.
    Reshape { shape: Vec<i64> },
    /// Runtime layout conversion (inserted by propagation, Fig. 5a).
    /// Cost = pure data movement of the tensor once through memory.
    LayoutConvert,
}

/// Infer `(dim_names, shape)` of the output. Inputs arrive in the
/// logical layouts documented on [`OpKind`].
pub fn infer_shape(
    kind: &OpKind,
    ins: &[Vec<i64>],
) -> Result<(Vec<String>, Vec<i64>), String> {
    let names_spatial = |n: usize| -> Vec<String> {
        let base = ["D", "H", "W"];
        let mut v = vec!["N".to_string()];
        for i in 0..n {
            v.push(base[3 - n + i].to_string());
        }
        v.push("O".to_string());
        v
    };
    match kind {
        OpKind::Conv { spatial, stride, dilation, groups, transposed, kernel } => {
            let x = &ins[0];
            let w = &ins[1];
            if x.len() != spatial + 2 {
                return Err(format!("conv input rank {} != {}", x.len(), spatial + 2));
            }
            if w.len() != spatial + 2 {
                return Err(format!("conv weight rank {}", w.len()));
            }
            let ci = x[spatial + 1];
            if w[*spatial] != ci / groups {
                return Err(format!(
                    "weight I {} != input I/groups {}",
                    w[*spatial],
                    ci / groups
                ));
            }
            let o = w[spatial + 1];
            let mut shape = vec![x[0]];
            for d in 0..*spatial {
                let k_eff = dilation[d] * (kernel[d] - 1) + 1;
                let s = if *transposed {
                    (x[1 + d] - 1) * stride[d] + k_eff
                } else {
                    (x[1 + d] - k_eff) / stride[d] + 1
                };
                if s <= 0 {
                    return Err(format!("conv spatial dim {d} collapses: {s}"));
                }
                shape.push(s);
            }
            shape.push(o);
            Ok((names_spatial(*spatial), shape))
        }
        OpKind::Matmul | OpKind::Dense => {
            let a = &ins[0];
            let b = &ins[1];
            if b.len() != 2 || a.is_empty() {
                return Err("matmul wants [.., M, K] x [K, N]".into());
            }
            let k = *a.last().unwrap();
            if b[0] != k {
                return Err(format!("matmul K mismatch {k} vs {}", b[0]));
            }
            let mut shape = a[..a.len() - 1].to_vec();
            shape.push(b[1]);
            let mut names: Vec<String> =
                (0..shape.len() - 2).map(|i| format!("B{i}")).collect();
            names.push("M".into());
            names.push("N".into());
            Ok((names, shape))
        }
        OpKind::Eltwise { arity, .. } => {
            for i in 1..*arity {
                if ins[i] != ins[0] {
                    return Err(format!(
                        "eltwise shape mismatch {:?} vs {:?}",
                        ins[i], ins[0]
                    ));
                }
            }
            Ok((default_names(ins[0].len()), ins[0].clone()))
        }
        OpKind::BiasAdd => {
            if ins[1].len() != 1 || ins[1][0] != *ins[0].last().unwrap() {
                return Err("bias must match last dim".into());
            }
            Ok((default_names(ins[0].len()), ins[0].clone()))
        }
        OpKind::PadOp { before, after } => {
            let x = &ins[0];
            if before.len() != x.len() || after.len() != x.len() {
                return Err("pad arity".into());
            }
            let shape =
                x.iter().zip(before.iter().zip(after)).map(|(d, (b, a))| d + b + a);
            Ok((default_names(x.len()), shape.collect()))
        }
        OpKind::Pool { kernel, stride, .. } => {
            let x = &ins[0];
            let sp = kernel.len();
            let mut shape = vec![x[0]];
            for d in 0..sp {
                shape.push((x[1 + d] - kernel[d]) / stride[d] + 1);
            }
            shape.push(*x.last().unwrap());
            Ok((names_spatial(sp), shape))
        }
        OpKind::BatchNorm => {
            if ins.len() != 5 {
                return Err("batchnorm wants x, gamma, beta, mean, var".into());
            }
            let c = *ins[0].last().unwrap();
            for p in &ins[1..5] {
                if p.len() != 1 || p[0] != c {
                    return Err(format!(
                        "batchnorm param shape {p:?} != channel dim {c}"
                    ));
                }
            }
            Ok((default_names(ins[0].len()), ins[0].clone()))
        }
        OpKind::Softmax { axis } | OpKind::LayerNorm { axis } => {
            if *axis >= ins[0].len() {
                return Err("softmax/ln axis out of range".into());
            }
            Ok((default_names(ins[0].len()), ins[0].clone()))
        }
        OpKind::Reduce { keep_last } => {
            let x = &ins[0];
            let shape = if *keep_last {
                vec![x[0], *x.last().unwrap()]
            } else {
                vec![x[0]]
            };
            Ok((default_names(shape.len()), shape))
        }
        OpKind::Reshape { shape } => {
            let from: i64 = ins[0].iter().product();
            let to: i64 = shape.iter().product();
            if from != to {
                return Err(format!("reshape {from} -> {to} element mismatch"));
            }
            Ok((default_names(shape.len()), shape.clone()))
        }
        OpKind::LayoutConvert => Ok((default_names(ins[0].len()), ins[0].clone())),
    }
}

fn default_names(rank: usize) -> Vec<String> {
    (0..rank).map(|i| format!("d{i}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv2d_shape() {
        let kind = OpKind::Conv {
            spatial: 2,
            stride: vec![2, 2],
            dilation: vec![1, 1],
            groups: 1,
            transposed: false,
            kernel: vec![7, 7],
        };
        let (names, shape) =
            infer_shape(&kind, &[vec![1, 230, 230, 3], vec![7, 7, 3, 64]]).unwrap();
        assert_eq!(shape, vec![1, 112, 112, 64]);
        assert_eq!(names, vec!["N", "H", "W", "O"]);
    }

    #[test]
    fn conv1d_and_3d_names() {
        let k1 = OpKind::Conv {
            spatial: 1,
            stride: vec![1],
            dilation: vec![1],
            groups: 1,
            transposed: false,
            kernel: vec![3],
        };
        let (n1, s1) = infer_shape(&k1, &[vec![1, 16, 4], vec![3, 4, 8]]).unwrap();
        assert_eq!(n1, vec!["N", "W", "O"]);
        assert_eq!(s1, vec![1, 14, 8]);

        let k3 = OpKind::Conv {
            spatial: 3,
            stride: vec![1, 1, 1],
            dilation: vec![1, 1, 1],
            groups: 1,
            transposed: false,
            kernel: vec![3, 3, 3],
        };
        let (n3, s3) =
            infer_shape(&k3, &[vec![1, 8, 10, 10, 4], vec![3, 3, 3, 4, 8]]).unwrap();
        assert_eq!(n3, vec!["N", "D", "H", "W", "O"]);
        assert_eq!(s3, vec![1, 6, 8, 8, 8]);
    }

    #[test]
    fn transposed_conv_expands() {
        let kind = OpKind::Conv {
            spatial: 2,
            stride: vec![2, 2],
            dilation: vec![1, 1],
            groups: 1,
            transposed: true,
            kernel: vec![4, 4],
        };
        let (_, shape) =
            infer_shape(&kind, &[vec![1, 8, 8, 16], vec![4, 4, 16, 8]]).unwrap();
        assert_eq!(shape, vec![1, 18, 18, 8]);
    }

    #[test]
    fn dilated_conv_shrinks_more() {
        let kind = OpKind::Conv {
            spatial: 2,
            stride: vec![1, 1],
            dilation: vec![2, 2],
            groups: 1,
            transposed: false,
            kernel: vec![3, 3],
        };
        let (_, shape) =
            infer_shape(&kind, &[vec![1, 16, 16, 4], vec![3, 3, 4, 8]]).unwrap();
        // effective kernel 5 -> 12
        assert_eq!(shape, vec![1, 12, 12, 8]);
    }

    #[test]
    fn matmul_batched() {
        let (names, shape) =
            infer_shape(&OpKind::Matmul, &[vec![2, 12, 128, 64], vec![64, 128]])
                .unwrap();
        assert_eq!(shape, vec![2, 12, 128, 128]);
        assert_eq!(names.last().unwrap(), "N");
    }

    #[test]
    fn errors_are_reported() {
        assert!(infer_shape(&OpKind::Matmul, &[vec![4, 8], vec![9, 2]]).is_err());
        let kind = OpKind::Reshape { shape: vec![3, 3] };
        assert!(infer_shape(&kind, &[vec![2, 4]]).is_err());
    }
}
