//! Computational-graph IR.
//!
//! Operators are nodes, tensors are edges (paper §2). The graph is the
//! unit the joint tuner works on: complex operators (convolutions, GMM)
//! get layout + loop tuning; everything else receives layouts by
//! propagation (§4.2) or keeps its default.

pub mod models;
pub mod ops;
pub mod shard;

pub use ops::{EltKind, OpKind, PoolKind};

use crate::tensor::{DType, Role, Tensor, TensorId};

/// Node id within a graph.
pub type NodeId = usize;

/// One operator instance.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub output: TensorId,
}

impl Node {
    /// Complex operators get independent layout tuning (paper §1:
    /// convolutions and GMM — the layout-sensitive ops).
    pub fn is_complex(&self) -> bool {
        matches!(self.kind, OpKind::Conv { .. } | OpKind::Matmul | OpKind::Dense)
    }

    /// Element-wise ops admit layout propagation through them
    /// (constraint 1 of §4.2).
    pub fn is_elementwise(&self) -> bool {
        matches!(
            self.kind,
            OpKind::Eltwise { .. } | OpKind::BiasAdd | OpKind::PadOp { .. }
        )
    }
}

/// A computational graph in topological order (builders only append).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub tensors: Vec<Tensor>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Self { name: name.into(), ..Default::default() }
    }

    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id]
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Nodes consuming `t`.
    pub fn consumers(&self, t: TensorId) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.contains(&t))
            .map(|n| n.id)
            .collect()
    }

    /// Node producing `t` (None for inputs/weights).
    pub fn producer(&self, t: TensorId) -> Option<NodeId> {
        self.tensors[t].producer
    }

    /// Complex nodes in topological order — the joint stage tunes these
    /// sequentially and propagates results (§6).
    pub fn complex_nodes(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.is_complex())
            .map(|n| n.id)
            .collect()
    }

    /// Total multiply-accumulate count (for reporting / op-intensity).
    pub fn total_flops(&self) -> f64 {
        self.nodes.iter().map(|n| self.node_flops(n.id)).sum()
    }

    /// FLOPs of one node (2 * MACs for contraction ops).
    pub fn node_flops(&self, id: NodeId) -> f64 {
        let n = &self.nodes[id];
        let out = self.tensor(n.output);
        let out_elems = out.elements() as f64;
        match &n.kind {
            OpKind::Conv { kernel, groups, .. } => {
                let cin = self.tensor(n.inputs[0]).shape.last().copied().unwrap_or(1);
                let k: i64 = kernel.iter().product();
                2.0 * out_elems * (cin / groups * k) as f64
            }
            OpKind::Matmul | OpKind::Dense => {
                let k = *self.tensor(n.inputs[0]).shape.last().unwrap();
                2.0 * out_elems * k as f64
            }
            OpKind::Pool { kernel, .. } => {
                out_elems * kernel.iter().product::<i64>() as f64
            }
            OpKind::Softmax { .. } | OpKind::LayerNorm { .. } => 5.0 * out_elems,
            OpKind::Reduce { .. } => {
                self.tensor(n.inputs[0]).elements() as f64
            }
            _ => out_elems,
        }
    }

    /// Short per-node description used by reports.
    pub fn describe(&self, id: NodeId) -> String {
        let n = &self.nodes[id];
        format!(
            "{}#{} {:?} -> {}",
            n.name,
            n.id,
            n.inputs
                .iter()
                .map(|&t| self.tensor(t).name.clone())
                .collect::<Vec<_>>(),
            self.tensor(n.output).name
        )
    }
}

/// Fluent graph builder with shape inference.
pub struct GraphBuilder {
    pub graph: Graph,
}

impl GraphBuilder {
    pub fn new(name: &str) -> Self {
        Self { graph: Graph::new(name) }
    }

    pub fn finish(self) -> Graph {
        self.graph
    }

    fn add_tensor(
        &mut self,
        name: &str,
        dim_names: &[&str],
        shape: &[i64],
        dtype: DType,
        role: Role,
        producer: Option<NodeId>,
    ) -> TensorId {
        let id = self.graph.tensors.len();
        assert_eq!(dim_names.len(), shape.len(), "tensor {name} arity");
        assert!(shape.iter().all(|&d| d > 0), "tensor {name} bad shape {shape:?}");
        self.graph.tensors.push(Tensor {
            id,
            name: name.into(),
            dim_names: dim_names.iter().map(|s| s.to_string()).collect(),
            shape: shape.to_vec(),
            dtype,
            role,
            producer,
        });
        id
    }

    pub fn input(&mut self, name: &str, dim_names: &[&str], shape: &[i64]) -> TensorId {
        self.add_tensor(name, dim_names, shape, DType::F32, Role::Input, None)
    }

    pub fn weight(&mut self, name: &str, dim_names: &[&str], shape: &[i64]) -> TensorId {
        self.add_tensor(name, dim_names, shape, DType::F32, Role::Weight, None)
    }

    /// Append an op; infers the output tensor from `kind` + inputs.
    pub fn op(&mut self, name: &str, kind: OpKind, inputs: &[TensorId]) -> TensorId {
        let node_id = self.graph.nodes.len();
        let in_shapes: Vec<Vec<i64>> = inputs
            .iter()
            .map(|&t| self.graph.tensor(t).shape.clone())
            .collect();
        let (dim_names, shape) = ops::infer_shape(&kind, &in_shapes)
            .unwrap_or_else(|e| panic!("shape inference failed for {name}: {e}"));
        let names_ref: Vec<&str> = dim_names.iter().map(|s| s.as_str()).collect();
        let out = self.add_tensor(
            &format!("{name}.out"),
            &names_ref,
            &shape,
            DType::F32,
            Role::Intermediate,
            Some(node_id),
        );
        self.graph.nodes.push(Node {
            id: node_id,
            name: name.into(),
            kind,
            inputs: inputs.to_vec(),
            output: out,
        });
        out
    }

    // ---- convenience layers used by the model builders ----

    /// conv2d in logical NHWI/HWIO/NHWO with explicit pre-padding.
    pub fn conv2d(
        &mut self,
        name: &str,
        x: TensorId,
        o: i64,
        k: i64,
        stride: i64,
        pad: i64,
    ) -> TensorId {
        self.conv2d_full(name, x, o, k, stride, pad, 1, 1)
    }

    pub fn conv2d_full(
        &mut self,
        name: &str,
        x: TensorId,
        o: i64,
        k: i64,
        stride: i64,
        pad: i64,
        dilation: i64,
        groups: i64,
    ) -> TensorId {
        let xs = self.graph.tensor(x).shape.clone();
        let ci = *xs.last().unwrap();
        assert!(ci % groups == 0 && o % groups == 0, "{name}: groups");
        let x = if pad > 0 {
            self.op(
                &format!("{name}.pad"),
                OpKind::PadOp { before: vec![0, pad, pad, 0], after: vec![0, pad, pad, 0] },
                &[x],
            )
        } else {
            x
        };
        let w = self.weight(
            &format!("{name}.w"),
            &["KH", "KW", "I", "O"],
            &[k, k, ci / groups, o],
        );
        self.op(
            name,
            OpKind::Conv {
                spatial: 2,
                stride: vec![stride, stride],
                dilation: vec![dilation, dilation],
                groups,
                transposed: false,
                kernel: vec![k, k],
            },
            &[x, w],
        )
    }

    pub fn conv_bias_relu(
        &mut self,
        name: &str,
        x: TensorId,
        o: i64,
        k: i64,
        stride: i64,
        pad: i64,
    ) -> TensorId {
        let c = self.conv2d(name, x, o, k, stride, pad);
        let b = self.weight(&format!("{name}.b"), &["O"], &[o]);
        let y = self.op(&format!("{name}.bias"), OpKind::BiasAdd, &[c, b]);
        self.op(
            &format!("{name}.relu"),
            OpKind::Eltwise { kind: EltKind::Relu, arity: 1 },
            &[y],
        )
    }

    pub fn dense(&mut self, name: &str, x: TensorId, n: i64) -> TensorId {
        let xs = self.graph.tensor(x).shape.clone();
        let k = *xs.last().unwrap();
        let w = self.weight(&format!("{name}.w"), &["K", "N"], &[k, n]);
        let y = self.op(name, OpKind::Dense, &[x, w]);
        let b = self.weight(&format!("{name}.b"), &["N"], &[n]);
        self.op(&format!("{name}.bias"), OpKind::BiasAdd, &[y, b])
    }

    pub fn relu(&mut self, name: &str, x: TensorId) -> TensorId {
        self.op(name, OpKind::Eltwise { kind: EltKind::Relu, arity: 1 }, &[x])
    }

    pub fn add(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        self.op(name, OpKind::Eltwise { kind: EltKind::Add, arity: 2 }, &[a, b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_shapes_r18_layer1() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["N", "H", "W", "I"], &[1, 224, 224, 3]);
        let y = b.conv_bias_relu("conv1", x, 64, 7, 2, 3);
        let g = b.finish();
        assert_eq!(g.tensor(y).shape, vec![1, 112, 112, 64]);
        // pad, conv, bias, relu
        assert_eq!(g.nodes.len(), 4);
        assert_eq!(g.complex_nodes().len(), 1);
    }

    #[test]
    fn consumers_and_producer() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["N", "K"], &[4, 8]);
        let y = b.dense("fc", x, 16);
        let g = b.finish();
        assert_eq!(g.producer(x), None);
        let dense_node = g.complex_nodes()[0];
        let dense_out = g.node(dense_node).output;
        assert_eq!(g.consumers(dense_out).len(), 1); // bias consumes
        assert!(g.producer(y).is_some());
    }

    #[test]
    fn flops_conv() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["N", "H", "W", "I"], &[1, 8, 8, 4]);
        let _ = b.conv2d("c", x, 16, 3, 1, 1);
        let g = b.finish();
        let conv = g.complex_nodes()[0];
        // out 8x8x16, 2 * 4*3*3 per out elem
        assert_eq!(g.node_flops(conv), 2.0 * (8 * 8 * 16) as f64 * 36.0);
    }
}
