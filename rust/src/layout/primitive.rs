//! The primitive vocabulary and per-dimension access descriptors.

use crate::expr::Expr;
use crate::tensor::TensorId;

/// How one logical dimension of a tensor is indexed by an operator.
///
/// Convolutions index their input spatial dims with the sliding-window
/// pattern `V*i + r` (stride `V`, window offset `r` with extent `M`);
/// the paper's Eq. (1) rewrite for `unfold` is only defined for that
/// pattern, so we keep it structured instead of flattening to a raw
/// expression.
#[derive(Clone, Debug, PartialEq)]
pub enum DimAccess {
    /// Arbitrary index expression.
    Simple(Expr),
    /// `stride * outer + window`, where `window` takes values in
    /// `[win_lo, win_lo + win_size)`.
    Sliding {
        stride: i64,
        outer: Expr,
        window: Expr,
        win_lo: i64,
        win_size: i64,
    },
}

impl DimAccess {
    /// Collapse to a raw expression (loses sliding structure).
    pub fn to_expr(&self) -> Expr {
        match self {
            DimAccess::Simple(e) => e.clone(),
            DimAccess::Sliding { stride, outer, window, .. } => Expr::add(
                Expr::mul(Expr::Const(*stride), outer.clone()),
                window.clone(),
            ),
        }
    }

    pub fn simple(e: Expr) -> Self {
        DimAccess::Simple(e)
    }
}

/// One layout primitive (paper §4.1). Dimension indices refer to the
/// tensor's *current* storage dims at the point the primitive is applied
/// (sequences are interpreted left to right).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Primitive {
    /// Split dim `dim` into `factors` (product must equal the extent;
    /// Table 1 row 1 with all new dims given explicitly).
    Split { dim: usize, factors: Vec<i64> },
    /// Permute storage dims: new dim `j` is old dim `perm[j]`
    /// (Table 1 row 2).
    Reorder { perm: Vec<usize> },
    /// Fuse `count` consecutive dims starting at `dim` (Table 1 row 3).
    Fuse { dim: usize, count: usize },
    /// Overlapped tiling (§4.1.2): dim of extent `D` becomes
    /// `[ceil((D - size)/stride) + 1, size]`.
    Unfold { dim: usize, size: i64, stride: i64 },
    /// Append zeros: extent `D` becomes `before + D + after`.
    Pad { dim: usize, before: i64, after: i64 },
    /// Attach tensor `other` into this tensor's storage along `dim`
    /// (graph-level; see module docs).
    StoreAt { other: TensorId, dim: usize },
    // ---- inverses ----
    /// Inverse of `Unfold` (drops the overlap duplicates).
    Fold { dim: usize, size: i64, stride: i64 },
    /// Inverse of `Pad`.
    Unpad { dim: usize, before: i64, after: i64 },
    /// Inverse of `StoreAt`.
    DecoupleAt { other: TensorId, dim: usize },
}

impl Primitive {
    /// Convenience constructors mirroring the paper's API.
    pub fn split(dim: usize, factors: &[i64]) -> Self {
        Primitive::Split { dim, factors: factors.to_vec() }
    }
    pub fn reorder(perm: &[usize]) -> Self {
        Primitive::Reorder { perm: perm.to_vec() }
    }
    pub fn fuse(dim: usize, count: usize) -> Self {
        Primitive::Fuse { dim, count }
    }
    pub fn unfold(dim: usize, size: i64, stride: i64) -> Self {
        Primitive::Unfold { dim, size, stride }
    }
    pub fn pad(dim: usize, before: i64, after: i64) -> Self {
        Primitive::Pad { dim, before, after }
    }

    /// Push this primitive's parameter state onto the RL state vector
    /// (§5.2.1: e.g. split state is its factor list).
    pub fn push_state(&self, out: &mut Vec<f64>) {
        match self {
            Primitive::Split { factors, .. } => {
                for &f in factors {
                    out.push(f as f64);
                }
            }
            Primitive::Reorder { perm } => {
                for &p in perm {
                    out.push(p as f64);
                }
            }
            Primitive::Fuse { dim, count } => {
                out.push(*dim as f64);
                out.push(*count as f64);
            }
            Primitive::Unfold { size, stride, .. }
            | Primitive::Fold { size, stride, .. } => {
                out.push(*size as f64);
                out.push(*stride as f64);
            }
            Primitive::Pad { before, after, .. }
            | Primitive::Unpad { before, after, .. } => {
                out.push(*before as f64);
                out.push(*after as f64);
            }
            Primitive::StoreAt { dim, .. } | Primitive::DecoupleAt { dim, .. } => {
                out.push(*dim as f64);
            }
        }
    }

    /// The inverse primitive, given the shape *before* this primitive
    /// was applied (needed to invert `Fuse` and `Split` positions).
    pub fn inverse(&self, shape_before: &[i64]) -> Primitive {
        match self {
            Primitive::Split { dim, factors } => {
                Primitive::Fuse { dim: *dim, count: factors.len() }
            }
            Primitive::Reorder { perm } => {
                let mut inv = vec![0usize; perm.len()];
                for (j, &p) in perm.iter().enumerate() {
                    inv[p] = j;
                }
                Primitive::Reorder { perm: inv }
            }
            Primitive::Fuse { dim, count } => Primitive::Split {
                dim: *dim,
                factors: shape_before[*dim..*dim + *count].to_vec(),
            },
            Primitive::Unfold { dim, size, stride } => {
                Primitive::Fold { dim: *dim, size: *size, stride: *stride }
            }
            Primitive::Fold { dim, size, stride } => {
                Primitive::Unfold { dim: *dim, size: *size, stride: *stride }
            }
            Primitive::Pad { dim, before, after } => {
                Primitive::Unpad { dim: *dim, before: *before, after: *after }
            }
            Primitive::Unpad { dim, before, after } => {
                Primitive::Pad { dim: *dim, before: *before, after: *after }
            }
            Primitive::StoreAt { other, dim } => {
                Primitive::DecoupleAt { other: *other, dim: *dim }
            }
            Primitive::DecoupleAt { other, dim } => {
                Primitive::StoreAt { other: *other, dim: *dim }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{Const, Var};

    #[test]
    fn sliding_to_expr() {
        let a = DimAccess::Sliding {
            stride: 2,
            outer: Var(0),
            window: Var(1),
            win_lo: 0,
            win_size: 3,
        };
        assert_eq!(a.to_expr().eval(&[5, 2]), 12);
    }

    #[test]
    fn reorder_inverse_roundtrip() {
        let p = Primitive::reorder(&[2, 0, 1]);
        let inv = p.inverse(&[4, 5, 6]);
        match inv {
            Primitive::Reorder { perm } => assert_eq!(perm, vec![1, 2, 0]),
            _ => panic!("wrong inverse kind"),
        }
    }

    #[test]
    fn split_inverse_is_fuse() {
        let p = Primitive::split(1, &[8, 4]);
        match p.inverse(&[2, 32, 7]) {
            Primitive::Fuse { dim: 1, count: 2 } => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn fuse_inverse_restores_factors() {
        let p = Primitive::fuse(0, 2);
        match p.inverse(&[3, 5, 7]) {
            Primitive::Split { dim: 0, factors } => {
                assert_eq!(factors, vec![3, 5])
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn state_vector_contents() {
        let p = Primitive::split(2, &[4, 16]);
        let mut v = Vec::new();
        p.push_state(&mut v);
        assert_eq!(v, vec![4.0, 16.0]);
        let _ = Const(0); // silence unused import in some cfg combos
    }
}
