//! Layout primitives — the paper's §4.1 transformation submodule.
//!
//! Six primitives manipulate tensor storage formats: the basic
//! one-to-one `split` / `reorder` / `fuse` (Table 1) and the advanced
//! `unfold` / `pad` / `store_at` (§4.1.2), plus inverses. A
//! [`LayoutSeq`] is the primitive sequence attached to one tensor; the
//! [`LayoutTransform`] engine applies a sequence to a concrete shape and
//! provides the three derived operations the rest of the compiler needs:
//!
//! 1. **shape rewrite** — the transformed storage shape;
//! 2. **forward access rewrite** — logical-index expressions → storage
//!    index expressions (Table 1 rules + Eq. (1) for `unfold`), which is
//!    the compilation pass that frees users from re-implementing
//!    operators;
//! 3. **backward mapping** — storage-dim loop variables → logical index
//!    expressions (`S⁻¹`, §6), used to reconstruct the producer's loop
//!    nest and remap every other operand's accesses.
//!
//! `store_at` is a *graph-level* pairing (attach tensor A into tensor B's
//! storage); it is represented here but applied by
//! [`crate::codegen`]/[`crate::propagate`], not by the index engine.

pub mod primitive;
pub mod transform;

pub use primitive::{DimAccess, Primitive};
pub use transform::LayoutTransform;

use crate::tensor::TensorId;

/// A primitive sequence for one tensor (paper notation `S(T)`).
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
pub struct LayoutSeq {
    pub prims: Vec<Primitive>,
}

impl LayoutSeq {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, p: Primitive) -> &mut Self {
        self.prims.push(p);
        self
    }

    pub fn is_identity(&self) -> bool {
        self.prims.is_empty()
    }

    /// True if the sequence contains a *non-trivial advanced* primitive
    /// (data-expanding `unfold`/`pad`, or `store_at`). Propagation
    /// constraint 2 (§4.2): such sequences are never propagated — a
    /// conversion operator is inserted instead.
    pub fn has_advanced(&self) -> bool {
        self.prims.iter().any(|p| {
            matches!(
                p,
                Primitive::Unfold { .. }
                    | Primitive::Pad { .. }
                    | Primitive::StoreAt { .. }
            )
        })
    }

    /// RL state vector (§5.2.1): concatenation of each primitive's
    /// current parameter state.
    pub fn state_vector(&self) -> Vec<f64> {
        let mut v = Vec::new();
        for p in &self.prims {
            p.push_state(&mut v);
        }
        v
    }

    /// Apply to a shape, returning the transformed storage shape.
    pub fn apply_shape(&self, shape: &[i64]) -> Vec<i64> {
        LayoutTransform::new(shape.to_vec(), self).final_shape().to_vec()
    }

    /// Whether every primitive is applicable to `shape` (dims in range,
    /// split factors divide, unfold fits). Used to validate sequences
    /// produced by mechanical rewrites (e.g. the Fig. 11 forced-sharing
    /// ablations) before they reach the transform engine.
    pub fn is_valid_for(&self, shape: &[i64]) -> bool {
        let mut s = shape.to_vec();
        for p in &self.prims {
            match p {
                Primitive::Split { dim, factors } => {
                    if *dim >= s.len()
                        || factors.is_empty()
                        || factors.iter().product::<i64>() != s[*dim]
                    {
                        return false;
                    }
                }
                Primitive::Reorder { perm } => {
                    if perm.len() != s.len() {
                        return false;
                    }
                    let mut seen = vec![false; s.len()];
                    for &i in perm {
                        if i >= s.len() || seen[i] {
                            return false;
                        }
                        seen[i] = true;
                    }
                }
                Primitive::Fuse { dim, count } => {
                    if *count < 1 || dim + count > s.len() {
                        return false;
                    }
                }
                Primitive::Unfold { dim, size, stride } => {
                    if *dim >= s.len() || *size > s[*dim] || *stride < 1 {
                        return false;
                    }
                }
                Primitive::Pad { dim, .. } | Primitive::StoreAt { dim, .. } => {
                    if *dim >= s.len() {
                        return false;
                    }
                }
                Primitive::Fold { dim, size, .. } => {
                    if dim + 1 >= s.len() || s[*dim + 1] != *size {
                        return false;
                    }
                }
                Primitive::Unpad { dim, before, after } => {
                    if *dim >= s.len() || s[*dim] <= before + after {
                        return false;
                    }
                }
                Primitive::DecoupleAt { dim, .. } => {
                    if *dim >= s.len() {
                        return false;
                    }
                }
            }
            s = transform::apply_shape(&s, p);
        }
        true
    }
}

/// The layout decision for one tensor inside a tuning assignment.
#[derive(Clone, Debug, Default)]
pub struct TensorLayout {
    pub tensor: TensorId,
    pub seq: LayoutSeq,
}
