//! The layout-transform engine: shape rewriting, forward access
//! rewriting (Table 1 + Eq. (1)), backward (`S⁻¹`) mapping, and concrete
//! data repacking for golden tests.

use crate::expr::{Const, Expr};
use crate::layout::{DimAccess, LayoutSeq, Primitive};

/// A layout sequence applied to a concrete starting shape. Records the
/// shape before every step so inverses are well-defined.
#[derive(Clone, Debug)]
pub struct LayoutTransform {
    /// Primitive + the shape it was applied to.
    steps: Vec<(Primitive, Vec<i64>)>,
    shape: Vec<i64>,
}

impl LayoutTransform {
    pub fn new(shape: Vec<i64>, seq: &LayoutSeq) -> Self {
        let mut t = Self { steps: Vec::new(), shape };
        for p in &seq.prims {
            t.apply(p.clone());
        }
        t
    }

    pub fn final_shape(&self) -> &[i64] {
        &self.shape
    }

    fn apply(&mut self, p: Primitive) {
        let before = self.shape.clone();
        self.shape = apply_shape(&self.shape, &p);
        self.steps.push((p, before));
    }

    /// Forward access rewrite: per-dimension accesses of the *logical*
    /// tensor → accesses of the transformed storage (the compilation
    /// pass of §4.1 that rewrites `T[n][h][w][o]` step by step).
    pub fn rewrite_access(&self, access: &[DimAccess]) -> Vec<DimAccess> {
        let mut acc = access.to_vec();
        for (p, shape_before) in &self.steps {
            acc = rewrite_step(&acc, p, shape_before);
        }
        acc
    }

    /// Backward mapping (`S⁻¹(L')`, §6): expressions for the final
    /// storage dims → expressions for the original logical dims.
    /// `vars[j]` is typically `Expr::Var(loop_var_of_dim_j)`.
    pub fn backward(&self, vars: &[Expr]) -> Vec<Expr> {
        assert_eq!(vars.len(), self.shape.len(), "backward arity mismatch");
        let mut exprs: Vec<Expr> = vars.to_vec();
        for (p, shape_before) in self.steps.iter().rev() {
            exprs = backward_step(&exprs, p, shape_before);
        }
        exprs
    }

    /// Concretely repack `data` (row-major over the original shape) into
    /// the transformed layout. Out-of-source positions (padding) become
    /// `fill`; `unfold` duplicates overlapped elements. This is the
    /// runtime job of an inserted conversion operator (Fig. 5a) and the
    /// golden reference for the expression rules.
    pub fn repack(&self, data: &[f32], orig_shape: &[i64], fill: f32) -> Vec<f32> {
        assert_eq!(
            data.len() as i64,
            orig_shape.iter().product::<i64>(),
            "data/shape mismatch"
        );
        let new_shape = self.final_shape();
        let total: i64 = new_shape.iter().product();
        let vars: Vec<Expr> = (0..new_shape.len()).map(Expr::Var).collect();
        let back = self.backward(&vars);
        let mut out = vec![fill; total as usize];
        let mut idx = vec![0i64; new_shape.len()];
        for flat in 0..total {
            // decode flat -> multi-index (row-major)
            let mut rem = flat;
            for d in (0..new_shape.len()).rev() {
                idx[d] = rem % new_shape[d];
                rem /= new_shape[d];
            }
            // evaluate original coordinates
            let mut ok = true;
            let mut off = 0i64;
            let mut stride = 1i64;
            for d in (0..orig_shape.len()).rev() {
                let v = back[d].eval(&idx);
                if v < 0 || v >= orig_shape[d] {
                    ok = false;
                    break;
                }
                off += v * stride;
                stride *= orig_shape[d];
            }
            if ok {
                out[flat as usize] = data[off as usize];
            }
        }
        out
    }

    /// Inverse of [`repack`](Self::repack): fold a storage buffer back
    /// to row-major over the original shape. Padding slots (positions
    /// whose backward coordinates fall outside the original shape) are
    /// skipped; `unfold` overlap duplicates all map to the same logical
    /// element and carry the same value, so writer order is irrelevant.
    /// This is the runtime job of reading a laid-out buffer at a layout
    /// boundary (the multi-op execution plan's repack steps).
    pub fn unpack(&self, data: &[f32], orig_shape: &[i64]) -> Vec<f32> {
        let new_shape = self.final_shape();
        let total: i64 = new_shape.iter().product();
        assert_eq!(data.len() as i64, total, "data/shape mismatch");
        let vars: Vec<Expr> = (0..new_shape.len()).map(Expr::Var).collect();
        let back = self.backward(&vars);
        let logical: i64 = orig_shape.iter().product();
        let mut out = vec![0f32; logical as usize];
        let mut idx = vec![0i64; new_shape.len()];
        for flat in 0..total {
            let mut rem = flat;
            for d in (0..new_shape.len()).rev() {
                idx[d] = rem % new_shape[d];
                rem /= new_shape[d];
            }
            let mut ok = true;
            let mut off = 0i64;
            let mut stride = 1i64;
            for d in (0..orig_shape.len()).rev() {
                let v = back[d].eval(&idx);
                if v < 0 || v >= orig_shape[d] {
                    ok = false;
                    break;
                }
                off += v * stride;
                stride *= orig_shape[d];
            }
            if ok {
                out[off as usize] = data[flat as usize];
            }
        }
        out
    }

    /// Lower this transform to a gather index map: entry `flat` of the
    /// result is the row-major *logical* offset the storage slot `flat`
    /// reads from, or `-1` for padding slots (which [`repack`] fills
    /// with the fill value). Applying the map element-by-element is
    /// exactly `repack` — built once at compile time so the per-run
    /// conversion is a strided gather instead of expression evaluation.
    pub fn pack_map(&self, orig_shape: &[i64]) -> Vec<i64> {
        let new_shape = self.final_shape();
        let total: i64 = new_shape.iter().product();
        let vars: Vec<Expr> = (0..new_shape.len()).map(Expr::Var).collect();
        let back = self.backward(&vars);
        let mut map = vec![-1i64; total as usize];
        let mut idx = vec![0i64; new_shape.len()];
        for flat in 0..total {
            let mut rem = flat;
            for d in (0..new_shape.len()).rev() {
                idx[d] = rem % new_shape[d];
                rem /= new_shape[d];
            }
            let mut ok = true;
            let mut off = 0i64;
            let mut stride = 1i64;
            for d in (0..orig_shape.len()).rev() {
                let v = back[d].eval(&idx);
                if v < 0 || v >= orig_shape[d] {
                    ok = false;
                    break;
                }
                off += v * stride;
                stride *= orig_shape[d];
            }
            if ok {
                map[flat as usize] = off;
            }
        }
        map
    }

    /// Lower the *inverse* direction to a gather map: entry `logical`
    /// is the storage slot [`unpack`] would read logical element
    /// `logical` from, or `-1` when no storage slot covers it (unpack
    /// leaves those at 0.0). Matches `unpack` exactly, including its
    /// last-writer-wins resolution of `unfold` overlap duplicates.
    pub fn unpack_map(&self, orig_shape: &[i64]) -> Vec<i64> {
        let new_shape = self.final_shape();
        let total: i64 = new_shape.iter().product();
        let vars: Vec<Expr> = (0..new_shape.len()).map(Expr::Var).collect();
        let back = self.backward(&vars);
        let logical: i64 = orig_shape.iter().product();
        let mut map = vec![-1i64; logical as usize];
        let mut idx = vec![0i64; new_shape.len()];
        for flat in 0..total {
            let mut rem = flat;
            for d in (0..new_shape.len()).rev() {
                idx[d] = rem % new_shape[d];
                rem /= new_shape[d];
            }
            let mut ok = true;
            let mut off = 0i64;
            let mut stride = 1i64;
            for d in (0..orig_shape.len()).rev() {
                let v = back[d].eval(&idx);
                if v < 0 || v >= orig_shape[d] {
                    ok = false;
                    break;
                }
                off += v * stride;
                stride *= orig_shape[d];
            }
            if ok {
                map[off as usize] = flat;
            }
        }
        map
    }
}

/// Shape rule for one primitive (Table 1 "Transformed Shape" column plus
/// §4.1.2 for the advanced ones).
pub fn apply_shape(shape: &[i64], p: &Primitive) -> Vec<i64> {
    let mut s = shape.to_vec();
    match p {
        Primitive::Split { dim, factors } => {
            let d = s[*dim];
            let prod: i64 = factors.iter().product();
            assert_eq!(
                d, prod,
                "split factors {factors:?} must multiply to extent {d}"
            );
            s.splice(*dim..*dim + 1, factors.iter().copied());
        }
        Primitive::Reorder { perm } => {
            assert_eq!(perm.len(), s.len(), "reorder perm arity");
            let mut seen = vec![false; s.len()];
            for &p in perm {
                assert!(!seen[p], "reorder perm must be a permutation");
                seen[p] = true;
            }
            s = perm.iter().map(|&i| s[i]).collect();
        }
        Primitive::Fuse { dim, count } => {
            assert!(*count >= 1 && dim + count <= s.len(), "fuse range");
            let prod: i64 = s[*dim..*dim + *count].iter().product();
            s.splice(*dim..*dim + *count, [prod]);
        }
        Primitive::Unfold { dim, size, stride } => {
            let d = s[*dim];
            assert!(*size <= d && *stride >= 1, "unfold {size}/{stride} on {d}");
            let ntiles = (d - size + stride - 1) / stride + 1;
            s.splice(*dim..*dim + 1, [ntiles, *size]);
        }
        Primitive::Pad { dim, before, after } => {
            s[*dim] += before + after;
        }
        Primitive::Fold { dim, size, stride } => {
            // [ntiles, size] -> original D = (ntiles-1)*stride + size
            assert_eq!(s[*dim + 1], *size, "fold inner dim mismatch");
            let d = (s[*dim] - 1) * stride + size;
            s.splice(*dim..*dim + 2, [d]);
        }
        Primitive::Unpad { dim, before, after } => {
            s[*dim] -= before + after;
            assert!(s[*dim] > 0, "unpad to non-positive extent");
        }
        Primitive::StoreAt { dim, .. } => {
            // attach a 1-wide slice of `other` along `dim` (e.g. the
            // bias vector as the extra row of a GMM weight — §4.1.2)
            s[*dim] += 1;
        }
        Primitive::DecoupleAt { dim, .. } => {
            s[*dim] -= 1;
            assert!(s[*dim] > 0, "decouple_at on 1-wide dim");
        }
    }
    s
}

/// Access rewrite for one primitive (Table 1 "Transformed Accessing
/// Expressions" column; Eq. (1) for unfold-on-sliding).
fn rewrite_step(
    acc: &[DimAccess],
    p: &Primitive,
    shape_before: &[i64],
) -> Vec<DimAccess> {
    let mut a = acc.to_vec();
    match p {
        Primitive::Split { dim, factors } => {
            let e = a[*dim].to_expr();
            let m = factors.len();
            let mut parts = Vec::with_capacity(m);
            for (j, &fj) in factors.iter().enumerate() {
                // suffix product F_{j+1..m}
                let suffix: i64 = factors[j + 1..].iter().product();
                let mut part = Expr::div(e.clone(), Const(suffix));
                if j > 0 {
                    part = Expr::rem(part, Const(fj));
                }
                parts.push(DimAccess::Simple(part));
            }
            a.splice(*dim..*dim + 1, parts);
        }
        Primitive::Reorder { perm } => {
            a = perm.iter().map(|&i| a[i].clone()).collect();
        }
        Primitive::Fuse { dim, count } => {
            // (i_k * N_{k+1..} + i_{k+1} * N_{k+2..} + ... + i_{k+m})
            let mut e = Const(0);
            for j in 0..*count {
                let suffix: i64 = shape_before[*dim + j + 1..*dim + *count]
                    .iter()
                    .product();
                e = Expr::add(
                    e,
                    Expr::mul(a[*dim + j].to_expr(), Const(suffix)),
                );
            }
            a.splice(*dim..*dim + *count, [DimAccess::Simple(e)]);
        }
        Primitive::Unfold { dim, size, stride } => {
            let d = shape_before[*dim];
            let ntiles = (d - size + stride - 1) / stride + 1;
            // the last tile is right-aligned: start(t) = min(S*t, D-B)
            let start_of = |tile: &Expr| {
                Expr::min(
                    Expr::mul(Const(*stride), tile.clone()),
                    Const(d - size),
                )
            };
            let (tile, off) = match &a[*dim] {
                DimAccess::Sliding { stride: v, outer, window, win_lo, win_size } => {
                    // Eq. (1): outputs-per-tile T = floor((B - M)/V) + 1
                    // with window span M measured from 0 (win_lo ≥ 0).
                    let m_eff = win_lo + win_size;
                    let t = (size - m_eff).div_euclid(*v) + 1;
                    assert!(t >= 1, "unfold tile smaller than window");
                    let tile = Expr::min(
                        Expr::div(outer.clone(), Const(t)),
                        Const(ntiles - 1),
                    );
                    let e = Expr::add(
                        Expr::mul(Const(*v), outer.clone()),
                        window.clone(),
                    );
                    let off = Expr::sub(e, start_of(&tile));
                    (tile, off)
                }
                DimAccess::Simple(e) => {
                    // Generic fallback: valid when stride == size
                    // (non-overlapping) or when accesses stay in-tile.
                    let tile = Expr::min(
                        Expr::div(e.clone(), Const(*stride)),
                        Const(ntiles - 1),
                    );
                    let off = Expr::sub(e.clone(), start_of(&tile));
                    (tile, off)
                }
            };
            a.splice(
                *dim..*dim + 1,
                [DimAccess::Simple(tile), DimAccess::Simple(off)],
            );
        }
        Primitive::Pad { dim, before, .. } => {
            a[*dim] = match &a[*dim] {
                DimAccess::Simple(e) => {
                    DimAccess::Simple(Expr::add(e.clone(), Const(*before)))
                }
                DimAccess::Sliding { stride, outer, window, win_lo, win_size } => {
                    DimAccess::Sliding {
                        stride: *stride,
                        outer: outer.clone(),
                        window: Expr::add(window.clone(), Const(*before)),
                        win_lo: win_lo + before,
                        win_size: *win_size,
                    }
                }
            };
        }
        Primitive::Fold { dim, stride, .. } => {
            // [tile, off] accesses -> tile*stride + off
            let e = Expr::add(
                Expr::mul(a[*dim].to_expr(), Const(*stride)),
                a[*dim + 1].to_expr(),
            );
            a.splice(*dim..*dim + 2, [DimAccess::Simple(e)]);
        }
        Primitive::Unpad { dim, before, .. } => {
            a[*dim] = DimAccess::Simple(Expr::sub(
                a[*dim].to_expr(),
                Const(*before),
            ));
        }
        Primitive::StoreAt { .. } | Primitive::DecoupleAt { .. } => {}
    }
    a
}

/// Inverse mapping for one primitive: expressions over the dims *after*
/// the primitive → expressions over the dims *before* it.
fn backward_step(exprs: &[Expr], p: &Primitive, shape_before: &[i64]) -> Vec<Expr> {
    let mut e = exprs.to_vec();
    match p {
        Primitive::Split { dim, factors } => {
            // combine m exprs into the original index:
            // ((e1*F2 + e2)*F3 + ...) + e_m
            let m = factors.len();
            let mut acc = e[*dim].clone();
            for j in 1..m {
                acc = Expr::add(
                    Expr::mul(acc, Const(factors[j])),
                    e[*dim + j].clone(),
                );
            }
            e.splice(*dim..*dim + m, [acc]);
        }
        Primitive::Reorder { perm } => {
            let mut out = vec![Const(0); e.len()];
            for (j, &p_) in perm.iter().enumerate() {
                out[p_] = e[j].clone();
            }
            e = out;
        }
        Primitive::Fuse { dim, count } => {
            // one expr -> count exprs via div/mod over original extents
            let sizes = &shape_before[*dim..*dim + *count];
            let fused = e[*dim].clone();
            let mut parts = Vec::with_capacity(*count);
            for j in 0..*count {
                let suffix: i64 = sizes[j + 1..].iter().product();
                let mut part = Expr::div(fused.clone(), Const(suffix));
                if j > 0 {
                    part = Expr::rem(part, Const(sizes[j]));
                }
                parts.push(part);
            }
            e.splice(*dim..*dim + 1, parts);
        }
        Primitive::Unfold { dim, size, stride } => {
            // (tile, off) -> min(stride*tile, D-B) + off — the last
            // tile is right-aligned (paper §4.1.2 clamp)
            let d = shape_before[*dim];
            let start = Expr::min(
                Expr::mul(Const(*stride), e[*dim].clone()),
                Const(d - size),
            );
            let orig = Expr::add(start, e[*dim + 1].clone());
            e.splice(*dim..*dim + 2, [orig]);
        }
        Primitive::Pad { dim, before, .. } => {
            e[*dim] = Expr::sub(e[*dim].clone(), Const(*before));
        }
        Primitive::Fold { dim, size, stride } => {
            // inverse of fold = unfold forward on expressions: the
            // canonical representative of element x is tile x/stride
            // clamped (matches apply_shape for Fold).
            let d = (shape_before[*dim] - 1) * stride + size;
            let ntiles = (d - size + stride - 1) / stride + 1;
            let tile = Expr::min(
                Expr::div(e[*dim].clone(), Const(*stride)),
                Const(ntiles - 1),
            );
            let off = Expr::sub(
                e[*dim].clone(),
                Expr::mul(Const(*stride), tile.clone()),
            );
            e.splice(*dim..*dim + 1, [tile, off]);
        }
        Primitive::Unpad { dim, before, .. } => {
            e[*dim] = Expr::add(e[*dim].clone(), Const(*before));
        }
        Primitive::StoreAt { .. } | Primitive::DecoupleAt { .. } => {}
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::Var;

    fn seq(prims: Vec<Primitive>) -> LayoutSeq {
        LayoutSeq { prims }
    }

    #[test]
    fn unpack_inverts_repack() {
        // bijective basic sequence: split + reorder
        let s = seq(vec![
            Primitive::split(1, &[4, 2]),
            Primitive::reorder(&[0, 2, 1, 3]),
        ]);
        let shape = [3i64, 8];
        let data: Vec<f32> = (0..24).map(|v| v as f32).collect();
        let tf = LayoutTransform::new(shape.to_vec(), &s);
        let packed = tf.repack(&data, &shape, 0.0);
        assert_eq!(tf.unpack(&packed, &shape), data);

        // data-expanding sequence: unfold duplicates + pad fills
        let s2 = seq(vec![
            Primitive::unfold(0, 3, 2),
            Primitive::pad(1, 1, 2),
        ]);
        let shape2 = [5i64];
        let d2 = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let tf2 = LayoutTransform::new(shape2.to_vec(), &s2);
        let packed2 = tf2.repack(&d2, &shape2, -9.0);
        assert_eq!(tf2.unpack(&packed2, &shape2), d2);
    }

    /// The paper's first §4.1.1 example: NOHW -> N (O/ot) H W ot.
    #[test]
    fn paper_example_split_reorder() {
        let s = seq(vec![
            Primitive::split(1, &[32 / 8, 8]),
            Primitive::reorder(&[0, 1, 3, 4, 2]),
        ]);
        let shape = s.apply_shape(&[2, 32, 14, 14]);
        assert_eq!(shape, vec![2, 4, 14, 14, 8]);
    }

    /// The paper's second §4.1.1 example: NHWO --fuse/split/reorder-->
    /// N (O/4) (HW) 4, with the documented access-expression chain.
    #[test]
    fn paper_example_fuse_split_reorder() {
        let (h, w, o) = (3, 5, 8);
        let s = seq(vec![
            Primitive::fuse(1, 3),
            Primitive::split(1, &[o / 4, 4, h * w]),
            Primitive::reorder(&[0, 1, 3, 2]),
        ]);
        let t = LayoutTransform::new(vec![2, h, w, o], &s);
        assert_eq!(t.final_shape(), &[2, o / 4, h * w, 4]);

        // Access T[n][h][w][o] becomes
        // T[n][e/(HW*4)][e % (HW)][ (e/HW) % 4 ] with e = h*WO + w*O + o.
        let acc: Vec<DimAccess> =
            (0..4).map(|i| DimAccess::Simple(Var(i))).collect();
        let out = t.rewrite_access(&acc);
        // check numerically over the whole index space
        for n in 0..2 {
            for hh in 0..h {
                for ww in 0..w {
                    for oo in 0..o {
                        let env = [n, hh, ww, oo];
                        let e = hh * (w * o) + ww * o + oo;
                        let want = [n, e / (h * w * 4), e % (h * w), (e / (h * w)) % 4];
                        for (d, a) in out.iter().enumerate() {
                            assert_eq!(
                                a.to_expr().eval(&env),
                                want[d],
                                "dim {d} at {env:?}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Paper §4.1.2: {1,2,3,4,5} unfolded with B=3, S=2 ->
    /// {{1,2,3},{3,4,5}}.
    #[test]
    fn unfold_paper_array_example() {
        let s = seq(vec![Primitive::unfold(0, 3, 2)]);
        let t = LayoutTransform::new(vec![5], &s);
        assert_eq!(t.final_shape(), &[2, 3]);
        let packed = t.repack(&[1.0, 2.0, 3.0, 4.0, 5.0], &[5], 0.0);
        assert_eq!(packed, vec![1.0, 2.0, 3.0, 3.0, 4.0, 5.0]);
    }

    /// Eq. (1): sliding access V*i + r through unfold lands in-tile and
    /// reads the same element the original access read.
    #[test]
    fn unfold_sliding_eq1() {
        // D = 10, window M = 3, conv stride V = 1 -> 8 outputs.
        // unfold size B = 6 = ht + (KH-1) with ht = 4, stride S = ht = 4.
        let (d, b, s_, v, m) = (10i64, 6i64, 4i64, 1i64, 3i64);
        let seq_ = seq(vec![Primitive::unfold(0, b, s_)]);
        let t = LayoutTransform::new(vec![d], &seq_);
        let ntiles = (d - b + s_ - 1) / s_ + 1;
        assert_eq!(t.final_shape(), &[ntiles, b]);

        let acc = vec![DimAccess::Sliding {
            stride: v,
            outer: Var(0),
            window: Var(1),
            win_lo: 0,
            win_size: m,
        }];
        let out = t.rewrite_access(&acc);
        assert_eq!(out.len(), 2);

        let data: Vec<f32> = (0..d).map(|x| x as f32).collect();
        let packed = t.repack(&data, &[d], -1.0);
        for i in 0..(d - m) / v + 1 {
            for r in 0..m {
                let env = [i, r];
                let tile = out[0].to_expr().eval(&env);
                let off = out[1].to_expr().eval(&env);
                assert!(
                    (0..ntiles).contains(&tile) && (0..b).contains(&off),
                    "OOB tile={tile} off={off} at i={i} r={r}"
                );
                let got = packed[(tile * b + off) as usize];
                let want = data[(v * i + r) as usize];
                assert_eq!(got, want, "i={i} r={r}");
            }
        }
    }

    /// Forward/backward consistency for a random-ish mixed sequence:
    /// repacked[forward(idx)] == data[idx] for every logical idx.
    #[test]
    fn forward_backward_consistency() {
        let shape = vec![3, 8, 6];
        let s = seq(vec![
            Primitive::split(1, &[2, 4]),
            Primitive::reorder(&[0, 3, 1, 2]),
            Primitive::fuse(2, 2),
        ]);
        let t = LayoutTransform::new(shape.clone(), &s);
        let total: i64 = shape.iter().product();
        let data: Vec<f32> = (0..total).map(|x| x as f32).collect();
        let packed = t.repack(&data, &shape, f32::NAN);

        let acc: Vec<DimAccess> =
            (0..3).map(|i| DimAccess::Simple(Var(i))).collect();
        let fwd = t.rewrite_access(&acc);
        let new_shape = t.final_shape().to_vec();
        for a in 0..shape[0] {
            for b in 0..shape[1] {
                for c in 0..shape[2] {
                    let env = [a, b, c];
                    let mut off = 0i64;
                    for (d, f) in fwd.iter().enumerate() {
                        let v = f.to_expr().eval(&env);
                        assert!(v >= 0 && v < new_shape[d]);
                        off = off * new_shape[d] + v;
                    }
                    let orig = (a * shape[1] + b) * shape[2] + c;
                    assert_eq!(packed[off as usize], data[orig as usize]);
                }
            }
        }
    }

    #[test]
    fn pad_shifts_and_fills() {
        let s = seq(vec![Primitive::pad(0, 2, 1)]);
        let t = LayoutTransform::new(vec![3], &s);
        assert_eq!(t.final_shape(), &[6]);
        let packed = t.repack(&[7.0, 8.0, 9.0], &[3], 0.0);
        assert_eq!(packed, vec![0.0, 0.0, 7.0, 8.0, 9.0, 0.0]);
    }

    #[test]
    fn unfold_nonoverlapping_equals_split() {
        // unfold with size == stride is a plain split.
        let su = seq(vec![Primitive::unfold(0, 4, 4)]);
        let ss = seq(vec![Primitive::split(0, &[3, 4])]);
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let tu = LayoutTransform::new(vec![12], &su);
        let ts = LayoutTransform::new(vec![12], &ss);
        assert_eq!(tu.repack(&data, &[12], 0.0), ts.repack(&data, &[12], 0.0));
    }

    #[test]
    fn unfold_ragged_last_tile_clamps() {
        // D=7, B=3, S=2 -> ntiles = ceil(4/2)+1 = 3, last tile starts at 4.
        let s = seq(vec![Primitive::unfold(0, 3, 2)]);
        let t = LayoutTransform::new(vec![7], &s);
        assert_eq!(t.final_shape(), &[3, 3]);
        let data: Vec<f32> = (0..7).map(|x| x as f32).collect();
        let packed = t.repack(&data, &[7], -1.0);
        assert_eq!(packed, vec![0., 1., 2., 2., 3., 4., 4., 5., 6.]);
    }

    #[test]
    fn c2d_template_layout_shape() {
        // §5.1 output template: N (H/ht) (W/wt) (O/ot) ht wt ot.
        let (n, h, w, o) = (1, 112, 112, 64);
        let (ht, wt, ot) = (4, 16, 16);
        let s = seq(vec![
            Primitive::split(1, &[h / ht, ht]),
            Primitive::split(3, &[w / wt, wt]),
            Primitive::split(5, &[o / ot, ot]),
            Primitive::reorder(&[0, 1, 3, 5, 2, 4, 6]),
        ]);
        assert_eq!(
            s.apply_shape(&[n, h, w, o]),
            vec![1, 28, 7, 4, 4, 16, 16]
        );
    }

    /// Applying `pack_map`/`unpack_map` element-by-element must equal
    /// `repack`/`unpack` — the maps are their compiled form.
    #[test]
    fn gather_maps_match_repack_and_unpack() {
        let cases: Vec<(Vec<i64>, LayoutSeq)> = vec![
            // bijective: split + reorder
            (
                vec![3, 8],
                seq(vec![
                    Primitive::split(1, &[4, 2]),
                    Primitive::reorder(&[0, 2, 1, 3]),
                ]),
            ),
            // expanding: unfold (overlap duplicates) + pad (fill)
            (vec![5], seq(vec![
                Primitive::unfold(0, 3, 2),
                Primitive::pad(1, 1, 2),
            ])),
            // ragged unfold (right-aligned last tile)
            (vec![7], seq(vec![Primitive::unfold(0, 3, 2)])),
            // mixed: split/reorder/fuse
            (
                vec![3, 8, 6],
                seq(vec![
                    Primitive::split(1, &[2, 4]),
                    Primitive::reorder(&[0, 3, 1, 2]),
                    Primitive::fuse(2, 2),
                ]),
            ),
        ];
        for (shape, s) in cases {
            let tf = LayoutTransform::new(shape.clone(), &s);
            let total: i64 = shape.iter().product();
            let data: Vec<f32> = (0..total).map(|x| x as f32 + 1.0).collect();
            let fill = -7.0f32;

            let want_packed = tf.repack(&data, &shape, fill);
            let pm = tf.pack_map(&shape);
            let got_packed: Vec<f32> = pm
                .iter()
                .map(|&src| if src < 0 { fill } else { data[src as usize] })
                .collect();
            assert_eq!(got_packed, want_packed, "pack_map vs repack");

            let storage = tf.repack(&data, &shape, 0.0);
            let want_logical = tf.unpack(&storage, &shape);
            let um = tf.unpack_map(&shape);
            let got_logical: Vec<f32> = um
                .iter()
                .map(|&src| if src < 0 { 0.0 } else { storage[src as usize] })
                .collect();
            assert_eq!(got_logical, want_logical, "unpack_map vs unpack");
        }
    }

    #[test]
    fn state_vector_concats() {
        let s = seq(vec![
            Primitive::split(1, &[2, 4]),
            Primitive::unfold(0, 6, 4),
        ]);
        assert_eq!(s.state_vector(), vec![2.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn has_advanced_detection() {
        let basic = seq(vec![Primitive::split(0, &[2, 2])]);
        assert!(!basic.has_advanced());
        let adv = seq(vec![Primitive::unfold(0, 3, 2)]);
        assert!(adv.has_advanced());
    }
}
