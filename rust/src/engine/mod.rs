//! Parallel candidate-evaluation engine.
//!
//! ALT's joint tuning loop is measurement-bound: every candidate costs
//! one pass of `lower_complex → feature extraction → cost-model predict
//! → simulate_program`, and the tuner runs thousands of them. This
//! module turns that inner loop into a batched, multi-core pipeline in
//! the spirit of TVM/Ansor's parallel measurement infrastructure:
//!
//! * **Worker pool** — [`Engine::run`] fans a batch of independent
//!   candidate evaluations across a scoped-thread pool
//!   (`std::thread::scope`, no external crates). Results come back in
//!   submission order, so every caller is bit-for-bit deterministic
//!   regardless of thread count — the property the determinism test in
//!   `tests/engine.rs` pins down.
//! * **Cross-round memoization** — duplicate candidates recur heavily:
//!   the incumbent point is re-measured every round, PPO walks revisit
//!   neighbours, and joint-stage layout proposals re-explore the same
//!   loop space. [`Engine`] caches the lowered [`Program`], its feature
//!   vector, and (lazily) its [`SimReport`] keyed by
//!   `(node, layout-assignment hash, loop schedule)`, so no candidate
//!   is ever lowered or simulated twice per engine lifetime.
//!
//! ### Memoization key derivation
//!
//! A lowered program is a pure function of `(graph, node, layout
//! assignment, fused tail, schedule, SIMD lanes)`. All but the
//! schedule fold into [`EvalContext::key_base`]: a
//! [`crate::util::stable_hash`] over the node id, the
//! [`LayoutAssignment::content_hash`] (all non-identity sequences +
//! read overrides), the fused tail, the hardware profile (its `Debug`
//! rendering covers every model parameter), and a graph fingerprint
//! covering exactly the neighbourhood lowering reads — so one engine
//! may safely outlive a graph. The schedule is kept *structurally* in
//! the key — schedules are tiny and exact comparison removes any
//! chance of a hash collision along the dimension that actually
//! varies per candidate.
//!
//! Cost-model *predictions* are deliberately **not** cached: the model
//! retrains online, so predictions must always go through the current
//! ensemble (cached feature vectors make them cheap). Only
//! deterministic pure stages are memoized, which is what keeps the
//! parallel engine's tuning trajectory identical to the serial one.
//!
//! ### Eviction
//!
//! The memo cache is size-capped with a **clock / second-chance**
//! policy ([`Engine::with_memo_cap`], default
//! [`Engine::DEFAULT_MEMO_CAP`]): every hit marks its entry
//! referenced; when an insert pushes the map over the cap, the clock
//! hand walks insertion order, giving referenced entries a second
//! chance and dropping cold ones. Entries are pure functions of their
//! key, so eviction can never change a tuning result — only force a
//! re-lower later (a fresh miss). When the cap binds under a parallel
//! batch, *which* entry gets evicted can depend on thread
//! interleaving; results still cannot, and with the default cap no
//! tier-1 workload ever binds. The invariance is property-tested in
//! `tests/batched_tuner.rs`.
//!
//! ### Batch submission & nested sub-batches
//!
//! [`Engine::run`] uses every pool thread. The speculative joint stage
//! instead fans K independent *proposals* at the outer level and gives
//! each one a width-capped [`EngineHandle`]
//! ([`Engine::handle_with`]) for its inner candidate batches, so
//! K × inner ≈ pool size and nested batches never oversubscribe the
//! machine. A handle shares the engine's memo cache and counters; the
//! width only caps how many workers one call may occupy.
//!
//! The shard orchestrator generalizes this: [`Engine::fair_handles`]
//! splits the pool into balanced per-shard shares (so concurrent
//! shards cannot starve each other), and a handle may carry an
//! [`EngineTally`] ([`EngineHandle::with_tally`]) that counts its own
//! scope's lookups in addition to the global counters — the basis of
//! the composable per-op/per-shard/per-graph stats accounting.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::codegen::{lower_complex, Program};
use crate::cost::{extract_features, CostModel};
use crate::graph::{Graph, NodeId};
use crate::layout::LayoutTransform;
use crate::loops::LoopSchedule;
use crate::propagate::PropagationResult;
use crate::sim::{simulate_program, simulate_streaming, HwProfile, SimReport};
use crate::util::stable_hash;

/// One fully-evaluated candidate: the lowered program, its cost-model
/// features, and (once a measurement stage ran) its simulation report.
/// Both stages fill lazily through `OnceLock`, so two workers racing
/// on the same candidate coordinate on one computation instead of
/// duplicating it.
#[derive(Debug)]
pub struct EvalEntry {
    lowered: OnceLock<Lowered>,
    report: OnceLock<SimReport>,
}

#[derive(Debug)]
struct Lowered {
    program: Arc<Program>,
    features: Arc<Vec<f64>>,
}

impl EvalEntry {
    fn empty() -> Self {
        Self { lowered: OnceLock::new(), report: OnceLock::new() }
    }

    fn lowered(&self) -> &Lowered {
        // entries are lowered before any caller sees them (see
        // `eval_tallied`); a bare OnceLock here is a construction bug
        match self.lowered.get() {
            Some(l) => l,
            None => unreachable!("entry handed out before lowering"),
        }
    }

    /// The lowered program (initialized before any caller sees the entry).
    pub fn program(&self) -> &Arc<Program> {
        &self.lowered().program
    }

    /// The cost-model feature vector of the lowered program.
    pub fn features(&self) -> &Arc<Vec<f64>> {
        &self.lowered().features
    }

    /// The simulation report, if this candidate was ever measured.
    pub fn report(&self) -> Option<&SimReport> {
        self.report.get()
    }
}

/// A measured candidate: raw nest latency plus the total including the
/// layout-conversion charges of the evaluation context.
#[derive(Clone, Debug)]
pub struct Measured {
    pub entry: Arc<EvalEntry>,
    /// `simulate_program` latency of the nest alone (what the cost
    /// model trains on, matching the serial tuner).
    pub raw_ms: f64,
    /// Nest latency plus conversion charges (what the tuner ranks by).
    pub total_ms: f64,
}

/// Monotonic counters snapshot; `hits / (hits + misses)` is the memo
/// hit rate over the engine lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Candidate evaluations answered from the memo cache.
    pub hits: u64,
    /// Candidate evaluations that had to lower + featurize.
    pub misses: u64,
    /// `simulate_program` executions (≤ misses once warm).
    pub simulated: u64,
    /// Memo entries dropped by the clock eviction (0 until the cap
    /// binds).
    pub evicted: u64,
}

impl EngineStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter delta since an earlier snapshot of the same engine.
    pub fn since(&self, earlier: &EngineStats) -> EngineStats {
        EngineStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            simulated: self.simulated - earlier.simulated,
            evicted: self.evicted - earlier.evicted,
        }
    }

    /// Component-wise sum — per-op tallies compose into per-shard and
    /// per-graph totals (the delta-based accounting contract).
    pub fn merged(&self, other: &EngineStats) -> EngineStats {
        EngineStats {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            simulated: self.simulated + other.simulated,
            evicted: self.evicted + other.evicted,
        }
    }
}

#[derive(Default)]
struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
    simulated: AtomicU64,
    evicted: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> EngineStats {
        EngineStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            simulated: self.simulated.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
        }
    }
}

/// A private counter scope: evaluations routed through a handle that
/// carries a tally are counted here *in addition to* the engine's
/// global counters. This is how per-op/per-shard stats stay exact when
/// many tuning runs share one engine concurrently — a global
/// before/after snapshot would interleave everybody's work, a tally
/// counts only its own scope's lookups. Because memo keys of distinct
/// ops never alias (the node id and graph fingerprint are in the key),
/// a tally's hit/miss counts are deterministic for a fixed candidate
/// sequence regardless of what runs concurrently (eviction under a
/// binding cap is the one documented exception).
#[derive(Default)]
pub struct EngineTally {
    counters: Counters,
}

impl EngineTally {
    pub fn new() -> Self {
        Self::default()
    }

    /// Counters recorded into this tally so far.
    pub fn stats(&self) -> EngineStats {
        self.counters.snapshot()
    }
}

/// Everything fixed across one batch of candidates: the operator being
/// tuned, the propagated layout assignment, the device model, and the
/// precomputed conversion charges that assignment forces.
pub struct EvalContext<'a> {
    pub graph: &'a Graph,
    pub node: NodeId,
    pub prop: &'a PropagationResult,
    pub hw: &'a HwProfile,
    tail: Vec<NodeId>,
    /// Conversion latency terms in graph order; applied to each
    /// candidate with left-to-right addition so totals stay bitwise
    /// identical to the historical serial accumulation.
    conv_terms: Vec<f64>,
    /// Hash over (node, layouts, tail, hardware, graph) — see module
    /// docs.
    key_base: u64,
}

impl<'a> EvalContext<'a> {
    /// Context for tuning `node`, charging the conversions its layout
    /// decisions force (the tuner's reward signal, Fig. 5).
    pub fn new(
        graph: &'a Graph,
        node: NodeId,
        prop: &'a PropagationResult,
        hw: &'a HwProfile,
    ) -> Self {
        let mut ctx = Self::for_node(graph, node, prop, hw);
        ctx.conv_terms = conversion_terms(graph, prop, hw);
        ctx
    }

    /// Context without conversion charges (whole-graph simulation
    /// accounts for conversions as explicit graph-level ops instead).
    pub fn for_node(
        graph: &'a Graph,
        node: NodeId,
        prop: &'a PropagationResult,
        hw: &'a HwProfile,
    ) -> Self {
        let tail = prop.fused_tails.get(&node).cloned().unwrap_or_default();
        // One engine may outlive a graph (tune_graph shares it across
        // ops and the final sim), so a (node, layouts) pair from a
        // *different* graph must never alias a cached program. Lowering
        // reads only the node, its fused tail, and their tensors —
        // graph_fingerprint hashes exactly that neighbourhood (plus
        // graph name/arity), staying O(node) on this hot path instead
        // of O(graph).
        let key_base = stable_hash(&(
            node,
            prop.layouts.content_hash(),
            &tail,
            format!("{hw:?}"),
            graph_fingerprint(graph, node, &tail),
        ));
        Self { graph, node, prop, hw, tail, conv_terms: Vec::new(), key_base }
    }

    /// Total conversion charge (diagnostics; candidates receive the
    /// terms one by one).
    pub fn conversion_ms(&self) -> f64 {
        self.conv_terms.iter().sum()
    }
}

/// Hash of everything `lower_complex` reads from the graph for one
/// node: the node and its fused-tail nodes (kind, name), every tensor
/// they touch (shape, dtype, dim names, producer), and the graph's
/// name/arity as a cheap global discriminator.
fn graph_fingerprint(graph: &Graph, node: NodeId, tail: &[NodeId]) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = crate::util::StableHasher::new();
    graph.name.hash(&mut h);
    graph.nodes.len().hash(&mut h);
    graph.tensors.len().hash(&mut h);
    for &id in std::iter::once(&node).chain(tail.iter()) {
        let n = graph.node(id);
        n.name.hash(&mut h);
        format!("{:?}", n.kind).hash(&mut h);
        for &t in n.inputs.iter().chain(std::iter::once(&n.output)) {
            let ten = graph.tensor(t);
            t.hash(&mut h);
            ten.shape.hash(&mut h);
            ten.dim_names.hash(&mut h);
            ten.dtype.hash(&mut h);
            ten.producer.hash(&mut h);
        }
    }
    h.finish()
}

/// Latency charge of every conversion in `prop`, in graph order —
/// exactly the per-measurement accounting the serial tuner used:
/// un-absorbed conversions (Fig. 5a) cost a standalone strided repack;
/// absorbed ones (Fig. 5b) cost the delta of the producer writing the
/// expanded layout instead of its plain contiguous output.
fn conversion_terms(graph: &Graph, prop: &PropagationResult, hw: &HwProfile) -> Vec<f64> {
    let mut terms = Vec::with_capacity(prop.conversions.len());
    for c in &prop.conversions {
        let t = graph.tensor(c.tensor);
        let plain = t.bytes() as f64;
        let expanded = {
            let base = crate::codegen::layout_base_shape(graph, c.tensor);
            let tf = LayoutTransform::new(base, &c.to);
            tf.final_shape().iter().product::<i64>() as f64 * t.dtype.bytes() as f64
        };
        // Repacks copy long contiguous runs on at least one side (tiles
        // are large blocks), so they are bandwidth-bound like a memcpy.
        if c.absorbed_by.is_none() {
            let conv = simulate_streaming(plain, expanded, true, hw);
            terms.push(conv.latency_ms);
        } else {
            let with = simulate_streaming(plain, expanded, true, hw);
            let without = simulate_streaming(plain, plain, true, hw);
            terms.push((with.latency_ms - without.latency_ms).max(0.0));
        }
    }
    terms
}

type MemoKey = (u64, LoopSchedule);

struct MemoSlot {
    entry: Arc<EvalEntry>,
    /// Clock reference bit: set on every hit, cleared when the hand
    /// passes, evicted when found clear.
    referenced: bool,
}

/// Size-capped memo cache with clock (second-chance) eviction. The
/// ring holds the keys in insertion order, `Arc`-shared with the map
/// so clock bookkeeping costs a pointer per entry, not a second
/// `LoopSchedule` clone; each live key appears exactly once (eviction
/// pops it, a second chance recycles it to the back).
struct MemoCache {
    map: HashMap<Arc<MemoKey>, MemoSlot>,
    ring: VecDeque<Arc<MemoKey>>,
    cap: usize,
}

impl MemoCache {
    fn new(cap: usize) -> Self {
        Self { map: HashMap::new(), ring: VecDeque::new(), cap: cap.max(1) }
    }

    /// Look up or claim `key`; returns the entry, whether it was
    /// created, and the number of entries evicted to stay under the
    /// cap.
    fn lookup_or_insert(&mut self, key: MemoKey) -> (Arc<EvalEntry>, bool, u64) {
        if let Some(slot) = self.map.get_mut(&key) {
            slot.referenced = true;
            return (slot.entry.clone(), false, 0);
        }
        let key = Arc::new(key);
        let entry = Arc::new(EvalEntry::empty());
        self.map.insert(
            key.clone(),
            MemoSlot { entry: entry.clone(), referenced: false },
        );
        self.ring.push_back(key.clone());
        let mut evicted = 0;
        while self.map.len() > self.cap {
            let Some(k) = self.ring.pop_front() else { break };
            if Arc::ptr_eq(&k, &key) {
                // the page being brought in is exempt from its own
                // eviction pass (classic second-chance): evicting it
                // would defeat the same-batch OnceLock dedup the memo
                // exists for when every resident entry is hot
                self.ring.push_back(k);
                continue;
            }
            match self.map.get_mut(k.as_ref()) {
                Some(slot) if slot.referenced => {
                    slot.referenced = false;
                    self.ring.push_back(k);
                }
                Some(_) => {
                    self.map.remove(k.as_ref());
                    evicted += 1;
                }
                None => {}
            }
        }
        (entry, true, evicted)
    }
}

/// The parallel candidate-evaluation engine: scoped worker pool plus
/// the cross-round memo cache. One engine normally spans a whole
/// tuning run (op or graph) so layout proposals that re-visit the same
/// loop points hit the cache.
pub struct Engine {
    threads: usize,
    memo: Mutex<MemoCache>,
    counters: Counters,
}

/// A width-capped view of an engine for nested batch submission: the
/// speculative joint stage runs K proposals at the outer level and the
/// shard orchestrator runs S shards, each holding a handle with
/// `width ≈ threads / K`, so nested candidate batches share the pool
/// instead of oversubscribing it ([`Engine::fair_handles`] computes a
/// balanced split). Handles share the engine's memo cache and global
/// counters, and may additionally carry an [`EngineTally`] that
/// records this scope's lookups for composable per-op accounting.
#[derive(Clone, Copy)]
pub struct EngineHandle<'e> {
    engine: &'e Engine,
    width: usize,
    tally: Option<&'e EngineTally>,
}

impl Engine {
    /// Default memo-cache entry cap — far above what any single tuning
    /// run touches, so eviction only fires in long-running services
    /// (or when a smaller cap is chosen explicitly).
    pub const DEFAULT_MEMO_CAP: usize = 1 << 16;

    /// `threads == 0` ⇒ one worker per available core.
    pub fn new(threads: usize) -> Self {
        Self::with_memo_cap(threads, Self::DEFAULT_MEMO_CAP)
    }

    /// An engine whose memo cache holds at most `cap` entries
    /// (clock-evicted beyond that; `cap` is clamped to ≥ 1). Eviction
    /// trades recomputation for memory and never changes results.
    pub fn with_memo_cap(threads: usize, cap: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Self {
            threads,
            memo: Mutex::new(MemoCache::new(cap)),
            counters: Counters::default(),
        }
    }

    /// Single-threaded engine — the serial baseline the determinism
    /// test and the hotpath bench compare against.
    pub fn serial() -> Self {
        Self::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Memo-cache entry cap.
    pub fn memo_cap(&self) -> usize {
        self.memo().cap
    }

    /// Number of memoized candidates.
    pub fn memo_len(&self) -> usize {
        self.memo().map.len()
    }

    /// The memo cache, tolerant of lock poisoning: the cache holds
    /// plain data (no invariants span the lock), so a worker that
    /// panicked mid-insert leaves at worst a missing entry — safe to
    /// keep serving from after the panic is isolated.
    fn memo(&self) -> std::sync::MutexGuard<'_, MemoCache> {
        self.memo.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Full-width handle (batch submission API).
    pub fn handle(&self) -> EngineHandle<'_> {
        self.handle_with(self.threads)
    }

    /// Handle whose batches use at most `width` workers — the
    /// per-proposal sub-batch view (min 1, capped at the pool size).
    pub fn handle_with(&self, width: usize) -> EngineHandle<'_> {
        EngineHandle {
            engine: self,
            width: width.clamp(1, self.threads.max(1)),
            tally: None,
        }
    }

    /// Split the pool into `n` fair shares: widths sum to the pool
    /// size (each at least 1), with the remainder spread over the
    /// first `threads % n` handles. The shard orchestrator hands one
    /// to each concurrent shard so no shard's candidate batches can
    /// starve another's — and the split is deterministic, so it never
    /// affects results, only throughput.
    pub fn fair_handles(&self, n: usize) -> Vec<EngineHandle<'_>> {
        let n = n.max(1);
        let base = self.threads / n;
        let extra = self.threads % n;
        (0..n)
            .map(|i| self.handle_with((base + usize::from(i < extra)).max(1)))
            .collect()
    }

    pub fn stats(&self) -> EngineStats {
        self.counters.snapshot()
    }

    /// Run `n` independent jobs on the worker pool; `out[i] = f(i)`.
    /// Order-preserving, so callers are deterministic for any pool size.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.run_with(self.threads, n, f)
    }

    /// [`Engine::run`] capped at `width` workers — the nested-batch
    /// primitive: an outer fan-out gives each job a slice of the pool
    /// for its own inner batches. Order-preserving like `run`.
    ///
    /// Panic isolation: every job runs under `catch_unwind`, so one
    /// panicking job never tears down the pool mid-batch — the other
    /// jobs complete and the engine (memo cache included) stays
    /// usable. This `Vec<T>` entry point then re-raises the first
    /// failure on the *caller's* thread with the typed message;
    /// callers that want to keep the survivors use
    /// [`Engine::try_run`] instead.
    pub fn run_with<T, F>(&self, width: usize, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.try_run_with(width, n, f)
            .into_iter()
            .map(|r| r.unwrap_or_else(|e| panic!("{e}")))
            .collect()
    }

    /// [`Engine::run`] returning per-job results: a panicking job
    /// yields a typed [`crate::error::ErrorKind::Panic`] error in its
    /// slot while every other job's output survives.
    pub fn try_run<T, F>(&self, n: usize, f: F) -> Vec<crate::error::Result<T>>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.try_run_with(self.threads, n, f)
    }

    /// [`Engine::try_run`] capped at `width` workers.
    pub fn try_run_with<T, F>(
        &self,
        width: usize,
        n: usize,
        f: F,
    ) -> Vec<crate::error::Result<T>>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        // AssertUnwindSafe: a job whose panic we catch contributes no
        // output (its slot holds the typed error instead), and the
        // shared state jobs touch — the memo cache — is plain data
        // behind a poison-tolerant lock.
        let job = |i: usize| -> crate::error::Result<T> {
            catch_unwind(AssertUnwindSafe(|| {
                #[cfg(feature = "fault-inject")]
                crate::faults::maybe_panic(crate::faults::FaultSite::EngineJob);
                f(i)
            }))
            .map_err(|p| crate::error::panic_error(p, &format!("engine job {i}")))
        };
        let workers = width.min(self.threads).min(n);
        if workers <= 1 {
            return (0..n).map(job).collect();
        }
        let slots: Vec<Mutex<Option<crate::error::Result<T>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = job(i);
                    *slots[i].lock().unwrap_or_else(|p| p.into_inner()) =
                        Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| {
                match s.into_inner().unwrap_or_else(|p| p.into_inner()) {
                    Some(r) => r,
                    // every index below n is claimed exactly once and
                    // written before the scope joins
                    None => unreachable!("worker filled every slot"),
                }
            })
            .collect()
    }

    /// Lower + featurize one candidate, memoized. The slot is claimed
    /// under a single lock acquisition, so a duplicate candidate in
    /// one parallel batch waits on the first worker's `OnceLock`
    /// instead of re-lowering — hit/miss counts are therefore
    /// deterministic for a given candidate sequence, any pool size
    /// (eviction victims, when the cap binds, are the one exception;
    /// see the module docs).
    pub fn eval(&self, ctx: &EvalContext, sched: &LoopSchedule) -> Arc<EvalEntry> {
        self.eval_tallied(ctx, sched, None)
    }

    /// [`Engine::eval`] that additionally records the lookup into a
    /// caller-scoped tally (handles carrying one route through here).
    fn eval_tallied(
        &self,
        ctx: &EvalContext,
        sched: &LoopSchedule,
        tally: Option<&EngineTally>,
    ) -> Arc<EvalEntry> {
        let key = (ctx.key_base, sched.clone());
        let (entry, created, evicted) = self.memo().lookup_or_insert(key);
        let bump = |c: &Counters| {
            if created {
                c.misses.fetch_add(1, Ordering::Relaxed);
            } else {
                c.hits.fetch_add(1, Ordering::Relaxed);
            }
            if evicted > 0 {
                c.evicted.fetch_add(evicted, Ordering::Relaxed);
            }
        };
        bump(&self.counters);
        if let Some(t) = tally {
            bump(&t.counters);
        }
        entry.lowered.get_or_init(|| {
            let p = lower_complex(
                ctx.graph,
                ctx.node,
                &ctx.prop.layouts,
                sched,
                &ctx.tail,
                ctx.hw.simd_lanes,
            );
            let features = Arc::new(extract_features(&p));
            Lowered { program: Arc::new(p), features }
        });
        entry
    }

    /// The candidate's simulation report, computed at most once.
    fn simulated(&self, ctx: &EvalContext, entry: &EvalEntry) -> SimReport {
        self.simulated_tallied(ctx, entry, None)
    }

    fn simulated_tallied(
        &self,
        ctx: &EvalContext,
        entry: &EvalEntry,
        tally: Option<&EngineTally>,
    ) -> SimReport {
        entry
            .report
            .get_or_init(|| {
                self.counters.simulated.fetch_add(1, Ordering::Relaxed);
                if let Some(t) = tally {
                    t.counters.simulated.fetch_add(1, Ordering::Relaxed);
                }
                simulate_program(entry.program(), ctx.hw)
            })
            .clone()
    }

    /// Batch-lower a candidate set (the ranking stage: programs +
    /// features for cost-model prediction).
    pub fn lower_batch(
        &self,
        ctx: &EvalContext,
        scheds: &[LoopSchedule],
    ) -> Vec<Arc<EvalEntry>> {
        self.handle().lower_batch(ctx, scheds)
    }

    /// Batch-measure a candidate set (lookup + simulate) — for
    /// standalone use. Inside a two-stage round prefer
    /// [`Engine::measure_entries`] with the entries `lower_batch`
    /// already returned: re-keying here would register a memo "hit"
    /// per candidate just lowered, polluting the hit rate that is
    /// supposed to witness *cross-round* deduplication.
    pub fn measure_batch(
        &self,
        ctx: &EvalContext,
        scheds: &[LoopSchedule],
    ) -> Vec<Measured> {
        let entries = self.lower_batch(ctx, scheds);
        self.measure_entries(ctx, &entries)
    }

    /// Simulate already-evaluated candidates and apply the context's
    /// conversion charges. No memo lookup happens, so stats reflect
    /// only genuine first-stage lookups.
    pub fn measure_entries(
        &self,
        ctx: &EvalContext,
        entries: &[Arc<EvalEntry>],
    ) -> Vec<Measured> {
        self.handle().measure_entries(ctx, entries)
    }

    /// Full per-candidate pipeline `lower → featurize → predict →
    /// simulate` in one parallel pass — the throughput unit the
    /// hotpath bench reports as candidates/sec.
    pub fn pipeline_batch(
        &self,
        ctx: &EvalContext,
        scheds: &[LoopSchedule],
        cost: &CostModel,
    ) -> Vec<(f64, Measured)> {
        self.run(scheds.len(), |i| {
            let entry = self.eval(ctx, &scheds[i]);
            let pred = cost.predict_features(entry.features(), entry.program());
            let report = self.simulated(ctx, &entry);
            let raw_ms = report.latency_ms;
            let mut total_ms = raw_ms;
            for t in &ctx.conv_terms {
                total_ms += *t;
            }
            (pred, Measured { entry, raw_ms, total_ms })
        })
    }

    /// Simulate many complex nodes of one graph under a shared
    /// propagation result — the whole-graph evaluation stage of
    /// [`crate::sim::netsim`]. Reports come back in `jobs` order.
    pub fn simulate_nodes(
        &self,
        graph: &Graph,
        prop: &PropagationResult,
        hw: &HwProfile,
        jobs: &[(NodeId, LoopSchedule)],
    ) -> Vec<SimReport> {
        self.run(jobs.len(), |i| {
            let (node, sched) = &jobs[i];
            let ctx = EvalContext::for_node(graph, *node, prop, hw);
            let entry = self.eval(&ctx, sched);
            self.simulated(&ctx, &entry)
        })
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new(0)
    }
}

impl<'e> EngineHandle<'e> {
    /// The underlying engine (shared memo + counters).
    pub fn engine(&self) -> &'e Engine {
        self.engine
    }

    /// Worker cap of this handle's batches.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Same engine *and tally*, narrower batch width — nested
    /// sub-batches (speculative proposals inside a shard) keep their
    /// caller's accounting scope.
    pub fn narrowed(self, width: usize) -> EngineHandle<'e> {
        EngineHandle {
            width: width.clamp(1, self.engine.threads.max(1)),
            ..self
        }
    }

    /// This handle with a per-scope tally attached: every lookup and
    /// simulation run through the returned handle is counted into
    /// `tally` as well as the engine's global counters.
    pub fn with_tally<'t>(self, tally: &'t EngineTally) -> EngineHandle<'t>
    where
        'e: 't,
    {
        EngineHandle { engine: self.engine, width: self.width, tally: Some(tally) }
    }

    /// Order-preserving batch run capped at this handle's width.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.engine.run_with(self.width, n, f)
    }

    /// Memoized single-candidate evaluation (same memo as the engine).
    pub fn eval(&self, ctx: &EvalContext, sched: &LoopSchedule) -> Arc<EvalEntry> {
        self.engine.eval_tallied(ctx, sched, self.tally)
    }

    /// Width-capped [`Engine::lower_batch`].
    pub fn lower_batch(
        &self,
        ctx: &EvalContext,
        scheds: &[LoopSchedule],
    ) -> Vec<Arc<EvalEntry>> {
        let tally = self.tally;
        self.run(scheds.len(), |i| self.engine.eval_tallied(ctx, &scheds[i], tally))
    }

    /// Width-capped [`Engine::measure_entries`].
    pub fn measure_entries(
        &self,
        ctx: &EvalContext,
        entries: &[Arc<EvalEntry>],
    ) -> Vec<Measured> {
        let tally = self.tally;
        self.run(entries.len(), |i| {
            let entry = entries[i].clone();
            let report = self.engine.simulated_tallied(ctx, &entry, tally);
            let raw_ms = report.latency_ms;
            let mut total_ms = raw_ms;
            for t in &ctx.conv_terms {
                total_ms += *t;
            }
            Measured { entry, raw_ms, total_ms }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::propagate::{propagate, PropMode};

    fn setup() -> (Graph, NodeId, PropagationResult, HwProfile) {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let prop = propagate(&g, &[], PropMode::Alt);
        (g, conv, prop, HwProfile::intel())
    }

    #[test]
    fn run_preserves_order() {
        let e = Engine::new(4);
        let out = e.run(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn try_run_isolates_a_panicking_job() {
        for threads in [1, 4] {
            let e = Engine::new(threads);
            let out = e.try_run(10, |i| {
                if i == 3 {
                    panic!("job {i} blew up");
                }
                i * 2
            });
            assert_eq!(out.len(), 10);
            for (i, r) in out.iter().enumerate() {
                if i == 3 {
                    let err = r.as_ref().unwrap_err();
                    assert_eq!(err.kind(), crate::error::ErrorKind::Panic);
                    assert!(err.to_string().contains("job 3 blew up"), "{err}");
                } else {
                    assert_eq!(*r.as_ref().unwrap(), i * 2);
                }
            }
            // the pool (and its memo lock) survives for the next batch
            let again = e.run(5, |i| i + 1);
            assert_eq!(again, vec![1, 2, 3, 4, 5]);
        }
    }

    #[test]
    fn memo_hits_on_duplicate_candidates() {
        let (g, conv, prop, hw) = setup();
        let ctx = EvalContext::new(&g, conv, &prop, &hw);
        let e = Engine::serial();
        let sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        let a = e.eval(&ctx, &sched);
        let b = e.eval(&ctx, &sched);
        assert!(Arc::ptr_eq(&a, &b), "duplicate candidate must hit memo");
        let s = e.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(e.memo_len(), 1);
    }

    #[test]
    fn measure_matches_direct_simulation() {
        let (g, conv, prop, hw) = setup();
        let ctx = EvalContext::new(&g, conv, &prop, &hw);
        let e = Engine::serial();
        let sched = LoopSchedule::identity(&[1, 112, 112, 64], &[3, 7, 7]);
        let batch = e.measure_batch(&ctx, std::slice::from_ref(&sched));
        let m = &batch[0];
        let p = lower_complex(&g, conv, &prop.layouts, &sched, &ctx.tail, hw.simd_lanes);
        let direct = simulate_program(&p, &hw);
        assert_eq!(m.raw_ms.to_bits(), direct.latency_ms.to_bits());
        assert!(m.total_ms >= m.raw_ms);
    }

    #[test]
    fn parallel_batch_matches_serial_batch() {
        let (g, conv, prop, hw) = setup();
        let ctx = EvalContext::new(&g, conv, &prop, &hw);
        let mut scheds = Vec::new();
        let mut rng = crate::util::Rng::new(5);
        let space = crate::autotune::LoopSpace::new(&[1, 112, 112, 64], &[3, 7, 7]);
        for _ in 0..12 {
            scheds.push(space.decode(&space.random_point(&mut rng)));
        }
        let serial = Engine::serial().measure_batch(&ctx, &scheds);
        let parallel = Engine::new(4).measure_batch(&ctx, &scheds);
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.total_ms.to_bits(), p.total_ms.to_bits());
        }
    }

    #[test]
    fn run_with_caps_width_and_preserves_order() {
        let e = Engine::new(4);
        for width in [0, 1, 2, 3, 8] {
            let out = e.run_with(width, 50, |i| i * 3);
            assert_eq!(out, (0..50).map(|i| i * 3).collect::<Vec<_>>());
        }
        let h = e.handle_with(2);
        assert_eq!(h.width(), 2);
        assert_eq!(e.handle_with(99).width(), 4, "width clamps to pool size");
        assert_eq!(h.run(10, |i| i + 1), (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn handle_batches_match_engine_batches() {
        let (g, conv, prop, hw) = setup();
        let ctx = EvalContext::new(&g, conv, &prop, &hw);
        let space = crate::autotune::LoopSpace::new(&[1, 112, 112, 64], &[3, 7, 7]);
        let mut rng = crate::util::Rng::new(11);
        let scheds: Vec<LoopSchedule> =
            (0..10).map(|_| space.decode(&space.random_point(&mut rng))).collect();
        let e = Engine::new(4);
        let full = e.measure_batch(&ctx, &scheds);
        let e2 = Engine::new(4);
        let entries = e2.handle_with(2).lower_batch(&ctx, &scheds);
        let narrow = e2.handle_with(2).measure_entries(&ctx, &entries);
        for (a, b) in full.iter().zip(&narrow) {
            assert_eq!(a.total_ms.to_bits(), b.total_ms.to_bits());
        }
    }

    #[test]
    fn clock_eviction_caps_memo_and_keeps_results() {
        let (g, conv, prop, hw) = setup();
        let ctx = EvalContext::new(&g, conv, &prop, &hw);
        let space = crate::autotune::LoopSpace::new(&[1, 112, 112, 64], &[3, 7, 7]);
        let mut rng = crate::util::Rng::new(13);
        let mut scheds: Vec<LoopSchedule> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        while scheds.len() < 8 {
            let p = space.random_point(&mut rng);
            if seen.insert(p.clone()) {
                scheds.push(space.decode(&p));
            }
        }
        let e = Engine::with_memo_cap(1, 4);
        assert_eq!(e.memo_cap(), 4);
        for s in &scheds {
            e.eval(&ctx, s);
        }
        assert!(e.memo_len() <= 4, "memo over cap: {}", e.memo_len());
        let s = e.stats();
        assert_eq!(s.misses, 8);
        assert_eq!(s.evicted, 4);
        // an evicted candidate re-lowers to the same program
        let uncapped = Engine::serial();
        let a = e.measure_batch(&ctx, &scheds[..1]);
        let b = uncapped.measure_batch(&ctx, &scheds[..1]);
        assert_eq!(a[0].total_ms.to_bits(), b[0].total_ms.to_bits());
        // second chance: a hit entry survives the hand passing over it
        let hot = scheds[7].clone();
        let before = e.eval(&ctx, &hot); // hit → referenced
        while scheds.len() < 11 {
            let p = space.random_point(&mut rng);
            if seen.insert(p.clone()) {
                scheds.push(space.decode(&p));
            }
        }
        e.eval(&ctx, &scheds[8]);
        e.eval(&ctx, &scheds[9]);
        e.eval(&ctx, &scheds[10]); // hand reaches `hot`: spared, next cold evicted
        let after = e.eval(&ctx, &hot);
        assert!(Arc::ptr_eq(&before, &after), "referenced entry was evicted");
        assert!(e.memo_len() <= 4);
    }

    #[test]
    fn fair_handles_split_the_pool() {
        let e = Engine::new(8);
        for n in [1usize, 2, 3, 5, 8, 11] {
            let hs = e.fair_handles(n);
            assert_eq!(hs.len(), n);
            let total: usize = hs.iter().map(|h| h.width()).sum();
            assert!(total >= 8, "widths {total} must cover the pool");
            // balanced: widths differ by at most one (before the ≥1 floor)
            let wmax = hs.iter().map(|h| h.width()).max().unwrap();
            let wmin = hs.iter().map(|h| h.width()).min().unwrap();
            assert!(wmax - wmin <= 1, "unbalanced split {wmin}..{wmax}");
        }
        // more shares than workers: every handle still gets one worker
        let hs = Engine::new(2).fair_handles(5);
        assert!(hs.iter().all(|h| h.width() == 1));
    }

    #[test]
    fn tally_counts_scope_exactly() {
        let (g, conv, prop, hw) = setup();
        let ctx = EvalContext::new(&g, conv, &prop, &hw);
        let e = Engine::new(2);
        let space = crate::autotune::LoopSpace::new(&[1, 112, 112, 64], &[3, 7, 7]);
        let mut rng = crate::util::Rng::new(3);
        let scheds: Vec<LoopSchedule> =
            (0..6).map(|_| space.decode(&space.random_point(&mut rng))).collect();
        // untallied warm-up traffic the tally must not see
        e.lower_batch(&ctx, &scheds[..2]);
        let tally = EngineTally::new();
        let before = e.stats();
        let h = e.handle().with_tally(&tally);
        let entries = h.lower_batch(&ctx, &scheds);
        h.measure_entries(&ctx, &entries);
        // scope counters == global delta when nothing else runs
        assert_eq!(tally.stats(), e.stats().since(&before));
        assert_eq!(tally.stats().hits, 2, "warm-up entries hit");
        assert_eq!(tally.stats().misses, 4);
        assert_eq!(tally.stats().simulated, 6);
        // narrowing keeps the tally attached
        let n = h.narrowed(1);
        assert_eq!(n.width(), 1);
        n.eval(&ctx, &scheds[0]);
        assert_eq!(tally.stats().hits, 3);
    }

    #[test]
    fn different_layouts_do_not_collide() {
        let (g, conv, prop, hw) = setup();
        // a second propagation with a non-identity decision must key
        // differently even for the same schedule
        let mut dec2 = crate::autotune::template::identity_decision(conv);
        dec2.out_seq.push(crate::layout::Primitive::split(3, &[4, 16]));
        let prop2 = propagate(&g, std::slice::from_ref(&dec2), PropMode::Alt);
        let ctx1 = EvalContext::new(&g, conv, &prop, &hw);
        let ctx2 = EvalContext::new(&g, conv, &prop2, &hw);
        assert_ne!(ctx1.key_base, ctx2.key_base);
    }
}
