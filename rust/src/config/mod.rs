//! Declarative configuration for tuning jobs.
//!
//! A tiny `key = value` format (INI-style, no external deps) drives the
//! launcher: budgets, stage split, hardware profile, template levels,
//! propagation mode, workload. CLI flags override file values.
//!
//! Malformed input is a typed [`ErrorKind::Config`] refusal, never a
//! panic. The lenient `get_*` accessors keep their historical
//! missing-or-malformed → default behavior for ad-hoc keys, but
//! [`Config::tune_options`] is *strict*: a key that is present but
//! does not parse is an error — a typo'd budget must not silently tune
//! with the default.

use std::collections::BTreeMap;
use std::fmt;

use crate::api::ServeOptions;
use crate::autotune::TuneOptions;
use crate::error::{Error, ErrorKind, Result};
use crate::propagate::PropMode;
use crate::rewrite::RewriteMode;

fn cfg_err(msg: impl fmt::Display) -> Error {
    Error::with_kind(ErrorKind::Config, msg)
}

/// Parsed configuration (flat key/value map with typed accessors).
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || line.starts_with('[') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                cfg_err(format!("line {}: expected key = value", ln + 1))
            })?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Self { map })
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| cfg_err(format!("read {path}: {e}")))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Strict typed accessor: a missing key yields `default`, a
    /// present-but-malformed value is a [`ErrorKind::Config`] error
    /// naming the key and value.
    fn strict<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| {
                cfg_err(format!("config key '{key}': bad value '{v}': {e}"))
            }),
        }
    }

    /// Strict boolean: same spellings as [`Config::get_bool`], but an
    /// unrecognized present value is an error instead of the default.
    fn strict_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("yes") | Some("on") | Some("1") => Ok(true),
            Some("false") | Some("no") | Some("off") | Some("0") => Ok(false),
            Some(v) => Err(cfg_err(format!(
                "config key '{key}': bad bool '{v}' \
                 (want true/false, yes/no, on/off, 1/0)"
            ))),
        }
    }

    /// Boolean accessor: accepts `true/false`, `yes/no`, `on/off`,
    /// `1/0`; anything else (or a missing key) yields the default.
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("yes") | Some("on") | Some("1") => true,
            Some("false") | Some("no") | Some("off") | Some("0") => false,
            _ => default,
        }
    }

    /// Runtime backend the launcher executes on (`alt run`): `native`
    /// (the default — zero dependencies) or `pjrt` (feature-gated).
    pub fn backend(&self) -> &str {
        self.get("backend").unwrap_or("native")
    }

    /// Directory tuned plans are saved to (`alt tune --save`) and
    /// loaded from (`alt run --load`). `None` (the default) keeps the
    /// historical behavior: nothing is persisted.
    pub fn save_dir(&self) -> Option<&str> {
        self.get("save_dir")
    }

    /// Build tuner options from this config (keys: `budget`,
    /// `joint_frac`, `batch`, `top_k`, `rounds_per_layout`, `levels`,
    /// `seed`, `mode`, `threads`, `speculation`, `memo_cap`, `shards`,
    /// `budget_realloc`, `rewrite`). Strict: present-but-malformed
    /// values are typed [`ErrorKind::Config`] errors, missing keys keep
    /// their defaults.
    pub fn tune_options(&self) -> Result<TuneOptions> {
        let d = TuneOptions::default();
        let mode_str = self.get("mode").unwrap_or("alt");
        let mode = PropMode::from_name(mode_str)
            .ok_or_else(|| cfg_err(format!("unknown mode '{mode_str}'")))?;
        // `off` (the default) is bit-for-bit the rewrite-free tuner
        let rw_str = self.get("rewrite").unwrap_or("off");
        let rewrite = RewriteMode::from_name(rw_str).ok_or_else(|| {
            cfg_err(format!(
                "config key 'rewrite': bad value '{rw_str}' (want off/on/joint)"
            ))
        })?;
        Ok(TuneOptions {
            budget: self.strict("budget", d.budget)?,
            joint_frac: self.strict("joint_frac", d.joint_frac)?,
            batch: self.strict("batch", d.batch)?,
            top_k: self.strict("top_k", d.top_k)?,
            rounds_per_layout: self
                .strict("rounds_per_layout", d.rounds_per_layout)?,
            levels: self.strict("levels", d.levels)?.clamp(1, 2),
            seed: self.strict("seed", d.seed)?,
            mode,
            threads: self.strict("threads", d.threads)?,
            // 0 is accepted as "no speculation" (same as 1)
            speculation: self.strict("speculation", d.speculation)?.max(1),
            memo_cap: self.strict("memo_cap", d.memo_cap)?,
            // 1 = sequential legacy path (default), 0 = auto-shard,
            // N>1 = pack independence groups into N shards
            shards: self.strict("shards", d.shards)?,
            budget_realloc: self
                .strict_bool("budget_realloc", d.budget_realloc)?,
            rewrite,
        })
    }

    /// Build serving options from this config (keys: `workers`,
    /// `max_batch`, `batch_window_us`, `queue_cap`, `pipeline_width`).
    /// Strict like [`Config::tune_options`]: present-but-malformed
    /// values are typed [`ErrorKind::Config`] errors, missing keys keep
    /// the [`ServeOptions`] defaults (so an empty config serves exactly
    /// like `ServeOptions::default()`).
    pub fn serve_options(&self) -> Result<ServeOptions> {
        let d = ServeOptions::default();
        Ok(ServeOptions {
            // 0 = one worker per core
            workers: self.strict("workers", d.workers)?,
            max_batch: self.strict("max_batch", d.max_batch)?.max(1),
            batch_window_us: self
                .strict("batch_window_us", d.batch_window_us)?,
            queue_cap: self.strict("queue_cap", d.queue_cap)?.max(1),
            // <= 1 disables intra-request pipelining
            pipeline_width: self.strict("pipeline_width", d.pipeline_width)?,
        })
    }
}

impl fmt::Display for Config {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (k, v) in &self.map {
            writeln!(f, "{k} = {v}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let c = Config::parse(
            "# a comment\n[tuning]\nbudget = 500\nmode = alt-wp\njoint_frac = 0.4\n",
        )
        .unwrap();
        assert_eq!(c.get_usize("budget", 1), 500);
        let o = c.tune_options().unwrap();
        assert_eq!(o.budget, 500);
        assert!((o.joint_frac - 0.4).abs() < 1e-12);
        assert_eq!(o.mode, PropMode::WithoutFusionProp);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("not a kv line").is_err());
        let c = Config::parse("mode = bogus").unwrap();
        assert!(c.tune_options().is_err());
    }

    #[test]
    fn config_errors_are_typed() {
        let err = Config::parse("not a kv line").unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Config);
        let err = Config::from_file("/no/such/config/file.ini").unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Config);
    }

    #[test]
    fn tune_options_rejects_present_but_malformed_values() {
        // one malformed spelling per value class: integer, float,
        // unsigned seed, and bool — each present key must be a typed
        // refusal naming the key, never a silent default
        for bad in [
            "budget = lots",
            "joint_frac = half",
            "seed = -3",
            "threads = 1.5",
            "budget_realloc = maybe",
        ] {
            let c = Config::parse(bad).unwrap();
            let err = c.tune_options().unwrap_err();
            assert_eq!(err.kind(), ErrorKind::Config, "{bad}: {err}");
            let key = bad.split('=').next().unwrap().trim();
            assert!(err.to_string().contains(key), "{bad}: {err}");
        }
        // ...while missing keys still default
        let o = Config::parse("").unwrap().tune_options().unwrap();
        assert_eq!(o.budget, TuneOptions::default().budget);
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        let o = c.tune_options().unwrap();
        assert_eq!(o.mode, PropMode::Alt);
        assert_eq!(o.budget, TuneOptions::default().budget);
    }

    #[test]
    fn threads_key_parses() {
        let c = Config::parse("threads = 3").unwrap();
        assert_eq!(c.tune_options().unwrap().threads, 3);
        let d = Config::parse("").unwrap();
        assert_eq!(d.tune_options().unwrap().threads, 0); // auto
    }

    #[test]
    fn speculation_and_memo_cap_keys_parse() {
        let c = Config::parse("speculation = 4\nmemo_cap = 512").unwrap();
        let o = c.tune_options().unwrap();
        assert_eq!(o.speculation, 4);
        assert_eq!(o.memo_cap, 512);
        let d = Config::parse("").unwrap().tune_options().unwrap();
        assert_eq!(d.speculation, 1); // serial walk by default
        assert_eq!(d.memo_cap, 0); // engine default cap
        // 0 means "no speculation", normalized to 1
        let z = Config::parse("speculation = 0").unwrap().tune_options().unwrap();
        assert_eq!(z.speculation, 1);
    }

    #[test]
    fn shards_and_realloc_keys_parse() {
        let c = Config::parse("shards = 4\nbudget_realloc = false").unwrap();
        let o = c.tune_options().unwrap();
        assert_eq!(o.shards, 4);
        assert!(!o.budget_realloc);
        // defaults preserve the historical behavior: sequential graph
        // tuning, adaptive reallocation armed for when sharding is on
        let d = Config::parse("").unwrap().tune_options().unwrap();
        assert_eq!(d.shards, 1);
        assert!(d.budget_realloc);
        // 0 = auto-shard (one shard per independence group)
        let z = Config::parse("shards = 0").unwrap().tune_options().unwrap();
        assert_eq!(z.shards, 0);
        // bool spellings
        for (s, v) in
            [("on", true), ("1", true), ("no", false), ("0", false)]
        {
            let c = Config::parse(&format!("budget_realloc = {s}")).unwrap();
            assert_eq!(c.tune_options().unwrap().budget_realloc, v, "{s}");
        }
    }

    #[test]
    fn display_round_trips_new_keys() {
        let mut c = Config::default();
        c.set("shards", "3");
        c.set("budget_realloc", "false");
        c.set("budget", "640");
        let reparsed = Config::parse(&format!("{c}")).unwrap();
        let o = reparsed.tune_options().unwrap();
        assert_eq!(o.shards, 3);
        assert!(!o.budget_realloc);
        assert_eq!(o.budget, 640);
    }

    #[test]
    fn rewrite_key_parses_defaults_and_round_trips() {
        for (s, v) in [
            ("off", RewriteMode::Off),
            ("on", RewriteMode::On),
            ("joint", RewriteMode::Joint),
        ] {
            let mut c = Config::default();
            c.set("rewrite", s);
            assert_eq!(c.tune_options().unwrap().rewrite, v, "{s}");
            // Display round-trip: re-parsing the rendered config keeps
            // the mode byte-exact
            let reparsed = Config::parse(&format!("{c}")).unwrap();
            assert_eq!(reparsed.tune_options().unwrap().rewrite, v, "{s}");
        }
        // missing key = off (today's behavior); a present-but-unknown
        // spelling is a typed refusal naming the key
        let d = Config::parse("").unwrap().tune_options().unwrap();
        assert_eq!(d.rewrite, RewriteMode::Off);
        let err =
            Config::parse("rewrite = always").unwrap().tune_options().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Config);
        assert!(err.to_string().contains("rewrite"), "{err}");
    }

    #[test]
    fn backend_and_save_dir_keys_parse() {
        let c = Config::parse("backend = pjrt\nsave_dir = plans/r18\n").unwrap();
        assert_eq!(c.backend(), "pjrt");
        assert_eq!(c.save_dir(), Some("plans/r18"));
        // defaults preserve current behavior: native backend, no
        // persistence — and they must not disturb tune_options
        let d = Config::parse("").unwrap();
        assert_eq!(d.backend(), "native");
        assert_eq!(d.save_dir(), None);
        assert!(d.tune_options().is_ok());
    }

    #[test]
    fn display_round_trips_backend_and_save_dir() {
        let mut c = Config::default();
        c.set("backend", "native");
        c.set("save_dir", "out/plan");
        c.set("budget", "64");
        let reparsed = Config::parse(&format!("{c}")).unwrap();
        assert_eq!(reparsed.backend(), "native");
        assert_eq!(reparsed.save_dir(), Some("out/plan"));
        assert_eq!(reparsed.tune_options().unwrap().budget, 64);
    }

    #[test]
    fn serve_keys_parse_and_default() {
        let c = Config::parse(
            "workers = 4\nmax_batch = 16\nbatch_window_us = 250\n\
             queue_cap = 32\npipeline_width = 2\n",
        )
        .unwrap();
        let o = c.serve_options().unwrap();
        assert_eq!(o.workers, 4);
        assert_eq!(o.max_batch, 16);
        assert_eq!(o.batch_window_us, 250);
        assert_eq!(o.queue_cap, 32);
        assert_eq!(o.pipeline_width, 2);
        // an empty config serves exactly like ServeOptions::default()
        let d = Config::parse("").unwrap().serve_options().unwrap();
        assert_eq!(d, ServeOptions::default());
        // degenerate sizes are clamped to a working server, not errors
        let z = Config::parse("max_batch = 0\nqueue_cap = 0")
            .unwrap()
            .serve_options()
            .unwrap();
        assert_eq!(z.max_batch, 1);
        assert_eq!(z.queue_cap, 1);
    }

    #[test]
    fn serve_options_reject_present_but_malformed_values() {
        for bad in [
            "workers = many",
            "max_batch = -2",
            "batch_window_us = 0.5",
            "queue_cap = big",
            "pipeline_width = wide",
        ] {
            let c = Config::parse(bad).unwrap();
            let err = c.serve_options().unwrap_err();
            assert_eq!(err.kind(), ErrorKind::Config, "{bad}: {err}");
            let key = bad.split('=').next().unwrap().trim();
            assert!(err.to_string().contains(key), "{bad}: {err}");
        }
    }

    #[test]
    fn display_round_trips_serve_keys() {
        let mut c = Config::default();
        c.set("workers", "2");
        c.set("max_batch", "4");
        c.set("batch_window_us", "50");
        c.set("queue_cap", "8");
        c.set("pipeline_width", "3");
        let reparsed = Config::parse(&format!("{c}")).unwrap();
        let o = reparsed.serve_options().unwrap();
        assert_eq!(o.workers, 2);
        assert_eq!(o.max_batch, 4);
        assert_eq!(o.batch_window_us, 50);
        assert_eq!(o.queue_cap, 8);
        assert_eq!(o.pipeline_width, 3);
        // serving keys must not disturb tuning keys sharing the file
        assert!(reparsed.tune_options().is_ok());
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse("budget = 10").unwrap();
        c.set("budget", "99");
        assert_eq!(c.get_usize("budget", 0), 99);
    }
}
