//! `figures` — regenerate every paper table and figure in one run.
//!
//! Usage: `figures [full] [name]` where name is one of fig1, fig9,
//! fig10, fig11, fig12, table2, table3, motivating, observations
//! (default: all). `full` uses the larger budgets from DESIGN.md;
//! the default quick scale finishes in minutes on one core.

use alt::bench::figures as f;

fn print_all(ts: Vec<alt::bench::harness::Table>) {
    for t in ts {
        t.print();
        println!();
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "full");
    let scale = if full { f::Scale::full() } else { f::Scale::quick() };
    let which = args
        .iter()
        .find(|a| *a != "full" && *a != "quick")
        .map(|s| s.as_str())
        .unwrap_or("all");

    let t0 = std::time::Instant::now();
    match which {
        "table2" => f::table2().print(),
        "motivating" => f::motivating(&scale).print(),
        "fig1" => print_all(f::fig1(&scale)),
        "fig9" => print_all(f::fig9(&scale)),
        "fig10" => print_all(f::fig10(&scale, !full)),
        "fig11" => f::fig11(&scale).print(),
        "fig12" => f::fig12(&scale).print(),
        "table3" => f::table3(&scale).print(),
        "observations" => f::observations(&scale).print(),
        "ablations" => print_all(f::ablations(&scale)),
        _ => {
            f::table2().print();
            println!();
            f::motivating(&scale).print();
            println!();
            print_all(f::fig1(&scale));
            print_all(f::fig9(&scale));
            print_all(f::fig10(&scale, !full));
            f::fig11(&scale).print();
            println!();
            f::fig12(&scale).print();
            println!();
            f::table3(&scale).print();
            println!();
            f::observations(&scale).print();
        }
    }
    eprintln!("[figures {which}] done in {:.1}s", t0.elapsed().as_secs_f64());
}
