//! Minimal crate error type — a dependency-free `anyhow` stand-in
//! with a coarse fault taxonomy for the serving layer.
//!
//! The crate must build in offline environments with no registry
//! access, so instead of pulling `anyhow` we carry a single
//! message-holding error plus an [`ErrorKind`] tag. Construction goes
//! through [`Error::msg`] or the [`crate::bail`] / [`crate::err`]
//! macros; interop `From` impls cover the std error types the crate
//! actually meets. The kind survives [`Error::context`] wrapping, so
//! callers can still route on it after layers of annotation — the
//! property the fault-injection suite leans on to distinguish "typed
//! refusal" from "crash".

use std::fmt;

/// Coarse classification of a crate error — what *layer* failed, so
/// serving callers can route without string-matching messages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// Unclassified (the historical default).
    Other,
    /// Malformed configuration input (`config::Config` parse paths).
    Config,
    /// Durable-plan load/save integrity failure (see [`PlanError`]).
    Plan(PlanError),
    /// Caller-supplied inputs rejected by validation (count, length,
    /// non-finite values).
    Input,
    /// Compilation of a nest or model failed structurally.
    Compile,
    /// A worker thread panicked; the panic was caught and isolated to
    /// this request.
    Panic,
    /// The serving queue is full (or the server is shutting down) and
    /// the request was shed instead of enqueued — retry later.
    Overload,
}

/// What exactly went wrong with a durable plan on disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// Filesystem-level failure reading or writing the plan directory.
    Io,
    /// The manifest or plan text failed to parse.
    Malformed,
    /// The manifest's format-version line is missing or names a
    /// version this build does not speak.
    VersionSkew,
    /// An artifact's recorded checksum does not match its bytes —
    /// truncation, torn write, or bit rot.
    ChecksumMismatch,
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            PlanError::Io => "io",
            PlanError::Malformed => "malformed",
            PlanError::VersionSkew => "version skew",
            PlanError::ChecksumMismatch => "checksum mismatch",
        };
        f.write_str(s)
    }
}

/// Crate-wide error: an explanatory message (optionally chained) plus
/// a routing [`ErrorKind`].
#[derive(Debug)]
pub struct Error {
    msg: String,
    kind: ErrorKind,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string(), kind: ErrorKind::Other }
    }

    /// Build an error with an explicit kind.
    pub fn with_kind(kind: ErrorKind, m: impl fmt::Display) -> Self {
        Self { msg: m.to_string(), kind }
    }

    /// The error's classification (survives [`Error::context`]).
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Retag an error (e.g. a generic io error discovered inside the
    /// plan loader becomes `Plan(Io)`).
    pub fn into_kind(mut self, kind: ErrorKind) -> Self {
        self.kind = kind;
        self
    }

    /// Wrap with leading context, mirroring `anyhow::Context`. The
    /// kind of the inner error is preserved.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg), kind: self.kind }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Self { msg: s, kind: ErrorKind::Other }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Self { msg: s.to_string(), kind: ErrorKind::Other }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Self::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Self::msg(e)
    }
}

/// Convert a caught panic payload (from `std::panic::catch_unwind`)
/// into a typed [`ErrorKind::Panic`] error. Payloads are `&str` or
/// `String` for every `panic!`/`assert!`/`unwrap` in practice;
/// anything else gets a generic label.
pub fn panic_error(payload: Box<dyn std::any::Any + Send>, site: &str) -> Error {
    let what = if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    };
    Error::with_kind(ErrorKind::Panic, format!("worker panic in {site}: {what}"))
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// `anyhow!`-style constructor: `err!("bad spec '{s}'")`.
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::error::Error::msg(format!($($t)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_roundtrip_and_context() {
        let e = Error::msg("boom").context("loading x");
        assert_eq!(e.to_string(), "loading x: boom");
    }

    fn fails() -> Result<()> {
        bail!("code {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "code 7");
    }

    #[test]
    fn from_std_errors() {
        let r: Result<i32> = "x".parse::<i32>().map_err(Error::from);
        assert!(r.is_err());
    }

    #[test]
    fn kind_survives_context_and_retag() {
        let e = Error::with_kind(ErrorKind::Plan(PlanError::ChecksumMismatch), "bad sum")
            .context("loading plans/x");
        assert_eq!(e.kind(), ErrorKind::Plan(PlanError::ChecksumMismatch));
        assert_eq!(e.to_string(), "loading plans/x: bad sum");
        let retagged = Error::msg("eof").into_kind(ErrorKind::Plan(PlanError::Io));
        assert_eq!(retagged.kind(), ErrorKind::Plan(PlanError::Io));
        assert_eq!(Error::msg("plain").kind(), ErrorKind::Other);
    }

    #[test]
    fn panic_payloads_become_typed_errors() {
        let p = std::panic::catch_unwind(|| panic!("blown fuse")).unwrap_err();
        let e = panic_error(p, "nest worker");
        assert_eq!(e.kind(), ErrorKind::Panic);
        assert!(e.to_string().contains("blown fuse"));
        assert!(e.to_string().contains("nest worker"));
    }
}
