//! Minimal crate error type — a dependency-free `anyhow` stand-in.
//!
//! The crate must build in offline environments with no registry
//! access, so instead of pulling `anyhow` we carry a single
//! message-holding error. Construction goes through [`Error::msg`] or
//! the [`crate::bail`] / [`crate::err`] macros; interop `From` impls
//! cover the std error types the crate actually meets.

use std::fmt;

/// Crate-wide error: an explanatory message (optionally chained).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }

    /// Wrap with leading context, mirroring `anyhow::Context`.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Self { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Self { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Self::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Self::msg(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// `anyhow!`-style constructor: `err!("bad spec '{s}'")`.
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::error::Error::msg(format!($($t)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_roundtrip_and_context() {
        let e = Error::msg("boom").context("loading x");
        assert_eq!(e.to_string(), "loading x: boom");
    }

    fn fails() -> Result<()> {
        bail!("code {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "code 7");
    }

    #[test]
    fn from_std_errors() {
        let r: Result<i32> = "x".parse::<i32>().map_err(Error::from);
        assert!(r.is_err());
    }
}
