//! Tiny statistics helpers used by benches and the tuner's reporting.

/// Online mean/min/max accumulator.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

/// `Default` must agree with [`Summary::new`]: a derived default would
/// start min/max at `0.0` and silently report `min = 0` for any
/// all-positive sample pushed into a defaulted accumulator.
impl Default for Summary {
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.sum / self.n as f64 }
    }
}

/// Pearson correlation — used to sanity-check the cost model's ranking
/// power against simulator ground truth.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Spearman rank correlation (ranking quality metric for the cost model).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

/// Fractional ranks with ties averaged (the standard Spearman
/// treatment: quantized latencies tie often, and assigning ties
/// arbitrary consecutive ranks biases the correlation). NaN-safe via
/// `total_cmp` (never panics; NaN placement follows the total order).
fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for &k in &idx[i..=j] {
            r[k] = avg;
        }
        i = j + 1;
    }
    r
}

/// True median of a sample (NaN-safe ordering: never panics). Even
/// sample sizes average the two middle values; empty input is NaN.
pub fn median(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.sort_by(|a, b| a.total_cmp(b));
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        (xs[n / 2 - 1] + xs[n / 2]) / 2.0
    }
}

/// Total order with every NaN ranked *last* regardless of sign bit.
/// `f64::total_cmp` alone puts sign-negative NaNs (what x86 invalid
/// ops actually produce) before `-inf` — fatal for "sort scores
/// ascending, measure the best" loops, where a garbage prediction
/// would win the ranking. This is the comparator every score/latency
/// sort in the tuner uses.
pub fn nan_last_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    a.is_nan().cmp(&b.is_nan()).then_with(|| a.total_cmp(&b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_tracks_extremes() {
        let mut s = Summary::new();
        for x in [3.0, 1.0, 2.0] {
            s.push(x);
        }
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 8.0, 27.0, 64.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_default_matches_new() {
        // regression: the derived Default used to start min/max at 0.0
        let mut s = Summary::default();
        assert!(s.min.is_infinite() && s.min > 0.0);
        assert!(s.max.is_infinite() && s.max < 0.0);
        s.push(5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.n, 1);
    }

    #[test]
    fn ranks_average_ties() {
        // [10, 20, 20, 30] -> ranks [0, 1.5, 1.5, 3]
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![0.0, 1.5, 1.5, 3.0]);
        // perfect anti-monotone with a tie must be exactly -1
        let s = spearman(&[1.0, 2.0, 2.0, 3.0], &[3.0, 2.0, 2.0, 1.0]);
        assert!((s + 1.0).abs() < 1e-12, "spearman with ties: {s}");
    }

    #[test]
    fn ranks_are_nan_safe() {
        // must not panic; NaNs sort last
        let r = ranks(&[f64::NAN, 1.0, 2.0]);
        assert_eq!(r[1], 0.0);
        assert_eq!(r[2], 1.0);
        assert_eq!(r[0], 2.0);
    }

    #[test]
    fn median_even_and_odd() {
        let mut odd = [3.0, 1.0, 2.0];
        assert_eq!(median(&mut odd), 2.0);
        let mut even = [4.0, 1.0, 3.0, 2.0];
        // the old `times[n/2]` bug would report 3.0 here
        assert_eq!(median(&mut even), 2.5);
        let mut empty: [f64; 0] = [];
        assert!(median(&mut empty).is_nan());
        // f64::NAN is a positive NaN -> sorts last under total_cmp
        let mut with_nan = [1.0, f64::NAN, 3.0];
        assert_eq!(median(&mut with_nan), 3.0);
    }

    #[test]
    fn nan_last_cmp_ranks_every_nan_last() {
        use std::cmp::Ordering;
        let neg_nan = -f64::NAN; // sign-negative NaN (x86 invalid-op default)
        assert!(neg_nan.is_nan() && neg_nan.is_sign_negative());
        // total_cmp alone would put neg_nan FIRST; nan_last_cmp must not
        assert_eq!(nan_last_cmp(neg_nan, f64::NEG_INFINITY), Ordering::Greater);
        assert_eq!(nan_last_cmp(f64::NAN, 1e300), Ordering::Greater);
        assert_eq!(nan_last_cmp(1.0, 2.0), Ordering::Less);
        assert_eq!(nan_last_cmp(2.0, 2.0), Ordering::Equal);
        // sorting scores with a NaN keeps real candidates in front
        let mut xs = [3.0, neg_nan, 1.0, f64::NAN, 2.0];
        xs.sort_by(|a, b| nan_last_cmp(*a, *b));
        assert_eq!(&xs[..3], &[1.0, 2.0, 3.0]);
        assert!(xs[3].is_nan() && xs[4].is_nan());
    }
}
