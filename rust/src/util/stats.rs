//! Tiny statistics helpers used by benches and the tuner's reporting.

/// Online mean/min/max accumulator.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.sum / self.n as f64 }
    }
}

/// Pearson correlation — used to sanity-check the cost model's ranking
/// power against simulator ground truth.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Spearman rank correlation (ranking quality metric for the cost model).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; xs.len()];
    for (rank, &i) in idx.iter().enumerate() {
        r[i] = rank as f64;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_tracks_extremes() {
        let mut s = Summary::new();
        for x in [3.0, 1.0, 2.0] {
            s.push(x);
        }
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 8.0, 27.0, 64.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }
}
