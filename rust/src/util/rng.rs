//! xoshiro256** — small, fast, seedable PRNG. Implemented locally so the
//! entire tuner (PPO init, space sampling, simulated annealing …) is
//! deterministic for a given seed with no dependency churn.

/// Deterministic PRNG (xoshiro256**).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 expansion (any u64 is a valid seed).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform choice from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range_and_spread() {
        let mut r = Rng::new(7);
        let mut lo = false;
        let mut hi = false;
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            lo |= x < 0.25;
            hi |= x > 0.75;
        }
        assert!(lo && hi, "uniform draws never reached the tails");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[r.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
