//! Small shared utilities: deterministic RNG, divisor enumeration,
//! statistics helpers. No external crates — the tuner must be
//! reproducible bit-for-bit from a seed.

pub mod rng;
pub mod stats;

pub use rng::Rng;

/// FNV-1a 64-bit `Hasher`. Std's `RandomState` is seeded per process;
/// memoization keys (the engine's candidate cache, layout hashes) need
/// a hasher that is reproducible run to run, so cache behaviour — and
/// therefore reported hit rates — is deterministic.
#[derive(Clone, Debug)]
pub struct StableHasher(u64);

impl StableHasher {
    pub fn new() -> Self {
        Self(0xcbf29ce484222325)
    }
}

impl Default for StableHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl std::hash::Hasher for StableHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
}

/// Stable 64-bit hash of any `Hash` value (see [`StableHasher`]).
pub fn stable_hash<T: std::hash::Hash + ?Sized>(v: &T) -> u64 {
    let mut h = StableHasher::new();
    v.hash(&mut h);
    std::hash::Hasher::finish(&h)
}

/// Raw FNV-1a 64 over a byte slice — the durable-plan artifact
/// checksum. Unlike [`stable_hash`] this feeds the bytes straight to
/// the FNV state with no `Hash`-impl framing (no length prefix), so
/// the value is the textbook FNV-1a digest of the file contents and
/// stays comparable across compiler/std versions.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// All divisors of `n`, ascending. Tuning spaces for split factors are
/// divisor sets (the paper rounds `R(D * a)` to a feasible factor).
pub fn divisors(n: i64) -> Vec<i64> {
    assert!(n >= 1, "divisors of non-positive {n}");
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Round `x` to the nearest divisor of `n` (the paper's `R(·)` with the
/// feasibility projection). Ties round down.
pub fn round_to_divisor(n: i64, x: f64) -> i64 {
    let divs = divisors(n);
    let mut best = divs[0];
    let mut best_d = f64::INFINITY;
    for &d in &divs {
        let dist = (d as f64 - x).abs();
        if dist < best_d {
            best_d = dist;
            best = d;
        }
    }
    best
}

/// Ceil division for positive integers.
pub fn ceil_div(a: i64, b: i64) -> i64 {
    assert!(b > 0);
    (a + b - 1) / b
}

/// Geometric mean of positive values (used by all speedup reports).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-30).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(7), vec![1, 7]);
        assert_eq!(divisors(64), vec![1, 2, 4, 8, 16, 32, 64]);
    }

    #[test]
    fn round_to_divisor_picks_nearest() {
        assert_eq!(round_to_divisor(32, 0.5 * 32.0), 16);
        assert_eq!(round_to_divisor(12, 5.0), 4); // 4 and 6 tie -> down
        assert_eq!(round_to_divisor(12, 5.1), 6);
        assert_eq!(round_to_divisor(7, 3.0), 1); // only 1 and 7
        assert_eq!(round_to_divisor(7, 6.0), 7);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 5), 1);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn stable_hash_is_stable_and_discriminating() {
        // fixed expectations would over-specify; determinism within and
        // across calls is the contract
        assert_eq!(stable_hash(&(1u64, "abc")), stable_hash(&(1u64, "abc")));
        assert_ne!(stable_hash(&(1u64, "abc")), stable_hash(&(2u64, "abc")));
        assert_ne!(stable_hash(&vec![1i64, 2]), stable_hash(&vec![2i64, 1]));
    }
}
