//! Deterministic fault injection for the robustness suite.
//!
//! Compiled only under `--features fault-inject`; in default builds
//! every hook site compiles to nothing. The registry is a process-wide
//! table of *armed* sites: production code calls [`fire`] (or
//! [`maybe_panic`]) at a named site, and the call reports whether the
//! test harness asked for a fault there. Arming is explicit and
//! counted — `arm` fires on every hit, `arm_nth` fires exactly once on
//! the n-th hit (0-based), which is how a test targets "the third nest
//! compiled" or "the second worker chunk" deterministically.
//!
//! Sites are *semantic*, not positional: each names one failure class
//! the serving stack must contain (see the site docs). The suite in
//! `rust/tests/faults.rs` drives every site under a seeded schedule
//! and checks the single invariant that matters: a typed `Err` or an
//! output bit-identical to the bytecode oracle — never a panic
//! escaping the API, never a silently wrong answer, and the shared
//! model stays re-runnable afterwards.
//!
//! Tests sharing the process must serialize around the registry (it is
//! global state); the suite holds one `Mutex` for that.

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Injection points wired into the stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// An index table trips the 2^22 alloc cap during fast-plan
    /// compilation → the nest degrades to bytecode.
    AllocCap,
    /// Stream analysis of an access expression fails → the nest
    /// degrades to bytecode.
    StreamAnalysis,
    /// A parallel nest worker panics mid-request → typed
    /// `ErrorKind::Panic`, model stays re-runnable.
    WorkerPanic,
    /// A packed weight is corrupted to NaN at compile time → typed
    /// compile error (the finiteness audit catches it).
    NanWeight,
    /// `save_plan` tears the plan file (truncated write) → the
    /// manifest checksum rejects the plan at load with a typed
    /// `PlanError::ChecksumMismatch`.
    TornPlanWrite,
    /// An engine evaluation job panics → typed error from `try_run`,
    /// engine stays usable.
    EngineJob,
    /// A serving worker drops a request it popped from the queue →
    /// only that request gets a typed `ErrorKind::Overload` error;
    /// the server keeps draining the rest.
    QueueDrop,
}

/// Every site, for exhaustive suite sweeps.
pub const ALL_SITES: [FaultSite; 7] = [
    FaultSite::AllocCap,
    FaultSite::StreamAnalysis,
    FaultSite::WorkerPanic,
    FaultSite::NanWeight,
    FaultSite::TornPlanWrite,
    FaultSite::EngineJob,
    FaultSite::QueueDrop,
];

#[derive(Default)]
struct SiteState {
    /// Times this site was reached since arming.
    hits: u64,
    /// Times the site actually injected.
    fired: u64,
    /// Fire on every hit.
    always: bool,
    /// Fire once, on this 0-based hit index.
    fire_on: Option<u64>,
}

static REGISTRY: OnceLock<Mutex<HashMap<FaultSite, SiteState>>> = OnceLock::new();

fn reg() -> MutexGuard<'static, HashMap<FaultSite, SiteState>> {
    REGISTRY
        .get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// Arm `site` to inject on every hit until [`disarm_all`].
pub fn arm(site: FaultSite) {
    let mut r = reg();
    let s = r.entry(site).or_default();
    *s = SiteState { always: true, ..SiteState::default() };
}

/// Arm `site` to inject exactly once, on its `n`-th hit (0-based).
pub fn arm_nth(site: FaultSite, n: u64) {
    let mut r = reg();
    let s = r.entry(site).or_default();
    *s = SiteState { fire_on: Some(n), ..SiteState::default() };
}

/// Disarm every site and reset all counters.
pub fn disarm_all() {
    reg().clear();
}

/// Hook call: records a hit at `site` and reports whether to inject.
pub fn fire(site: FaultSite) -> bool {
    let mut r = reg();
    let Some(s) = r.get_mut(&site) else { return false };
    let hit = s.hits;
    s.hits += 1;
    let go = s.always || s.fire_on == Some(hit);
    if go {
        s.fired += 1;
    }
    go
}

/// Hook call for panic sites: panics (with a recognizable payload) if
/// the site fires.
pub fn maybe_panic(site: FaultSite) {
    if fire(site) {
        panic!("injected fault at {site:?}");
    }
}

/// Times `site` was reached since arming (0 if never armed).
pub fn hits(site: FaultSite) -> u64 {
    reg().get(&site).map(|s| s.hits).unwrap_or(0)
}

/// Times `site` actually injected since arming.
pub fn fired(site: FaultSite) -> u64 {
    reg().get(&site).map(|s| s.fired).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Registry state is process-global; this in-crate test is the only
    // unit test touching it (the integration suite serializes itself).
    #[test]
    fn arm_nth_fires_exactly_once_on_target_hit() {
        disarm_all();
        assert!(!fire(FaultSite::AllocCap), "unarmed site must not fire");
        arm_nth(FaultSite::AllocCap, 2);
        assert!(!fire(FaultSite::AllocCap));
        assert!(!fire(FaultSite::AllocCap));
        assert!(fire(FaultSite::AllocCap), "third hit is index 2");
        assert!(!fire(FaultSite::AllocCap), "nth arming fires once");
        assert_eq!(hits(FaultSite::AllocCap), 4);
        assert_eq!(fired(FaultSite::AllocCap), 1);
        arm(FaultSite::AllocCap);
        assert!(fire(FaultSite::AllocCap) && fire(FaultSite::AllocCap));
        disarm_all();
        assert!(!fire(FaultSite::AllocCap));
    }
}
