//! # ALT — joint graph-level layout & operator-level loop optimization
//!
//! Reproduction of *“ALT: Breaking the Wall between Graph and Operator
//! Level Optimizations for Deep Learning Compilation”* (Xu et al., 2022)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate is the Layer-3 compiler/auto-tuner — the paper's system
//! contribution lives here:
//!
//! * [`expr`] — index-expression IR (affine + floor-div/mod) used by the
//!   layout rewrite rules of Table 1 and Eq. (1).
//! * [`analysis`] — static access analysis over that IR: an interval ×
//!   congruence (range + stride) abstract domain that proves write-map
//!   injectivity, stream bounds and worker race-freedom symbolically at
//!   compile time (enumeration survives as fallback and differential
//!   oracle), plus the plan linter behind `CompiledModel::diagnostics()`
//!   and the `alt check` CLI verb.
//! * [`tensor`] — tensor descriptors and concrete layouts.
//! * [`layout`] — the six layout primitives (`split`, `reorder`, `fuse`,
//!   `unfold`, `pad`, `store_at`) plus inverses; shape and
//!   access-expression rewriting; data repacking for golden tests.
//! * [`graph`] — computational-graph IR and builders for the paper's
//!   workloads (ResNet-18, MobileNet-V2, BERT, ResNet3D-18, micro graphs).
//! * [`propagate`] — the layout-propagation pass (§4.2, §6) with its
//!   three constraints and conversion-operator insertion.
//! * [`rewrite`] — the graph-rewrite subsystem between graph
//!   construction and tuning: constant folding, pad-into-conv and
//!   BatchNorm-into-Conv folding, and pattern-based epilogue fusion
//!   (softmax/layernorm tails, the IPEX production patterns). Rewrite
//!   choices that interact with layout are discrete decisions the
//!   joint stage samples alongside layout proposals.
//! * [`loops`] — loop-nest IR + TVM-style loop primitives.
//! * [`codegen`] — program generation: graph + layout assignment + loop
//!   schedule → tensor program (loop nests with rewritten accesses).
//! * [`sim`] — the simulated device (cache hierarchy with hardware
//!   prefetch, SIMD, parallelism): the substitution for the paper's
//!   Intel/NVIDIA/ARM testbeds (see DESIGN.md §Hardware-Adaptation).
//! * [`cost`] — gradient-boosted-tree cost model trained online.
//! * [`autotune`] — PPO agents (with batched rollout/update paths),
//!   layout/loop tuning templates, and the two-stage cross-exploration
//!   joint tuner (Fig. 8); the joint stage can speculatively evaluate
//!   K layout proposals per PPO step (`TuneOptions::speculation`) with
//!   a deterministic seed-split and ordered reduction. Graph-level
//!   tuning goes through the sharded orchestrator
//!   (`autotune::orchestrator`): §4.2 shard analysis
//!   (`graph::shard`), fair-share engine handles, adaptive budget
//!   reallocation, and the `tune_graphs` multi-workload front end.
//! * [`engine`] — the parallel candidate-evaluation engine: a scoped
//!   worker pool that batches the `lower → featurize → predict →
//!   simulate` pipeline across cores, with cross-round memoization of
//!   duplicate candidates (size-capped, clock-evicted) and width-capped
//!   handles for nested per-proposal sub-batches.
//! * [`baselines`] — Ansor-like, AutoTVM-like, FlexTensor-like and
//!   vendor-library-like comparators.
//! * [`runtime`] — pluggable execution backends (real-host validation
//!   leg): a zero-dependency native interpreter that executes generated
//!   tensor programs on host `f32` buffers (always on, cross-checks
//!   simulator rankings in tier-1), plus the PJRT executor for the AOT
//!   HLO artifacts produced by the Python build layer (`pjrt` feature).
//! * [`bench`] — the figure/table harnesses shared by `cargo bench`,
//!   the `figures` binary and the examples.
//! * [`api`] — the unified staged pipeline over all of the above:
//!   `Session::new(graph).tune()` → `TunedGraph::compile()` →
//!   `CompiledModel::run(inputs)` executes a whole model natively
//!   (weights packed once at compile time, inter-op buffers reused,
//!   repacks only where producer/consumer layouts disagree), and
//!   `CompiledModel::save(dir)` / `Session::load(dir)` make tuning
//!   durable across processes.

// The serving-critical modules (everything a request touches at run
// time) ban `unwrap`/`expect` outside tests: a malformed input or a
// poisoned lock must become a typed `error::Error`, never a process
// abort. Tuner-internal modules keep the default lint set — their
// invariant panics are caught at the engine/runtime isolation
// boundaries instead.
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod analysis;
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod api;
pub mod autotune;
pub mod baselines;
pub mod bench;
pub mod codegen;
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod config;
pub mod cost;
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod engine;
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod error;
pub mod expr;
#[cfg(feature = "fault-inject")]
pub mod faults;
pub mod graph;
pub mod layout;
pub mod loops;
pub mod propagate;
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod rewrite;
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod runtime;
pub mod sim;
pub mod tensor;
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod util;

pub use api::Session;
pub use error::Error;

/// Crate-wide result alias.
pub type Result<T> = error::Result<T>;
