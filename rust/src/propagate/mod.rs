//! Layout propagation (paper §4.2 + §6).
//!
//! Layout decisions are made per *complex* operator (convolutions, GMM).
//! This pass distributes those decisions across the graph while
//! eliminating the two overheads of layout transformation:
//!
//! * **layout-conversion overhead** — instead of inserting a conversion
//!   operator, let the producer yield elements in the new layout
//!   directly (Fig. 5b). Possible when the producer is an element-wise
//!   op (incl. padding); otherwise a [`Conversion`] is recorded, which
//!   the graph simulator charges as a data-movement op (Fig. 5a).
//! * **fusion-conflict overhead** — replicate the output primitive
//!   sequence onto the element-wise consumers so their loop nests
//!   reconstruct identically and fusion-after-tiling still applies
//!   (Figs. 6–7).
//!
//! The three §4.2 constraints are enforced:
//! 1. propagation only walks element-wise ops between same-shape tensors;
//! 2. sequences containing non-trivial advanced primitives (`unfold`,
//!    `pad`, `store_at`) are never propagated — conversions are inserted
//!    when they arise;
//! 3. each complex operator is tuned independently; between two adjacent
//!    complex ops a conversion is inserted (or absorbed by an
//!    intervening simple op) rather than sharing one layout.

use std::collections::HashMap;

use crate::codegen::LayoutAssignment;
use crate::graph::{Graph, NodeId};
use crate::layout::LayoutSeq;
use crate::tensor::{Role, TensorId};

/// Propagation mode — the paper's ablation variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PropMode {
    /// Full ALT: propagation + fusion alignment + independent tuning.
    Alt,
    /// ALT-WP (§7.2): conversions are still absorbed, but the output
    /// sequence is NOT replicated onto consumers — fusion is lost
    /// whenever the output layout is non-default.
    WithoutFusionProp,
    /// ALT-OL: loop tuning only; every layout stays default.
    LoopOnly,
    /// ALT-FP (§7.3.1): force-propagate the first complex op's output
    /// layout forward into the next complex op's input.
    ForwardShare,
    /// ALT-BP: force the downstream op's preferred input layout back
    /// onto the producing complex op's output.
    BackwardShare,
}

impl PropMode {
    /// Canonical short name — the spelling config files and saved
    /// plans write.
    pub fn name(self) -> &'static str {
        match self {
            PropMode::Alt => "alt",
            PropMode::WithoutFusionProp => "wp",
            PropMode::LoopOnly => "ol",
            PropMode::ForwardShare => "fp",
            PropMode::BackwardShare => "bp",
        }
    }

    /// Parse any accepted spelling (the single name↔mode table the
    /// config parser and the plan parser both use).
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "alt" => Some(PropMode::Alt),
            "alt-wp" | "wp" => Some(PropMode::WithoutFusionProp),
            "alt-ol" | "ol" | "loop-only" => Some(PropMode::LoopOnly),
            "alt-fp" | "fp" => Some(PropMode::ForwardShare),
            "alt-bp" | "bp" => Some(PropMode::BackwardShare),
            _ => None,
        }
    }
}

/// Layout decision for one complex operator (instantiated template).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ComplexDecision {
    pub node: NodeId,
    /// Output tensor sequence (basic primitives only).
    pub out_seq: LayoutSeq,
    /// Input (activation) tensor sequence — may contain `unfold`.
    pub in_seq: LayoutSeq,
    /// Weight tensor sequence — transformed offline for free.
    pub w_seq: LayoutSeq,
}

/// A required runtime layout conversion (Fig. 5a) and whether an
/// element-wise producer absorbed it (Fig. 5b).
#[derive(Clone, Debug)]
pub struct Conversion {
    pub tensor: TensorId,
    pub to: LayoutSeq,
    /// Node that performs the conversion for free as part of its own
    /// write (element-wise producer); `None` = standalone conversion op.
    pub absorbed_by: Option<NodeId>,
}

/// Result of the pass: per-tensor layout sequences, per-complex-node
/// fused element-wise tails, and the conversion list.
#[derive(Clone, Debug, Default)]
pub struct PropagationResult {
    pub layouts: LayoutAssignment,
    pub fused_tails: HashMap<NodeId, Vec<NodeId>>,
    pub conversions: Vec<Conversion>,
    /// Element-wise nodes covered by some fusion group (skipped by the
    /// graph simulator).
    pub fused_nodes: Vec<NodeId>,
}

/// Walk the single-consumer element-wise chain downstream of `tensor`.
pub fn eltwise_chain(graph: &Graph, tensor: TensorId) -> Vec<NodeId> {
    let mut chain = Vec::new();
    let mut t = tensor;
    loop {
        let consumers = graph.consumers(t);
        if consumers.len() != 1 {
            break;
        }
        let c = consumers[0];
        let node = graph.node(c);
        // constraint 1: element-wise, same shape (bias broadcast allowed)
        let same_shape = graph.tensor(node.output).shape == graph.tensor(t).shape;
        let is_fusable = matches!(
            node.kind,
            crate::graph::OpKind::Eltwise { .. } | crate::graph::OpKind::BiasAdd
        );
        if !is_fusable || !same_shape {
            break;
        }
        chain.push(c);
        t = node.output;
    }
    chain
}

/// Apply the pass. `decisions` must cover each complex node at most
/// once; complex nodes without a decision keep default layouts.
pub fn propagate(
    graph: &Graph,
    decisions: &[ComplexDecision],
    mode: PropMode,
) -> PropagationResult {
    let mut res = PropagationResult {
        layouts: LayoutAssignment::identity(graph),
        ..Default::default()
    };

    // Fig. 11 forced-sharing variants rewrite the decision list first.
    let decisions = match mode {
        PropMode::ForwardShare | PropMode::BackwardShare => {
            shared_decisions(graph, decisions, mode)
        }
        _ => decisions.to_vec(),
    };
    let by_node: HashMap<NodeId, ComplexDecision> =
        decisions.iter().map(|d| (d.node, d.clone())).collect();

    for node in &graph.nodes {
        if !node.is_complex() {
            continue;
        }
        let default = ComplexDecision { node: node.id, ..Default::default() };
        let dec = by_node.get(&node.id).unwrap_or(&default);
        let effective = if mode == PropMode::LoopOnly { &default } else { dec };

        // ---- weight: offline transform, always free ----
        if node.inputs.len() > 1 && !effective.w_seq.is_identity() {
            res.layouts.set(node.inputs[1], effective.w_seq.clone());
        }

        // ---- input activation ----
        let x = node.inputs[0];
        // If upstream propagation already produced exactly this layout,
        // no conversion is needed at all.
        if !effective.in_seq.is_identity()
            && res.layouts.get(x) != effective.in_seq
        {
            let xt = graph.tensor(x);
            if xt.role == Role::Weight {
                res.layouts.set(x, effective.in_seq.clone());
            } else {
                let producer = xt.producer.map(|p| graph.node(p));
                let absorbable = producer
                    .map(|p| p.is_elementwise() && !res.fused_nodes.contains(&p.id))
                    .unwrap_or(false);
                if absorbable {
                    // Fig. 5b: the element-wise producer yields the new
                    // layout directly — the tensor's allocation layout
                    // becomes the consumer's preference.
                    res.layouts.set(x, effective.in_seq.clone());
                } else {
                    // Fig. 5a: a conversion op repacks; the producer
                    // keeps its own layout, only this consumer observes
                    // the converted one.
                    res.layouts
                        .set_read_override(node.id, x, effective.in_seq.clone());
                }
                res.conversions.push(Conversion {
                    tensor: x,
                    to: effective.in_seq.clone(),
                    // constraint 2: advanced primitives are never
                    // propagated across *complex* producers; an
                    // element-wise producer (e.g. the padding op) may
                    // still absorb the conversion (Fig. 5b).
                    absorbed_by: if absorbable {
                        producer.map(|p| p.id)
                    } else {
                        None
                    },
                });
            }
        }

        // ---- output + downstream fusion alignment ----
        res.layouts.set(node.output, effective.out_seq.clone());
        let chain = eltwise_chain(graph, node.output);
        let fuse_ok = match mode {
            // ALT-WP: the tail keeps its default layout, so a
            // reconstructed (non-identity) conv nest cannot align with
            // the tail's nest — fusion is lost (Fig. 6).
            PropMode::WithoutFusionProp => effective.out_seq.is_identity(),
            _ => true,
        };
        // constraint 2: out_seq is basic-only by template construction
        if fuse_ok && !chain.is_empty() && !effective.out_seq.has_advanced() {
            for &c in &chain {
                res.layouts.set(graph.node(c).output, effective.out_seq.clone());
            }
            res.fused_tails.insert(node.id, chain.clone());
            res.fused_nodes.extend(chain);
        }
    }
    res
}

/// Rewrites for the Fig. 11 forced-sharing ablations.
fn shared_decisions(
    graph: &Graph,
    decisions: &[ComplexDecision],
    mode: PropMode,
) -> Vec<ComplexDecision> {
    let mut out = decisions.to_vec();
    let complex = graph.complex_nodes();
    for pair in complex.windows(2) {
        let (a, b) = (pair[0], pair[1]);
        let ia = out.iter().position(|d| d.node == a);
        let ib = out.iter().position(|d| d.node == b);
        if let (Some(ia), Some(ib)) = (ia, ib) {
            match mode {
                PropMode::ForwardShare => {
                    // downstream op consumes the upstream layout as-is
                    // (when applicable to its input's logical shape)
                    let seq = out[ia].out_seq.clone();
                    let in_shape =
                        &graph.tensor(graph.node(b).inputs[0]).shape;
                    out[ib].in_seq = if seq.is_valid_for(in_shape) {
                        seq
                    } else {
                        LayoutSeq::new()
                    };
                }
                PropMode::BackwardShare => {
                    // upstream op must emit the downstream's preference;
                    // basic-only constraint: drop advanced primitives.
                    // The remaining primitives may reference dims the
                    // dropped ones would have created — validate against
                    // the producer's output shape and fall back to the
                    // identity layout when the rewrite is inapplicable.
                    let mut seq = out[ib].in_seq.clone();
                    seq.prims.retain(|p| {
                        !matches!(
                            p,
                            crate::layout::Primitive::Unfold { .. }
                                | crate::layout::Primitive::Pad { .. }
                                | crate::layout::Primitive::StoreAt { .. }
                        )
                    });
                    let out_shape =
                        &graph.tensor(graph.node(a).output).shape;
                    out[ia].out_seq = if seq.is_valid_for(out_shape) {
                        seq
                    } else {
                        LayoutSeq::new()
                    };
                }
                _ => unreachable!(),
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::layout::Primitive;

    fn tiled_seq() -> LayoutSeq {
        let mut s = LayoutSeq::new();
        s.push(Primitive::split(3, &[4, 16]))
            .push(Primitive::reorder(&[0, 1, 2, 3, 4]));
        s
    }

    #[test]
    fn mode_names_round_trip() {
        for m in [
            PropMode::Alt,
            PropMode::WithoutFusionProp,
            PropMode::LoopOnly,
            PropMode::ForwardShare,
            PropMode::BackwardShare,
        ] {
            assert_eq!(PropMode::from_name(m.name()), Some(m));
            // the config parser's long spellings resolve too
            assert_eq!(
                PropMode::from_name(&format!("alt-{}", m.name())),
                if m == PropMode::Alt { None } else { Some(m) }
            );
        }
        assert!(PropMode::from_name("bogus").is_none());
    }

    #[test]
    fn fusion_tail_detected_and_aligned() {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let dec = ComplexDecision {
            node: conv,
            out_seq: tiled_seq(),
            ..Default::default()
        };
        let res = propagate(&g, &[dec], PropMode::Alt);
        let tail = &res.fused_tails[&conv];
        assert_eq!(tail.len(), 2, "bias + relu fused");
        for &t in tail {
            assert_eq!(res.layouts.get(g.node(t).output), tiled_seq());
        }
    }

    #[test]
    fn wp_mode_loses_fusion_for_nondefault_layout() {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let dec = ComplexDecision {
            node: conv,
            out_seq: tiled_seq(),
            ..Default::default()
        };
        let res = propagate(&g, &[dec], PropMode::WithoutFusionProp);
        assert!(res.fused_tails.get(&conv).is_none());
        // but with a default layout fusion survives
        let dec2 = ComplexDecision { node: conv, ..Default::default() };
        let res2 = propagate(&g, &[dec2], PropMode::WithoutFusionProp);
        assert!(res2.fused_tails.get(&conv).is_some());
    }

    #[test]
    fn loop_only_ignores_decisions() {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let dec = ComplexDecision {
            node: conv,
            out_seq: tiled_seq(),
            ..Default::default()
        };
        let res = propagate(&g, &[dec], PropMode::LoopOnly);
        assert!(res.layouts.is_identity(g.node(conv).output));
        assert!(res.conversions.is_empty());
    }

    #[test]
    fn pad_producer_absorbs_input_conversion() {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let mut in_seq = LayoutSeq::new();
        in_seq.push(Primitive::unfold(1, 13, 8));
        let dec = ComplexDecision { node: conv, in_seq, ..Default::default() };
        let res = propagate(&g, &[dec], PropMode::Alt);
        assert_eq!(res.conversions.len(), 1);
        let conv_in = g.node(conv).inputs[0];
        assert_eq!(res.conversions[0].tensor, conv_in);
        // the producer of the conv input is the padding op -> absorbed
        let pad = g.tensor(conv_in).producer.unwrap();
        assert_eq!(res.conversions[0].absorbed_by, Some(pad));
    }

    #[test]
    fn complex_to_complex_needs_real_conversion() {
        // prop_subgraph: pad -> c3x3 -> c1x1 (no eltwise between convs)
        let g = models::prop_subgraph(7);
        let convs = g.complex_nodes();
        let mut in_seq = LayoutSeq::new();
        in_seq.push(Primitive::split(3, &[32, 16]));
        let decs = vec![
            ComplexDecision {
                node: convs[0],
                out_seq: tiled_seq(),
                ..Default::default()
            },
            ComplexDecision { node: convs[1], in_seq, ..Default::default() },
        ];
        let res = propagate(&g, &decs, PropMode::Alt);
        let conv2_in = g.node(convs[1]).inputs[0];
        let conv = res
            .conversions
            .iter()
            .find(|c| c.tensor == conv2_in)
            .expect("conversion for complex-complex edge");
        assert!(conv.absorbed_by.is_none());
    }

    #[test]
    fn forward_share_copies_out_to_downstream_in() {
        let g = models::prop_subgraph(7);
        let convs = g.complex_nodes();
        let decs = vec![
            ComplexDecision {
                node: convs[0],
                out_seq: tiled_seq(),
                ..Default::default()
            },
            ComplexDecision { node: convs[1], ..Default::default() },
        ];
        let res = propagate(&g, &decs, PropMode::ForwardShare);
        let conv2_in = g.node(convs[1]).inputs[0];
        assert_eq!(res.layouts.get(conv2_in), tiled_seq());
    }

    #[test]
    fn weights_transform_free() {
        let g = models::case_study();
        let conv = g.complex_nodes()[0];
        let mut w_seq = LayoutSeq::new();
        w_seq.push(Primitive::split(3, &[4, 16]));
        let dec =
            ComplexDecision { node: conv, w_seq: w_seq.clone(), ..Default::default() };
        let res = propagate(&g, &[dec], PropMode::Alt);
        assert_eq!(res.layouts.get(g.node(conv).inputs[1]), w_seq);
        assert!(res.conversions.is_empty(), "weights never convert at runtime");
    }
}
