//! Static access analysis: abstract interpretation over the
//! index-expression IR ([`crate::expr`]).
//!
//! The fast serving path (PR 6) proves the safety properties it relies
//! on — write-map injectivity, stream bounds — by **exhaustive
//! enumeration** capped at 2^22 points; nests above the cap silently
//! degrade to staged-scatter writes. This module replaces brute force
//! with a symbolic proof over an **interval × congruence** domain:
//!
//! * [`Range`] — each expression abstracts to `{lo, lo+stride, …, hi}`,
//!   a classic interval refined with a stride (congruence) component.
//!   [`range_of`] computes sound transfer functions for the whole IR
//!   operator set (affine arithmetic plus floor-div, mod and min).
//! * [`analyze_write`] — proves a write map injective and in-bounds
//!   over its spatial iteration box by decomposing it into independent
//!   *components* (affine terms plus div/mod/min groups over disjoint
//!   variables) and checking a **gap/span separation** condition:
//!   sorted by minimum gap, each component's gap must exceed the total
//!   span of all smaller-gap components. Two distinct points differ in
//!   some component; taking the differing component with the largest
//!   gap, the address difference is at least that gap minus the spans
//!   of everything below it — strictly positive, so addresses never
//!   collide. Mixed-radix (row-major) writes — the shape codegen
//!   produces for every nest output — always satisfy the condition.
//! * Verdicts are three-valued ([`Verdict`]): `Disproven` is only
//!   returned with a counterexample-by-construction (a duplicate in an
//!   enumerated component, an uncovered variable, an attainable
//!   out-of-bounds address), which is what lets the differential suite
//!   test the analyzer in *both* directions against enumeration.
//! * [`lint_nest`] — the expression-level half of the plan linter:
//!   zero-trip loops and dead `min` pad clamps, diagnosed from the
//!   same ranges. `CompiledModel::diagnostics()` adds the model-level
//!   lints (never-firing gather slots, non-stride-1 innermost reads,
//!   analyzer-dischargeable degradations) and `alt check` surfaces
//!   both on saved plans.
//!
//! Everything here is compile-time only and pure: no allocation is
//! shared with the runtime, and all arithmetic is checked (i64 inputs,
//! i128 intermediates) — an overflow yields `Unknown`/`top`, never a
//! wrong certificate.

use std::collections::BTreeSet;
use std::fmt;

use crate::codegen::Program;
use crate::expr::Expr;
use crate::graph::NodeId;

/// Per-component enumeration cap for the symbolic prover. Components
/// are tiny in practice (one or two split/pad variables); the cap only
/// guards against adversarial inputs. Distinct from the whole-box
/// `INJECTIVITY_CAP` in the runtime: components multiply, so a nest
/// far above 2^22 total points stays provable as long as each coupled
/// variable group is small.
pub const COMPONENT_CAP: i64 = 1 << 20;

// ---------------------------------------------------------------------------
// Interval × congruence domain
// ---------------------------------------------------------------------------

/// Abstract value: the set of concrete values is a subset of
/// `{lo, lo + stride, lo + 2*stride, …, hi}`.
///
/// Invariants: `lo <= hi`; `stride == 0` iff `lo == hi` (a point);
/// otherwise `stride >= 1` and `(hi - lo) % stride == 0`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Range {
    pub lo: i64,
    pub hi: i64,
    /// Congruence step between representable values (0 for a point).
    pub stride: i64,
}

impl Range {
    /// Single concrete value.
    pub fn point(c: i64) -> Self {
        Range { lo: c, hi: c, stride: 0 }
    }

    /// The whole of `i64` — the "don't know" element.
    pub fn top() -> Self {
        Range { lo: i64::MIN, hi: i64::MAX, stride: 1 }
    }

    pub fn is_top(&self) -> bool {
        self.lo == i64::MIN && self.hi == i64::MAX
    }

    /// Normalizing constructor over i128 intermediates: snaps `hi`
    /// down onto the congruence lattice and widens to `top` on i64
    /// overflow, so transfer functions can't manufacture precision.
    fn mk(lo: i128, hi: i128, stride: i128) -> Range {
        debug_assert!(lo <= hi, "inverted range {lo}..{hi}");
        let (lo, hi) = if lo <= hi { (lo, hi) } else { (hi, lo) };
        if lo == hi {
            return match i64::try_from(lo) {
                Ok(c) => Range::point(c),
                Err(_) => Range::top(),
            };
        }
        let s = if stride <= 0 { 1 } else { stride };
        let hi = lo + ((hi - lo) / s) * s;
        match (i64::try_from(lo), i64::try_from(hi), i64::try_from(s)) {
            (Ok(lo), Ok(hi), Ok(s)) if lo != hi => Range { lo, hi, stride: s },
            (Ok(c), Ok(h), _) if c == h => Range::point(c),
            _ => Range::top(),
        }
    }

    /// Is `v` a member of the abstract set?
    pub fn contains(&self, v: i64) -> bool {
        v >= self.lo
            && v <= self.hi
            && (self.stride == 0
                || (i128::from(v) - i128::from(self.lo)) % i128::from(self.stride) == 0)
    }

    /// Is every representable value inside `[lo, hi_excl)`?
    pub fn within(&self, lo: i64, hi_excl: i64) -> bool {
        self.lo >= lo && self.hi < hi_excl
    }
}

impl fmt::Display for Range {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_top() {
            write!(f, "⊤")
        } else if self.stride == 0 {
            write!(f, "{{{}}}", self.lo)
        } else {
            write!(f, "[{}..{}]/{}", self.lo, self.hi, self.stride)
        }
    }
}

fn gcd(a: i128, b: i128) -> i128 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Abstract range of `e` with `extents[v]` giving each loop variable's
/// iteration extent (`v` ranges over `0..extents[v]`). A missing or
/// non-positive extent means the variable is unconstrained (`top`).
pub fn range_of(e: &Expr, extents: &[i64]) -> Range {
    match e {
        Expr::Var(v) => match extents.get(*v) {
            Some(&ext) if ext >= 1 => Range::mk(0, i128::from(ext) - 1, 1),
            _ => Range::top(),
        },
        Expr::Const(c) => Range::point(*c),
        Expr::Add(a, b) => {
            let (x, y) = (range_of(a, extents), range_of(b, extents));
            Range::mk(
                i128::from(x.lo) + i128::from(y.lo),
                i128::from(x.hi) + i128::from(y.hi),
                gcd(x.stride.into(), y.stride.into()),
            )
        }
        Expr::Sub(a, b) => {
            let (x, y) = (range_of(a, extents), range_of(b, extents));
            Range::mk(
                i128::from(x.lo) - i128::from(y.hi),
                i128::from(x.hi) - i128::from(y.lo),
                gcd(x.stride.into(), y.stride.into()),
            )
        }
        Expr::Mul(a, b) => mul_range(range_of(a, extents), range_of(b, extents)),
        Expr::Div(a, b) => div_range(range_of(a, extents), range_of(b, extents)),
        Expr::Mod(a, b) => mod_range(range_of(a, extents), range_of(b, extents)),
        Expr::Min(a, b) => {
            let (x, y) = (range_of(a, extents), range_of(b, extents));
            // either branch's values stay congruent modulo
            // gcd(s_x, s_y, |lo_x - lo_y|): both anchors coincide there.
            let g = gcd(
                gcd(x.stride.into(), y.stride.into()),
                i128::from(x.lo) - i128::from(y.lo),
            );
            Range::mk(
                i128::from(x.lo.min(y.lo)),
                i128::from(x.hi.min(y.hi)),
                g,
            )
        }
    }
}

/// Scale a range by a constant (swapping endpoints when negative).
fn scale_range(r: Range, k: i64) -> Range {
    if k == 0 {
        return Range::point(0);
    }
    let k = i128::from(k);
    let (a, b) = (i128::from(r.lo) * k, i128::from(r.hi) * k);
    Range::mk(a.min(b), a.max(b), i128::from(r.stride) * k.abs())
}

fn mul_range(x: Range, y: Range) -> Range {
    if x.stride == 0 {
        return scale_range(y, x.lo);
    }
    if y.stride == 0 {
        return scale_range(x, y.lo);
    }
    // var*var: interval from the four corner products; every product
    // (lo_x + a·s_x)(lo_y + b·s_y) is congruent to lo_x·lo_y modulo
    // gcd(lo_x·s_y, lo_y·s_x, s_x·s_y) — including the corners, so the
    // min corner is a sound anchor.
    let (xl, xh) = (i128::from(x.lo), i128::from(x.hi));
    let (yl, yh) = (i128::from(y.lo), i128::from(y.hi));
    let corners = [xl * yl, xl * yh, xh * yl, xh * yh];
    let (mut mn, mut mx) = (corners[0], corners[0]);
    for &c in &corners[1..] {
        mn = mn.min(c);
        mx = mx.max(c);
    }
    let g = gcd(
        gcd(xl * i128::from(y.stride), yl * i128::from(x.stride)),
        i128::from(x.stride) * i128::from(y.stride),
    );
    Range::mk(mn, mx, g)
}

fn div_range(x: Range, d: Range) -> Range {
    if d.lo <= 0 && d.hi >= 0 {
        // divisor set may contain 0 — undefined, give up
        return Range::top();
    }
    let (xl, xh) = (i128::from(x.lo), i128::from(x.hi));
    if d.stride == 0 {
        let k = i128::from(d.lo);
        if k > 0 && x.stride > 0 && i128::from(x.stride) % k == 0 {
            // exact progression: (lo + j·s) ÷ k steps by s/k
            return Range::mk(
                xl.div_euclid(k),
                xh.div_euclid(k),
                i128::from(x.stride) / k,
            );
        }
        let (a, b) = (xl.div_euclid(k), xh.div_euclid(k));
        return Range::mk(a.min(b), a.max(b), 1);
    }
    // sign-definite divisor interval: div_euclid is monotone in each
    // argument over such a box, so the extrema sit on the corners
    let (dl, dh) = (i128::from(d.lo), i128::from(d.hi));
    let corners = [
        xl.div_euclid(dl),
        xl.div_euclid(dh),
        xh.div_euclid(dl),
        xh.div_euclid(dh),
    ];
    let (mut mn, mut mx) = (corners[0], corners[0]);
    for &c in &corners[1..] {
        mn = mn.min(c);
        mx = mx.max(c);
    }
    Range::mk(mn, mx, 1)
}

fn mod_range(x: Range, d: Range) -> Range {
    if d.lo <= 0 && d.hi >= 0 {
        return Range::top();
    }
    let (xl, xh) = (i128::from(x.lo), i128::from(x.hi));
    if d.stride == 0 {
        // rem_euclid depends only on |divisor|
        let m = i128::from(d.lo).abs();
        if x.stride == 0 {
            return Range::mk(xl.rem_euclid(m), xl.rem_euclid(m), 0);
        }
        if xl.div_euclid(m) == xh.div_euclid(m) {
            // whole range inside one block: mod is a pure shift
            return Range::mk(
                xl.rem_euclid(m),
                xh.rem_euclid(m),
                x.stride.into(),
            );
        }
        // wraps: values stay congruent to lo modulo gcd(stride, m)
        let g = gcd(x.stride.into(), m);
        return Range::mk(xl.rem_euclid(g), m - 1, g);
    }
    let m = i128::from(d.lo).abs().max(i128::from(d.hi).abs());
    Range::mk(0, m - 1, 1)
}

// ---------------------------------------------------------------------------
// Write-map certificates
// ---------------------------------------------------------------------------

/// Three-valued proof outcome. `Disproven` always carries a genuine
/// counterexample by construction — never "couldn't prove".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Proven,
    Disproven,
    Unknown,
}

/// How a nest's write map was (or wasn't) certified at compile time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProofKind {
    /// Decided by the symbolic analyzer (either direction).
    Symbolic,
    /// Decided by exhaustive enumeration under the 2^22 cap.
    Enumerated,
    /// Neither method resolved it — the nest degrades to staged writes.
    Unproven,
}

impl ProofKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ProofKind::Symbolic => "symbolic",
            ProofKind::Enumerated => "enumerated",
            ProofKind::Unproven => "unproven",
        }
    }
}

impl fmt::Display for ProofKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Result of [`analyze_write`]: separate injectivity and bounds
/// verdicts plus the exact address extremes when the decomposition was
/// exhaustive (`None` when only interval information was available).
#[derive(Clone, Copy, Debug)]
pub struct WriteAnalysis {
    pub injective: Verdict,
    pub in_bounds: Verdict,
    pub min_addr: Option<i64>,
    pub max_addr: Option<i64>,
}

impl WriteAnalysis {
    /// Combined verdict matching the runtime's direct-write criterion
    /// (enumeration accepts iff every address is fresh *and* in range).
    pub fn verdict(&self) -> Verdict {
        match (self.injective, self.in_bounds) {
            (Verdict::Disproven, _) | (_, Verdict::Disproven) => Verdict::Disproven,
            (Verdict::Proven, Verdict::Proven) => Verdict::Proven,
            _ => Verdict::Unknown,
        }
    }
}

/// Affine skeleton of an access expression:
/// `c0 + Σ coeff[v]·v + Σ k_i·term_i(vars)`.
struct Decomp {
    c0: i64,
    coeff: Vec<i64>,
    terms: Vec<(i64, Expr)>,
}

/// Evaluate a variable-free expression, or `None` if it mentions a
/// variable, divides by zero, or overflows i64.
fn const_value(e: &Expr) -> Option<i64> {
    match e {
        Expr::Const(c) => Some(*c),
        Expr::Var(_) => None,
        Expr::Add(a, b) => const_value(a)?.checked_add(const_value(b)?),
        Expr::Sub(a, b) => const_value(a)?.checked_sub(const_value(b)?),
        Expr::Mul(a, b) => const_value(a)?.checked_mul(const_value(b)?),
        Expr::Div(a, b) => const_value(a)?.checked_div_euclid(const_value(b)?),
        Expr::Mod(a, b) => const_value(a)?.checked_rem_euclid(const_value(b)?),
        Expr::Min(a, b) => Some(const_value(a)?.min(const_value(b)?)),
    }
}

/// Distribute `k * e` into `d` exactly. Only constructions whose value
/// the skeleton represents exactly are accepted; overflow fails.
fn decompose(e: &Expr, k: i64, d: &mut Decomp) -> Option<()> {
    match e {
        Expr::Const(c) => d.c0 = d.c0.checked_add(k.checked_mul(*c)?)?,
        Expr::Var(v) => d.coeff[*v] = d.coeff[*v].checked_add(k)?,
        Expr::Add(a, b) => {
            decompose(a, k, d)?;
            decompose(b, k, d)?;
        }
        Expr::Sub(a, b) => {
            decompose(a, k, d)?;
            decompose(b, k.checked_neg()?, d)?;
        }
        Expr::Mul(a, b) => {
            if let Some(c) = const_value(a) {
                decompose(b, k.checked_mul(c)?, d)?;
            } else if let Some(c) = const_value(b) {
                decompose(a, k.checked_mul(c)?, d)?;
            } else if k != 0 {
                d.terms.push((k, e.clone()));
            }
        }
        Expr::Div(_, _) | Expr::Mod(_, _) | Expr::Min(_, _) => {
            if let Some(c) = const_value(e) {
                d.c0 = d.c0.checked_add(k.checked_mul(c)?)?;
            } else if k != 0 {
                d.terms.push((k, e.clone()));
            }
        }
    }
    Some(())
}

/// Per-component image statistics (i128 so affine spans can't wrap):
/// `gap` is the minimum distance between two distinct image values
/// (`i128::MAX` for a single-value image), `span = max - min`, and
/// `min`/`max` are attained by some assignment of the component's vars.
struct CompStats {
    gap: i128,
    span: i128,
    min: i128,
    max: i128,
}

#[derive(Default)]
struct Comp {
    vars: Vec<(usize, i64)>,
    terms: Vec<usize>,
}

fn find(parent: &mut [usize], mut v: usize) -> usize {
    while parent[v] != v {
        parent[v] = parent[parent[v]];
        v = parent[v];
    }
    v
}

/// Enumerate one coupled component's image over its (small) box.
/// Returns `(duplicate_found, stats)`, or `None` past the cap or on
/// overflow. `env` must be zeroed outside the component's vars and is
/// restored to zero on return.
fn enum_comp(comp: &Comp, d: &Decomp, env: &mut [i64]) -> Option<(bool, CompStats)> {
    let mut size: i128 = 1;
    for &(_, e) in &comp.vars {
        size = size.checked_mul(i128::from(e))?;
        if size > i128::from(COMPONENT_CAP) {
            return None;
        }
    }
    let n = usize::try_from(size).ok()?;
    for &(v, _) in &comp.vars {
        env[v] = 0;
    }
    let mut vals: Vec<i64> = Vec::with_capacity(n);
    for _ in 0..n {
        let mut acc: i64 = 0;
        for &(v, _) in &comp.vars {
            acc = acc.checked_add(d.coeff[v].checked_mul(env[v])?)?;
        }
        for &ti in &comp.terms {
            let (k, t) = &d.terms[ti];
            acc = acc.checked_add(k.checked_mul(t.eval(env))?)?;
        }
        vals.push(acc);
        for &(v, e) in comp.vars.iter().rev() {
            env[v] += 1;
            if env[v] < e {
                break;
            }
            env[v] = 0;
        }
    }
    for &(v, _) in &comp.vars {
        env[v] = 0;
    }
    vals.sort_unstable();
    let mut dup = false;
    let mut gap = i128::MAX;
    for w in vals.windows(2) {
        let diff = i128::from(w[1]) - i128::from(w[0]);
        if diff == 0 {
            dup = true;
        } else {
            gap = gap.min(diff);
        }
    }
    let (mn, mx) = (i128::from(vals[0]), i128::from(vals[vals.len() - 1]));
    Some((dup, CompStats { gap, span: mx - mn, min: mn, max: mx }))
}

/// Interval-only fallback when the exact decomposition is unavailable:
/// containment of the over-approximating range still *proves* bounds,
/// but nothing can be disproven and injectivity stays unknown.
fn interval_only(write: &Expr, extents: &[i64], out_len: i64) -> WriteAnalysis {
    let r = range_of(write, extents);
    let in_bounds = if r.within(0, out_len) {
        Verdict::Proven
    } else {
        Verdict::Unknown
    };
    WriteAnalysis {
        injective: Verdict::Unknown,
        in_bounds,
        min_addr: None,
        max_addr: None,
    }
}

/// Prove (or refute) that `write` is injective and in `[0, out_len)`
/// over the iteration box `spatial` (`(var, extent)` pairs, extents as
/// the runtime's write-proof enumeration iterates them: each var in
/// `0..extent`, all other variables held at 0).
///
/// Contract, relied on by the differential suite and the runtime:
/// `Proven` implies exhaustive enumeration of the box would accept the
/// write (all addresses fresh and in range); `Disproven` implies it
/// would reject; `Unknown` implies nothing.
pub fn analyze_write(write: &Expr, spatial: &[(usize, i64)], out_len: i64) -> WriteAnalysis {
    if spatial.iter().any(|&(_, e)| e <= 0) {
        // empty iteration box: vacuously injective and in-bounds
        return WriteAnalysis {
            injective: Verdict::Proven,
            in_bounds: Verdict::Proven,
            min_addr: None,
            max_addr: None,
        };
    }
    let wvars = write.vars();
    let nvars = spatial
        .iter()
        .map(|&(v, _)| v + 1)
        .chain(wvars.iter().map(|&v| v + 1))
        .max()
        .unwrap_or(0);
    let mut extents = vec![0i64; nvars];
    let mut is_spatial = vec![false; nvars];
    for &(v, e) in spatial {
        extents[v] = e;
        is_spatial[v] = true;
    }
    if wvars.iter().any(|&v| !is_spatial[v]) {
        // mentions a variable outside the box — not a write map we
        // understand; interval reasoning only (unknown vars are top)
        return interval_only(write, &extents, out_len);
    }

    let mut d = Decomp { c0: 0, coeff: vec![0; nvars], terms: Vec::new() };
    if decompose(write, 1, &mut d).is_none() {
        return interval_only(write, &extents, out_len);
    }

    // group variables coupled through non-affine terms into components
    let mut parent: Vec<usize> = (0..nvars).collect();
    let mut term_vars: Vec<Vec<usize>> = Vec::with_capacity(d.terms.len());
    for (_, t) in &d.terms {
        let vs: Vec<usize> = t.vars().into_iter().collect();
        for w in vs.windows(2) {
            let (a, b) = (find(&mut parent, w[0]), find(&mut parent, w[1]));
            if a != b {
                parent[a] = b;
            }
        }
        term_vars.push(vs);
    }
    let mut comps: Vec<Comp> = Vec::new();
    let mut comp_of_root = std::collections::BTreeMap::new();
    for &(v, e) in spatial {
        let r = find(&mut parent, v);
        let id = *comp_of_root.entry(r).or_insert_with(|| {
            comps.push(Comp::default());
            comps.len() - 1
        });
        comps[id].vars.push((v, e));
    }
    for (ti, vs) in term_vars.iter().enumerate() {
        // non-empty (a var-free term folds into c0) and all spatial
        let r = find(&mut parent, vs[0]);
        comps[comp_of_root[&r]].terms.push(ti);
    }

    let mut injective = Verdict::Proven;
    let mut exact = true; // do we have every component's min/max?
    let mut stats: Vec<CompStats> = Vec::new();
    let mut env = vec![0i64; nvars];
    for comp in &comps {
        if comp.terms.is_empty() && comp.vars.len() == 1 {
            // pure affine variable: image is {0, c, …, c·(e-1)}
            let (v, e) = comp.vars[0];
            let c = d.coeff[v];
            if e <= 1 {
                stats.push(CompStats { gap: i128::MAX, span: 0, min: 0, max: 0 });
            } else if c == 0 {
                // the write ignores a variable with 2+ iterations:
                // two distinct points share an address — refuted
                injective = Verdict::Disproven;
                stats.push(CompStats { gap: 0, span: 0, min: 0, max: 0 });
            } else {
                let ce = i128::from(c) * i128::from(e - 1);
                stats.push(CompStats {
                    gap: i128::from(c).abs(),
                    span: ce.abs(),
                    min: ce.min(0),
                    max: ce.max(0),
                });
            }
        } else {
            match enum_comp(comp, &d, &mut env) {
                Some((dup, s)) => {
                    if dup {
                        // distinct assignments of this component's vars
                        // collide (others fixed) — a real counterexample
                        injective = Verdict::Disproven;
                    }
                    stats.push(s);
                }
                None => {
                    exact = false;
                    if injective == Verdict::Proven {
                        injective = Verdict::Unknown;
                    }
                }
            }
        }
    }

    // separation: ascending by gap, each component must out-gap the
    // accumulated span of everything below it
    if injective == Verdict::Proven {
        let mut order: Vec<&CompStats> = stats.iter().collect();
        order.sort_by_key(|s| s.gap);
        let mut span_below: i128 = 0;
        for s in &order {
            if s.gap <= span_below {
                injective = Verdict::Unknown;
                break;
            }
            span_below = span_below.saturating_add(s.span);
        }
    }

    if !exact {
        let iv = interval_only(write, &extents, out_len);
        return WriteAnalysis { injective, ..iv };
    }

    // components partition the variables, so the global extremes are
    // the sums of the per-component extremes — exact and attained
    let mn: i128 = i128::from(d.c0) + stats.iter().map(|s| s.min).sum::<i128>();
    let mx: i128 = i128::from(d.c0) + stats.iter().map(|s| s.max).sum::<i128>();
    let in_bounds = if mn >= 0 && mx < i128::from(out_len) {
        Verdict::Proven
    } else {
        Verdict::Disproven
    };
    WriteAnalysis {
        injective,
        in_bounds,
        min_addr: i64::try_from(mn).ok(),
        max_addr: i64::try_from(mx).ok(),
    }
}

// ---------------------------------------------------------------------------
// Plan linter
// ---------------------------------------------------------------------------

/// Finding severity. `Error` findings mean the plan cannot run
/// correctly; `Warning` means wasted or degraded execution; `Perf` is
/// advisory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Error,
    Warning,
    Perf,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Perf => "perf",
        })
    }
}

/// One linter finding, attributable to a nest when `nest` is set.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Graph node of the offending nest, if nest-scoped.
    pub nest: Option<NodeId>,
    /// Stable machine-readable finding code.
    pub code: &'static str,
    pub message: String,
}

impl Diagnostic {
    pub fn nest_scoped(
        severity: Severity,
        nest: NodeId,
        code: &'static str,
        message: String,
    ) -> Self {
        Diagnostic { severity, nest: Some(nest), code, message }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.nest {
            Some(n) => write!(f, "[{}] nest {}: {}: {}", self.severity, n, self.code, self.message),
            None => write!(f, "[{}] {}: {}", self.severity, self.code, self.message),
        }
    }
}

/// Expression-level lints for one generated tensor program: zero-trip
/// loops and `min` clamps the ranges prove can never (or always) fire.
/// Model-level lints (gather slots, innermost strides, dischargeable
/// degradations) live in `CompiledModel::diagnostics()`.
pub fn lint_nest(p: &Program) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let nvars = p.loops.iter().map(|l| l.var + 1).max().unwrap_or(0);
    let mut extents = vec![0i64; nvars];
    for l in &p.loops {
        if l.extent <= 0 {
            out.push(Diagnostic::nest_scoped(
                Severity::Warning,
                p.node,
                "zero-trip-loop",
                format!(
                    "loop {} (v{}) has extent {}; the nest body never runs",
                    l.name, l.var, l.extent
                ),
            ));
        }
        extents[l.var] = l.extent;
    }
    let mut seen = BTreeSet::new();
    for a in &p.accesses {
        for e in &a.idx {
            scan_clamps(e, &extents, p.node, &mut seen, &mut out);
        }
    }
    out
}

fn scan_clamps(
    e: &Expr,
    extents: &[i64],
    node: NodeId,
    seen: &mut BTreeSet<String>,
    out: &mut Vec<Diagnostic>,
) {
    if let Expr::Min(a, b) = e {
        let (ra, rb) = (range_of(a, extents), range_of(b, extents));
        let msg = if ra.hi <= rb.lo {
            Some(format!(
                "clamp min({a},{b}) never fires: {a} ∈ {ra} stays ≤ {}",
                rb.lo
            ))
        } else if rb.hi <= ra.lo {
            Some(format!(
                "clamp min({a},{b}) always fires: {b} ∈ {rb} stays ≤ {}",
                ra.lo
            ))
        } else {
            None
        };
        if let Some(m) = msg {
            // hash-consing shares subtrees; report each shape once
            if seen.insert(m.clone()) {
                out.push(Diagnostic::nest_scoped(
                    Severity::Perf,
                    node,
                    "dead-pad-clamp",
                    m,
                ));
            }
        }
    }
    match e {
        Expr::Add(a, b)
        | Expr::Sub(a, b)
        | Expr::Mul(a, b)
        | Expr::Div(a, b)
        | Expr::Mod(a, b)
        | Expr::Min(a, b) => {
            scan_clamps(a, extents, node, seen, out);
            scan_clamps(b, extents, node, seen, out);
        }
        Expr::Var(_) | Expr::Const(_) => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{Const, Var};

    fn v(i: usize) -> Expr {
        Var(i)
    }

    #[test]
    fn range_affine_combines_interval_and_stride() {
        // 4*v0 over v0 in 0..5 -> {0,4,8,12,16}
        let e = Expr::mul(Const(4), v(0));
        assert_eq!(range_of(&e, &[5]), Range { lo: 0, hi: 16, stride: 4 });
        // 4*v0 + v1 (v1 in 0..2) -> stride gcd(4,1)=1
        let e = Expr::add(e, v(1));
        assert_eq!(range_of(&e, &[5, 2]), Range { lo: 0, hi: 17, stride: 1 });
    }

    #[test]
    fn range_negative_scale_swaps_endpoints() {
        let e = Expr::mul(Const(-3), v(0));
        assert_eq!(range_of(&e, &[4]), Range { lo: -9, hi: 0, stride: 3 });
        let e = Expr::sub(Const(10), v(0));
        assert_eq!(range_of(&e, &[4]), Range { lo: 7, hi: 10, stride: 1 });
    }

    #[test]
    fn range_div_preserves_exact_progressions() {
        // (6*v0)/3 -> {0,2,4,6}
        let e = Expr::div(Expr::mul(Const(6), v(0)), Const(3));
        assert_eq!(range_of(&e, &[4]), Range { lo: 0, hi: 6, stride: 2 });
        // v0/3 over 0..7 -> {0,1,2}
        let e = Expr::div(v(0), Const(3));
        assert_eq!(range_of(&e, &[7]), Range { lo: 0, hi: 2, stride: 1 });
    }

    #[test]
    fn range_mod_keeps_congruence() {
        // (4*v0) % 8 over v0 in 0..8 -> {0,4}
        let e = Expr::rem(Expr::mul(Const(4), v(0)), Const(8));
        assert_eq!(range_of(&e, &[8]), Range { lo: 0, hi: 4, stride: 4 });
        // v0 % 8 with v0 in 0..5 stays in one block: exact shift
        let e = Expr::rem(v(0), Const(8));
        assert_eq!(range_of(&e, &[5]), Range { lo: 0, hi: 4, stride: 1 });
    }

    #[test]
    fn range_min_clamp() {
        let e = Expr::min(v(0), Const(3));
        let r = range_of(&e, &[6]);
        assert_eq!((r.lo, r.hi), (0, 3));
        // soundness on the clamped tail: every concrete value included
        for x in 0..6 {
            assert!(r.contains(e.eval(&[x])));
        }
    }

    #[test]
    fn range_unknown_var_is_top() {
        assert!(range_of(&v(3), &[2]).is_top());
        assert!(range_of(&v(0), &[0]).is_top());
    }

    #[test]
    fn range_sound_on_composed_idioms() {
        // unfold-style: (v0 + v1) with pad clamp and split-remainder
        let idx = Expr::add(Expr::mul(v(0), Const(2)), v(1));
        let e = Expr::add(
            Expr::mul(Expr::div(idx.clone(), Const(3)), Const(16)),
            Expr::rem(idx, Const(3)),
        );
        let extents = [4, 2];
        let r = range_of(&e, &extents);
        for a in 0..extents[0] {
            for b in 0..extents[1] {
                assert!(r.contains(e.eval(&[a, b])), "{e} at ({a},{b}) escapes {r}");
            }
        }
    }

    #[test]
    fn write_row_major_proves_symbolically() {
        // v0*8 + v1 over [4, 8] into 32 slots: the codegen shape
        let w = Expr::flatten(&[v(0), v(1)], &[4, 8]);
        let a = analyze_write(&w, &[(0, 4), (1, 8)], 32);
        assert_eq!(a.verdict(), Verdict::Proven);
        assert_eq!((a.min_addr, a.max_addr), (Some(0), Some(31)));
    }

    #[test]
    fn write_above_enumeration_cap_proves_symbolically() {
        // 2052*2048 > 2^22 spatial points: enumeration gives up, the
        // separation argument doesn't care
        let w = Expr::flatten(&[v(0), v(1)], &[2052, 2048]);
        let a = analyze_write(&w, &[(0, 2052), (1, 2048)], 2052 * 2048);
        assert_eq!(a.verdict(), Verdict::Proven);
    }

    #[test]
    fn write_ignoring_a_var_is_disproven() {
        // v0*8 broadcast over v1: collides for every v1 pair
        let w = Expr::mul(v(0), Const(8));
        let a = analyze_write(&w, &[(0, 4), (1, 8)], 32);
        assert_eq!(a.injective, Verdict::Disproven);
    }

    #[test]
    fn write_out_of_bounds_is_disproven_exactly() {
        let w = Expr::add(v(0), Const(1));
        let a = analyze_write(&w, &[(0, 4)], 4);
        assert_eq!(a.injective, Verdict::Proven);
        assert_eq!(a.in_bounds, Verdict::Disproven);
        assert_eq!(a.max_addr, Some(4));
    }

    #[test]
    fn write_overlapping_strides_stay_unknown() {
        // 3*v0 + 2*v1 over [2, 3] is injective, but separation can't
        // see it (gap 2 ≤ span 3): documented incompleteness — falls
        // back to enumeration, never a wrong verdict
        let w = Expr::add(
            Expr::mul(v(0), Const(3)),
            Expr::mul(v(1), Const(2)),
        );
        let a = analyze_write(&w, &[(0, 2), (1, 3)], 8);
        assert_eq!(a.injective, Verdict::Unknown);
    }

    #[test]
    fn write_div_mod_recombination_proves_by_component() {
        // (v0/4)*4 + v0%4 == v0: one coupled component, enumerated
        let w = Expr::add(
            Expr::mul(Expr::div(v(0), Const(4)), Const(4)),
            Expr::rem(v(0), Const(4)),
        );
        let a = analyze_write(&w, &[(0, 12)], 12);
        assert_eq!(a.verdict(), Verdict::Proven);
        assert_eq!((a.min_addr, a.max_addr), (Some(0), Some(11)));
    }

    #[test]
    fn write_empty_box_is_vacuously_proven() {
        let w = Expr::mul(v(0), Const(1 << 40));
        let a = analyze_write(&w, &[(0, 0)], 1);
        assert_eq!(a.verdict(), Verdict::Proven);
    }

    #[test]
    fn write_mixed_component_and_affine_separation() {
        // split-remainder pair (coupled through v0) times a clean
        // outer stride: (v0%3) + (v0/3)*3 + v1*16 over v0 in 0..12
        let inner = Expr::add(
            Expr::rem(v(0), Const(3)),
            Expr::mul(Expr::div(v(0), Const(3)), Const(3)),
        );
        let w = Expr::add(inner, Expr::mul(v(1), Const(16)));
        let a = analyze_write(&w, &[(0, 12), (1, 4)], 64);
        assert_eq!(a.verdict(), Verdict::Proven);
    }

    #[test]
    fn lint_flags_zero_trip_and_dead_clamp() {
        use crate::loops::{Annotation, Loop, LoopKind};
        let mk_loop = |var: usize, extent: i64| Loop {
            var,
            name: format!("l{var}"),
            extent,
            kind: LoopKind::Spatial,
            ann: Annotation::None,
        };
        let p = Program {
            node: 7,
            loops: vec![mk_loop(0, 4), mk_loop(1, 0)],
            accesses: vec![crate::codegen::TensorAccess {
                tensor: 0,
                storage_shape: vec![8],
                idx: vec![Expr::min(v(0), Const(5))],
                is_write: false,
                elem_bytes: 4,
            }],
            flops_per_iter: 1.0,
            fused: vec![],
        };
        let diags = lint_nest(&p);
        assert!(diags.iter().any(|d| d.code == "zero-trip-loop"
            && d.severity == Severity::Warning));
        assert!(diags.iter().any(|d| d.code == "dead-pad-clamp"
            && d.severity == Severity::Perf));
        // a clamp that can fire is not flagged
        let p2 = Program {
            loops: vec![mk_loop(0, 9)],
            accesses: vec![crate::codegen::TensorAccess {
                tensor: 0,
                storage_shape: vec![8],
                idx: vec![Expr::min(v(0), Const(5))],
                is_write: false,
                elem_bytes: 4,
            }],
            ..p
        };
        assert!(lint_nest(&p2).iter().all(|d| d.code != "dead-pad-clamp"));
    }
}
