//! Loop-nest IR and loop schedules (paper §4.3).
//!
//! ALT reuses TVM's loop primitives (`split`, `reorder`, `vectorize`,
//! `unroll`, `parallel`, `compute_at`, …). We model the subset those
//! primitives generate when driven by the paper's tuning templates: a
//! two-level tiled nest per operator —
//!
//! ```text
//! parallel outer-spatial loops        (split outer halves, in order)
//!   outer-reduction loops
//!     inner-spatial tile loops        (tunable permutation)
//!       inner-reduction loops
//!         [vectorized innermost]      (vectorize)
//! ```
//!
//! plus `compute_at` fusion of the elementwise tail into the tile body
//! (fusion-after-tiling, Figs. 6–7). A [`LoopSchedule`] is the point in
//! loop-tuning space; [`build_nest`] materializes the ordered loop list
//! that codegen attaches access expressions to.

use crate::util::divisors;

/// Loop annotation produced by `vectorize` / `parallel` / `unroll`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Annotation {
    None,
    Parallel,
    Vectorize,
    Unroll,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopKind {
    Spatial,
    Reduction,
}

/// One loop in the generated nest, outermost first. `var` is the loop
/// variable id used by access expressions.
#[derive(Clone, Debug)]
pub struct Loop {
    pub var: usize,
    pub name: String,
    pub extent: i64,
    pub kind: LoopKind,
    pub ann: Annotation,
}

/// The loop-tuning decision for one operator: tile factor per spatial
/// storage dim, tile factor per reduction dim, inner-loop permutation and
/// annotation knobs. This matches the `O(10^7)` 7-nested-loop space the
/// paper quotes for C2D.
///
/// `Eq + Hash` so the candidate-evaluation engine can memoize lowered
/// programs by `(layout hash, schedule)` across tuning rounds.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct LoopSchedule {
    /// Inner tile extent per spatial storage dim (must divide extent).
    pub spatial_tiles: Vec<i64>,
    /// Inner tile extent per reduction dim (must divide extent).
    pub reduction_tiles: Vec<i64>,
    /// Permutation of the inner-spatial tile loops.
    pub inner_perm: Vec<usize>,
    /// Vectorize the innermost loop.
    pub vectorize: bool,
    /// Annotate up to this many outermost loops parallel.
    pub parallel: usize,
    /// Unroll inner-reduction loops whose total extent is below this.
    pub unroll: i64,
    /// `compute_at` the elementwise tail into the tile body.
    pub fuse_eltwise: bool,
}

impl LoopSchedule {
    /// The untuned default: no tiling (tiles == extents), natural order.
    pub fn identity(spatial: &[i64], reduction: &[i64]) -> Self {
        Self {
            spatial_tiles: spatial.to_vec(),
            reduction_tiles: reduction.to_vec(),
            inner_perm: (0..spatial.len()).collect(),
            vectorize: false,
            parallel: 0,
            unroll: 0,
            fuse_eltwise: true,
        }
    }

    /// Clamp/repair a schedule so every factor divides its extent (the
    /// tuner's feasibility projection).
    pub fn repair(&mut self, spatial: &[i64], reduction: &[i64]) {
        fix_tiles(&mut self.spatial_tiles, spatial);
        fix_tiles(&mut self.reduction_tiles, reduction);
        if self.inner_perm.len() != spatial.len()
            || !is_perm(&self.inner_perm)
        {
            self.inner_perm = (0..spatial.len()).collect();
        }
        self.parallel = self.parallel.min(spatial.len());
    }
}

fn fix_tiles(tiles: &mut Vec<i64>, extents: &[i64]) {
    tiles.resize(extents.len(), 1);
    for (t, &e) in tiles.iter_mut().zip(extents) {
        if e <= 0 {
            *t = 1;
        } else if *t <= 0 || e % *t != 0 {
            *t = crate::util::round_to_divisor(e, (*t).max(1) as f64);
        }
    }
}

fn is_perm(p: &[usize]) -> bool {
    let mut seen = vec![false; p.len()];
    p.iter().all(|&i| {
        if i < seen.len() && !seen[i] {
            seen[i] = true;
            true
        } else {
            false
        }
    })
}

/// Materialize the ordered loop list for a tiled nest.
///
/// `spatial`/`reduction` are the storage-dim extents; returns the loops
/// outermost-first plus, for each spatial dim `d`, the pair of loop-var
/// ids `(outer_d, inner_d)` so codegen can write the access expression
/// `idx_d = outer_d * tile_d + inner_d` (and similarly for reductions).
pub fn build_nest(
    spatial: &[i64],
    spatial_names: &[String],
    reduction: &[i64],
    reduction_names: &[String],
    sched: &LoopSchedule,
    simd_lanes: i64,
) -> Nest {
    assert_eq!(spatial.len(), sched.spatial_tiles.len(), "spatial arity");
    assert_eq!(reduction.len(), sched.reduction_tiles.len(), "reduction arity");

    let mut loops = Vec::new();
    let mut var = 0usize;
    let mut alloc = |name: String, extent: i64, kind: LoopKind, ann: Annotation| {
        let l = Loop { var, name, extent, kind, ann };
        var += 1;
        loops.push(l);
        var - 1
    };

    let ns = spatial.len();
    let mut spatial_pairs = vec![(usize::MAX, usize::MAX); ns];
    let mut reduction_pairs = vec![(usize::MAX, usize::MAX); reduction.len()];

    // outer spatial (parallel annotation on the first `parallel` loops)
    for d in 0..ns {
        let outer = spatial[d] / sched.spatial_tiles[d];
        let ann = if d < sched.parallel { Annotation::Parallel } else { Annotation::None };
        spatial_pairs[d].0 = alloc(format!("{}.o", spatial_names[d]), outer, LoopKind::Spatial, ann);
    }
    // outer reduction
    for r in 0..reduction.len() {
        let outer = reduction[r] / sched.reduction_tiles[r];
        reduction_pairs[r].0 = alloc(
            format!("{}.o", reduction_names[r]),
            outer,
            LoopKind::Reduction,
            Annotation::None,
        );
    }
    // inner spatial in tuned order
    for &d in &sched.inner_perm {
        spatial_pairs[d].1 = alloc(
            format!("{}.i", spatial_names[d]),
            sched.spatial_tiles[d],
            LoopKind::Spatial,
            Annotation::None,
        );
    }
    // inner reduction (+ unroll annotation)
    for r in 0..reduction.len() {
        let ext = sched.reduction_tiles[r];
        let ann = if sched.unroll > 0 && ext <= sched.unroll {
            Annotation::Unroll
        } else {
            Annotation::None
        };
        reduction_pairs[r].1 =
            alloc(format!("{}.i", reduction_names[r]), ext, LoopKind::Reduction, ann);
    }
    drop(alloc);

    // vectorize: the innermost loop, if it is spatial and its extent is
    // a multiple (or divisor) of the lane count.
    if sched.vectorize {
        if let Some(last) = loops.last_mut() {
            if last.kind == LoopKind::Spatial
                && (last.extent % simd_lanes == 0 || simd_lanes % last.extent == 0)
            {
                last.ann = Annotation::Vectorize;
            }
        }
        // if reductions are innermost, try the innermost spatial loop
        if loops.last().map(|l| l.kind) == Some(LoopKind::Reduction) {
            if let Some(l) = loops
                .iter_mut()
                .rev()
                .find(|l| l.kind == LoopKind::Spatial)
            {
                if l.extent % simd_lanes == 0 || simd_lanes % l.extent == 0 {
                    l.ann = Annotation::Vectorize;
                }
            }
        }
    }

    Nest { loops, spatial_pairs, reduction_pairs }
}

/// Output of [`build_nest`].
#[derive(Clone, Debug)]
pub struct Nest {
    pub loops: Vec<Loop>,
    /// (outer var, inner var) per spatial storage dim.
    pub spatial_pairs: Vec<(usize, usize)>,
    /// (outer var, inner var) per reduction dim.
    pub reduction_pairs: Vec<(usize, usize)>,
}

impl Nest {
    pub fn total_iters(&self) -> f64 {
        self.loops.iter().map(|l| l.extent as f64).product()
    }
}

/// Enumerate candidate tile factors for an extent (the per-dimension
/// option list the tuners index into).
pub fn tile_options(extent: i64) -> Vec<i64> {
    divisors(extent)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: &[&str]) -> Vec<String> {
        n.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn nest_structure_and_extents() {
        let sched = LoopSchedule {
            spatial_tiles: vec![4, 8],
            reduction_tiles: vec![3],
            inner_perm: vec![1, 0],
            vectorize: true,
            parallel: 1,
            unroll: 4,
            fuse_eltwise: true,
        };
        let nest = build_nest(
            &[16, 32],
            &names(&["h", "w"]),
            &[9],
            &names(&["rk"]),
            &sched,
            8,
        );
        // loops: h.o(4) w.o(4) rk.o(3) w.i(8) h.i(4) rk.i(3)
        let extents: Vec<i64> = nest.loops.iter().map(|l| l.extent).collect();
        assert_eq!(extents, vec![4, 4, 3, 8, 4, 3]);
        assert_eq!(nest.loops[0].ann, Annotation::Parallel);
        assert_eq!(nest.total_iters(), (4 * 4 * 3 * 8 * 4 * 3) as f64);
        // innermost is a reduction -> vectorize falls back to h.i? h.i ext 4, lanes 8 -> 8%4==0 ok
        let vec_loop = nest.loops.iter().find(|l| l.ann == Annotation::Vectorize);
        assert!(vec_loop.is_some());
        // unroll on rk.i (extent 3 <= 4)
        assert_eq!(nest.loops.last().unwrap().ann, Annotation::Unroll);
    }

    #[test]
    fn identity_schedule_single_loop_per_dim() {
        let sched = LoopSchedule::identity(&[8, 8], &[4]);
        let nest = build_nest(
            &[8, 8],
            &names(&["a", "b"]),
            &[4],
            &names(&["r"]),
            &sched,
            8,
        );
        // outer loops extent 1, inner loops full extent
        let extents: Vec<i64> = nest.loops.iter().map(|l| l.extent).collect();
        assert_eq!(extents, vec![1, 1, 1, 8, 8, 4]);
    }

    #[test]
    fn repair_fixes_bad_factors() {
        let mut s = LoopSchedule {
            spatial_tiles: vec![5, 0],
            reduction_tiles: vec![7],
            inner_perm: vec![0, 0],
            vectorize: false,
            parallel: 9,
            unroll: 0,
            fuse_eltwise: false,
        };
        s.repair(&[16, 8], &[9]);
        assert!(16 % s.spatial_tiles[0] == 0);
        assert!(8 % s.spatial_tiles[1] == 0);
        assert!(9 % s.reduction_tiles[0] == 0);
        assert_eq!(s.inner_perm, vec![0, 1]);
        assert_eq!(s.parallel, 2);
    }

    #[test]
    fn tile_options_are_divisors() {
        assert_eq!(tile_options(12), vec![1, 2, 3, 4, 6, 12]);
    }
}
