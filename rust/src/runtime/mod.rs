//! Runtime backends: execute compiled layout variants for real.
//!
//! This is the real-host validation leg of the three-layer stack. The
//! layout rankings the simulated device produces are only credible if
//! genuine execution agrees, so the runtime exposes a pluggable
//! [`Backend`] trait with two implementations:
//!
//! * [`native`] — a zero-dependency interpreter that executes the
//!   *generated tensor programs* (codegen's loop nest + storage access
//!   expressions) directly on host `f32` buffers, honoring each
//!   operand's layout sequence, the fused elementwise tail and the
//!   `parallel` loop annotations (`std::thread` scoped workers). It is
//!   always compiled, so tier-1 tests cross-check simulator rankings
//!   against real execution offline ([`variants::cross_check`]).
//! * `pjrt` (cargo feature `pjrt`) — the original XLA-backed client:
//!   the Python build layer (`python/compile/aot.py`) lowers each L2
//!   graph variant to HLO text once; [`Runtime`] loads those artifacts
//!   via the `xla` crate and times them. Enabling the feature requires
//!   adding the `xla` crate to `Cargo.toml` by hand (it cannot be
//!   fetched in offline build environments).
//!
//! Manifest/spec parsing and deterministic input generation are pure
//! std and shared by both backends.

pub mod native;
pub mod variants;

use std::path::Path;

use crate::error::{Error, Result};
use crate::{bail, err};

/// Parsed entry of `artifacts/manifest.txt` (written by aot.py):
/// `name \t file \t in_specs \t out_specs` with specs like
/// `float32[1,224,224,3]`.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

fn parse_spec(s: &str) -> Result<TensorSpec> {
    let (dtype, rest) = s
        .split_once('[')
        .ok_or_else(|| err!("bad tensor spec '{s}'"))?;
    let dims = rest
        .strip_suffix(']')
        .ok_or_else(|| err!("bad tensor spec '{s}': missing ']'"))?;
    let shape = if dims.is_empty() {
        vec![]
    } else {
        dims.split(',')
            .map(|d| {
                d.trim()
                    .parse::<usize>()
                    .map_err(|e| err!("bad dim '{d}' in spec '{s}': {e}"))
            })
            .collect::<Result<Vec<_>>>()?
    };
    Ok(TensorSpec { dtype: dtype.to_string(), shape })
}

/// Parse manifest text (`name \t file \t in_specs \t out_specs` lines).
/// Tolerates CRLF line endings and trailing `;` in spec lists; rejects
/// duplicate artifact names and malformed dims.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut out: Vec<ArtifactSpec> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for line in text.lines() {
        // `str::lines` splits on \n; strip the \r of CRLF files.
        let line = line.strip_suffix('\r').unwrap_or(line);
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            bail!("manifest line has {} cols: {line}", cols.len());
        }
        let parse_list = |s: &str| -> Result<Vec<TensorSpec>> {
            s.split(';').filter(|p| !p.is_empty()).map(parse_spec).collect()
        };
        let name = cols[0].to_string();
        if !seen.insert(name.clone()) {
            bail!("duplicate artifact '{name}' in manifest");
        }
        out.push(ArtifactSpec {
            name,
            file: cols[1].to_string(),
            inputs: parse_list(cols[2])?,
            outputs: parse_list(cols[3])?,
        });
    }
    Ok(out)
}

/// Read the artifact manifest from `dir/manifest.txt`.
pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| Error::msg(e).context(format!("reading {}", path.display())))?;
    parse_manifest(&text)
}

/// Result of one timed execution.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub latency_ms: f64,
    pub output_elems: usize,
    /// first few output values (for cross-variant numeric checks)
    pub sample: Vec<f32>,
}

/// Deterministic pseudo-random input for a spec (seeded; build-agnostic).
pub fn random_input(spec: &TensorSpec, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::Rng::new(seed);
    (0..spec.elements())
        .map(|_| (rng.uniform() as f32 - 0.5) * 0.2)
        .collect()
}

/// Deterministic seeded inputs for a spec list — input `i` is seeded
/// with `seed + i`. The one seeding convention every backend shares,
/// so the same `(variant, seed)` means the same data on native and
/// PJRT alike.
pub fn seeded_inputs(specs: &[TensorSpec], seed: u64) -> Vec<Vec<f32>> {
    specs
        .iter()
        .enumerate()
        .map(|(i, s)| random_input(s, seed + i as u64))
        .collect()
}

/// A runtime backend: a registry of compiled layout variants that can
/// execute requests for real (as opposed to predicting them). Both the
/// native interpreter and the PJRT client implement this, so serving
/// drivers and the cross-check harness are backend-agnostic.
pub trait Backend {
    /// Short backend id (`"native"`, `"pjrt"`).
    fn backend_name(&self) -> &'static str;

    /// Human description of the execution substrate.
    fn platform(&self) -> String;

    /// Names of the loadable variants, sorted.
    fn entries(&self) -> Vec<String>;

    /// Whether `variant` is available.
    fn has(&self, variant: &str) -> bool {
        self.entries().iter().any(|e| e == variant)
    }

    /// Logical input specs of one variant — what
    /// [`execute_with`](Self::execute_with) expects, in order.
    fn input_specs(&self, variant: &str) -> Result<Vec<TensorSpec>>;

    /// Execute one variant with caller-provided inputs matching
    /// [`input_specs`](Self::input_specs) — the serving request path:
    /// generate (or receive) inputs once, vary only what changes per
    /// request.
    fn execute_with(&self, variant: &str, inputs: &[Vec<f32>]) -> Result<RunStats>;

    /// Execute one variant with deterministic seeded inputs.
    fn execute(&self, variant: &str, seed: u64) -> Result<RunStats> {
        let inputs = seeded_inputs(&self.input_specs(variant)?, seed);
        self.execute_with(variant, &inputs)
    }

    /// Median-of-`iters` latency (ms) of one variant, seeded inputs.
    fn bench_variant(&self, variant: &str, seed: u64, iters: usize) -> Result<f64>;
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::time::Instant;

    use super::*;
    use crate::{bail, err};

    /// A compiled artifact ready to execute.
    pub struct Executable {
        pub spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        /// Execute with the given f32 inputs (row-major, matching the spec).
        pub fn run(&self, inputs: &[Vec<f32>]) -> Result<RunStats> {
            if inputs.len() != self.spec.inputs.len() {
                bail!(
                    "{}: want {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                );
            }
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, spec) in inputs.iter().zip(&self.spec.inputs) {
                if data.len() != spec.elements() {
                    bail!("{}: input size mismatch", self.spec.name);
                }
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lits.push(
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| err!("reshape: {e:?}"))?,
                );
            }
            let t0 = Instant::now();
            let result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| err!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| err!("sync: {e:?}"))?;
            let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
            // aot.py lowers with return_tuple=True → 1-tuple
            let out = result.to_tuple1().map_err(|e| err!("tuple: {e:?}"))?;
            let values =
                out.to_vec::<f32>().map_err(|e| err!("to_vec: {e:?}"))?;
            let sample = values.iter().take(8).copied().collect();
            Ok(RunStats { latency_ms, output_elems: values.len(), sample })
        }

        /// Median-of-n timed runs (first run excluded as warmup).
        pub fn bench(&self, inputs: &[Vec<f32>], n: usize) -> Result<f64> {
            let _ = self.run(inputs)?; // warmup + compile caches
            let mut times = Vec::with_capacity(n);
            for _ in 0..n {
                times.push(self.run(inputs)?.latency_ms);
            }
            Ok(crate::util::stats::median(&mut times))
        }
    }

    /// Registry of compiled artifacts backed by one PJRT CPU client.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: HashMap<String, ArtifactSpec>,
    }

    impl Runtime {
        /// Create a CPU runtime over an artifact directory.
        pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            let dir = artifacts_dir.as_ref().to_path_buf();
            let client = xla::PjRtClient::cpu()
                .map_err(|e| err!("PJRT cpu client: {e:?}"))?;
            let cache = read_manifest(&dir)?
                .into_iter()
                .map(|s| (s.name.clone(), s))
                .collect();
            Ok(Self { client, dir, cache })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn entries(&self) -> Vec<String> {
            let mut v: Vec<String> = self.cache.keys().cloned().collect();
            v.sort();
            v
        }

        pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
            self.cache.get(name)
        }

        /// Load + compile one artifact.
        pub fn load(&self, name: &str) -> Result<Executable> {
            let spec = self
                .cache
                .get(name)
                .ok_or_else(|| err!("unknown artifact '{name}'"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| err!("bad path"))?,
            )
            .map_err(|e| err!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err!("compile {name}: {e:?}"))?;
            Ok(Executable { spec, exe })
        }
    }

    impl Backend for Runtime {
        fn backend_name(&self) -> &'static str {
            "pjrt"
        }

        fn platform(&self) -> String {
            Runtime::platform(self)
        }

        fn entries(&self) -> Vec<String> {
            Runtime::entries(self)
        }

        fn input_specs(&self, variant: &str) -> Result<Vec<TensorSpec>> {
            Ok(self
                .spec(variant)
                .ok_or_else(|| err!("unknown artifact '{variant}'"))?
                .inputs
                .clone())
        }

        fn execute_with(
            &self,
            variant: &str,
            inputs: &[Vec<f32>],
        ) -> Result<RunStats> {
            self.load(variant)?.run(inputs)
        }

        fn bench_variant(
            &self,
            variant: &str,
            seed: u64,
            iters: usize,
        ) -> Result<f64> {
            let exe = self.load(variant)?;
            let inputs = seeded_inputs(&exe.spec.inputs, seed);
            exe.bench(&inputs, iters.max(1))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

pub use native::{
    DegradeReason, ExecMode, ExecScratch, NativeExecutable, NativeRuntime,
    OperandView,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_roundtrip() {
        let s = parse_spec("float32[1,224,224,3]").unwrap();
        assert_eq!(s.dtype, "float32");
        assert_eq!(s.shape, vec![1, 224, 224, 3]);
        assert_eq!(s.elements(), 150528);
        let scalar = parse_spec("float32[]").unwrap();
        assert_eq!(scalar.elements(), 1);
        assert!(parse_spec("garbage").is_err());
    }

    #[test]
    fn parse_spec_rejects_malformed_dims() {
        assert!(parse_spec("float32[1,x,3]").is_err());
        assert!(parse_spec("float32[1,-2]").is_err());
        assert!(parse_spec("float32[1,2").is_err()); // missing ]
        assert!(parse_spec("float32[1,2]junk").is_err());
        assert!(parse_spec("float32[1,,2]").is_err());
    }

    #[test]
    fn manifest_parses_basic_and_trailing_semicolon() {
        let text = "model\tmodel.hlo\tfloat32[2,3];float32[3,4];\tfloat32[2,4]\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].name, "model");
        // trailing ';' must not create a phantom empty spec
        assert_eq!(m[0].inputs.len(), 2);
        assert_eq!(m[0].outputs.len(), 1);
    }

    #[test]
    fn manifest_tolerates_crlf_lines() {
        let text = "a\ta.hlo\tfloat32[4]\tfloat32[4]\r\nb\tb.hlo\tfloat32[2,2]\tfloat32[2,2]\r\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        // the \r must not leak into the last spec's dims
        assert_eq!(m[0].outputs[0].shape, vec![4]);
        assert_eq!(m[1].name, "b");
    }

    #[test]
    fn manifest_rejects_duplicate_names() {
        let text = "m\tm1.hlo\tfloat32[4]\tfloat32[4]\nm\tm2.hlo\tfloat32[4]\tfloat32[4]\n";
        let err = parse_manifest(text).unwrap_err();
        assert!(format!("{err}").contains("duplicate artifact 'm'"));
    }

    #[test]
    fn manifest_rejects_malformed_rows() {
        // wrong column count
        assert!(parse_manifest("just three\tcols\there\n").is_err());
        // malformed dims inside a spec list
        assert!(
            parse_manifest("m\tm.hlo\tfloat32[1,oops]\tfloat32[4]\n").is_err()
        );
    }

    #[test]
    fn manifest_skips_blank_lines() {
        let text = "\n\nm\tm.hlo\tfloat32[4]\tfloat32[4]\n\n";
        assert_eq!(parse_manifest(text).unwrap().len(), 1);
    }

    #[test]
    fn manifest_parses_if_present() {
        // integration-level loading runs in rust/tests/; here we only
        // exercise the parser against the checked-in artifacts when the
        // build has produced them.
        let dir = Path::new("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = read_manifest(dir).unwrap();
            assert!(m.iter().any(|s| s.name == "model"));
            for s in &m {
                assert!(!s.inputs.is_empty());
                assert_eq!(s.outputs.len(), 1);
            }
        }
    }
}
