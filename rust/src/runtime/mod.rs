//! PJRT runtime: load and execute the AOT HLO artifacts.
//!
//! This is the real-host validation leg of the three-layer stack: the
//! Python build layer (`python/compile/aot.py`) lowers each L2 graph
//! variant to HLO *text* once; this module loads those artifacts via the
//! `xla` crate (`PjRtClient::cpu` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`) and times them, so the layout rankings the
//! simulated device produces can be cross-checked against genuine
//! execution on the host CPU. Python is never on this path.
//!
//! The `xla`-backed half ([`Executable`], [`Runtime`]) is gated behind
//! the `pjrt` cargo feature: the crate must build with zero external
//! dependencies in offline environments, so enabling `pjrt` requires
//! adding the `xla` crate to `Cargo.toml` by hand. Manifest/spec
//! parsing and deterministic input generation are always available
//! (they are pure std and unit-tested offline).

use std::path::Path;

use crate::error::{Error, Result};
use crate::{bail, err};

/// Parsed entry of `artifacts/manifest.txt` (written by aot.py):
/// `name \t file \t in_specs \t out_specs` with specs like
/// `float32[1,224,224,3]`.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

fn parse_spec(s: &str) -> Result<TensorSpec> {
    let (dtype, rest) = s
        .split_once('[')
        .ok_or_else(|| err!("bad tensor spec '{s}'"))?;
    let dims = rest.trim_end_matches(']');
    let shape = if dims.is_empty() {
        vec![]
    } else {
        dims.split(',')
            .map(|d| {
                d.trim()
                    .parse::<usize>()
                    .map_err(|e| Error::msg(e).context("dim"))
            })
            .collect::<Result<Vec<_>>>()?
    };
    Ok(TensorSpec { dtype: dtype.to_string(), shape })
}

/// Read the artifact manifest.
pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| Error::msg(e).context(format!("reading {}", path.display())))?;
    let mut out = Vec::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            bail!("manifest line has {} cols: {line}", cols.len());
        }
        let parse_list = |s: &str| -> Result<Vec<TensorSpec>> {
            s.split(';').filter(|p| !p.is_empty()).map(parse_spec).collect()
        };
        out.push(ArtifactSpec {
            name: cols[0].to_string(),
            file: cols[1].to_string(),
            inputs: parse_list(cols[2])?,
            outputs: parse_list(cols[3])?,
        });
    }
    Ok(out)
}

/// Result of one timed execution.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub latency_ms: f64,
    pub output_elems: usize,
    /// first few output values (for cross-variant numeric checks)
    pub sample: Vec<f32>,
}

/// Deterministic pseudo-random input for a spec (seeded; build-agnostic).
pub fn random_input(spec: &TensorSpec, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::Rng::new(seed);
    (0..spec.elements())
        .map(|_| (rng.uniform() as f32 - 0.5) * 0.2)
        .collect()
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::time::Instant;

    use super::*;
    use crate::{bail, err};

    /// A compiled artifact ready to execute.
    pub struct Executable {
        pub spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        /// Execute with the given f32 inputs (row-major, matching the spec).
        pub fn run(&self, inputs: &[Vec<f32>]) -> Result<RunStats> {
            if inputs.len() != self.spec.inputs.len() {
                bail!(
                    "{}: want {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                );
            }
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, spec) in inputs.iter().zip(&self.spec.inputs) {
                if data.len() != spec.elements() {
                    bail!("{}: input size mismatch", self.spec.name);
                }
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lits.push(
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| err!("reshape: {e:?}"))?,
                );
            }
            let t0 = Instant::now();
            let result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| err!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| err!("sync: {e:?}"))?;
            let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
            // aot.py lowers with return_tuple=True → 1-tuple
            let out = result.to_tuple1().map_err(|e| err!("tuple: {e:?}"))?;
            let values =
                out.to_vec::<f32>().map_err(|e| err!("to_vec: {e:?}"))?;
            let sample = values.iter().take(8).copied().collect();
            Ok(RunStats { latency_ms, output_elems: values.len(), sample })
        }

        /// Median-of-n timed runs (first run excluded as warmup).
        pub fn bench(&self, inputs: &[Vec<f32>], n: usize) -> Result<f64> {
            let _ = self.run(inputs)?; // warmup + compile caches
            let mut times = Vec::with_capacity(n);
            for _ in 0..n {
                times.push(self.run(inputs)?.latency_ms);
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            Ok(times[times.len() / 2])
        }
    }

    /// Registry of compiled artifacts backed by one PJRT CPU client.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: HashMap<String, ArtifactSpec>,
    }

    impl Runtime {
        /// Create a CPU runtime over an artifact directory.
        pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            let dir = artifacts_dir.as_ref().to_path_buf();
            let client = xla::PjRtClient::cpu()
                .map_err(|e| err!("PJRT cpu client: {e:?}"))?;
            let cache = read_manifest(&dir)?
                .into_iter()
                .map(|s| (s.name.clone(), s))
                .collect();
            Ok(Self { client, dir, cache })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn entries(&self) -> Vec<String> {
            let mut v: Vec<String> = self.cache.keys().cloned().collect();
            v.sort();
            v
        }

        pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
            self.cache.get(name)
        }

        /// Load + compile one artifact.
        pub fn load(&self, name: &str) -> Result<Executable> {
            let spec = self
                .cache
                .get(name)
                .ok_or_else(|| err!("unknown artifact '{name}'"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| err!("bad path"))?,
            )
            .map_err(|e| err!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err!("compile {name}: {e:?}"))?;
            Ok(Executable { spec, exe })
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_roundtrip() {
        let s = parse_spec("float32[1,224,224,3]").unwrap();
        assert_eq!(s.dtype, "float32");
        assert_eq!(s.shape, vec![1, 224, 224, 3]);
        assert_eq!(s.elements(), 150528);
        let scalar = parse_spec("float32[]").unwrap();
        assert_eq!(scalar.elements(), 1);
        assert!(parse_spec("garbage").is_err());
    }

    #[test]
    fn manifest_parses_if_present() {
        // integration-level loading runs in rust/tests/; here we only
        // exercise the parser against the checked-in artifacts when the
        // build has produced them.
        let dir = Path::new("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = read_manifest(dir).unwrap();
            assert!(m.iter().any(|s| s.name == "model"));
            for s in &m {
                assert!(!s.inputs.is_empty());
                assert_eq!(s.outputs.len(), 1);
            }
        }
    }
}
