//! Native interpreter backend: execute generated tensor programs on
//! host `f32` buffers with zero external dependencies.
//!
//! [`NativeExecutable::compile`] takes the same inputs as the simulator
//! path — a graph, one complex node plus its fused elementwise tail, a
//! [`LayoutAssignment`] and a [`LoopSchedule`] — lowers them through
//! [`lower_complex`] and *executes the resulting [`Program`] for real*:
//!
//! * every operand buffer is packed into its layout sequence's storage
//!   format ([`LayoutTransform::repack`]), so the interpreter reads and
//!   writes through the exact storage access expressions codegen
//!   emitted — the same expressions the simulator samples;
//! * the loop nest runs output-element-major: for each spatial
//!   coordinate the reduction loops accumulate in nest order, then the
//!   fused elementwise tail (bias/ReLU/…, `compute_at` fusion) applies
//!   in registers and the final tensor is written once. Per-element
//!   accumulation order equals the nest's reduction order, so results
//!   are bit-for-bit independent of how the spatial space is chunked;
//! * `parallel`-annotated programs fan spatial chunks across
//!   `std::thread::scope` workers (the same scoped-pool pattern as
//!   [`crate::engine`]); programs without a `parallel` annotation run
//!   on one thread regardless of `--threads`, so the schedule knob has
//!   a real execution-time effect. Outputs are bit-identical across
//!   thread counts.
//!
//! Access expressions are compiled once to a small stack bytecode
//! ([`Code`]), with the spatial-only part of each address hoisted out
//! of the reduction loop, so the timed loop does data movement and
//! multiply-adds rather than `Arc` tree walks.
//!
//! On top of the bytecode, `compile` additionally lowers every access
//! expression to a strided *address stream* ([`Stream`]): an affine
//! recurrence over the loop odometer (constant + per-loop-variable
//! stride), with non-affine sub-terms (unfold/pad clamps, div/mod of
//! split dims) precomputed into index tables over exactly the loop
//! variables they mention. The reduction loop then advances addresses
//! by constant bumps instead of re-evaluating bytecode per MAC, and
//! the innermost MAC runs as an unrolled dot-product over the longest
//! trailing run of reduction levels whose per-step address delta is
//! constant for both operands. Accumulation order is exactly the
//! nest's reduction order in every mode, so fast-path outputs are
//! bit-identical to the bytecode interpreter (kept as the reference
//! oracle behind [`ExecMode::Bytecode`]). When any expression resists
//! the decomposition (a table would exceed its size cap), the whole
//! executable stays on bytecode permanently.
//!
//! Reported latency covers execution only; packing/unpacking is the
//! job of conversion operators and is charged separately by the cost
//! model (see `conversion_terms` in the tuner).
//!
//! Safety certificates come from [`crate::analysis`]: the write map's
//! injectivity and bounds are proven *symbolically* (interval ×
//! congruence abstract interpretation) with no size cap, so direct
//! parallel writes apply to nests far above the old 2^22 enumeration
//! wall; enumeration survives as the fallback for verdicts the
//! analyzer can't reach and, in debug builds, as the differential
//! oracle cross-checking the ones it can. Read streams carry the same
//! in-bounds certificates (surfaced through `HealthReport`).
//!
//! Unsupported (returns an error at compile): transposed convolutions
//! (zero-expanded inputs) and `store_at`-packed operands.

// Address arithmetic here mixes i64 expression values with usize
// indexing — the PR 6 u32-truncation bug class. Every narrowing cast
// must either go through a checked conversion or be locally allowed
// with a certificate-backed justification.
#![warn(clippy::cast_possible_truncation)]

use std::collections::BTreeSet;
use std::time::Instant;

use crate::analysis::{self, ProofKind, Verdict};
use crate::codegen::{lower_complex, LayoutAssignment, Program, TensorAccess};
use crate::error::Result;
use crate::expr::{Const, Expr};
use crate::graph::{EltKind, Graph, NodeId, OpKind};
use crate::layout::{LayoutTransform, Primitive};
use crate::loops::{Annotation, LoopKind, LoopSchedule};
use crate::tensor::TensorId;
use crate::{bail, err};

use super::{Backend, RunStats, TensorSpec};

/// One bytecode step of a compiled index expression.
#[derive(Clone, Debug)]
enum Step {
    Var(usize),
    Const(i64),
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Min,
}

/// A compiled index expression: postfix steps over an `i64` stack.
/// Matches [`Expr::eval`] exactly (euclidean div/mod).
#[derive(Clone, Debug)]
struct Code {
    steps: Vec<Step>,
}

impl Code {
    fn compile(e: &Expr) -> Self {
        let mut steps = Vec::new();
        fn push(e: &Expr, out: &mut Vec<Step>) {
            match e {
                Expr::Var(i) => out.push(Step::Var(*i)),
                Expr::Const(c) => out.push(Step::Const(*c)),
                Expr::Add(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Add);
                }
                Expr::Sub(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Sub);
                }
                Expr::Mul(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Mul);
                }
                Expr::Div(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Div);
                }
                Expr::Mod(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Mod);
                }
                Expr::Min(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Min);
                }
            }
        }
        push(e, &mut steps);
        Self { steps }
    }

    fn eval(&self, env: &[i64], stack: &mut Vec<i64>) -> i64 {
        stack.clear();
        for s in &self.steps {
            match s {
                Step::Var(i) => stack.push(env[*i]),
                Step::Const(c) => stack.push(*c),
                op => {
                    // compile() emits balanced postfix, so underflow is
                    // structurally impossible for any Code it built
                    let (Some(b), Some(a)) = (stack.pop(), stack.pop()) else {
                        unreachable!("code underflow")
                    };
                    stack.push(match op {
                        Step::Add => a + b,
                        Step::Sub => a - b,
                        Step::Mul => a * b,
                        Step::Div => a.div_euclid(b),
                        Step::Mod => a.rem_euclid(b),
                        Step::Min => a.min(b),
                        _ => unreachable!(),
                    });
                }
            }
        }
        match stack.pop() {
            Some(v) => v,
            None => unreachable!("empty code"),
        }
    }
}

/// Which executor a compiled nest runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Strided address streams + unrolled dot-product MAC loops (the
    /// default; falls back to bytecode when no fast plan compiled).
    #[default]
    Fast,
    /// The stack-bytecode interpreter — the reference oracle the fast
    /// path is golden-tested against, and the baseline the serving
    /// bench's within-run speedup ratio is measured over.
    Bytecode,
}

/// Why a nest sits below the top rung of the execution ladder —
/// recorded at compile (or forced-degrade) time and surfaced through
/// `CompiledModel::health()`. The ladder is: strided fast plan with
/// direct parallel writes → fast plan with staged scatter →
/// bytecode interpreter → typed compile error. Every rung computes
/// bit-identical outputs; only throughput degrades.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradeReason {
    /// A non-affine index term's lookup table would exceed the 2^22
    /// entry cap ([`TABLE_CAP`]); the nest runs on bytecode.
    TableCap,
    /// An access expression mentions a loop variable with no known
    /// extent, so stream analysis cannot decompose it; bytecode.
    StreamAnalysis,
    /// Neither the symbolic analyzer nor fallback enumeration (capped
    /// at 2^22) proved the write map injective + in-bounds; parallel
    /// workers use the staged-scatter pass instead of direct
    /// shared-buffer writes (the nest stays fast).
    UnprovenWrite,
    /// A fused repack edge's composed gather map referenced source
    /// storage out of range; the repack materializes instead of
    /// fusing.
    GatherCompose,
    /// A fault-injection hook forced this degrade
    /// (`--features fault-inject` builds only).
    Injected,
}

impl std::fmt::Display for DegradeReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            DegradeReason::TableCap => "index table over alloc cap",
            DegradeReason::StreamAnalysis => "stream analysis failed",
            DegradeReason::UnprovenWrite => "write map not proven injective",
            DegradeReason::GatherCompose => "gather-map composition out of range",
            DegradeReason::Injected => "injected fault",
        };
        f.write_str(s)
    }
}

/// A read-only operand slot: raw storage, optionally redirected through
/// a precompiled gather map (a Fig. 5a repack fused into this nest's
/// read side — entry `i` is the source index storage slot `i` reads, or
/// `-1` for a padding slot that reads as `0.0`).
#[derive(Clone, Copy)]
pub struct OperandView<'a> {
    pub data: &'a [f32],
    pub gather: Option<&'a [i64]>,
}

impl<'a> OperandView<'a> {
    pub fn direct(data: &'a [f32]) -> Self {
        Self { data, gather: None }
    }

    /// Length of the storage layout this view presents to the nest.
    fn view_len(&self) -> usize {
        match self.gather {
            None => self.data.len(),
            Some(g) => g.len(),
        }
    }

    // Gather entries are validated (or symbolically proven) in
    // `0..data.len()` at compile time, so the narrowing is safe here.
    #[allow(clippy::cast_possible_truncation)]
    #[inline]
    fn ld(&self, i: usize) -> f32 {
        match self.gather {
            None => self.data[i],
            Some(g) => {
                let s = g[i];
                if s < 0 {
                    0.0
                } else {
                    self.data[s as usize]
                }
            }
        }
    }
}

/// Reusable per-worker execution scratch (loop env, bytecode stack,
/// table cursors) — hoisted out of the per-chunk hot path so repeated
/// runs allocate nothing.
#[derive(Debug, Default)]
pub struct ExecScratch {
    env: Vec<i64>,
    stack: Vec<i64>,
    tcur: Vec<i64>,
}

/// Hard cap on one index table's entry count (the non-affine fallback
/// stays a compile-time artifact, never a memory hazard).
const TABLE_CAP: i64 = 1 << 22;

/// Largest spatial space the *fallback* write-injectivity enumeration
/// will walk. The symbolic analyzer ([`crate::analysis`]) has no such
/// cap and decides most nests first; enumeration only runs when its
/// verdict is `Unknown` (and, in debug builds, as the cross-check
/// oracle for verdicts it reached).
const INJECTIVITY_CAP: u64 = 1 << 22;

/// A non-affine sub-term lowered to a lookup table over exactly the
/// loop variables it mentions (mixed-radix index over their extents).
#[derive(Clone, Debug)]
struct StreamTable {
    /// Mentioned loop variables, ascending.
    vars: Vec<usize>,
    /// Stride of each variable into `values` (mixed radix).
    radix: Vec<i64>,
    /// Precomputed term values, pre-scaled by the term's constant
    /// multiplier.
    values: Vec<i64>,
}

impl StreamTable {
    #[inline]
    fn index_of(&self, env: &[i64]) -> i64 {
        let mut idx = 0i64;
        for (v, r) in self.vars.iter().zip(&self.radix) {
            idx += env[*v] * r;
        }
        idx
    }
}

/// Affine-plus-tables decomposition of an index expression:
/// `value(env) = c0 + Σ_v coeff[v]·env[v] + Σ_t values_t[idx_t(env)]`.
/// Semantically equal to [`Expr::eval`] on every in-extent env (pinned
/// by the randomized property tests below).
#[derive(Clone, Debug)]
struct Stream {
    c0: i64,
    coeff: Vec<i64>,
    tables: Vec<StreamTable>,
}

impl Stream {
    /// Decompose `e` over loop variables with the given per-var
    /// extents. `None` when a non-affine sub-term's table would exceed
    /// [`TABLE_CAP`] (or mentions a var without a known extent).
    fn analyze(e: &Expr, extents: &[i64]) -> Option<Self> {
        Self::try_analyze(e, extents).ok()
    }

    /// [`Stream::analyze`] that reports *which* rung of the
    /// degradation ladder the expression fell off — the per-nest
    /// [`DegradeReason`] the health report surfaces.
    fn try_analyze(
        e: &Expr,
        extents: &[i64],
    ) -> std::result::Result<Self, DegradeReason> {
        let mut s = Self {
            c0: 0,
            coeff: vec![0i64; extents.len()],
            tables: Vec::new(),
        };
        decompose(e, 1, extents, &mut s)?;
        Ok(s)
    }

    /// Affine part only (tables excluded) — the cursor initialization.
    #[inline]
    fn affine_eval(&self, env: &[i64]) -> i64 {
        let mut v = self.c0;
        for (c, x) in self.coeff.iter().zip(env) {
            v += c * x;
        }
        v
    }

    /// Full value, tables included.
    // Table indices are mixed-radix over loop extents whose product is
    // bounded by `TABLE_CAP` (< 2^22), so they always fit usize.
    #[allow(clippy::cast_possible_truncation)]
    #[inline]
    fn eval(&self, env: &[i64]) -> i64 {
        let mut v = self.affine_eval(env);
        for t in &self.tables {
            v += t.values[t.index_of(env) as usize];
        }
        v
    }
}

/// Accumulate `k · e` into `out`. Affine structure (vars, constants,
/// add/sub, multiplication by var-free factors) distributes exactly;
/// anything else becomes a table over its mentioned variables.
fn decompose(
    e: &Expr,
    k: i64,
    extents: &[i64],
    out: &mut Stream,
) -> std::result::Result<(), DegradeReason> {
    if e.vars().is_empty() {
        out.c0 += k * e.eval(&[]);
        return Ok(());
    }
    match e {
        Expr::Var(i) => {
            out.coeff[*i] += k;
            Ok(())
        }
        Expr::Add(a, b) => {
            decompose(a, k, extents, out)?;
            decompose(b, k, extents, out)
        }
        Expr::Sub(a, b) => {
            decompose(a, k, extents, out)?;
            decompose(b, -k, extents, out)
        }
        Expr::Mul(a, b) => {
            if a.vars().is_empty() {
                decompose(b, k * a.eval(&[]), extents, out)
            } else if b.vars().is_empty() {
                decompose(a, k * b.eval(&[]), extents, out)
            } else {
                tabulate(e, k, extents, out)
            }
        }
        Expr::Div(..) | Expr::Mod(..) | Expr::Min(..) => {
            tabulate(e, k, extents, out)
        }
        // Const is var-free, handled above
        Expr::Const(_) => unreachable!("const has no vars"),
    }
}

/// Lower `k · e` to a lookup table over the variables `e` mentions.
fn tabulate(
    e: &Expr,
    k: i64,
    extents: &[i64],
    out: &mut Stream,
) -> std::result::Result<(), DegradeReason> {
    let vars: Vec<usize> = e.vars().into_iter().collect();
    let mut exts = Vec::with_capacity(vars.len());
    let mut size = 1i64;
    for &v in &vars {
        let ext = match extents.get(v) {
            Some(&x) if x >= 1 => x,
            _ => return Err(DegradeReason::StreamAnalysis),
        };
        size = size.saturating_mul(ext);
        exts.push(ext);
    }
    if size > TABLE_CAP {
        return Err(DegradeReason::TableCap);
    }
    let Ok(size_us) = usize::try_from(size) else {
        return Err(DegradeReason::TableCap);
    };
    let mut radix = vec![1i64; vars.len()];
    for j in (0..vars.len().saturating_sub(1)).rev() {
        radix[j] = radix[j + 1] * exts[j + 1];
    }
    let mut env = vec![0i64; extents.len()];
    let mut values = vec![0i64; size_us];
    for (flat, slot) in values.iter_mut().enumerate() {
        let mut rem = flat as i64;
        for j in (0..vars.len()).rev() {
            env[vars[j]] = rem % exts[j];
            rem /= exts[j];
        }
        *slot = k * e.eval(&env);
    }
    out.tables.push(StreamTable { vars, radix, values });
    Ok(())
}

/// Row-major strides of a storage shape.
fn strides_of(shape: &[i64]) -> Vec<i64> {
    let mut strides = vec![1i64; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    strides
}

/// Flat-address expression of an access (sum of dim-index * stride).
fn flat_expr(acc: &TensorAccess) -> Expr {
    Expr::flatten(&acc.idx, &acc.storage_shape)
}

/// A MAC operand read with the spatial-only address part hoisted:
/// `addr = base(spatial env) + red(full env)`.
#[derive(Clone, Debug)]
struct MacRead {
    buf: usize,
    base: Code,
    red: Code,
    has_red: bool,
}

/// Split a flat access into its spatial-only base and the
/// reduction-varying remainder (per-dim terms; a term goes to the red
/// part when its dim's index mentions any reduction var).
fn split_access(acc: &TensorAccess, red_vars: &BTreeSet<usize>) -> (Expr, Expr) {
    let strides = strides_of(&acc.storage_shape);
    let mut base = Const(0);
    let mut red = Const(0);
    for (idx, &s) in acc.idx.iter().zip(&strides) {
        let term = Expr::mul(idx.clone(), Const(s));
        if idx.vars().iter().any(|v| red_vars.contains(v)) {
            red = Expr::add(red, term);
        } else {
            base = Expr::add(base, term);
        }
    }
    (base, red)
}

impl MacRead {
    fn build(buf: usize, acc: &TensorAccess, red_vars: &BTreeSet<usize>) -> Self {
        let (base, red) = split_access(acc, red_vars);
        let has_red = !matches!(red, Const(0));
        Self { buf, base: Code::compile(&base), red: Code::compile(&red), has_red }
    }
}

/// The compiled fast plan of one nest: every access expression lowered
/// to an address stream, plus the reduction-odometer bump schedule and
/// the trailing contiguous run the inner dot-product covers.
#[derive(Debug)]
struct FastNest {
    lhs_base: Stream,
    rhs_base: Stream,
    /// Reduction-varying address parts (cursor-advanced by `*_bump`).
    lhs_red: Stream,
    rhs_red: Stream,
    write: Stream,
    /// Per tail stage, per operand: spatial address stream (`None` for
    /// the chain value flowing through in registers).
    tails: Vec<Vec<Option<Stream>>>,
    /// Steps of the trailing contiguous run (product of the trailing
    /// reduction-level extents whose per-step address delta is the
    /// innermost stride for both operands); ≥ 1, divides `red_total`.
    run_len: u64,
    lhs_stride: i64,
    rhs_stride: i64,
    /// Reduction levels above the run (nest order).
    outer: Vec<(usize, i64)>,
    /// Cursor bump applied when outer level `li` increments by one
    /// (deeper outer levels wrap from extent−1 to 0; run-level odometer
    /// digits stay 0 — the run is walked by stride arithmetic instead).
    lhs_bump: Vec<i64>,
    rhs_bump: Vec<i64>,
    /// Table-index cursor bumps: per outer level, one bump per table
    /// (lhs tables first, then rhs — the `ExecScratch::tcur` layout).
    tbl_bump: Vec<Vec<i64>>,
}

impl FastNest {
    #[allow(clippy::too_many_arguments)]
    fn build(
        extents: &[i64],
        reduction: &[(usize, i64)],
        lhs_base_e: &Expr,
        lhs_red_e: &Expr,
        rhs_base_e: &Expr,
        rhs_red_e: &Expr,
        write_e: &Expr,
        tail_exprs: &[Vec<Option<Expr>>],
    ) -> std::result::Result<Self, DegradeReason> {
        #[cfg(feature = "fault-inject")]
        {
            if crate::faults::fire(crate::faults::FaultSite::StreamAnalysis) {
                return Err(DegradeReason::Injected);
            }
            if crate::faults::fire(crate::faults::FaultSite::AllocCap) {
                return Err(DegradeReason::TableCap);
            }
        }
        let lhs_base = Stream::try_analyze(lhs_base_e, extents)?;
        let rhs_base = Stream::try_analyze(rhs_base_e, extents)?;
        let lhs_red = Stream::try_analyze(lhs_red_e, extents)?;
        let rhs_red = Stream::try_analyze(rhs_red_e, extents)?;
        let write = Stream::try_analyze(write_e, extents)?;
        let mut tails = Vec::with_capacity(tail_exprs.len());
        for stage in tail_exprs {
            let mut ops = Vec::with_capacity(stage.len());
            for e in stage {
                ops.push(match e {
                    None => None,
                    Some(e) => Some(Stream::try_analyze(e, extents)?),
                });
            }
            tails.push(ops);
        }

        // Trailing contiguous run: grow K from the innermost level out
        // while (a) no table of either red stream mentions a run var —
        // gathered terms must stay constant across the run — and (b)
        // the per-step bump at every run level equals the innermost
        // stride for both operands, so run addresses form an exact
        // arithmetic progression.
        let r = reduction.len();
        let table_vars: BTreeSet<usize> = lhs_red
            .tables
            .iter()
            .chain(&rhs_red.tables)
            .flat_map(|t| t.vars.iter().copied())
            .collect();
        let mut k = 0usize;
        'grow: while k < r {
            let li = r - 1 - k;
            let (v, _) = reduction[li];
            if table_vars.contains(&v) {
                break;
            }
            for s in [&lhs_red, &rhs_red] {
                let d = s.coeff[reduction[r - 1].0];
                let mut bump = s.coeff[v];
                for &(vj, ej) in &reduction[li + 1..] {
                    bump -= s.coeff[vj] * (ej - 1);
                }
                if bump != d {
                    break 'grow;
                }
            }
            k += 1;
        }
        let run_len: u64 = reduction[r - k..]
            .iter()
            .map(|&(_, e)| e as u64)
            .product::<u64>()
            .max(1);
        let (lhs_stride, rhs_stride) = if k > 0 {
            let vin = reduction[r - 1].0;
            (lhs_red.coeff[vin], rhs_red.coeff[vin])
        } else {
            (0, 0)
        };
        let outer: Vec<(usize, i64)> = reduction[..r - k].to_vec();

        // Bump schedule per cursor channel: incrementing outer level
        // `li` adds coeff(v_li) while every deeper *outer* level wraps
        // from extent−1 back to 0 (run levels never leave 0).
        let bumps_for = |cv: &dyn Fn(usize) -> i64| -> Vec<i64> {
            (0..outer.len())
                .map(|li| {
                    let mut b = cv(outer[li].0);
                    for &(vj, ej) in &outer[li + 1..] {
                        b -= cv(vj) * (ej - 1);
                    }
                    b
                })
                .collect()
        };
        let lhs_bump = bumps_for(&|v| lhs_red.coeff[v]);
        let rhs_bump = bumps_for(&|v| rhs_red.coeff[v]);
        let tbl_coeff = |t: &StreamTable, v: usize| -> i64 {
            t.vars
                .iter()
                .position(|&tv| tv == v)
                .map(|j| t.radix[j])
                .unwrap_or(0)
        };
        let tbl_bump: Vec<Vec<i64>> = (0..outer.len())
            .map(|li| {
                lhs_red
                    .tables
                    .iter()
                    .chain(&rhs_red.tables)
                    .map(|t| {
                        let mut b = tbl_coeff(t, outer[li].0);
                        for &(vj, ej) in &outer[li + 1..] {
                            b -= tbl_coeff(t, vj) * (ej - 1);
                        }
                        b
                    })
                    .collect()
            })
            .collect();

        Ok(Self {
            lhs_base,
            rhs_base,
            lhs_red,
            rhs_red,
            write,
            tails,
            run_len,
            lhs_stride,
            rhs_stride,
            outer,
            lhs_bump,
            rhs_bump,
            tbl_bump,
        })
    }
}

/// Inner dot-product over one contiguous run: both addresses advance by
/// a constant stride per step. The stride-1/no-gather specialization is
/// a 4×-unrolled slice walk with a single accumulator — the exact
/// accumulation order of the interpreter (element by element, in nest
/// order), so results stay bit-identical; the win is dropping per-MAC
/// bytecode dispatch, not reassociation.
// Run addresses are certificate-backed: the stream analyzer bounds
// every base, and run lengths stay under the loop extents, so the
// i64→usize narrowing never truncates.
#[allow(clippy::too_many_arguments, clippy::cast_possible_truncation)]
#[inline]
fn dot(
    lhs: OperandView,
    rhs: OperandView,
    la: i64,
    ra: i64,
    sl: i64,
    sr: i64,
    n: u64,
    acc: &mut f32,
) {
    if sl == 1 && sr == 1 && lhs.gather.is_none() && rhs.gather.is_none() {
        let n = n as usize;
        let xs = &lhs.data[la as usize..la as usize + n];
        let ys = &rhs.data[ra as usize..ra as usize + n];
        let mut i = 0usize;
        while i + 4 <= n {
            *acc += xs[i] * ys[i];
            *acc += xs[i + 1] * ys[i + 1];
            *acc += xs[i + 2] * ys[i + 2];
            *acc += xs[i + 3] * ys[i + 3];
            i += 4;
        }
        while i < n {
            *acc += xs[i] * ys[i];
            i += 1;
        }
    } else {
        let (mut la, mut ra) = (la, ra);
        for _ in 0..n {
            *acc += lhs.ld(la as usize) * rhs.ld(ra as usize);
            la += sl;
            ra += sr;
        }
    }
}

/// How a fused elementwise stage combines its operands.
#[derive(Clone, Copy, Debug)]
enum TailKind {
    Sum,
    Product,
    Relu,
    Relu6,
    Sigmoid,
    Gelu,
    Tanh,
    Identity,
}

#[derive(Clone, Debug)]
enum TailOperand {
    /// The running value of the fusion chain (the complex op's result
    /// flowing through the tail in registers).
    Chain,
    /// A read of an external operand at its storage address.
    Read { buf: usize, addr: Code },
}

#[derive(Clone, Debug)]
struct TailStage {
    kind: TailKind,
    operands: Vec<TailOperand>,
}

impl TailStage {
    /// Combine operand values (fetched by index through `val`) per the
    /// stage's kind — shared by the bytecode and stream executors so
    /// both paths apply the exact same `f32` operations in the exact
    /// same order.
    #[inline]
    fn combine(&self, mut val: impl FnMut(usize) -> f32) -> f32 {
        match self.kind {
            TailKind::Sum => {
                let mut s = val(0);
                for i in 1..self.operands.len() {
                    s += val(i);
                }
                s
            }
            TailKind::Product => {
                let mut p = val(0);
                for i in 1..self.operands.len() {
                    p *= val(i);
                }
                p
            }
            TailKind::Relu => val(0).max(0.0),
            TailKind::Relu6 => val(0).clamp(0.0, 6.0),
            TailKind::Sigmoid => {
                let x = val(0);
                1.0 / (1.0 + (-x).exp())
            }
            TailKind::Gelu => {
                let x = val(0);
                0.5 * x
                    * (1.0
                        + (0.797_884_6_f32 * (x + 0.044_715 * x * x * x))
                            .tanh())
            }
            TailKind::Tanh => val(0).tanh(),
            TailKind::Identity => val(0),
        }
    }

    // Tail addresses are nest accesses validated in-bounds (or proven
    // by the analyzer) at compile time; they fit usize.
    #[allow(clippy::cast_possible_truncation)]
    #[inline]
    fn apply(
        &self,
        chain: f32,
        bufs: &[OperandView],
        env: &[i64],
        stack: &mut Vec<i64>,
    ) -> f32 {
        self.combine(|i| match &self.operands[i] {
            TailOperand::Chain => chain,
            TailOperand::Read { buf, addr } => {
                bufs[*buf].ld(addr.eval(env, stack) as usize)
            }
        })
    }

    /// Fast-path variant: operand addresses come from precompiled
    /// streams (index-aligned with `operands`; `None` for the chain
    /// value flowing through in registers).
    // Same certificate as `apply`: stream values are in-bounds
    // storage addresses.
    #[allow(clippy::cast_possible_truncation)]
    #[inline]
    fn apply_streams(
        &self,
        chain: f32,
        bufs: &[OperandView],
        env: &[i64],
        streams: &[Option<Stream>],
    ) -> f32 {
        self.combine(|i| match (&self.operands[i], &streams[i]) {
            (TailOperand::Chain, _) => chain,
            (TailOperand::Read { buf, .. }, Some(s)) => {
                bufs[*buf].ld(s.eval(env) as usize)
            }
            (TailOperand::Read { .. }, None) => {
                unreachable!("tail read without a compiled stream")
            }
        })
    }
}

/// One logical input the caller must provide, plus its packing recipe.
#[derive(Debug)]
struct InputBuf {
    tensor: TensorId,
    name: String,
    /// Logical row-major shape the caller provides data in.
    shape: Vec<i64>,
    elements: usize,
    /// Storage elements after the layout sequence (what
    /// [`NativeExecutable::run_storage_into`] expects for this slot).
    packed_len: usize,
    transform: LayoutTransform,
    identity: bool,
}

/// Forward mapping logical index → storage flat address, used to fold
/// the executed storage buffer back to a logical row-major output.
#[derive(Debug)]
struct UnpackPlan {
    logical_shape: Vec<i64>,
    logical_len: usize,
    /// One code per storage dim, over logical-dim vars `0..rank`.
    dims: Vec<Code>,
    storage_strides: Vec<i64>,
    /// Precompiled storage address per logical element — the gather map
    /// [`ExecMode::Fast`] unpacks through instead of re-evaluating
    /// `dims` bytecode per element on every run.
    map: Vec<i64>,
}

/// A compiled tensor-program variant, ready to execute on the host.
///
/// Every field is immutable after compilation, so one executable can be
/// shared across serving workers behind an `Arc`: all execution entry
/// points take `&self` plus caller-owned output/[`ExecScratch`]
/// buffers, and concurrent `run_storage_views_into` calls from
/// different threads (each with its own scratch) are bit-identical to
/// serial runs. The serving layer (`api::serve`) leans on this — give
/// each worker its own scratch, never share one `ExecScratch` between
/// threads.
#[derive(Debug)]
pub struct NativeExecutable {
    name: String,
    program: Program,
    threads: usize,
    env_len: usize,
    /// (loop var, extent) of spatial loops, nest order.
    spatial: Vec<(usize, i64)>,
    /// (loop var, extent) of reduction loops, nest order.
    reduction: Vec<(usize, i64)>,
    spatial_total: u64,
    red_total: u64,
    inputs: Vec<InputBuf>,
    lhs: MacRead,
    rhs: MacRead,
    tail: Vec<TailStage>,
    write: Code,
    out_len: usize,
    /// Tensor whose storage buffer the nest writes (the last fused
    /// node's output, or the complex op's own output without a tail).
    written: TensorId,
    unpack: UnpackPlan,
    /// Product of `parallel`-annotated spatial loop extents (1 when
    /// the schedule grants no parallelism).
    par_extent: u64,
    /// Strided fast plan (`None` when some access resisted the
    /// affine-plus-tables decomposition — the nest stays on bytecode).
    fast: Option<FastNest>,
    /// Why `fast` is `None` (set at compile, or by a forced
    /// [`degrade`](Self::degrade)); `None` while the fast plan holds.
    fast_degrade: Option<DegradeReason>,
    /// Which executor runs (Fast is only effective when `fast` is
    /// `Some`; Bytecode always forces the interpreter).
    mode: ExecMode,
    /// Compile-time proof that the write map is injective and
    /// in-bounds over the spatial space, enabling the direct-write
    /// parallel path (workers share the output buffer instead of
    /// staging `(addr, value)` pairs for a serial scatter).
    write_direct: bool,
    /// How the write-map proof was obtained (symbolic analyzer,
    /// fallback enumeration, or not at all).
    write_proof: ProofKind,
    /// Every read stream symbolically proven in-bounds over the full
    /// iteration box.
    reads_bounded: bool,
}

/// Shared output pointer for the injective direct-write parallel path.
///
/// Safety: `compile` proved every spatial point writes a distinct
/// in-bounds slot (`write_direct`), and workers own disjoint spatial
/// chunks, so no two threads ever write the same element.
#[derive(Clone, Copy)]
struct SharedOut(*mut f32);
unsafe impl Send for SharedOut {}
unsafe impl Sync for SharedOut {}

fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

impl NativeExecutable {
    /// Lower `node` (+ fused tail) under `layouts`/`sched` and compile
    /// the resulting program for host execution. `threads == 0` means
    /// all available cores; threads only apply to `parallel`-annotated
    /// programs.
    #[allow(clippy::too_many_arguments)]
    pub fn compile(
        name: &str,
        graph: &Graph,
        node_id: NodeId,
        fused_tail: &[NodeId],
        layouts: &LayoutAssignment,
        sched: &LoopSchedule,
        simd_lanes: i64,
        threads: usize,
    ) -> Result<Self> {
        let node = graph.node(node_id);
        match &node.kind {
            OpKind::Conv { transposed: true, .. } => {
                bail!("{name}: transposed convs are not supported by the native backend")
            }
            OpKind::Conv { .. } | OpKind::Matmul | OpKind::Dense => {}
            other => bail!("{name}: not a complex op: {other:?}"),
        }
        if let Some(&w) = node.inputs.get(1) {
            let seq = layouts.get(w);
            if seq.prims.iter().any(|p| {
                matches!(p, Primitive::StoreAt { .. } | Primitive::DecoupleAt { .. })
            }) {
                bail!("{name}: store_at-packed operands are not supported by the native backend");
            }
        }

        let program =
            lower_complex(graph, node_id, layouts, sched, fused_tail, simd_lanes);

        // Loop variable tables (nest order). build_nest allocates var
        // ids in push order, but derive everything from the loop list.
        let env_len = program
            .loops
            .iter()
            .map(|l| l.var + 1)
            .max()
            .ok_or_else(|| err!("{name}: empty loop nest"))?;
        let spatial: Vec<(usize, i64)> = program
            .loops
            .iter()
            .filter(|l| l.kind == LoopKind::Spatial)
            .map(|l| (l.var, l.extent))
            .collect();
        let reduction: Vec<(usize, i64)> = program
            .loops
            .iter()
            .filter(|l| l.kind == LoopKind::Reduction)
            .map(|l| (l.var, l.extent))
            .collect();
        let red_vars: BTreeSet<usize> = reduction.iter().map(|&(v, _)| v).collect();
        let spatial_total: u64 =
            spatial.iter().map(|&(_, e)| e as u64).product();
        let red_total: u64 = reduction.iter().map(|&(_, e)| e as u64).product();

        // Access layout (the lower_complex contract):
        //   [0] complex-op output (the write iff no fused tail)
        //   [1] lhs operand, [2] rhs operand
        //   [3..] fused-tail external reads, then the final write.
        let accs = &program.accesses;
        if accs.len() < 3 {
            bail!("{name}: program has {} accesses, want >= 3", accs.len());
        }
        let write_idx = if fused_tail.is_empty() { 0 } else { accs.len() - 1 };
        if !accs[write_idx].is_write {
            bail!("{name}: unexpected write-access placement");
        }
        if accs[1].is_write || accs[2].is_write {
            bail!("{name}: unexpected operand write");
        }
        if accs[1].tensor != node.inputs[0] || accs[2].tensor != node.inputs[1] {
            bail!("{name}: operand accesses do not match node inputs");
        }
        let spatial_only = |acc: &TensorAccess| -> bool {
            acc.idx
                .iter()
                .all(|e| e.vars().iter().all(|v| !red_vars.contains(v)))
        };
        if !spatial_only(&accs[write_idx]) {
            bail!("{name}: write access depends on reduction vars");
        }

        // Input buffers, keyed by tensor, in first-appearance order.
        let mut inputs: Vec<InputBuf> = Vec::new();
        let mut buf_of = |t: TensorId, acc: &TensorAccess| -> Result<usize> {
            if let Some(i) = inputs.iter().position(|b| b.tensor == t) {
                return Ok(i);
            }
            let ten = graph.tensor(t);
            let seq = layouts.get_for(node_id, t);
            let tf = LayoutTransform::new(ten.shape.clone(), &seq);
            if tf.final_shape() != acc.storage_shape.as_slice() {
                bail!(
                    "{name}: storage shape mismatch for {}: {:?} vs {:?}",
                    ten.name,
                    tf.final_shape(),
                    acc.storage_shape
                );
            }
            let elements = usize::try_from(ten.elements()).map_err(|_| {
                err!("{name}: {} element count overflows usize", ten.name)
            })?;
            let packed: i64 = tf.final_shape().iter().product();
            let packed_len = usize::try_from(packed).map_err(|_| {
                err!("{name}: {} packed length overflows usize", ten.name)
            })?;
            inputs.push(InputBuf {
                tensor: t,
                name: ten.name.clone(),
                shape: ten.shape.clone(),
                elements,
                packed_len,
                identity: seq.is_identity(),
                transform: tf,
            });
            Ok(inputs.len() - 1)
        };

        let lhs_buf = buf_of(node.inputs[0], &accs[1])?;
        let rhs_buf = buf_of(node.inputs[1], &accs[2])?;
        let lhs = MacRead::build(lhs_buf, &accs[1], &red_vars);
        let rhs = MacRead::build(rhs_buf, &accs[2], &red_vars);

        // Fused tail: replay lower_complex's operand walk so external
        // reads line up with accesses[3..] (store_at operands, which
        // lower_complex would skip, were rejected above).
        let mut next_acc = 3usize;
        let tail_end = if fused_tail.is_empty() { 3 } else { accs.len() - 1 };
        let mut tail: Vec<TailStage> = Vec::new();
        let mut tail_exprs: Vec<Vec<Option<Expr>>> = Vec::new();
        for &tid in fused_tail {
            let tnode = graph.node(tid);
            let kind = match &tnode.kind {
                OpKind::BiasAdd => TailKind::Sum,
                OpKind::Eltwise { kind, .. } => match kind {
                    EltKind::Add => TailKind::Sum,
                    EltKind::Mul => TailKind::Product,
                    EltKind::Relu => TailKind::Relu,
                    EltKind::Relu6 => TailKind::Relu6,
                    EltKind::Sigmoid => TailKind::Sigmoid,
                    EltKind::Gelu => TailKind::Gelu,
                    EltKind::Tanh => TailKind::Tanh,
                    EltKind::Identity => TailKind::Identity,
                },
                other => bail!(
                    "{name}: unsupported fused tail op {other:?} in {}",
                    tnode.name
                ),
            };
            let mut operands = Vec::new();
            let mut op_exprs: Vec<Option<Expr>> = Vec::new();
            for &inp in &tnode.inputs {
                let prod = graph.tensor(inp).producer;
                let is_chain = prod == Some(node_id)
                    || prod.map(|p| fused_tail.contains(&p)).unwrap_or(false);
                if is_chain {
                    operands.push(TailOperand::Chain);
                    op_exprs.push(None);
                    continue;
                }
                if next_acc >= tail_end {
                    bail!("{name}: ran out of tail accesses for {}", tnode.name);
                }
                let acc = &accs[next_acc];
                if acc.tensor != inp {
                    bail!(
                        "{name}: tail access order mismatch (t{} vs t{})",
                        acc.tensor,
                        inp
                    );
                }
                if !spatial_only(acc) {
                    bail!("{name}: tail read depends on reduction vars");
                }
                let buf = buf_of(inp, acc)?;
                let e = flat_expr(acc);
                operands.push(TailOperand::Read {
                    buf,
                    addr: Code::compile(&e),
                });
                op_exprs.push(Some(e));
                next_acc += 1;
            }
            tail.push(TailStage { kind, operands });
            tail_exprs.push(op_exprs);
        }
        if next_acc != tail_end {
            bail!(
                "{name}: {} tail accesses left unconsumed",
                tail_end - next_acc
            );
        }

        // Final write + logical unpack plan.
        let write_acc = &accs[write_idx];
        let out_len: i64 = write_acc.storage_shape.iter().product();
        if out_len <= 0 {
            bail!("{name}: output storage of {out_len} elements out of range");
        }
        let fin = if let Some(&last) = fused_tail.last() {
            graph.node(last).output
        } else {
            node.output
        };
        let fin_t = graph.tensor(fin);
        let fin_tf = LayoutTransform::new(fin_t.shape.clone(), &layouts.get(fin));
        if fin_tf.final_shape() != write_acc.storage_shape.as_slice() {
            bail!("{name}: output storage shape mismatch");
        }
        let logical_acc: Vec<crate::layout::DimAccess> = (0..fin_t.rank())
            .map(|d| crate::layout::DimAccess::Simple(Expr::Var(d)))
            .collect();
        let dims: Vec<Code> = fin_tf
            .rewrite_access(&logical_acc)
            .iter()
            .map(|a| Code::compile(&a.to_expr()))
            .collect();
        let storage_strides = strides_of(&write_acc.storage_shape);
        // Precompute the logical→storage gather map once; fast-mode
        // unpacking is then a straight indexed copy.
        let Ok(logical_len) = usize::try_from(fin_t.elements()) else {
            bail!("{name}: output element count overflows usize");
        };
        let rank = fin_t.rank();
        let mut map = vec![0i64; logical_len];
        {
            let mut idx = vec![0i64; rank];
            let mut stack: Vec<i64> = Vec::with_capacity(16);
            for (flat, slot) in map.iter_mut().enumerate() {
                let mut rem = flat as i64;
                for d in (0..rank).rev() {
                    idx[d] = rem % fin_t.shape[d];
                    rem /= fin_t.shape[d];
                }
                let mut saddr = 0i64;
                for (code, &stride) in dims.iter().zip(&storage_strides) {
                    saddr += code.eval(&idx, &mut stack) * stride;
                }
                *slot = saddr;
            }
        }
        let unpack = UnpackPlan {
            logical_shape: fin_t.shape.clone(),
            logical_len,
            dims,
            storage_strides,
            map,
        };

        // Parallel width granted by the schedule: the product of the
        // `parallel`-annotated spatial loop extents — the same quantity
        // the simulator's scaling model caps speedup at, so native
        // execution and simulation honor the annotation identically.
        let par_extent: u64 = program
            .loops
            .iter()
            .filter(|l| l.ann == Annotation::Parallel && l.kind == LoopKind::Spatial)
            .map(|l| l.extent as u64)
            .product();

        // Strided fast plan: lower every access to an address stream
        // over the loop odometer. Any access that resists (a non-affine
        // sub-term whose table would blow TABLE_CAP) leaves the whole
        // nest on bytecode permanently.
        let mut var_extents = vec![0i64; env_len];
        for l in &program.loops {
            var_extents[l.var] = l.extent;
        }
        let (lhs_base_e, lhs_red_e) = split_access(&accs[1], &red_vars);
        let (rhs_base_e, rhs_red_e) = split_access(&accs[2], &red_vars);
        let write_e = flat_expr(write_acc);
        let (fast, fast_degrade) = match FastNest::build(
            &var_extents,
            &reduction,
            &lhs_base_e,
            &lhs_red_e,
            &rhs_base_e,
            &rhs_red_e,
            &write_e,
            &tail_exprs,
        ) {
            Ok(f) => (Some(f), None),
            // One rung down, not an error: the bytecode oracle computes
            // the same bits, so the nest stays servable.
            Err(reason) => (None, Some(reason)),
        };

        // Write-map certificates: injectivity + bounds together mean
        // every spatial point writes a distinct in-bounds address, so
        // parallel workers can write the shared output buffer directly
        // (no staged scatter) — worker output slices are disjoint by
        // construction. The symbolic analyzer decides most nests
        // outright with no size cap; enumeration survives as the
        // fallback for verdicts it cannot reach and, in debug builds,
        // as the differential oracle cross-checking the ones it can.
        let write = Code::compile(&write_e);
        let Ok(out_len_us) = usize::try_from(out_len) else {
            bail!("{name}: output length {out_len} overflows usize");
        };
        let enumerate_write = || -> bool {
            if spatial_total > INJECTIVITY_CAP {
                return false;
            }
            let mut env = vec![0i64; env_len];
            let mut stack: Vec<i64> = Vec::with_capacity(16);
            let mut seen = vec![false; out_len_us];
            for _ in 0..spatial_total {
                let a = write.eval(&env, &mut stack);
                match usize::try_from(a).ok().filter(|&i| i < seen.len()) {
                    Some(i) if !seen[i] => seen[i] = true,
                    _ => return false,
                }
                for &(v, e) in spatial.iter().rev() {
                    env[v] += 1;
                    if env[v] < e {
                        break;
                    }
                    env[v] = 0;
                }
            }
            true
        };
        let wa = analysis::analyze_write(&write_e, &spatial, out_len);
        let (write_direct, write_proof) = match wa.verdict() {
            Verdict::Proven => {
                debug_assert!(
                    spatial_total > INJECTIVITY_CAP || enumerate_write(),
                    "{name}: symbolic injectivity proof contradicts enumeration"
                );
                (true, ProofKind::Symbolic)
            }
            Verdict::Disproven => {
                debug_assert!(
                    spatial_total > INJECTIVITY_CAP || !enumerate_write(),
                    "{name}: symbolic refutation contradicts enumeration"
                );
                (false, ProofKind::Symbolic)
            }
            Verdict::Unknown if spatial_total <= INJECTIVITY_CAP => {
                (enumerate_write(), ProofKind::Enumerated)
            }
            Verdict::Unknown => (false, ProofKind::Unproven),
        };

        // In-bounds certificates for the read streams: when every read
        // address provably stays inside its operand's packed storage,
        // the runtime checks guarding those streams are dead weight
        // (surfaced in `HealthReport` and the serve-bench `proof`
        // counters; the linter flags the opposite).
        let reads_bounded = accs[1..tail_end].iter().all(|acc| {
            acc.is_write || {
                let len: i64 = acc.storage_shape.iter().product();
                analysis::range_of(&flat_expr(acc), &var_extents).within(0, len)
            }
        });

        Ok(Self {
            name: name.to_string(),
            threads: resolve_threads(threads),
            env_len,
            spatial,
            reduction,
            spatial_total,
            red_total,
            inputs,
            lhs,
            rhs,
            tail,
            write,
            out_len: out_len_us,
            written: fin,
            unpack,
            par_extent,
            fast,
            fast_degrade,
            mode: ExecMode::Fast,
            write_direct,
            write_proof,
            reads_bounded,
            program,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The lowered program this executable runs (what the simulator
    /// scores — the cross-check compares both on the same object).
    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Select the executor. `Fast` (the default) runs the strided
    /// address-stream plan when one compiled, falling back to bytecode
    /// otherwise; `Bytecode` always forces the reference interpreter
    /// (the oracle the fast path is golden-tested against).
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    pub fn exec_mode(&self) -> ExecMode {
        self.mode
    }

    /// Whether the strided fast plan compiled for this nest (i.e. every
    /// access expression decomposed into an address stream).
    pub fn has_fast_path(&self) -> bool {
        self.fast.is_some()
    }

    /// Why this nest is off the strided fast plan (`None` while it
    /// holds). Distinguishes a *degraded* nest from one whose model
    /// was merely switched to [`ExecMode::Bytecode`] for oracle runs.
    pub fn degrade_reason(&self) -> Option<DegradeReason> {
        self.fast_degrade
    }

    /// Force this nest one rung down the ladder: drop the fast plan
    /// and record why. Execution continues on the bytecode oracle with
    /// bit-identical outputs; the rest of the model is unaffected.
    pub fn degrade(&mut self, reason: DegradeReason) {
        self.fast = None;
        self.fast_degrade = Some(reason);
    }

    /// Ladder rung of the parallel write path: `Some(UnprovenWrite)`
    /// when a parallel nest fell back to staged scatter because
    /// neither the symbolic analyzer nor fallback enumeration closed
    /// the injectivity + bounds proof.
    pub fn write_degrade(&self) -> Option<DegradeReason> {
        if self.is_parallel() && !self.write_direct {
            Some(DegradeReason::UnprovenWrite)
        } else {
            None
        }
    }

    /// Whether the compile-time injectivity proof enables direct
    /// shared-buffer writes on the parallel path.
    pub fn writes_direct(&self) -> bool {
        self.write_direct
    }

    /// How the write-map certificate was obtained: `Symbolic` when the
    /// analyzer decided it (either way), `Enumerated` when exhaustive
    /// enumeration under the 2^22 cap had to settle it, `Unproven`
    /// when neither closed. A `Symbolic`/`Enumerated` proof combined
    /// with [`writes_direct`](Self::writes_direct) is the data-race-
    /// freedom certificate for parallel workers: distinct spatial
    /// points write distinct addresses, so worker output slices are
    /// disjoint.
    pub fn write_proof(&self) -> ProofKind {
        self.write_proof
    }

    /// Whether every read stream of this nest is symbolically proven
    /// in-bounds over the full iteration box (interval × congruence
    /// range of each flat address vs its operand's packed length).
    pub fn reads_bounded(&self) -> bool {
        self.reads_bounded
    }

    /// Innermost-run address strides of the fast plan's MAC operands,
    /// `None` off the fast path. The perf linter flags non-unit
    /// innermost reads (no contiguous unrolled run to vectorize).
    pub fn innermost_strides(&self) -> Option<(i64, i64)> {
        self.fast.as_ref().map(|f| (f.lhs_stride, f.rhs_stride))
    }

    /// Whether this program carries a live `parallel` annotation (and
    /// therefore actually fans out across threads).
    pub fn is_parallel(&self) -> bool {
        self.par_extent > 1
    }

    /// Logical input specs, in the order [`run`](Self::run) expects.
    // Dims are validated ≥ 1 at graph construction; they fit usize.
    #[allow(clippy::cast_possible_truncation)]
    pub fn input_specs(&self) -> Vec<TensorSpec> {
        self.inputs
            .iter()
            .map(|b| TensorSpec {
                dtype: "float32".into(),
                shape: b.shape.iter().map(|&d| d as usize).collect(),
            })
            .collect()
    }

    /// Deterministic seeded inputs matching [`input_specs`](Self::input_specs).
    pub fn seeded_inputs(&self, seed: u64) -> Vec<Vec<f32>> {
        super::seeded_inputs(&self.input_specs(), seed)
    }

    // ---- storage-level entry points (the multi-op execution plan) ----
    //
    // A whole-model plan keeps inter-op buffers in their *storage*
    // layouts and feeds them straight back into downstream nests, so it
    // bypasses the logical pack/unpack round trip `run` performs per
    // call. The methods below expose the operand contract: which tensor
    // each slot reads, how long its packed buffer must be, how to pack
    // one logical operand (weights, at compile time), and an execute
    // that takes pre-packed buffers and leaves the result packed.

    /// Tensor each operand slot reads, in the order
    /// [`run_storage_into`](Self::run_storage_into) expects
    /// (first-appearance order: lhs, rhs, then fused-tail reads).
    pub fn operand_tensors(&self) -> Vec<TensorId> {
        self.inputs.iter().map(|b| b.tensor).collect()
    }

    /// Packed storage length of operand slot `i`.
    pub fn operand_storage_len(&self, i: usize) -> usize {
        self.inputs[i].packed_len
    }

    /// Pack one logical row-major operand into slot `i`'s storage
    /// layout (identity layouts copy through).
    pub fn pack_operand(&self, i: usize, data: &[f32]) -> Result<Vec<f32>> {
        let buf = self
            .inputs
            .get(i)
            .ok_or_else(|| err!("{}: no operand slot {i}", self.name))?;
        if data.len() != buf.elements {
            bail!(
                "{}: operand {} has {} elements, want {}",
                self.name,
                buf.name,
                data.len(),
                buf.elements
            );
        }
        Ok(if buf.identity {
            data.to_vec()
        } else {
            buf.transform.repack(data, &buf.shape, 0.0)
        })
    }

    /// Tensor whose storage buffer the nest writes (the fused chain's
    /// final output).
    pub fn written_tensor(&self) -> TensorId {
        self.written
    }

    /// Length of the produced storage buffer.
    pub fn output_storage_len(&self) -> usize {
        self.out_len
    }

    /// Execute over already-packed storage buffers, writing the output
    /// tensor's *storage* buffer into `out` (cleared and resized — pass
    /// a recycled buffer to reuse its capacity).
    pub fn run_storage_into(
        &self,
        bufs: &[&[f32]],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let views: Vec<OperandView> =
            bufs.iter().map(|b| OperandView::direct(b)).collect();
        let mut scratch = ExecScratch::default();
        self.run_storage_views_into(&views, out, &mut scratch)
    }

    /// [`run_storage_into`](Self::run_storage_into) over operand
    /// *views*: each slot is raw storage or storage redirected through
    /// a precompiled gather map (a fused Fig. 5a repack edge), and the
    /// caller supplies reusable execution scratch so repeated runs
    /// allocate nothing.
    pub fn run_storage_views_into(
        &self,
        ops: &[OperandView],
        out: &mut Vec<f32>,
        scratch: &mut ExecScratch,
    ) -> Result<()> {
        if ops.len() != self.inputs.len() {
            bail!(
                "{}: want {} packed operands, got {}",
                self.name,
                self.inputs.len(),
                ops.len()
            );
        }
        for (view, buf) in ops.iter().zip(&self.inputs) {
            if view.view_len() != buf.packed_len {
                bail!(
                    "{}: packed operand {} has {} elements, want {}",
                    self.name,
                    buf.name,
                    view.view_len(),
                    buf.packed_len
                );
            }
        }
        self.execute_into(ops, out, scratch)
    }

    /// Fold a storage buffer produced by
    /// [`run_storage_into`](Self::run_storage_into) back to the logical
    /// row-major output.
    pub fn unpack_storage(&self, storage: &[f32]) -> Vec<f32> {
        self.unpack(storage)
    }

    /// Execute with logical row-major `f32` inputs; returns stats only.
    pub fn run(&self, inputs: &[Vec<f32>]) -> Result<RunStats> {
        self.run_with_output(inputs).map(|(stats, _)| stats)
    }

    /// Execute and also return the full logical row-major output.
    pub fn run_with_output(
        &self,
        inputs: &[Vec<f32>],
    ) -> Result<(RunStats, Vec<f32>)> {
        let packed = self.pack_inputs(inputs)?;
        self.run_packed(&packed)
    }

    /// Validate logical inputs and pack each into its operand's
    /// storage layout (untimed: this is the conversion-op /
    /// offline-weight-repack job, charged separately by the cost
    /// model).
    fn pack_inputs(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: want {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        let mut packed: Vec<Vec<f32>> = Vec::with_capacity(inputs.len());
        for (data, buf) in inputs.iter().zip(&self.inputs) {
            if data.len() != buf.elements {
                bail!(
                    "{}: input {} has {} elements, want {}",
                    self.name,
                    buf.name,
                    data.len(),
                    buf.elements
                );
            }
            packed.push(if buf.identity {
                data.clone()
            } else {
                buf.transform.repack(data, &buf.shape, 0.0)
            });
        }
        Ok(packed)
    }

    /// Timed execution over already-packed storage buffers.
    fn run_packed(&self, packed: &[Vec<f32>]) -> Result<(RunStats, Vec<f32>)> {
        let views: Vec<OperandView> =
            packed.iter().map(|v| OperandView::direct(v)).collect();
        let mut scratch = ExecScratch::default();
        let t0 = Instant::now();
        let mut storage = Vec::new();
        self.execute_into(&views, &mut storage, &mut scratch)?;
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;

        let out = self.unpack(&storage);
        let sample = out.iter().take(8).copied().collect();
        Ok((RunStats { latency_ms, output_elems: out.len(), sample }, out))
    }

    /// Median-of-n timed runs (first run excluded as warmup). Inputs
    /// are packed once and reused across iterations.
    pub fn bench(&self, inputs: &[Vec<f32>], n: usize) -> Result<f64> {
        self.bench_with_output(inputs, n).map(|(ms, _)| ms)
    }

    /// [`bench`](Self::bench) that also returns the warmup run's
    /// logical output, so callers checking numerics *and* timing (the
    /// cross-check harness) execute no extra full runs.
    pub fn bench_with_output(
        &self,
        inputs: &[Vec<f32>],
        n: usize,
    ) -> Result<(f64, Vec<f32>)> {
        let packed = self.pack_inputs(inputs)?;
        let (_, out) = self.run_packed(&packed)?; // warmup + numerics
        let mut times = Vec::with_capacity(n.max(1));
        for _ in 0..n.max(1) {
            times.push(self.run_packed(&packed)?.0.latency_ms);
        }
        Ok((crate::util::stats::median(&mut times), out))
    }

    /// Execute the program over packed operand views, producing the
    /// final tensor's storage buffer in `storage` (cleared + zeroed, so
    /// recycled buffers are safe).
    ///
    /// Panic isolation: every execution leg — serial and both parallel
    /// paths — runs under `catch_unwind`, so a worker panic becomes a
    /// typed [`ErrorKind::Panic`](crate::error::ErrorKind) error that
    /// poisons only this request. The executable itself holds no
    /// mutable state across runs (operand packing and weights are the
    /// caller's), so it stays fully re-runnable after an `Err`; the
    /// possibly-torn `storage` buffer is the caller's to discard.
    fn execute_into(
        &self,
        bufs: &[OperandView],
        storage: &mut Vec<f32>,
        scratch: &mut ExecScratch,
    ) -> Result<()> {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let total = self.spatial_total;
        // Honor the `parallel` annotation the way the simulator does:
        // the schedule grants at most `par_extent` parallel units, the
        // host at most `threads`.
        // Capped by `self.threads` (already a usize), so the narrowing
        // conversion can't fail; 1 is the degenerate fallback.
        let workers = usize::try_from(
            (self.threads as u64).min(self.par_extent).min(total).max(1),
        )
        .unwrap_or(1);
        storage.clear();
        storage.resize(self.out_len, 0f32);
        if workers <= 1 {
            return catch_unwind(AssertUnwindSafe(|| {
                self.exec_range(bufs, 0, total, scratch, |a, v| storage[a] = v);
            }))
            .map_err(|p| self.worker_panic(p));
        }
        let chunk = total.div_ceil(workers as u64);
        if self.write_direct {
            // Injective in-bounds write map (proved at compile): each
            // spatial chunk writes a disjoint set of output slots, so
            // workers write the shared buffer in place — no staged
            // `(addr, value)` pairs, no serial scatter.
            let out = SharedOut(storage.as_mut_ptr());
            let results: Vec<Result<()>> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..workers as u64)
                    .map(|w| {
                        let lo = (w * chunk).min(total);
                        let hi = ((w + 1) * chunk).min(total);
                        s.spawn(move || {
                            catch_unwind(AssertUnwindSafe(|| {
                                let mut scratch = ExecScratch::default();
                                self.exec_range(
                                    bufs,
                                    lo,
                                    hi,
                                    &mut scratch,
                                    |a, v| {
                                        // SAFETY: see SharedOut —
                                        // addresses are in-bounds and
                                        // disjoint across workers.
                                        unsafe { *out.0.add(a) = v }
                                    },
                                );
                            }))
                            .map_err(|p| self.worker_panic(p))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|p| Err(self.worker_panic(p))))
                    .collect()
            });
            for r in results {
                r?;
            }
            return Ok(());
        }
        // Fallback (write map not proved injective, e.g. beyond the
        // enumeration cap): workers emit (address, value) pairs merged
        // by one serial scatter — O(out_len) extra work, bounded by the
        // output size.
        let parts: Vec<Result<Vec<(usize, f32)>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers as u64)
                .map(|w| {
                    let lo = (w * chunk).min(total);
                    let hi = ((w + 1) * chunk).min(total);
                    s.spawn(move || {
                        catch_unwind(AssertUnwindSafe(|| {
                            let mut scratch = ExecScratch::default();
                            let mut part = Vec::with_capacity(
                                usize::try_from(hi - lo).unwrap_or(0),
                            );
                            self.exec_range(bufs, lo, hi, &mut scratch, |a, v| {
                                part.push((a, v));
                            });
                            part
                        }))
                        .map_err(|p| self.worker_panic(p))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|p| Err(self.worker_panic(p))))
                .collect()
        });
        // Chunks own disjoint spatial coordinates, so each address is
        // written by exactly one worker; scatter in worker order.
        for part in parts {
            for (a, v) in part? {
                storage[a] = v;
            }
        }
        Ok(())
    }

    /// Convert a caught worker-panic payload into the typed error that
    /// poisons only the affected request.
    fn worker_panic(
        &self,
        p: Box<dyn std::any::Any + Send>,
    ) -> crate::error::Error {
        crate::error::panic_error(p, &format!("{} nest worker", self.name))
    }

    /// Execute spatial iterations `[lo, hi)` of the flattened spatial
    /// space (nest order, last spatial loop least significant),
    /// emitting one `(storage address, value)` per output element.
    fn exec_range<F: FnMut(usize, f32)>(
        &self,
        bufs: &[OperandView],
        lo: u64,
        hi: u64,
        scratch: &mut ExecScratch,
        emit: F,
    ) {
        #[cfg(feature = "fault-inject")]
        crate::faults::maybe_panic(crate::faults::FaultSite::WorkerPanic);
        match (&self.fast, self.mode) {
            (Some(fast), ExecMode::Fast) => {
                self.exec_range_fast(fast, bufs, lo, hi, scratch, emit)
            }
            _ => self.exec_range_bytecode(bufs, lo, hi, scratch, emit),
        }
    }

    /// The stack-bytecode interpreter: re-evaluates the reduction
    /// address codes per MAC. Kept as the reference oracle
    /// ([`ExecMode::Bytecode`]) and the fallback when no fast plan
    /// compiled.
    // Hot path: odometer residues are < loop extents and nest
    // addresses carry compile-time bounds certificates, so the
    // narrowing casts cannot truncate.
    #[allow(clippy::cast_possible_truncation)]
    fn exec_range_bytecode<F: FnMut(usize, f32)>(
        &self,
        bufs: &[OperandView],
        lo: u64,
        hi: u64,
        scratch: &mut ExecScratch,
        mut emit: F,
    ) {
        let ExecScratch { env, stack, .. } = scratch;
        env.clear();
        env.resize(self.env_len, 0);
        // decode `lo` into the spatial odometer
        let mut rem = lo;
        for &(v, e) in self.spatial.iter().rev() {
            env[v] = (rem % e as u64) as i64;
            rem /= e as u64;
        }
        let lhs_view = bufs[self.lhs.buf];
        let rhs_view = bufs[self.rhs.buf];
        for _ in lo..hi {
            // spatial-invariant address parts, hoisted
            let lhs_base = self.lhs.base.eval(env, stack);
            let rhs_base = self.rhs.base.eval(env, stack);
            // reduction loops, nest order (all red vars start at 0 and
            // wrap back to 0 after red_total steps)
            let mut acc = 0f32;
            if self.lhs.has_red || self.rhs.has_red {
                for _ in 0..self.red_total {
                    let a = lhs_view
                        .ld((lhs_base + self.lhs.red.eval(env, stack)) as usize);
                    let b = rhs_view
                        .ld((rhs_base + self.rhs.red.eval(env, stack)) as usize);
                    acc += a * b;
                    for &(v, e) in self.reduction.iter().rev() {
                        env[v] += 1;
                        if env[v] < e {
                            break;
                        }
                        env[v] = 0;
                    }
                }
            } else {
                // degenerate: both operands spatial-only
                let a = lhs_view.ld(lhs_base as usize);
                let b = rhs_view.ld(rhs_base as usize);
                acc = a * b * self.red_total as f32;
            }
            // fused elementwise tail, in registers
            let mut v = acc;
            for stage in &self.tail {
                v = stage.apply(v, bufs, env, stack);
            }
            let addr = self.write.eval(env, stack);
            emit(addr as usize, v);
            // advance the spatial odometer
            for &(sv, e) in self.spatial.iter().rev() {
                env[sv] += 1;
                if env[sv] < e {
                    break;
                }
                env[sv] = 0;
            }
        }
    }

    /// The strided executor: per spatial point, reduction addresses are
    /// cursors advanced by precomputed bumps as the outer reduction
    /// odometer turns, and the trailing contiguous run is an unrolled
    /// dot-product. Accumulation order is identical to the bytecode
    /// interpreter (nest order, one accumulator), so outputs are
    /// bit-identical.
    // Hot path: table cursors stay under TABLE_CAP and stream
    // addresses carry compile-time bounds certificates, so the
    // narrowing casts cannot truncate.
    #[allow(clippy::cast_possible_truncation)]
    fn exec_range_fast<F: FnMut(usize, f32)>(
        &self,
        fast: &FastNest,
        bufs: &[OperandView],
        lo: u64,
        hi: u64,
        scratch: &mut ExecScratch,
        mut emit: F,
    ) {
        let ExecScratch { env, tcur, .. } = scratch;
        env.clear();
        env.resize(self.env_len, 0);
        let n_lt = fast.lhs_red.tables.len();
        let n_tbl = n_lt + fast.rhs_red.tables.len();
        tcur.clear();
        tcur.resize(n_tbl, 0);
        // decode `lo` into the spatial odometer
        let mut rem = lo;
        for &(v, e) in self.spatial.iter().rev() {
            env[v] = (rem % e as u64) as i64;
            rem /= e as u64;
        }
        let lhs_view = bufs[self.lhs.buf];
        let rhs_view = bufs[self.rhs.buf];
        let runs = self.red_total / fast.run_len;
        for _ in lo..hi {
            let mut acc = 0f32;
            if self.lhs.has_red || self.rhs.has_red {
                // cursors at the spatial point (all red vars are 0)
                let mut lc =
                    fast.lhs_base.eval(env) + fast.lhs_red.affine_eval(env);
                let mut rc =
                    fast.rhs_base.eval(env) + fast.rhs_red.affine_eval(env);
                for (j, t) in fast.lhs_red.tables.iter().enumerate() {
                    tcur[j] = t.index_of(env);
                }
                for (j, t) in fast.rhs_red.tables.iter().enumerate() {
                    tcur[n_lt + j] = t.index_of(env);
                }
                for _ in 0..runs {
                    let mut la = lc;
                    for (j, t) in fast.lhs_red.tables.iter().enumerate() {
                        la += t.values[tcur[j] as usize];
                    }
                    let mut ra = rc;
                    for (j, t) in fast.rhs_red.tables.iter().enumerate() {
                        ra += t.values[tcur[n_lt + j] as usize];
                    }
                    dot(
                        lhs_view,
                        rhs_view,
                        la,
                        ra,
                        fast.lhs_stride,
                        fast.rhs_stride,
                        fast.run_len,
                        &mut acc,
                    );
                    // advance the outer reduction odometer one notch
                    // (after the final run every level wraps back to 0,
                    // leaving env clean for the tail/write evals)
                    for (li, &(v, e)) in fast.outer.iter().enumerate().rev()
                    {
                        env[v] += 1;
                        if env[v] < e {
                            lc += fast.lhs_bump[li];
                            rc += fast.rhs_bump[li];
                            for (j, b) in
                                fast.tbl_bump[li].iter().enumerate()
                            {
                                tcur[j] += b;
                            }
                            break;
                        }
                        env[v] = 0;
                    }
                }
            } else {
                // degenerate: both operands spatial-only
                let a = lhs_view.ld(fast.lhs_base.eval(env) as usize);
                let b = rhs_view.ld(fast.rhs_base.eval(env) as usize);
                acc = a * b * self.red_total as f32;
            }
            // fused elementwise tail, in registers
            let mut v = acc;
            for (stage, streams) in self.tail.iter().zip(&fast.tails) {
                v = stage.apply_streams(v, bufs, env, streams);
            }
            emit(fast.write.eval(env) as usize, v);
            // advance the spatial odometer
            for &(sv, e) in self.spatial.iter().rev() {
                env[sv] += 1;
                if env[sv] < e {
                    break;
                }
                env[sv] = 0;
            }
        }
    }

    /// Fold the executed storage buffer back to logical row-major.
    // Gather-map entries and rewritten storage addresses are validated
    // against `storage.len()` when the map is built at compile time.
    #[allow(clippy::cast_possible_truncation)]
    fn unpack(&self, storage: &[f32]) -> Vec<f32> {
        let u = &self.unpack;
        if self.mode == ExecMode::Fast {
            // precompiled gather map: one indexed copy per element
            return u.map.iter().map(|&a| storage[a as usize]).collect();
        }
        let rank = u.logical_shape.len();
        let mut out = vec![0f32; u.logical_len];
        let mut idx = vec![0i64; rank];
        let mut stack: Vec<i64> = Vec::with_capacity(16);
        for (flat, slot) in out.iter_mut().enumerate() {
            let mut rem = flat as i64;
            for d in (0..rank).rev() {
                idx[d] = rem % u.logical_shape[d];
                rem /= u.logical_shape[d];
            }
            let mut saddr = 0i64;
            for (code, &stride) in u.dims.iter().zip(&u.storage_strides) {
                saddr += code.eval(&idx, &mut stack) * stride;
            }
            *slot = storage[saddr as usize];
        }
        out
    }
}

/// A registry of compiled native variants — the [`Backend`] the
/// serving drivers and `alt run --backend native` use.
pub struct NativeRuntime {
    entries: Vec<NativeExecutable>,
}

impl NativeRuntime {
    /// Build from compiled executables (sorted by name).
    pub fn from_executables(mut exes: Vec<NativeExecutable>) -> Self {
        exes.sort_by(|a, b| a.name.cmp(&b.name));
        Self { entries: exes }
    }

    pub fn load(&self, name: &str) -> Result<&NativeExecutable> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| err!("unknown native variant '{name}'"))
    }
}

impl Backend for NativeRuntime {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        let threads =
            self.entries.iter().map(|e| e.threads).max().unwrap_or(1);
        format!("native host interpreter ({threads} threads)")
    }

    fn entries(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    fn input_specs(&self, variant: &str) -> Result<Vec<TensorSpec>> {
        Ok(self.load(variant)?.input_specs())
    }

    fn execute_with(&self, variant: &str, inputs: &[Vec<f32>]) -> Result<RunStats> {
        self.load(variant)?.run(inputs)
    }

    fn bench_variant(&self, variant: &str, seed: u64, iters: usize) -> Result<f64> {
        let exe = self.load(variant)?;
        exe.bench(&exe.seeded_inputs(seed), iters.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn native_executable_is_share_everything_thread_safe() {
        // the serving layer Arc-shares one executable across workers;
        // pin the auto-derived thread-safety so a future field (Rc,
        // RefCell, raw pointer...) can't silently revoke it
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<NativeExecutable>();
        assert_send_sync::<ExecScratch>();
    }

    #[test]
    fn tiny_dense_identity_matches_hand_matmul() {
        // x [2,3] = 1..6, w [3,2] = 1..6 -> [[22,28],[49,64]], +bias
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["M", "K"], &[2, 3]);
        b.dense("fc", x, 2);
        let g = b.finish();
        let dense = g.complex_nodes()[0];
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[2, 2], &[3]);
        let exe = NativeExecutable::compile(
            "gmm_golden",
            &g,
            dense,
            &[dense + 1],
            &layouts,
            &sched,
            16,
            1,
        )
        .unwrap();
        let xs: Vec<f32> = (1..=6).map(|v| v as f32).collect();
        let ws: Vec<f32> = (1..=6).map(|v| v as f32).collect();
        let bias = vec![0.5f32, -1.0];
        let (stats, out) = exe.run_with_output(&[xs, ws, bias]).unwrap();
        assert_eq!(stats.output_elems, 4);
        assert_eq!(out, vec![22.5, 27.0, 49.5, 63.0]);
    }

    /// Random access-like expression over `nvars` loop vars. Div/Mod
    /// divisors are positive constants (mirroring split/unfold codegen;
    /// `Expr::eval` debug-asserts on zero divisors).
    fn rand_expr(rng: &mut crate::util::rng::Rng, depth: usize, nvars: usize) -> Expr {
        if depth == 0 || rng.below(4) == 0 {
            return if rng.below(2) == 0 {
                Expr::Var(rng.below(nvars))
            } else {
                Const(rng.below(7) as i64 - 3)
            };
        }
        let a = rand_expr(rng, depth - 1, nvars);
        let b = rand_expr(rng, depth - 1, nvars);
        match rng.below(6) {
            0 => Expr::add(a, b),
            1 => Expr::sub(a, b),
            2 => Expr::mul(a, b),
            3 => Expr::div(a, Const(1 + rng.below(7) as i64)),
            4 => Expr::rem(a, Const(1 + rng.below(7) as i64)),
            _ => Expr::min(a, b),
        }
    }

    #[test]
    fn stream_analyzer_agrees_with_expr_and_code_eval() {
        let extents = [3i64, 4, 2, 5];
        let total: i64 = extents.iter().product();
        let mut rng = crate::util::rng::Rng::new(0xA17);
        let mut analyzed = 0usize;
        let mut stack: Vec<i64> = Vec::new();
        let mut env = vec![0i64; extents.len()];
        for _ in 0..300 {
            let e = rand_expr(&mut rng, 3, extents.len());
            let s = match Stream::analyze(&e, &extents) {
                Some(s) => s,
                None => continue,
            };
            analyzed += 1;
            let code = Code::compile(&e);
            for flat in 0..total {
                let mut rem = flat;
                for d in (0..extents.len()).rev() {
                    env[d] = rem % extents[d];
                    rem /= extents[d];
                }
                let want = e.eval(&env);
                assert_eq!(s.eval(&env), want, "stream vs expr: {e:?} @ {env:?}");
                assert_eq!(
                    code.eval(&env, &mut stack),
                    want,
                    "code vs expr: {e:?} @ {env:?}"
                );
            }
        }
        assert!(analyzed > 100, "only {analyzed}/300 exprs analyzed");
    }

    #[test]
    fn stream_tabulates_pad_clamp_and_split_idioms() {
        // min(v0, 3) — the pad-clamp shape; (v0*4+v1) div/mod — the
        // split-dim recombination shape (non-affine over two vars).
        let extents = [6i64, 4];
        let clamp = Expr::min(Expr::Var(0), Const(3));
        let recomb = Expr::add(
            Expr::mul(
                Expr::div(
                    Expr::add(Expr::mul(Expr::Var(0), Const(4)), Expr::Var(1)),
                    Const(3),
                ),
                Const(7),
            ),
            Expr::rem(Expr::Var(0), Const(2)),
        );
        for e in [clamp, recomb] {
            let s = Stream::analyze(&e, &extents).expect("analyzable");
            assert!(!s.tables.is_empty(), "{e:?} should need a table");
            for a in 0..extents[0] {
                for b in 0..extents[1] {
                    let env = [a, b];
                    assert_eq!(s.eval(&env), e.eval(&env), "{e:?} @ {env:?}");
                }
            }
        }
    }

    #[test]
    fn analyzer_rejects_oversized_tables() {
        // v0*v1 over extents whose product exceeds TABLE_CAP
        let big = [TABLE_CAP / 2, 3];
        let e = Expr::mul(Expr::Var(0), Expr::Var(1));
        assert!(Stream::analyze(&e, &big).is_none());
        // affine exprs are immune to the cap
        let aff = Expr::add(Expr::mul(Expr::Var(0), Const(9)), Expr::Var(1));
        assert!(Stream::analyze(&aff, &big).is_some());
    }

    #[test]
    fn fast_path_matches_bytecode_on_tiny_dense() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["M", "K"], &[2, 3]);
        b.dense("fc", x, 2);
        let g = b.finish();
        let dense = g.complex_nodes()[0];
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[2, 2], &[3]);
        let mut exe = NativeExecutable::compile(
            "fastcheck",
            &g,
            dense,
            &[dense + 1],
            &layouts,
            &sched,
            16,
            1,
        )
        .unwrap();
        assert!(exe.has_fast_path(), "identity dense must get a fast plan");
        assert_eq!(exe.exec_mode(), ExecMode::Fast);
        let inputs = exe.seeded_inputs(3);
        let (_, fast) = exe.run_with_output(&inputs).unwrap();
        exe.set_exec_mode(ExecMode::Bytecode);
        assert_eq!(exe.exec_mode(), ExecMode::Bytecode);
        let (_, slow) = exe.run_with_output(&inputs).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&fast), bits(&slow), "fast path diverged from oracle");
    }

    #[test]
    fn non_complex_node_is_rejected() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["N", "K"], &[2, 4]);
        let _ = b.relu("r", x);
        let g = b.finish();
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[2, 4], &[1]);
        assert!(NativeExecutable::compile(
            "bad", &g, 0, &[], &layouts, &sched, 16, 1
        )
        .is_err());
    }

    #[test]
    fn input_size_mismatch_is_an_error() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["M", "K"], &[2, 3]);
        b.dense("fc", x, 2);
        let g = b.finish();
        let dense = g.complex_nodes()[0];
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[2, 2], &[3]);
        let exe = NativeExecutable::compile(
            "gmm", &g, dense, &[dense + 1], &layouts, &sched, 16, 1,
        )
        .unwrap();
        assert!(exe.run(&[vec![0.0; 5], vec![0.0; 6], vec![0.0; 2]]).is_err());
        assert!(exe.run(&[vec![0.0; 6]]).is_err());
    }
}
