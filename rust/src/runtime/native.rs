//! Native interpreter backend: execute generated tensor programs on
//! host `f32` buffers with zero external dependencies.
//!
//! [`NativeExecutable::compile`] takes the same inputs as the simulator
//! path — a graph, one complex node plus its fused elementwise tail, a
//! [`LayoutAssignment`] and a [`LoopSchedule`] — lowers them through
//! [`lower_complex`] and *executes the resulting [`Program`] for real*:
//!
//! * every operand buffer is packed into its layout sequence's storage
//!   format ([`LayoutTransform::repack`]), so the interpreter reads and
//!   writes through the exact storage access expressions codegen
//!   emitted — the same expressions the simulator samples;
//! * the loop nest runs output-element-major: for each spatial
//!   coordinate the reduction loops accumulate in nest order, then the
//!   fused elementwise tail (bias/ReLU/…, `compute_at` fusion) applies
//!   in registers and the final tensor is written once. Per-element
//!   accumulation order equals the nest's reduction order, so results
//!   are bit-for-bit independent of how the spatial space is chunked;
//! * `parallel`-annotated programs fan spatial chunks across
//!   `std::thread::scope` workers (the same scoped-pool pattern as
//!   [`crate::engine`]); programs without a `parallel` annotation run
//!   on one thread regardless of `--threads`, so the schedule knob has
//!   a real execution-time effect. Outputs are bit-identical across
//!   thread counts.
//!
//! Access expressions are compiled once to a small stack bytecode
//! ([`Code`]), with the spatial-only part of each address hoisted out
//! of the reduction loop, so the timed loop does data movement and
//! multiply-adds rather than `Arc` tree walks.
//!
//! Reported latency covers execution only; packing/unpacking is the
//! job of conversion operators and is charged separately by the cost
//! model (see `conversion_terms` in the tuner).
//!
//! Unsupported (returns an error at compile): transposed convolutions
//! (zero-expanded inputs) and `store_at`-packed operands.

use std::collections::BTreeSet;
use std::time::Instant;

use crate::codegen::{lower_complex, LayoutAssignment, Program, TensorAccess};
use crate::error::Result;
use crate::expr::{Const, Expr};
use crate::graph::{EltKind, Graph, NodeId, OpKind};
use crate::layout::{LayoutTransform, Primitive};
use crate::loops::{Annotation, LoopKind, LoopSchedule};
use crate::tensor::TensorId;
use crate::{bail, err};

use super::{Backend, RunStats, TensorSpec};

/// One bytecode step of a compiled index expression.
#[derive(Clone, Debug)]
enum Step {
    Var(usize),
    Const(i64),
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Min,
}

/// A compiled index expression: postfix steps over an `i64` stack.
/// Matches [`Expr::eval`] exactly (euclidean div/mod).
#[derive(Clone, Debug)]
struct Code {
    steps: Vec<Step>,
}

impl Code {
    fn compile(e: &Expr) -> Self {
        let mut steps = Vec::new();
        fn push(e: &Expr, out: &mut Vec<Step>) {
            match e {
                Expr::Var(i) => out.push(Step::Var(*i)),
                Expr::Const(c) => out.push(Step::Const(*c)),
                Expr::Add(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Add);
                }
                Expr::Sub(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Sub);
                }
                Expr::Mul(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Mul);
                }
                Expr::Div(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Div);
                }
                Expr::Mod(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Mod);
                }
                Expr::Min(a, b) => {
                    push(a, out);
                    push(b, out);
                    out.push(Step::Min);
                }
            }
        }
        push(e, &mut steps);
        Self { steps }
    }

    fn eval(&self, env: &[i64], stack: &mut Vec<i64>) -> i64 {
        stack.clear();
        for s in &self.steps {
            match s {
                Step::Var(i) => stack.push(env[*i]),
                Step::Const(c) => stack.push(*c),
                op => {
                    let b = stack.pop().expect("code underflow");
                    let a = stack.pop().expect("code underflow");
                    stack.push(match op {
                        Step::Add => a + b,
                        Step::Sub => a - b,
                        Step::Mul => a * b,
                        Step::Div => a.div_euclid(b),
                        Step::Mod => a.rem_euclid(b),
                        Step::Min => a.min(b),
                        _ => unreachable!(),
                    });
                }
            }
        }
        stack.pop().expect("empty code")
    }
}

/// Row-major strides of a storage shape.
fn strides_of(shape: &[i64]) -> Vec<i64> {
    let mut strides = vec![1i64; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    strides
}

/// Flat-address expression of an access (sum of dim-index * stride).
fn flat_expr(acc: &TensorAccess) -> Expr {
    Expr::flatten(&acc.idx, &acc.storage_shape)
}

/// A MAC operand read with the spatial-only address part hoisted:
/// `addr = base(spatial env) + red(full env)`.
#[derive(Clone, Debug)]
struct MacRead {
    buf: usize,
    base: Code,
    red: Code,
    has_red: bool,
}

impl MacRead {
    fn build(buf: usize, acc: &TensorAccess, red_vars: &BTreeSet<usize>) -> Self {
        let strides = strides_of(&acc.storage_shape);
        let mut base = Const(0);
        let mut red = Const(0);
        for (idx, &s) in acc.idx.iter().zip(&strides) {
            let term = Expr::mul(idx.clone(), Const(s));
            if idx.vars().iter().any(|v| red_vars.contains(v)) {
                red = Expr::add(red, term);
            } else {
                base = Expr::add(base, term);
            }
        }
        let has_red = !matches!(red, Const(0));
        Self { buf, base: Code::compile(&base), red: Code::compile(&red), has_red }
    }
}

/// How a fused elementwise stage combines its operands.
#[derive(Clone, Copy, Debug)]
enum TailKind {
    Sum,
    Product,
    Relu,
    Relu6,
    Sigmoid,
    Gelu,
    Tanh,
    Identity,
}

#[derive(Clone, Debug)]
enum TailOperand {
    /// The running value of the fusion chain (the complex op's result
    /// flowing through the tail in registers).
    Chain,
    /// A read of an external operand at its storage address.
    Read { buf: usize, addr: Code },
}

#[derive(Clone, Debug)]
struct TailStage {
    kind: TailKind,
    operands: Vec<TailOperand>,
}

impl TailStage {
    #[inline]
    fn apply(
        &self,
        chain: f32,
        bufs: &[&[f32]],
        env: &[i64],
        stack: &mut Vec<i64>,
    ) -> f32 {
        let val = |op: &TailOperand| -> f32 {
            match op {
                TailOperand::Chain => chain,
                TailOperand::Read { buf, addr } => {
                    bufs[*buf][addr.eval(env, stack) as usize]
                }
            }
        };
        match self.kind {
            TailKind::Sum => {
                let mut s = val(&self.operands[0]);
                for op in &self.operands[1..] {
                    s += val(op);
                }
                s
            }
            TailKind::Product => {
                let mut p = val(&self.operands[0]);
                for op in &self.operands[1..] {
                    p *= val(op);
                }
                p
            }
            TailKind::Relu => val(&self.operands[0]).max(0.0),
            TailKind::Relu6 => val(&self.operands[0]).clamp(0.0, 6.0),
            TailKind::Sigmoid => {
                let x = val(&self.operands[0]);
                1.0 / (1.0 + (-x).exp())
            }
            TailKind::Gelu => {
                let x = val(&self.operands[0]);
                0.5 * x
                    * (1.0
                        + (0.797_884_6_f32 * (x + 0.044_715 * x * x * x))
                            .tanh())
            }
            TailKind::Tanh => val(&self.operands[0]).tanh(),
            TailKind::Identity => val(&self.operands[0]),
        }
    }
}

/// One logical input the caller must provide, plus its packing recipe.
#[derive(Debug)]
struct InputBuf {
    tensor: TensorId,
    name: String,
    /// Logical row-major shape the caller provides data in.
    shape: Vec<i64>,
    elements: usize,
    /// Storage elements after the layout sequence (what
    /// [`NativeExecutable::run_storage_into`] expects for this slot).
    packed_len: usize,
    transform: LayoutTransform,
    identity: bool,
}

/// Forward mapping logical index → storage flat address, used to fold
/// the executed storage buffer back to a logical row-major output.
#[derive(Debug)]
struct UnpackPlan {
    logical_shape: Vec<i64>,
    logical_len: usize,
    /// One code per storage dim, over logical-dim vars `0..rank`.
    dims: Vec<Code>,
    storage_strides: Vec<i64>,
}

/// A compiled tensor-program variant, ready to execute on the host.
#[derive(Debug)]
pub struct NativeExecutable {
    name: String,
    program: Program,
    threads: usize,
    env_len: usize,
    /// (loop var, extent) of spatial loops, nest order.
    spatial: Vec<(usize, i64)>,
    /// (loop var, extent) of reduction loops, nest order.
    reduction: Vec<(usize, i64)>,
    spatial_total: u64,
    red_total: u64,
    inputs: Vec<InputBuf>,
    lhs: MacRead,
    rhs: MacRead,
    tail: Vec<TailStage>,
    write: Code,
    out_len: usize,
    /// Tensor whose storage buffer the nest writes (the last fused
    /// node's output, or the complex op's own output without a tail).
    written: TensorId,
    unpack: UnpackPlan,
    /// Product of `parallel`-annotated spatial loop extents (1 when
    /// the schedule grants no parallelism).
    par_extent: u64,
}

fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

impl NativeExecutable {
    /// Lower `node` (+ fused tail) under `layouts`/`sched` and compile
    /// the resulting program for host execution. `threads == 0` means
    /// all available cores; threads only apply to `parallel`-annotated
    /// programs.
    #[allow(clippy::too_many_arguments)]
    pub fn compile(
        name: &str,
        graph: &Graph,
        node_id: NodeId,
        fused_tail: &[NodeId],
        layouts: &LayoutAssignment,
        sched: &LoopSchedule,
        simd_lanes: i64,
        threads: usize,
    ) -> Result<Self> {
        let node = graph.node(node_id);
        match &node.kind {
            OpKind::Conv { transposed: true, .. } => {
                bail!("{name}: transposed convs are not supported by the native backend")
            }
            OpKind::Conv { .. } | OpKind::Matmul | OpKind::Dense => {}
            other => bail!("{name}: not a complex op: {other:?}"),
        }
        if let Some(&w) = node.inputs.get(1) {
            let seq = layouts.get(w);
            if seq.prims.iter().any(|p| {
                matches!(p, Primitive::StoreAt { .. } | Primitive::DecoupleAt { .. })
            }) {
                bail!("{name}: store_at-packed operands are not supported by the native backend");
            }
        }

        let program =
            lower_complex(graph, node_id, layouts, sched, fused_tail, simd_lanes);

        // Loop variable tables (nest order). build_nest allocates var
        // ids in push order, but derive everything from the loop list.
        let env_len = program
            .loops
            .iter()
            .map(|l| l.var + 1)
            .max()
            .ok_or_else(|| err!("{name}: empty loop nest"))?;
        let spatial: Vec<(usize, i64)> = program
            .loops
            .iter()
            .filter(|l| l.kind == LoopKind::Spatial)
            .map(|l| (l.var, l.extent))
            .collect();
        let reduction: Vec<(usize, i64)> = program
            .loops
            .iter()
            .filter(|l| l.kind == LoopKind::Reduction)
            .map(|l| (l.var, l.extent))
            .collect();
        let red_vars: BTreeSet<usize> = reduction.iter().map(|&(v, _)| v).collect();
        let spatial_total: u64 =
            spatial.iter().map(|&(_, e)| e as u64).product();
        let red_total: u64 = reduction.iter().map(|&(_, e)| e as u64).product();

        // Access layout (the lower_complex contract):
        //   [0] complex-op output (the write iff no fused tail)
        //   [1] lhs operand, [2] rhs operand
        //   [3..] fused-tail external reads, then the final write.
        let accs = &program.accesses;
        if accs.len() < 3 {
            bail!("{name}: program has {} accesses, want >= 3", accs.len());
        }
        let write_idx = if fused_tail.is_empty() { 0 } else { accs.len() - 1 };
        if !accs[write_idx].is_write {
            bail!("{name}: unexpected write-access placement");
        }
        if accs[1].is_write || accs[2].is_write {
            bail!("{name}: unexpected operand write");
        }
        if accs[1].tensor != node.inputs[0] || accs[2].tensor != node.inputs[1] {
            bail!("{name}: operand accesses do not match node inputs");
        }
        let spatial_only = |acc: &TensorAccess| -> bool {
            acc.idx
                .iter()
                .all(|e| e.vars().iter().all(|v| !red_vars.contains(v)))
        };
        if !spatial_only(&accs[write_idx]) {
            bail!("{name}: write access depends on reduction vars");
        }

        // Input buffers, keyed by tensor, in first-appearance order.
        let mut inputs: Vec<InputBuf> = Vec::new();
        let mut buf_of = |t: TensorId, acc: &TensorAccess| -> Result<usize> {
            if let Some(i) = inputs.iter().position(|b| b.tensor == t) {
                return Ok(i);
            }
            let ten = graph.tensor(t);
            let seq = layouts.get_for(node_id, t);
            let tf = LayoutTransform::new(ten.shape.clone(), &seq);
            if tf.final_shape() != acc.storage_shape.as_slice() {
                bail!(
                    "{name}: storage shape mismatch for {}: {:?} vs {:?}",
                    ten.name,
                    tf.final_shape(),
                    acc.storage_shape
                );
            }
            inputs.push(InputBuf {
                tensor: t,
                name: ten.name.clone(),
                shape: ten.shape.clone(),
                elements: ten.elements() as usize,
                packed_len: tf.final_shape().iter().product::<i64>() as usize,
                identity: seq.is_identity(),
                transform: tf,
            });
            Ok(inputs.len() - 1)
        };

        let lhs_buf = buf_of(node.inputs[0], &accs[1])?;
        let rhs_buf = buf_of(node.inputs[1], &accs[2])?;
        let lhs = MacRead::build(lhs_buf, &accs[1], &red_vars);
        let rhs = MacRead::build(rhs_buf, &accs[2], &red_vars);

        // Fused tail: replay lower_complex's operand walk so external
        // reads line up with accesses[3..] (store_at operands, which
        // lower_complex would skip, were rejected above).
        let mut next_acc = 3usize;
        let tail_end = if fused_tail.is_empty() { 3 } else { accs.len() - 1 };
        let mut tail: Vec<TailStage> = Vec::new();
        for &tid in fused_tail {
            let tnode = graph.node(tid);
            let kind = match &tnode.kind {
                OpKind::BiasAdd => TailKind::Sum,
                OpKind::Eltwise { kind, .. } => match kind {
                    EltKind::Add => TailKind::Sum,
                    EltKind::Mul => TailKind::Product,
                    EltKind::Relu => TailKind::Relu,
                    EltKind::Relu6 => TailKind::Relu6,
                    EltKind::Sigmoid => TailKind::Sigmoid,
                    EltKind::Gelu => TailKind::Gelu,
                    EltKind::Tanh => TailKind::Tanh,
                    EltKind::Identity => TailKind::Identity,
                },
                other => bail!(
                    "{name}: unsupported fused tail op {other:?} in {}",
                    tnode.name
                ),
            };
            let mut operands = Vec::new();
            for &inp in &tnode.inputs {
                let prod = graph.tensor(inp).producer;
                let is_chain = prod == Some(node_id)
                    || prod.map(|p| fused_tail.contains(&p)).unwrap_or(false);
                if is_chain {
                    operands.push(TailOperand::Chain);
                    continue;
                }
                if next_acc >= tail_end {
                    bail!("{name}: ran out of tail accesses for {}", tnode.name);
                }
                let acc = &accs[next_acc];
                if acc.tensor != inp {
                    bail!(
                        "{name}: tail access order mismatch (t{} vs t{})",
                        acc.tensor,
                        inp
                    );
                }
                if !spatial_only(acc) {
                    bail!("{name}: tail read depends on reduction vars");
                }
                let buf = buf_of(inp, acc)?;
                operands.push(TailOperand::Read {
                    buf,
                    addr: Code::compile(&flat_expr(acc)),
                });
                next_acc += 1;
            }
            tail.push(TailStage { kind, operands });
        }
        if next_acc != tail_end {
            bail!(
                "{name}: {} tail accesses left unconsumed",
                tail_end - next_acc
            );
        }

        // Final write + logical unpack plan.
        let write_acc = &accs[write_idx];
        let out_len: i64 = write_acc.storage_shape.iter().product();
        if out_len <= 0 || out_len as u64 > u32::MAX as u64 {
            bail!("{name}: output storage of {out_len} elements out of range");
        }
        let fin = if let Some(&last) = fused_tail.last() {
            graph.node(last).output
        } else {
            node.output
        };
        let fin_t = graph.tensor(fin);
        let fin_tf = LayoutTransform::new(fin_t.shape.clone(), &layouts.get(fin));
        if fin_tf.final_shape() != write_acc.storage_shape.as_slice() {
            bail!("{name}: output storage shape mismatch");
        }
        let logical_acc: Vec<crate::layout::DimAccess> = (0..fin_t.rank())
            .map(|d| crate::layout::DimAccess::Simple(Expr::Var(d)))
            .collect();
        let unpack = UnpackPlan {
            logical_shape: fin_t.shape.clone(),
            logical_len: fin_t.elements() as usize,
            dims: fin_tf
                .rewrite_access(&logical_acc)
                .iter()
                .map(|a| Code::compile(&a.to_expr()))
                .collect(),
            storage_strides: strides_of(&write_acc.storage_shape),
        };

        // Parallel width granted by the schedule: the product of the
        // `parallel`-annotated spatial loop extents — the same quantity
        // the simulator's scaling model caps speedup at, so native
        // execution and simulation honor the annotation identically.
        let par_extent: u64 = program
            .loops
            .iter()
            .filter(|l| l.ann == Annotation::Parallel && l.kind == LoopKind::Spatial)
            .map(|l| l.extent as u64)
            .product();

        Ok(Self {
            name: name.to_string(),
            threads: resolve_threads(threads),
            env_len,
            spatial,
            reduction,
            spatial_total,
            red_total,
            inputs,
            lhs,
            rhs,
            tail,
            write: Code::compile(&flat_expr(write_acc)),
            out_len: out_len as usize,
            written: fin,
            unpack,
            par_extent,
            program,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The lowered program this executable runs (what the simulator
    /// scores — the cross-check compares both on the same object).
    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether this program carries a live `parallel` annotation (and
    /// therefore actually fans out across threads).
    pub fn is_parallel(&self) -> bool {
        self.par_extent > 1
    }

    /// Logical input specs, in the order [`run`](Self::run) expects.
    pub fn input_specs(&self) -> Vec<TensorSpec> {
        self.inputs
            .iter()
            .map(|b| TensorSpec {
                dtype: "float32".into(),
                shape: b.shape.iter().map(|&d| d as usize).collect(),
            })
            .collect()
    }

    /// Deterministic seeded inputs matching [`input_specs`](Self::input_specs).
    pub fn seeded_inputs(&self, seed: u64) -> Vec<Vec<f32>> {
        super::seeded_inputs(&self.input_specs(), seed)
    }

    // ---- storage-level entry points (the multi-op execution plan) ----
    //
    // A whole-model plan keeps inter-op buffers in their *storage*
    // layouts and feeds them straight back into downstream nests, so it
    // bypasses the logical pack/unpack round trip `run` performs per
    // call. The methods below expose the operand contract: which tensor
    // each slot reads, how long its packed buffer must be, how to pack
    // one logical operand (weights, at compile time), and an execute
    // that takes pre-packed buffers and leaves the result packed.

    /// Tensor each operand slot reads, in the order
    /// [`run_storage_into`](Self::run_storage_into) expects
    /// (first-appearance order: lhs, rhs, then fused-tail reads).
    pub fn operand_tensors(&self) -> Vec<TensorId> {
        self.inputs.iter().map(|b| b.tensor).collect()
    }

    /// Packed storage length of operand slot `i`.
    pub fn operand_storage_len(&self, i: usize) -> usize {
        self.inputs[i].packed_len
    }

    /// Pack one logical row-major operand into slot `i`'s storage
    /// layout (identity layouts copy through).
    pub fn pack_operand(&self, i: usize, data: &[f32]) -> Result<Vec<f32>> {
        let buf = self
            .inputs
            .get(i)
            .ok_or_else(|| err!("{}: no operand slot {i}", self.name))?;
        if data.len() != buf.elements {
            bail!(
                "{}: operand {} has {} elements, want {}",
                self.name,
                buf.name,
                data.len(),
                buf.elements
            );
        }
        Ok(if buf.identity {
            data.to_vec()
        } else {
            buf.transform.repack(data, &buf.shape, 0.0)
        })
    }

    /// Tensor whose storage buffer the nest writes (the fused chain's
    /// final output).
    pub fn written_tensor(&self) -> TensorId {
        self.written
    }

    /// Length of the produced storage buffer.
    pub fn output_storage_len(&self) -> usize {
        self.out_len
    }

    /// Execute over already-packed storage buffers, writing the output
    /// tensor's *storage* buffer into `out` (cleared and resized — pass
    /// a recycled buffer to reuse its capacity).
    pub fn run_storage_into(
        &self,
        bufs: &[&[f32]],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if bufs.len() != self.inputs.len() {
            bail!(
                "{}: want {} packed operands, got {}",
                self.name,
                self.inputs.len(),
                bufs.len()
            );
        }
        for (data, buf) in bufs.iter().zip(&self.inputs) {
            if data.len() != buf.packed_len {
                bail!(
                    "{}: packed operand {} has {} elements, want {}",
                    self.name,
                    buf.name,
                    data.len(),
                    buf.packed_len
                );
            }
        }
        self.execute_into(bufs, out);
        Ok(())
    }

    /// Fold a storage buffer produced by
    /// [`run_storage_into`](Self::run_storage_into) back to the logical
    /// row-major output.
    pub fn unpack_storage(&self, storage: &[f32]) -> Vec<f32> {
        self.unpack(storage)
    }

    /// Execute with logical row-major `f32` inputs; returns stats only.
    pub fn run(&self, inputs: &[Vec<f32>]) -> Result<RunStats> {
        self.run_with_output(inputs).map(|(stats, _)| stats)
    }

    /// Execute and also return the full logical row-major output.
    pub fn run_with_output(
        &self,
        inputs: &[Vec<f32>],
    ) -> Result<(RunStats, Vec<f32>)> {
        let packed = self.pack_inputs(inputs)?;
        Ok(self.run_packed(&packed))
    }

    /// Validate logical inputs and pack each into its operand's
    /// storage layout (untimed: this is the conversion-op /
    /// offline-weight-repack job, charged separately by the cost
    /// model).
    fn pack_inputs(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: want {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        let mut packed: Vec<Vec<f32>> = Vec::with_capacity(inputs.len());
        for (data, buf) in inputs.iter().zip(&self.inputs) {
            if data.len() != buf.elements {
                bail!(
                    "{}: input {} has {} elements, want {}",
                    self.name,
                    buf.name,
                    data.len(),
                    buf.elements
                );
            }
            packed.push(if buf.identity {
                data.clone()
            } else {
                buf.transform.repack(data, &buf.shape, 0.0)
            });
        }
        Ok(packed)
    }

    /// Timed execution over already-packed storage buffers.
    fn run_packed(&self, packed: &[Vec<f32>]) -> (RunStats, Vec<f32>) {
        let refs: Vec<&[f32]> = packed.iter().map(|v| v.as_slice()).collect();
        let t0 = Instant::now();
        let mut storage = Vec::new();
        self.execute_into(&refs, &mut storage);
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;

        let out = self.unpack(&storage);
        let sample = out.iter().take(8).copied().collect();
        (RunStats { latency_ms, output_elems: out.len(), sample }, out)
    }

    /// Median-of-n timed runs (first run excluded as warmup). Inputs
    /// are packed once and reused across iterations.
    pub fn bench(&self, inputs: &[Vec<f32>], n: usize) -> Result<f64> {
        self.bench_with_output(inputs, n).map(|(ms, _)| ms)
    }

    /// [`bench`](Self::bench) that also returns the warmup run's
    /// logical output, so callers checking numerics *and* timing (the
    /// cross-check harness) execute no extra full runs.
    pub fn bench_with_output(
        &self,
        inputs: &[Vec<f32>],
        n: usize,
    ) -> Result<(f64, Vec<f32>)> {
        let packed = self.pack_inputs(inputs)?;
        let (_, out) = self.run_packed(&packed); // warmup + numerics
        let mut times = Vec::with_capacity(n.max(1));
        for _ in 0..n.max(1) {
            times.push(self.run_packed(&packed).0.latency_ms);
        }
        Ok((crate::util::stats::median(&mut times), out))
    }

    /// Execute the program over packed storage buffers, producing the
    /// final tensor's storage buffer in `storage` (cleared + zeroed, so
    /// recycled buffers are safe).
    fn execute_into(&self, bufs: &[&[f32]], storage: &mut Vec<f32>) {
        let total = self.spatial_total;
        // Honor the `parallel` annotation the way the simulator does:
        // the schedule grants at most `par_extent` parallel units, the
        // host at most `threads`.
        let workers = (self.threads as u64)
            .min(self.par_extent)
            .min(total)
            .max(1) as usize;
        storage.clear();
        storage.resize(self.out_len, 0f32);
        if workers <= 1 {
            self.exec_range(bufs, 0, total, |a, v| storage[a as usize] = v);
            return;
        }
        // Workers emit (address, value) pairs merged by one serial
        // scatter: O(out_len) extra work inside the timed region, a
        // deliberate trade for safe disjoint-write parallelism. It is
        // bounded by the output size — two orders of magnitude below
        // the MAC loop for every shipped variant — so it cannot
        // meaningfully compress a parallel variant's measured edge.
        let chunk = total.div_ceil(workers as u64);
        let parts: Vec<Vec<(u32, f32)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers as u64)
                .map(|w| {
                    let lo = (w * chunk).min(total);
                    let hi = ((w + 1) * chunk).min(total);
                    s.spawn(move || {
                        let mut part =
                            Vec::with_capacity((hi - lo) as usize);
                        self.exec_range(bufs, lo, hi, |a, v| {
                            part.push((a, v));
                        });
                        part
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker")).collect()
        });
        // Chunks own disjoint spatial coordinates, so each address is
        // written by exactly one worker; scatter in worker order.
        for part in parts {
            for (a, v) in part {
                storage[a as usize] = v;
            }
        }
    }

    /// Execute spatial iterations `[lo, hi)` of the flattened spatial
    /// space (nest order, last spatial loop least significant),
    /// emitting one `(storage address, value)` per output element.
    fn exec_range<F: FnMut(u32, f32)>(
        &self,
        bufs: &[&[f32]],
        lo: u64,
        hi: u64,
        mut emit: F,
    ) {
        let mut env = vec![0i64; self.env_len];
        let mut stack: Vec<i64> = Vec::with_capacity(16);
        // decode `lo` into the spatial odometer
        let mut rem = lo;
        for &(v, e) in self.spatial.iter().rev() {
            env[v] = (rem % e as u64) as i64;
            rem /= e as u64;
        }
        let lhs_buf = &bufs[self.lhs.buf];
        let rhs_buf = &bufs[self.rhs.buf];
        for _ in lo..hi {
            // spatial-invariant address parts, hoisted
            let lhs_base = self.lhs.base.eval(&env, &mut stack);
            let rhs_base = self.rhs.base.eval(&env, &mut stack);
            // reduction loops, nest order (all red vars start at 0 and
            // wrap back to 0 after red_total steps)
            let mut acc = 0f32;
            if self.lhs.has_red || self.rhs.has_red {
                for _ in 0..self.red_total {
                    let a = lhs_buf
                        [(lhs_base + self.lhs.red.eval(&env, &mut stack)) as usize];
                    let b = rhs_buf
                        [(rhs_base + self.rhs.red.eval(&env, &mut stack)) as usize];
                    acc += a * b;
                    for &(v, e) in self.reduction.iter().rev() {
                        env[v] += 1;
                        if env[v] < e {
                            break;
                        }
                        env[v] = 0;
                    }
                }
            } else {
                // degenerate: both operands spatial-only
                let a = lhs_buf[lhs_base as usize];
                let b = rhs_buf[rhs_base as usize];
                acc = a * b * self.red_total as f32;
            }
            // fused elementwise tail, in registers
            let mut v = acc;
            for stage in &self.tail {
                v = stage.apply(v, bufs, &env, &mut stack);
            }
            let addr = self.write.eval(&env, &mut stack);
            emit(addr as u32, v);
            // advance the spatial odometer
            for &(sv, e) in self.spatial.iter().rev() {
                env[sv] += 1;
                if env[sv] < e {
                    break;
                }
                env[sv] = 0;
            }
        }
    }

    /// Fold the executed storage buffer back to logical row-major.
    fn unpack(&self, storage: &[f32]) -> Vec<f32> {
        let u = &self.unpack;
        let rank = u.logical_shape.len();
        let mut out = vec![0f32; u.logical_len];
        let mut idx = vec![0i64; rank];
        let mut stack: Vec<i64> = Vec::with_capacity(16);
        for (flat, slot) in out.iter_mut().enumerate() {
            let mut rem = flat as i64;
            for d in (0..rank).rev() {
                idx[d] = rem % u.logical_shape[d];
                rem /= u.logical_shape[d];
            }
            let mut saddr = 0i64;
            for (code, &stride) in u.dims.iter().zip(&u.storage_strides) {
                saddr += code.eval(&idx, &mut stack) * stride;
            }
            *slot = storage[saddr as usize];
        }
        out
    }
}

/// A registry of compiled native variants — the [`Backend`] the
/// serving drivers and `alt run --backend native` use.
pub struct NativeRuntime {
    entries: Vec<NativeExecutable>,
}

impl NativeRuntime {
    /// Build from compiled executables (sorted by name).
    pub fn from_executables(mut exes: Vec<NativeExecutable>) -> Self {
        exes.sort_by(|a, b| a.name.cmp(&b.name));
        Self { entries: exes }
    }

    pub fn load(&self, name: &str) -> Result<&NativeExecutable> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| err!("unknown native variant '{name}'"))
    }
}

impl Backend for NativeRuntime {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        let threads =
            self.entries.iter().map(|e| e.threads).max().unwrap_or(1);
        format!("native host interpreter ({threads} threads)")
    }

    fn entries(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    fn input_specs(&self, variant: &str) -> Result<Vec<TensorSpec>> {
        Ok(self.load(variant)?.input_specs())
    }

    fn execute_with(&self, variant: &str, inputs: &[Vec<f32>]) -> Result<RunStats> {
        self.load(variant)?.run(inputs)
    }

    fn bench_variant(&self, variant: &str, seed: u64, iters: usize) -> Result<f64> {
        let exe = self.load(variant)?;
        exe.bench(&exe.seeded_inputs(seed), iters.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn tiny_dense_identity_matches_hand_matmul() {
        // x [2,3] = 1..6, w [3,2] = 1..6 -> [[22,28],[49,64]], +bias
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["M", "K"], &[2, 3]);
        b.dense("fc", x, 2);
        let g = b.finish();
        let dense = g.complex_nodes()[0];
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[2, 2], &[3]);
        let exe = NativeExecutable::compile(
            "gmm_golden",
            &g,
            dense,
            &[dense + 1],
            &layouts,
            &sched,
            16,
            1,
        )
        .unwrap();
        let xs: Vec<f32> = (1..=6).map(|v| v as f32).collect();
        let ws: Vec<f32> = (1..=6).map(|v| v as f32).collect();
        let bias = vec![0.5f32, -1.0];
        let (stats, out) = exe.run_with_output(&[xs, ws, bias]).unwrap();
        assert_eq!(stats.output_elems, 4);
        assert_eq!(out, vec![22.5, 27.0, 49.5, 63.0]);
    }

    #[test]
    fn non_complex_node_is_rejected() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["N", "K"], &[2, 4]);
        let _ = b.relu("r", x);
        let g = b.finish();
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[2, 4], &[1]);
        assert!(NativeExecutable::compile(
            "bad", &g, 0, &[], &layouts, &sched, 16, 1
        )
        .is_err());
    }

    #[test]
    fn input_size_mismatch_is_an_error() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &["M", "K"], &[2, 3]);
        b.dense("fc", x, 2);
        let g = b.finish();
        let dense = g.complex_nodes()[0];
        let layouts = LayoutAssignment::identity(&g);
        let sched = LoopSchedule::identity(&[2, 2], &[3]);
        let exe = NativeExecutable::compile(
            "gmm", &g, dense, &[dense + 1], &layouts, &sched, 16, 1,
        )
        .unwrap();
        assert!(exe.run(&[vec![0.0; 5], vec![0.0; 6], vec![0.0; 2]]).is_err());
        assert!(exe.run(&[vec![0.0; 6]]).is_err());
    }
}
