//! Case-study layout variants + the sim-vs-native cross-check harness.
//!
//! The §7.3.3 case study compares storage layouts for the ResNet-18
//! layer-1 convolution (+ fused bias/ReLU): vendor-style NHWO and NOHW
//! baselines against ALT's jointly tuned tiled configuration (layout
//! tiling + vectorize/parallel/unroll loop annotations, optionally
//! with the `unfold` overlapped input tiling of Eq. (1)). This module
//! builds those variants as *native executables* so the ranking the
//! simulated device produces can be cross-checked against genuine host
//! execution — the real-host validation leg of the stack, now tier-1.
//!
//! Two scales share one variant vocabulary: [`Scale::Full`] is the
//! paper's layer (230²×3 pre-padded input → 112²×64), used by `alt run
//! --backend native` and the runtime bench; [`Scale::Small`] is a
//! proportionally shrunk copy that keeps `cargo test` fast.
//!
//! [`cross_check`] executes every case variant natively, simulates the
//! same lowered programs on a *host-matched* profile (cores clamped to
//! the executor's thread count), and reports Spearman correlation plus
//! a tolerance-aware rank-agreement verdict: the orders agree when no
//! pair the simulator separates by ≥2× is inverted by ≥25% natively,
//! and the natively fastest variant is in the simulator's top group.

use crate::codegen::LayoutAssignment;
use crate::error::Result;
use crate::graph::{Graph, GraphBuilder, NodeId};
use crate::layout::{LayoutSeq, Primitive};
use crate::loops::LoopSchedule;
use crate::sim::{simulate_program, HwProfile};
use crate::util::stats::spearman;

use super::native::{NativeExecutable, NativeRuntime};

/// Problem size of the case-study variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Shrunk copy for tier-1 tests (28²×16 output, 3×3×8 reduction).
    Small,
    /// The paper's layer (112²×64 output, 7×7×3 reduction).
    Full,
}

impl Scale {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "small" => Some(Scale::Small),
            "full" => Some(Scale::Full),
            _ => None,
        }
    }

    /// (pre-padded input H/W, in-channels, out-channels, kernel, stride,
    /// layout tiles ht/wt/ot)
    fn params(self) -> (i64, i64, i64, i64, i64, i64, i64, i64) {
        match self {
            Scale::Small => (30, 8, 16, 3, 1, 4, 4, 8),
            Scale::Full => (230, 3, 64, 7, 2, 4, 16, 16),
        }
    }
}

/// The case-study graph at one scale: a pre-padded conv + fused
/// bias/ReLU (node ids: conv 0, bias 1, relu 2).
pub fn case_graph(scale: Scale) -> Graph {
    let (h, ci, o, k, s, ..) = scale.params();
    let mut b = GraphBuilder::new(match scale {
        Scale::Small => "case_study_small",
        Scale::Full => "case_study_native",
    });
    let x = b.input("x", &["N", "H", "W", "I"], &[1, h, h, ci]);
    b.conv_bias_relu("conv1", x, o, k, s, 0);
    b.finish()
}

/// One named layout/schedule point of the case study.
struct VariantDef {
    name: &'static str,
    layouts: LayoutAssignment,
    sched: LoopSchedule,
}

fn out_tensors(g: &Graph, node: NodeId, fused: &[NodeId]) -> Vec<usize> {
    std::iter::once(g.node(node).output)
        .chain(fused.iter().map(|&f| g.node(f).output))
        .collect()
}

fn case_variant_defs(scale: Scale, g: &Graph) -> Vec<VariantDef> {
    let (_, ci, o, k, s, ht, wt, ot) = scale.params();
    let conv = g.complex_nodes()[0];
    let node = g.node(conv);
    let x = node.inputs[0];
    let fused = [conv + 1, conv + 2];
    let outs = out_tensors(g, conv, &fused);
    let out_shape = g.tensor(node.output).shape.clone();
    let (hh, ww) = (out_shape[1], out_shape[2]);
    let red = vec![ci, k, k];

    // NHWO: the logical channels-last layout, untiled serial loops.
    let nhwo = VariantDef {
        name: "case_nhwo",
        layouts: LayoutAssignment::identity(g),
        sched: LoopSchedule::identity(&out_shape, &red),
    };

    // NOHW: channels-first activations (input and output), untiled.
    let nohw = {
        let mut layouts = LayoutAssignment::identity(g);
        let mut seq = LayoutSeq::new();
        seq.push(Primitive::reorder(&[0, 3, 1, 2]));
        for &t in &outs {
            layouts.set(t, seq.clone());
        }
        layouts.set(x, seq.clone());
        VariantDef {
            name: "case_nohw",
            layouts,
            sched: LoopSchedule::identity(&[1, o, hh, ww], &red),
        }
    };

    // ALT tiled: N (H/ht) (W/wt) (O/ot) ht wt ot output storage with
    // the tuned loop annotations (vectorize innermost tile, parallel
    // block loops, unrolled reduction tiles).
    let tiled_seq = {
        let mut seq = LayoutSeq::new();
        seq.push(Primitive::split(1, &[hh / ht, ht]))
            .push(Primitive::split(3, &[ww / wt, wt]))
            .push(Primitive::split(5, &[o / ot, ot]))
            .push(Primitive::reorder(&[0, 1, 3, 5, 2, 4, 6]));
        seq
    };
    let tiled_sched = LoopSchedule {
        spatial_tiles: vec![1, 1, 1, 1, ht, wt, ot],
        reduction_tiles: red.clone(),
        inner_perm: (0..7).collect(),
        vectorize: true,
        parallel: 4,
        unroll: 8,
        fuse_eltwise: true,
    };
    let tiled = {
        let mut layouts = LayoutAssignment::identity(g);
        for &t in &outs {
            layouts.set(t, tiled_seq.clone());
        }
        VariantDef { name: "case_tiled", layouts, sched: tiled_sched.clone() }
    };

    // ALT tiled + Eq. (1) overlapped input tiling: unfold H and W so
    // each output tile reads one contiguous input block.
    let tiled_unfold = {
        let mut layouts = LayoutAssignment::identity(g);
        for &t in &outs {
            layouts.set(t, tiled_seq.clone());
        }
        let mut xs = LayoutSeq::new();
        xs.push(Primitive::unfold(1, s * (ht - 1) + k, s * ht))
            .push(Primitive::unfold(3, s * (wt - 1) + k, s * wt));
        layouts.set(x, xs);
        VariantDef { name: "case_tiled_unfold", layouts, sched: tiled_sched }
    };

    vec![nhwo, nohw, tiled, tiled_unfold]
}

/// Compile the case-study variants (`case_nhwo`, `case_nohw`,
/// `case_tiled`, `case_tiled_unfold`) at one scale.
pub fn case_executables(
    scale: Scale,
    hw: &HwProfile,
    threads: usize,
) -> Result<Vec<NativeExecutable>> {
    let g = case_graph(scale);
    let conv = g.complex_nodes()[0];
    let fused = [conv + 1, conv + 2];
    case_variant_defs(scale, &g)
        .into_iter()
        .map(|v| {
            NativeExecutable::compile(
                v.name,
                &g,
                conv,
                &fused,
                &v.layouts,
                &v.sched,
                hw.simd_lanes,
                threads,
            )
        })
        .collect()
}

/// A small GMM (dense + fused bias) pair: identity layout vs tiled
/// M/N-blocked output storage.
fn gmm_executables(hw: &HwProfile, threads: usize) -> Result<Vec<NativeExecutable>> {
    let (m, kk, n) = (64i64, 32i64, 48i64);
    let mut b = GraphBuilder::new("gmm_native");
    let x = b.input("x", &["M", "K"], &[m, kk]);
    b.dense("fc", x, n);
    let g = b.finish();
    let dense = g.complex_nodes()[0];
    let fused = [dense + 1];
    let outs = out_tensors(&g, dense, &fused);

    let plain = NativeExecutable::compile(
        "gmm",
        &g,
        dense,
        &fused,
        &LayoutAssignment::identity(&g),
        &LoopSchedule::identity(&[m, n], &[kk]),
        hw.simd_lanes,
        threads,
    )?;

    let (mt, nt) = (8i64, 16i64);
    let mut layouts = LayoutAssignment::identity(&g);
    let mut seq = LayoutSeq::new();
    seq.push(Primitive::split(0, &[m / mt, mt]))
        .push(Primitive::split(2, &[n / nt, nt]))
        .push(Primitive::reorder(&[0, 2, 1, 3]));
    for &t in &outs {
        layouts.set(t, seq.clone());
    }
    let sched = LoopSchedule {
        spatial_tiles: vec![1, 1, mt, nt],
        reduction_tiles: vec![kk],
        inner_perm: (0..4).collect(),
        vectorize: true,
        parallel: 2,
        unroll: 0,
        fuse_eltwise: true,
    };
    let tiled = NativeExecutable::compile(
        "gmm_tiled",
        &g,
        dense,
        &fused,
        &layouts,
        &sched,
        hw.simd_lanes,
        threads,
    )?;
    Ok(vec![plain, tiled])
}

/// The full native registry (case-study + GMM variants) behind
/// `alt run --backend native` and the serving example.
pub fn native_runtime(
    scale: Scale,
    hw: &HwProfile,
    threads: usize,
) -> Result<NativeRuntime> {
    let mut exes = case_executables(scale, hw, threads)?;
    exes.extend(gmm_executables(hw, threads)?);
    Ok(NativeRuntime::from_executables(exes))
}

/// A simulated profile matched to the actual host execution width:
/// parallel speedup in the simulator is clamped to the threads the
/// native executor really uses, so rankings are apples-to-apples.
pub fn host_profile(base: &HwProfile, threads: usize) -> HwProfile {
    let t = threads.max(1);
    let mut hw = base.clone();
    hw.cores = t as i64;
    hw.bw_saturation_cores = hw.bw_saturation_cores.min(t as f64);
    hw
}

/// Result of one sim-vs-native cross-check over the case variants.
#[derive(Clone, Debug)]
pub struct CrossCheck {
    pub threads: usize,
    pub names: Vec<String>,
    /// Simulated latency on the host-matched profile, per variant.
    pub sim_ms: Vec<f64>,
    /// Measured native latency (median of `reps`), per variant.
    pub native_ms: Vec<f64>,
    /// Spearman rank correlation between the two latency vectors.
    pub spearman: f64,
    /// Pairs the simulator separates by ≥2× whose order native
    /// execution inverts by ≥25% (sim-preferred name first).
    pub strong_inversions: Vec<(String, String)>,
    /// The natively fastest variant is within 1.5× of the simulator's
    /// best.
    pub best_agrees: bool,
    /// All variants computed the same output values (the layouts are
    /// pure storage transforms, so the math must not change).
    pub numerics_ok: bool,
}

impl CrossCheck {
    /// Tolerance-aware rank agreement: no strong inversions and the
    /// native winner sits in the simulator's top group.
    pub fn rank_agreement(&self) -> bool {
        self.strong_inversions.is_empty() && self.best_agrees
    }
}

/// Execute every case-study variant natively and compare the measured
/// latency ranking against the simulator's preference order on the
/// same lowered programs. `threads == 0` uses all available cores;
/// `reps` is the per-variant measurement count (median taken).
pub fn cross_check(
    scale: Scale,
    hw: &HwProfile,
    threads: usize,
    reps: usize,
    seed: u64,
) -> Result<CrossCheck> {
    let exes = case_executables(scale, hw, threads)?;
    let threads = exes.iter().map(|e| e.threads()).max().unwrap_or(1);
    let sim_hw = host_profile(hw, threads);

    let names: Vec<String> = exes.iter().map(|e| e.name().to_string()).collect();
    let sim_ms: Vec<f64> = exes
        .iter()
        .map(|e| simulate_program(e.program(), &sim_hw).latency_ms)
        .collect();

    // Same logical inputs for every variant (they share one graph).
    // Each variant's warmup run doubles as its numerics check, so no
    // execution is wasted.
    let inputs = exes[0].seeded_inputs(seed);
    let mut numerics_ok = true;
    let mut reference: Option<Vec<f32>> = None;
    let mut native_ms = Vec::with_capacity(exes.len());
    for exe in &exes {
        let (ms, out) = exe.bench_with_output(&inputs, reps.max(1))?;
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                let close = r.len() == out.len()
                    && r.iter().zip(&out).all(|(a, b)| {
                        (a - b).abs() <= 1e-5 * (1.0 + a.abs())
                    });
                if !close {
                    numerics_ok = false;
                }
            }
        }
        native_ms.push(ms);
    }

    let spear = spearman(&sim_ms, &native_ms);
    let mut strong_inversions = Vec::new();
    for i in 0..names.len() {
        for j in 0..names.len() {
            if i == j {
                continue;
            }
            // simulator strongly prefers i; native strongly disagrees
            if sim_ms[i] * 2.0 <= sim_ms[j]
                && native_ms[i] >= native_ms[j] * 1.25
            {
                strong_inversions.push((names[i].clone(), names[j].clone()));
            }
        }
    }
    let arg_min = |xs: &[f64]| -> usize {
        xs.iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    };
    let sim_best = sim_ms[arg_min(&sim_ms)];
    let best_agrees = sim_ms[arg_min(&native_ms)] <= 1.5 * sim_best;

    Ok(CrossCheck {
        threads,
        names,
        sim_ms,
        native_ms,
        spearman: spear,
        strong_inversions,
        best_agrees,
        numerics_ok,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_case_graph_shapes() {
        let g = case_graph(Scale::Small);
        let conv = g.complex_nodes()[0];
        assert_eq!(g.tensor(g.node(conv).output).shape, vec![1, 28, 28, 16]);
        // conv + bias + relu, no pad node (input arrives pre-padded)
        assert_eq!(g.nodes.len(), 3);
    }

    #[test]
    fn registry_compiles_all_variants() {
        let hw = HwProfile::intel();
        let rt = native_runtime(Scale::Small, &hw, 1).unwrap();
        use crate::runtime::Backend;
        let entries = rt.entries();
        for required in [
            "case_nhwo",
            "case_nohw",
            "case_tiled",
            "case_tiled_unfold",
            "gmm",
            "gmm_tiled",
        ] {
            assert!(
                entries.iter().any(|e| e == required),
                "missing variant {required}; have {entries:?}"
            );
        }
        assert!(rt.load("nonexistent").is_err());
    }

    #[test]
    fn host_profile_clamps_cores() {
        let hw = HwProfile::intel();
        let h2 = host_profile(&hw, 2);
        assert_eq!(h2.cores, 2);
        assert!(h2.bw_saturation_cores <= 2.0);
        let h0 = host_profile(&hw, 0);
        assert_eq!(h0.cores, 1);
    }
}
