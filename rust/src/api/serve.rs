//! High-throughput serving over a shared [`CompiledModel`].
//!
//! ```text
//!             submit()                   workers (N threads)
//!   clients ──────────► bounded queue ──► pop + batch gather ──► run
//!      ▲                (queue_cap,        │                      │
//!      │  Overload       Condvar)          │ 1 job   → run_in /   │
//!      │  when full                        │           run_pipelined_in
//!      └───────────── ServeReply ◄─────────┤ ≤max_batch jobs      │
//!                     (mpsc per req)       └─────────► run_batch_in
//! ```
//!
//! One `Arc`'d model — packed weights, gather maps, strided plans —
//! serves every worker; what is per-worker is only the mutable scratch
//! ([`RunScratch`]/[`BatchScratch`]/[`PipeScratch`]), so steady-state
//! serving allocates nothing on the f32 hot path. Three multiplicative
//! throughput mechanisms:
//!
//! * **concurrent sessions** — `workers` threads drain the queue
//!   independently; requests never block each other beyond the queue.
//! * **dynamic batching** — a worker that pops a request keeps
//!   gathering waiting requests (up to `max_batch`, within
//!   `batch_window_us`) and folds them into one batch-dim-aware
//!   execution whose outputs are bit-identical to sequential runs.
//! * **intra-request pipelining** — when the queue is shallow and
//!   `pipeline_width > 1`, a single request's data-independent plan
//!   steps fan out across idle cores instead of waiting for a batch.
//!
//! Failure semantics ride PR 7's ladder: a panicking request yields a
//! typed [`ErrorKind::Panic`] error for *that* request only (the
//! worker discards its scratch and keeps serving), a full queue yields
//! a typed [`ErrorKind::Overload`] refusal at `submit` time, and
//! degraded nests keep serving bit-identically. [`Server::pause`] /
//! [`Server::resume`] quiesce the workers — the deterministic lever
//! the overload and fault-injection tests use.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{panic_error, Error, ErrorKind, Result};
use crate::runtime::RunStats;

use super::model::{
    BatchScratch, CompiledModel, HealthReport, PhaseBreakdown, PipeScratch,
    RunScratch,
};

/// Serving knobs (see [`crate::config::Config::serve_options`] for the
/// text-config spelling).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeOptions {
    /// Worker threads draining the queue (`0` = one per core).
    pub workers: usize,
    /// Most requests one worker folds into a single batched execution.
    pub max_batch: usize,
    /// How long a worker holding one request waits for more before
    /// giving up on a bigger batch (µs; `0` = batch only what is
    /// already queued).
    pub batch_window_us: u64,
    /// Bounded queue capacity; a submit beyond it is shed with a typed
    /// [`ErrorKind::Overload`] error instead of queuing unboundedly.
    pub queue_cap: usize,
    /// Cores fanned over one request's independent plan steps when the
    /// queue is shallow (`<= 1` disables intra-request pipelining).
    pub pipeline_width: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            workers: 0,
            max_batch: 8,
            batch_window_us: 100,
            queue_cap: 256,
            pipeline_width: 1,
        }
    }
}

impl ServeOptions {
    /// The actual worker-thread count `workers = 0` resolves to.
    pub fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

/// One served inference: output + stats, plus how it was executed.
#[derive(Debug)]
pub struct ServeReply {
    pub stats: RunStats,
    /// Per-phase breakdown; [`PhaseBreakdown::queue_ms`] is the time
    /// this request waited in the queue before a worker picked it up.
    pub phases: PhaseBreakdown,
    /// Logical row-major model output.
    pub output: Vec<f32>,
    /// Size of the dynamic batch this request rode in (1 = solo).
    pub batched: usize,
}

/// Monotonic serving counters (snapshot via [`Server::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// Requests completed successfully.
    pub served: u64,
    /// Requests shed with [`ErrorKind::Overload`] (full queue at
    /// submit, or an injected queue drop).
    pub shed: u64,
    /// Multi-request batched executions run.
    pub batches: u64,
}

struct Job {
    inputs: Vec<Vec<f32>>,
    tx: mpsc::Sender<Result<ServeReply>>,
    enqueued: Instant,
}

#[derive(Default)]
struct QueueState {
    q: VecDeque<Job>,
    closed: bool,
}

struct Shared {
    model: Arc<CompiledModel>,
    opts: ServeOptions,
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    paused: AtomicBool,
    served: AtomicU64,
    shed: AtomicU64,
    batches: AtomicU64,
}

fn lock(m: &Mutex<QueueState>) -> MutexGuard<'_, QueueState> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// An in-flight request handle; [`Pending::wait`] blocks for the reply.
pub struct Pending {
    rx: mpsc::Receiver<Result<ServeReply>>,
}

impl Pending {
    /// Block until the request completes. Every failure is a typed
    /// [`Error`]: `Overload` (shed/shutdown), `Panic` (isolated worker
    /// panic), `Input` (validation), or whatever execution returned.
    pub fn wait(self) -> Result<ServeReply> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::with_kind(
                ErrorKind::Overload,
                "server shut down before completing the request",
            )),
        }
    }
}

/// A multi-worker inference server over one shared compiled model.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spawn the worker pool over `model`. The model is shared
    /// immutably (`CompiledModel` is `Send + Sync`); all mutable state
    /// is per-worker scratch.
    pub fn start(model: Arc<CompiledModel>, opts: ServeOptions) -> Self {
        let n = opts.resolved_workers();
        let shared = Arc::new(Shared {
            model,
            opts,
            queue: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            paused: AtomicBool::new(false),
            served: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        });
        let workers = (0..n)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        Self { shared, workers }
    }

    /// Enqueue one request. Returns a typed [`ErrorKind::Overload`]
    /// error immediately when the queue is at `queue_cap` (or the
    /// server is shutting down) — the backpressure signal.
    pub fn submit(&self, inputs: Vec<Vec<f32>>) -> Result<Pending> {
        let (tx, rx) = mpsc::channel();
        {
            let mut guard = lock(&self.shared.queue);
            if guard.closed {
                return Err(Error::with_kind(
                    ErrorKind::Overload,
                    "server is shutting down",
                ));
            }
            if guard.q.len() >= self.shared.opts.queue_cap.max(1) {
                self.shared.shed.fetch_add(1, Ordering::Relaxed);
                return Err(Error::with_kind(
                    ErrorKind::Overload,
                    format!(
                        "queue full ({} requests) — shedding load",
                        guard.q.len()
                    ),
                ));
            }
            guard.q.push_back(Job {
                inputs,
                tx,
                enqueued: Instant::now(),
            });
        }
        self.shared.not_empty.notify_one();
        Ok(Pending { rx })
    }

    /// Submit + wait: the blocking closed-loop client call.
    pub fn infer(&self, inputs: Vec<Vec<f32>>) -> Result<ServeReply> {
        self.submit(inputs)?.wait()
    }

    /// Quiesce the workers: requests keep queuing (and shedding past
    /// `queue_cap`) but nothing executes until [`Server::resume`]. The
    /// deterministic lever for overload and fault tests.
    pub fn pause(&self) {
        self.shared.paused.store(true, Ordering::SeqCst);
    }

    /// Release a [`Server::pause`]; queued requests drain immediately.
    pub fn resume(&self) {
        self.shared.paused.store(false, Ordering::SeqCst);
        self.shared.not_empty.notify_all();
    }

    /// Requests currently waiting in the queue.
    pub fn queue_depth(&self) -> usize {
        lock(&self.shared.queue).q.len()
    }

    /// Snapshot of the monotonic serving counters.
    pub fn stats(&self) -> ServerStats {
        ServerStats {
            served: self.shared.served.load(Ordering::Relaxed),
            shed: self.shared.shed.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
        }
    }

    /// The shared model (e.g. for [`CompiledModel::health`] under load).
    pub fn model(&self) -> &CompiledModel {
        &self.shared.model
    }

    /// Per-nest degradation report of the shared model.
    pub fn health(&self) -> HealthReport {
        self.shared.model.health()
    }

    /// The options this server was started with.
    pub fn options(&self) -> &ServeOptions {
        &self.shared.opts
    }

    /// Graceful shutdown: close the queue (new submits are refused with
    /// `Overload`), drain everything already queued, join the workers.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        {
            let mut guard = lock(&self.shared.queue);
            guard.closed = true;
        }
        // a paused server would never drain — release the brake
        self.shared.paused.store(false, Ordering::SeqCst);
        self.shared.not_empty.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// One worker: wait → pop → gather a batch → execute → reply, forever.
/// All scratch is thread-local and reused, so after warmup the f32 hot
/// path allocates nothing.
fn worker_loop(shared: &Shared) {
    let mut scratch = RunScratch::default();
    let mut batch = BatchScratch::default();
    let mut pipe = PipeScratch::default();
    loop {
        let (jobs, shallow) = {
            let mut guard = lock(&shared.queue);
            loop {
                // pause quiesces execution (ignored once closing, so
                // shutdown always drains)
                let paused =
                    shared.paused.load(Ordering::SeqCst) && !guard.closed;
                if !paused && !guard.q.is_empty() {
                    break;
                }
                if guard.closed && guard.q.is_empty() {
                    return;
                }
                guard = shared
                    .not_empty
                    .wait(guard)
                    .unwrap_or_else(|p| p.into_inner());
            }
            let cap = shared.opts.max_batch.max(1);
            let mut jobs = Vec::with_capacity(cap);
            if let Some(j) = guard.q.pop_front() {
                jobs.push(j);
            }
            // dynamic batch gather: anything already queued comes along
            // for free; otherwise wait out the batch window for
            // stragglers
            let window = Duration::from_micros(shared.opts.batch_window_us);
            let deadline = Instant::now() + window;
            while jobs.len() < cap {
                if let Some(j) = guard.q.pop_front() {
                    jobs.push(j);
                    continue;
                }
                if guard.closed || window.is_zero() {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g, timeout) = shared
                    .not_empty
                    .wait_timeout(guard, deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                guard = g;
                if timeout.timed_out() && guard.q.is_empty() {
                    break;
                }
            }
            let shallow = guard.q.is_empty();
            (jobs, shallow)
        };
        #[cfg(feature = "fault-inject")]
        let jobs: Vec<Job> = jobs
            .into_iter()
            .filter_map(|job| {
                if crate::faults::fire(crate::faults::FaultSite::QueueDrop) {
                    shared.shed.fetch_add(1, Ordering::Relaxed);
                    let _ = job.tx.send(Err(Error::with_kind(
                        ErrorKind::Overload,
                        "injected fault: worker dropped a queued request",
                    )));
                    None
                } else {
                    Some(job)
                }
            })
            .collect();
        let mut jobs = jobs;
        if jobs.is_empty() {
            continue;
        }
        let queued_ms: Vec<f64> = jobs
            .iter()
            .map(|j| j.enqueued.elapsed().as_secs_f64() * 1e3)
            .collect();
        if jobs.len() == 1 {
            if let Some(job) = jobs.pop() {
                // latency-critical solo request on a shallow queue:
                // fan its independent plan steps over idle cores
                let width = shared.opts.pipeline_width;
                let pipelined = width > 1 && shallow;
                let ran = catch_unwind(AssertUnwindSafe(|| {
                    if pipelined {
                        shared.model.run_pipelined_in(
                            &mut scratch,
                            &mut pipe,
                            width,
                            &job.inputs,
                        )
                    } else {
                        shared.model.run_profiled_in(&mut scratch, &job.inputs)
                    }
                }));
                let reply = match ran {
                    Ok(Ok((stats, mut phases, output))) => {
                        phases.queue_ms = queued_ms[0];
                        shared.served.fetch_add(1, Ordering::Relaxed);
                        Ok(ServeReply { stats, phases, output, batched: 1 })
                    }
                    Ok(Err(e)) => Err(e),
                    Err(p) => {
                        // the panicked request's scratch may be mid-
                        // mutation — discard it; the fault stays
                        // isolated to this request
                        scratch = RunScratch::default();
                        pipe = PipeScratch::default();
                        Err(panic_error(p, "serve worker"))
                    }
                };
                let _ = job.tx.send(reply);
            }
        } else {
            shared.batches.fetch_add(1, Ordering::Relaxed);
            let n = jobs.len();
            let ran = {
                let reqs: Vec<&[Vec<f32>]> =
                    jobs.iter().map(|j| j.inputs.as_slice()).collect();
                catch_unwind(AssertUnwindSafe(|| {
                    shared.model.run_batch_in(&mut batch, &reqs)
                }))
            };
            match ran {
                Ok(results) => {
                    for ((job, r), qm) in
                        jobs.iter().zip(results).zip(queued_ms)
                    {
                        let reply = r.map(|(stats, mut phases, output)| {
                            phases.queue_ms = qm;
                            shared.served.fetch_add(1, Ordering::Relaxed);
                            ServeReply { stats, phases, output, batched: n }
                        });
                        let _ = job.tx.send(reply);
                    }
                }
                Err(p) => {
                    // run_batch_in already isolates per-lane panics;
                    // this catches the batch loop itself blowing up
                    batch = BatchScratch::default();
                    let msg = panic_error(p, "serve batch worker").to_string();
                    for job in &jobs {
                        let _ = job.tx.send(Err(Error::with_kind(
                            ErrorKind::Panic,
                            msg.clone(),
                        )));
                    }
                }
            }
        }
    }
}
