//! Durable tuned plans: the serializable output of `Session::tune`.
//!
//! A [`TunedPlan`] is everything needed to rebuild a compiled model
//! without re-tuning: the model-zoo name, the hardware profile, the
//! propagation mode, the weight seed, and — per complex operator — the
//! layout decision (three primitive sequences) plus the loop schedule.
//! The format is a line-based `key = value` text (the same family as
//! `config/mod.rs`) with one `[op N]` section per tuned operator, so
//! plans diff cleanly and survive hand edits.
//!
//! `save` writes the plan next to an *extended manifest*
//! (`manifest.txt`: a format-version line, per-artifact FNV-1a
//! checksum lines, then the same tab-separated `name \t file \t
//! in_specs \t out_specs` rows the PJRT artifact directory uses,
//! parsed by [`crate::runtime::parse_manifest`]), so a plan directory
//! is self-describing: the manifest row carries the model's logical
//! input and output tensor specs and names the plan file as its
//! artifact.
//!
//! Saving is *atomic*: the directory is built under a temp sibling
//! name and renamed into place, so a crash mid-save leaves the old
//! plan (or nothing), never a torn directory. Loading verifies the
//! version line and every checksum before parsing anything, and every
//! integrity failure is a typed [`PlanError`] — version skew,
//! truncation, and corruption are refusals, not garbage or panics.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, ErrorKind, PlanError, Result};
use crate::graph::{Graph, NodeId};
use crate::layout::{LayoutSeq, Primitive};
use crate::loops::LoopSchedule;
use crate::propagate::{ComplexDecision, PropMode};
use crate::rewrite::RewriteDecision;
use crate::runtime::TensorSpec;
use crate::tensor::Role;
use crate::{bail, err};

/// One complex operator's tuned outcome.
#[derive(Clone, Debug, PartialEq)]
pub struct OpPlan {
    pub node: NodeId,
    pub decision: ComplexDecision,
    pub sched: LoopSchedule,
}

/// The serializable tuned plan for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct TunedPlan {
    /// Model-zoo name ([`crate::graph::models::by_name`] key) — how
    /// `Session::load` rebuilds the graph.
    pub model: String,
    /// Hardware profile name ([`crate::sim::HwProfile::by_name`] key).
    pub hw: String,
    /// Propagation mode the decisions were tuned under.
    pub mode: PropMode,
    /// Tuning seed (informational; compilation does not re-tune).
    pub seed: u64,
    /// Seed the compiled model's constant weights are drawn from.
    pub weight_seed: u64,
    /// Native execution threads (0 = all cores; a pure throughput
    /// knob — outputs are bit-identical at any value).
    pub threads: usize,
    /// Graph-rewrite decisions baked into this plan (empty = the graph
    /// executes exactly as the zoo emits it; the `rewrite =` line is
    /// omitted entirely so rewrite-free plans are byte-identical to
    /// pre-rewrite builds).
    pub rewrites: Vec<RewriteDecision>,
    pub ops: Vec<OpPlan>,
}


fn fmt_prim(p: &Primitive) -> String {
    match p {
        Primitive::Split { dim, factors } => {
            let fs: Vec<String> = factors.iter().map(|f| f.to_string()).collect();
            format!("split({dim},{})", fs.join(","))
        }
        Primitive::Reorder { perm } => {
            let ps: Vec<String> = perm.iter().map(|p| p.to_string()).collect();
            format!("reorder({})", ps.join(","))
        }
        Primitive::Fuse { dim, count } => format!("fuse({dim},{count})"),
        Primitive::Unfold { dim, size, stride } => {
            format!("unfold({dim},{size},{stride})")
        }
        Primitive::Pad { dim, before, after } => {
            format!("pad({dim},{before},{after})")
        }
        Primitive::StoreAt { other, dim } => format!("store_at({other},{dim})"),
        Primitive::Fold { dim, size, stride } => {
            format!("fold({dim},{size},{stride})")
        }
        Primitive::Unpad { dim, before, after } => {
            format!("unpad({dim},{before},{after})")
        }
        Primitive::DecoupleAt { other, dim } => {
            format!("decouple_at({other},{dim})")
        }
    }
}

fn parse_prim(s: &str) -> Result<Primitive> {
    let (name, rest) = s
        .split_once('(')
        .ok_or_else(|| err!("bad primitive '{s}': missing '('"))?;
    let args = rest
        .strip_suffix(')')
        .ok_or_else(|| err!("bad primitive '{s}': missing ')'"))?;
    let ints = |want_at_least: usize| -> Result<Vec<i64>> {
        let v: Vec<i64> = args
            .split(',')
            .map(|a| {
                a.trim()
                    .parse::<i64>()
                    .map_err(|e| err!("bad arg '{a}' in '{s}': {e}"))
            })
            .collect::<Result<_>>()?;
        if v.len() < want_at_least {
            bail!("primitive '{s}' wants >= {want_at_least} args");
        }
        Ok(v)
    };
    let exact = |n: usize| -> Result<Vec<i64>> {
        let v = ints(n)?;
        if v.len() != n {
            bail!("primitive '{s}' wants {n} args, got {}", v.len());
        }
        Ok(v)
    };
    let usz = |v: i64| -> Result<usize> {
        usize::try_from(v).map_err(|_| err!("negative index in '{s}'"))
    };
    Ok(match name {
        "split" => {
            let v = ints(2)?;
            Primitive::Split { dim: usz(v[0])?, factors: v[1..].to_vec() }
        }
        "reorder" => {
            let v = ints(1)?;
            Primitive::Reorder {
                perm: v.into_iter().map(usz).collect::<Result<_>>()?,
            }
        }
        "fuse" => {
            let v = exact(2)?;
            Primitive::Fuse { dim: usz(v[0])?, count: usz(v[1])? }
        }
        "unfold" => {
            let v = exact(3)?;
            Primitive::Unfold { dim: usz(v[0])?, size: v[1], stride: v[2] }
        }
        "pad" => {
            let v = exact(3)?;
            Primitive::Pad { dim: usz(v[0])?, before: v[1], after: v[2] }
        }
        "store_at" => {
            let v = exact(2)?;
            Primitive::StoreAt { other: usz(v[0])?, dim: usz(v[1])? }
        }
        "fold" => {
            let v = exact(3)?;
            Primitive::Fold { dim: usz(v[0])?, size: v[1], stride: v[2] }
        }
        "unpad" => {
            let v = exact(3)?;
            Primitive::Unpad { dim: usz(v[0])?, before: v[1], after: v[2] }
        }
        "decouple_at" => {
            let v = exact(2)?;
            Primitive::DecoupleAt { other: usz(v[0])?, dim: usz(v[1])? }
        }
        other => bail!("unknown primitive '{other}' in '{s}'"),
    })
}

fn fmt_seq(seq: &LayoutSeq) -> String {
    if seq.is_identity() {
        return "-".into();
    }
    seq.prims.iter().map(fmt_prim).collect::<Vec<_>>().join(";")
}

fn parse_seq(s: &str) -> Result<LayoutSeq> {
    let s = s.trim();
    if s == "-" || s.is_empty() {
        return Ok(LayoutSeq::new());
    }
    let prims = s
        .split(';')
        .filter(|p| !p.trim().is_empty())
        .map(|p| parse_prim(p.trim()))
        .collect::<Result<_>>()?;
    Ok(LayoutSeq { prims })
}

fn fmt_list<T: std::fmt::Display>(v: &[T]) -> String {
    if v.is_empty() {
        return "-".into();
    }
    v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    let s = s.trim();
    if s == "-" || s.is_empty() {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|a| a.trim().parse::<T>().map_err(|e| err!("bad list item '{a}': {e}")))
        .collect()
}

fn parse_bool(s: &str) -> Result<bool> {
    match s.trim() {
        "true" | "1" => Ok(true),
        "false" | "0" => Ok(false),
        other => bail!("bad bool '{other}'"),
    }
}

impl TunedPlan {
    /// Render the plan as its durable text form.
    pub fn serialize(&self) -> String {
        let mut out = String::from("# ALT tuned plan v1\n");
        out.push_str(&format!("model = {}\n", self.model));
        out.push_str(&format!("hw = {}\n", self.hw));
        out.push_str(&format!("mode = {}\n", self.mode.name()));
        out.push_str(&format!("seed = {}\n", self.seed));
        out.push_str(&format!("weight_seed = {}\n", self.weight_seed));
        out.push_str(&format!("threads = {}\n", self.threads));
        if !self.rewrites.is_empty() {
            let rs: Vec<String> =
                self.rewrites.iter().map(RewriteDecision::fmt).collect();
            out.push_str(&format!("rewrite = {}\n", rs.join(",")));
        }
        for op in &self.ops {
            out.push_str(&format!("\n[op {}]\n", op.node));
            out.push_str(&format!("out_seq = {}\n", fmt_seq(&op.decision.out_seq)));
            out.push_str(&format!("in_seq = {}\n", fmt_seq(&op.decision.in_seq)));
            out.push_str(&format!("w_seq = {}\n", fmt_seq(&op.decision.w_seq)));
            let s = &op.sched;
            out.push_str(&format!(
                "spatial_tiles = {}\n",
                fmt_list(&s.spatial_tiles)
            ));
            out.push_str(&format!(
                "reduction_tiles = {}\n",
                fmt_list(&s.reduction_tiles)
            ));
            out.push_str(&format!("inner_perm = {}\n", fmt_list(&s.inner_perm)));
            out.push_str(&format!("vectorize = {}\n", s.vectorize));
            out.push_str(&format!("parallel = {}\n", s.parallel));
            out.push_str(&format!("unroll = {}\n", s.unroll));
            out.push_str(&format!("fuse_eltwise = {}\n", s.fuse_eltwise));
        }
        out
    }

    /// Parse a plan from its text form.
    pub fn parse(text: &str) -> Result<TunedPlan> {
        let mut plan = TunedPlan {
            model: String::new(),
            hw: String::new(),
            mode: PropMode::Alt,
            seed: 0,
            weight_seed: 0,
            threads: 0,
            rewrites: Vec::new(),
            ops: Vec::new(),
        };
        let mut cur: Option<OpPlan> = None;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let loc = |e: crate::error::Error| e.context(format!("plan line {}", ln + 1));
            if let Some(section) = line.strip_prefix('[') {
                let section = section
                    .strip_suffix(']')
                    .ok_or_else(|| err!("plan line {}: missing ']'", ln + 1))?;
                let node = section
                    .strip_prefix("op ")
                    .ok_or_else(|| err!("plan line {}: unknown section '[{section}]'", ln + 1))?
                    .trim()
                    .parse::<usize>()
                    .map_err(|e| err!("plan line {}: bad op id: {e}", ln + 1))?;
                if let Some(op) = cur.take() {
                    plan.ops.push(op);
                }
                cur = Some(OpPlan {
                    node,
                    decision: ComplexDecision { node, ..Default::default() },
                    sched: LoopSchedule::identity(&[], &[]),
                });
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err!("plan line {}: expected key = value", ln + 1))?;
            let (k, v) = (k.trim(), v.trim());
            match (&mut cur, k) {
                (None, "model") => plan.model = v.to_string(),
                (None, "hw") => plan.hw = v.to_string(),
                (None, "mode") => {
                    plan.mode = PropMode::from_name(v).ok_or_else(|| {
                        err!("plan line {}: unknown mode '{v}'", ln + 1)
                    })?
                }
                (None, "seed") => plan.seed = v.parse().map_err(|e| err!("plan line {}: seed: {e}", ln + 1))?,
                (None, "weight_seed") => {
                    plan.weight_seed =
                        v.parse().map_err(|e| err!("plan line {}: weight_seed: {e}", ln + 1))?
                }
                (None, "threads") => {
                    plan.threads =
                        v.parse().map_err(|e| err!("plan line {}: threads: {e}", ln + 1))?
                }
                (None, "rewrite") => {
                    plan.rewrites = v
                        .split(',')
                        .map(|r| {
                            RewriteDecision::parse(r.trim()).ok_or_else(|| {
                                err!(
                                    "plan line {}: bad rewrite '{}'",
                                    ln + 1,
                                    r.trim()
                                )
                            })
                        })
                        .collect::<Result<_>>()?
                }
                (Some(op), "out_seq") => op.decision.out_seq = parse_seq(v).map_err(loc)?,
                (Some(op), "in_seq") => op.decision.in_seq = parse_seq(v).map_err(loc)?,
                (Some(op), "w_seq") => op.decision.w_seq = parse_seq(v).map_err(loc)?,
                (Some(op), "spatial_tiles") => {
                    op.sched.spatial_tiles = parse_list(v).map_err(loc)?
                }
                (Some(op), "reduction_tiles") => {
                    op.sched.reduction_tiles = parse_list(v).map_err(loc)?
                }
                (Some(op), "inner_perm") => {
                    op.sched.inner_perm = parse_list(v).map_err(loc)?
                }
                (Some(op), "vectorize") => {
                    op.sched.vectorize = parse_bool(v).map_err(loc)?
                }
                (Some(op), "parallel") => {
                    op.sched.parallel =
                        v.parse().map_err(|e| err!("plan line {}: parallel: {e}", ln + 1))?
                }
                (Some(op), "unroll") => {
                    op.sched.unroll =
                        v.parse().map_err(|e| err!("plan line {}: unroll: {e}", ln + 1))?
                }
                (Some(op), "fuse_eltwise") => {
                    op.sched.fuse_eltwise = parse_bool(v).map_err(loc)?
                }
                (_, other) => bail!("plan line {}: unknown key '{other}'", ln + 1),
            }
        }
        if let Some(op) = cur.take() {
            plan.ops.push(op);
        }
        if plan.model.is_empty() {
            bail!("plan is missing the 'model' key");
        }
        if plan.hw.is_empty() {
            bail!("plan is missing the 'hw' key");
        }
        Ok(plan)
    }

    /// Check the plan against a concrete graph: every op id must be a
    /// complex node, named at most once.
    pub fn validate_against(&self, graph: &Graph) -> Result<()> {
        let complex = graph.complex_nodes();
        let mut seen = std::collections::HashSet::new();
        for op in &self.ops {
            if !complex.contains(&op.node) {
                bail!(
                    "plan op {} is not a complex node of {}",
                    op.node,
                    graph.name
                );
            }
            if !seen.insert(op.node) {
                bail!("plan names op {} twice", op.node);
            }
            if op.decision.node != op.node {
                bail!("plan op {} carries decision for {}", op.node, op.decision.node);
            }
        }
        Ok(())
    }

    /// Decisions in plan order (what `propagate` consumes).
    pub fn decisions(&self) -> Vec<ComplexDecision> {
        self.ops.iter().map(|o| o.decision.clone()).collect()
    }

    /// Node → schedule map (what the graph simulator consumes).
    pub fn scheds(&self) -> HashMap<NodeId, LoopSchedule> {
        self.ops.iter().map(|o| (o.node, o.sched.clone())).collect()
    }
}

/// Logical input specs of a graph (its `Role::Input` tensors, id order)
/// — the inputs `CompiledModel::run` expects.
pub(crate) fn input_specs_of(graph: &Graph) -> Vec<TensorSpec> {
    graph
        .tensors
        .iter()
        .filter(|t| t.role == Role::Input)
        .map(|t| TensorSpec {
            dtype: "float32".into(),
            shape: t.shape.iter().map(|&d| d as usize).collect(),
        })
        .collect()
}

/// Logical output spec of a graph (its last node's output; an empty
/// graph — which can never compile — yields an empty-shape spec
/// rather than panicking).
pub(crate) fn output_spec_of(graph: &Graph) -> TensorSpec {
    let shape = match graph.nodes.last() {
        Some(n) => {
            graph.tensor(n.output).shape.iter().map(|&d| d as usize).collect()
        }
        None => Vec::new(),
    };
    TensorSpec { dtype: "float32".into(), shape }
}

fn fmt_specs(specs: &[TensorSpec]) -> String {
    specs
        .iter()
        .map(|s| {
            let dims: Vec<String> = s.shape.iter().map(|d| d.to_string()).collect();
            format!("{}[{}]", s.dtype, dims.join(","))
        })
        .collect::<Vec<_>>()
        .join(";")
}

/// Name of the plan file inside a saved directory.
pub const PLAN_FILE: &str = "plan.txt";

/// First line of a saved plan's manifest. Bumped when the directory
/// format changes incompatibly; the loader refuses manifests that do
/// not announce a version this build speaks.
pub const MANIFEST_VERSION_LINE: &str = "# alt-plan-manifest v2";

fn plan_err(kind: PlanError, msg: impl std::fmt::Display) -> Error {
    Error::with_kind(ErrorKind::Plan(kind), msg)
}

/// Write `plan.txt` + the extended `manifest.txt` into `dir`,
/// atomically: the directory is assembled under a temp sibling name
/// and renamed into place, so a crash mid-save leaves the previous
/// plan (or nothing) — never a half-written directory. The manifest
/// records an FNV-1a checksum per artifact, so torn writes and later
/// corruption are caught at load time.
pub(crate) fn save_plan(dir: &Path, plan: &TunedPlan, graph: &Graph) -> Result<()> {
    // fail at save time, not at load time: a plan whose model the zoo
    // cannot rebuild would persist fine but never restore, silently
    // stranding the tuning spend
    if crate::graph::models::by_name(&plan.model).is_none() {
        bail!(
            "model '{}' is not in the model zoo (graph::models::by_name), \
             so a saved plan could never be loaded back",
            plan.model
        );
    }
    let plan_text = plan.serialize();
    let checksum = crate::util::fnv1a64(plan_text.as_bytes());
    let manifest = format!(
        "{MANIFEST_VERSION_LINE}\n# checksum {PLAN_FILE} {checksum:016x}\n{}\t{}\t{}\t{}\n",
        plan.model,
        PLAN_FILE,
        fmt_specs(&input_specs_of(graph)),
        fmt_specs(&[output_spec_of(graph)]),
    );
    let tmp = dir.with_file_name(format!(
        "{}.tmp.{}",
        dir.file_name().and_then(|n| n.to_str()).unwrap_or("plan"),
        std::process::id()
    ));
    let built = (|| -> Result<()> {
        std::fs::create_dir_all(&tmp).map_err(|e| {
            plan_err(PlanError::Io, format!("creating {}: {e}", tmp.display()))
        })?;
        let plan_path = tmp.join(PLAN_FILE);
        #[allow(unused_mut)]
        let mut plan_bytes = plan_text.into_bytes();
        #[cfg(feature = "fault-inject")]
        if crate::faults::fire(crate::faults::FaultSite::TornPlanWrite) {
            // simulate a write torn mid-file: the checksum above was
            // taken over the full serialization, so the loader must
            // refuse this plan with `ChecksumMismatch`
            plan_bytes.truncate(plan_bytes.len() / 2);
        }
        std::fs::write(&plan_path, &plan_bytes).map_err(|e| {
            plan_err(
                PlanError::Io,
                format!("writing {}: {e}", plan_path.display()),
            )
        })?;
        let mpath = tmp.join("manifest.txt");
        std::fs::write(&mpath, &manifest).map_err(|e| {
            plan_err(PlanError::Io, format!("writing {}: {e}", mpath.display()))
        })?;
        Ok(())
    })();
    if let Err(e) = built {
        std::fs::remove_dir_all(&tmp).ok();
        return Err(e);
    }
    if dir.exists() {
        std::fs::remove_dir_all(dir).map_err(|e| {
            plan_err(
                PlanError::Io,
                format!("replacing {}: {e}", dir.display()),
            )
        })?;
    }
    if let Err(e) = std::fs::rename(&tmp, dir) {
        std::fs::remove_dir_all(&tmp).ok();
        return Err(plan_err(
            PlanError::Io,
            format!("publishing {}: {e}", dir.display()),
        ));
    }
    Ok(())
}

/// Read a plan directory back: version-checked, checksum-verified
/// manifest + plan file, spec-checked against the rebuilt graph.
/// Integrity failures are typed [`PlanError`]s — see [`ErrorKind::Plan`].
pub(crate) fn load_plan(dir: &Path) -> Result<(TunedPlan, Graph)> {
    let mpath = dir.join("manifest.txt");
    let mtext = std::fs::read_to_string(&mpath).map_err(|e| {
        plan_err(PlanError::Io, format!("reading {}: {e}", mpath.display()))
    })?;
    let mut lines = mtext.lines();
    let head = lines.next().map(str::trim);
    if head != Some(MANIFEST_VERSION_LINE) {
        return Err(plan_err(
            PlanError::VersionSkew,
            format!(
                "{}: expected '{MANIFEST_VERSION_LINE}', found '{}' — \
                 re-save the plan with this build",
                mpath.display(),
                head.unwrap_or("<empty manifest>")
            ),
        ));
    }
    // Split annotation lines (`# checksum file hex`; unknown `#` lines
    // are ignored for forward compatibility) from artifact rows.
    let mut checksums: Vec<(String, u64)> = Vec::new();
    let mut rows = String::new();
    for line in lines {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("# checksum ") {
            let (file, hex) = rest.rsplit_once(' ').ok_or_else(|| {
                plan_err(
                    PlanError::Malformed,
                    format!("{}: bad checksum line '{t}'", mpath.display()),
                )
            })?;
            let sum = u64::from_str_radix(hex.trim(), 16).map_err(|e| {
                plan_err(
                    PlanError::Malformed,
                    format!("{}: bad checksum '{hex}': {e}", mpath.display()),
                )
            })?;
            checksums.push((file.trim().to_string(), sum));
        } else if !t.starts_with('#') {
            rows.push_str(line);
            rows.push('\n');
        }
    }
    // Verify every recorded artifact BEFORE parsing anything, so a
    // truncated or corrupted plan is reported as what it is.
    for (file, want) in &checksums {
        let fpath = dir.join(file);
        let bytes = std::fs::read(&fpath).map_err(|e| {
            plan_err(PlanError::Io, format!("reading {}: {e}", fpath.display()))
        })?;
        let got = crate::util::fnv1a64(&bytes);
        if got != *want {
            return Err(plan_err(
                PlanError::ChecksumMismatch,
                format!(
                    "{}: manifest records {want:016x} but the bytes hash \
                     to {got:016x} (truncated or corrupted write)",
                    fpath.display()
                ),
            ));
        }
    }
    let malformed = |e: Error| {
        e.into_kind(ErrorKind::Plan(PlanError::Malformed))
            .context(format!("loading {}", dir.display()))
    };
    let entries = crate::runtime::parse_manifest(&rows).map_err(malformed)?;
    let entry = entries.first().ok_or_else(|| {
        plan_err(
            PlanError::Malformed,
            format!("{}: no artifact rows", mpath.display()),
        )
    })?;
    if !checksums.iter().any(|(f, _)| f == &entry.file) {
        return Err(plan_err(
            PlanError::Malformed,
            format!(
                "{}: artifact '{}' carries no checksum line",
                mpath.display(),
                entry.file
            ),
        ));
    }
    let plan_path = dir.join(&entry.file);
    let text = std::fs::read_to_string(&plan_path).map_err(|e| {
        plan_err(
            PlanError::Io,
            format!("reading {}: {e}", plan_path.display()),
        )
    })?;
    let plan = TunedPlan::parse(&text).map_err(malformed)?;
    if plan.model != entry.name {
        return Err(plan_err(
            PlanError::Malformed,
            format!(
                "manifest names '{}' but the plan was tuned for '{}'",
                entry.name, plan.model
            ),
        ));
    }
    let graph = crate::graph::models::by_name(&plan.model).ok_or_else(|| {
        plan_err(
            PlanError::Malformed,
            format!(
                "plan model '{}' is not in the model zoo \
                 (graph::models::by_name)",
                plan.model
            ),
        )
    })?;
    plan.validate_against(&graph).map_err(malformed)?;
    // the manifest's specs must match the rebuilt graph (defends
    // against a zoo definition drifting under a saved plan)
    let want_in = fmt_specs(&input_specs_of(&graph));
    let got_in = fmt_specs(&entry.inputs);
    if want_in != got_in {
        return Err(plan_err(
            PlanError::Malformed,
            format!(
                "manifest input specs {got_in} do not match {} ({want_in})",
                plan.model
            ),
        ));
    }
    let want_out = fmt_specs(&[output_spec_of(&graph)]);
    let got_out = fmt_specs(&entry.outputs);
    if want_out != got_out {
        return Err(plan_err(
            PlanError::Malformed,
            format!(
                "manifest output specs {got_out} do not match {} ({want_out})",
                plan.model
            ),
        ));
    }
    Ok((plan, graph))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn sample_plan() -> TunedPlan {
        let mut out_seq = LayoutSeq::new();
        out_seq
            .push(Primitive::split(3, &[4, 16]))
            .push(Primitive::reorder(&[0, 1, 2, 3, 4]));
        let mut in_seq = LayoutSeq::new();
        in_seq.push(Primitive::unfold(1, 9, 8));
        TunedPlan {
            model: "case_study".into(),
            hw: "intel".into(),
            mode: PropMode::Alt,
            seed: 42,
            weight_seed: 7,
            threads: 0,
            rewrites: Vec::new(),
            ops: vec![OpPlan {
                node: 1,
                decision: ComplexDecision {
                    node: 1,
                    out_seq,
                    in_seq,
                    w_seq: LayoutSeq::new(),
                },
                sched: LoopSchedule {
                    spatial_tiles: vec![1, 4, 4, 16],
                    reduction_tiles: vec![3, 7, 7],
                    inner_perm: vec![0, 1, 2, 3],
                    vectorize: true,
                    parallel: 2,
                    unroll: 8,
                    fuse_eltwise: true,
                },
            }],
        }
    }

    #[test]
    fn plan_text_roundtrips_exactly() {
        let plan = sample_plan();
        let text = plan.serialize();
        let parsed = TunedPlan::parse(&text).unwrap();
        assert_eq!(parsed, plan);
        // serialize(parse(serialize(p))) is byte-identical
        assert_eq!(parsed.serialize(), text);
        // a rewrite-free plan carries no `rewrite =` line at all, so
        // plans from pre-rewrite builds parse and re-serialize bytewise
        assert!(!text.contains("rewrite"));
    }

    #[test]
    fn rewrite_line_roundtrips_exactly() {
        use crate::rewrite::{RewriteDecision, RewriteKind};
        let mut plan = sample_plan();
        plan.rewrites = vec![
            RewriteDecision { kind: RewriteKind::FoldPad, node: 0, anchor: 1 },
            RewriteDecision {
                kind: RewriteKind::FuseEpilogue,
                node: 5,
                anchor: 3,
            },
        ];
        let text = plan.serialize();
        assert!(
            text.contains("rewrite = fold_pad:0:1,fuse_epilogue:5:3"),
            "{text}"
        );
        let parsed = TunedPlan::parse(&text).unwrap();
        assert_eq!(parsed, plan);
        assert_eq!(parsed.serialize(), text);
        // malformed rewrite entries are refusals, not silent drops
        let bad = text.replace("fold_pad:0:1", "fold_pad:0");
        assert!(TunedPlan::parse(&bad).is_err());
        let bad = text.replace("fold_pad", "fold_nonsense");
        assert!(TunedPlan::parse(&bad).is_err());
    }

    #[test]
    fn every_primitive_spelling_roundtrips() {
        let prims = vec![
            Primitive::split(2, &[3, 5, 7]),
            Primitive::reorder(&[1, 0]),
            Primitive::fuse(0, 2),
            Primitive::unfold(1, 9, 8),
            Primitive::pad(3, 1, 2),
            Primitive::StoreAt { other: 11, dim: 0 },
            Primitive::Fold { dim: 1, size: 9, stride: 8 },
            Primitive::Unpad { dim: 3, before: 1, after: 2 },
            Primitive::DecoupleAt { other: 11, dim: 0 },
        ];
        for p in prims {
            let s = fmt_prim(&p);
            assert_eq!(parse_prim(&s).unwrap(), p, "{s}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(TunedPlan::parse("nonsense").is_err());
        assert!(TunedPlan::parse("model = x\n").is_err()); // no hw
        assert!(
            TunedPlan::parse("model = x\nhw = intel\nmode = bogus\n").is_err()
        );
        // op-scoped key outside a section
        assert!(
            TunedPlan::parse("model = x\nhw = intel\nout_seq = -\n").is_err()
        );
        assert!(parse_prim("split(oops)").is_err());
        assert!(parse_prim("warp(1,2)").is_err());
        assert!(parse_seq("split(1,2);;").is_ok(), "empty segments tolerated");
    }

    #[test]
    fn validate_against_checks_node_ids() {
        let g = models::case_study();
        let mut plan = sample_plan();
        assert!(plan.validate_against(&g).is_ok());
        plan.ops[0].node = 0; // the pad node, not complex
        plan.ops[0].decision.node = 0;
        assert!(plan.validate_against(&g).is_err());
    }

    #[test]
    fn save_rejects_non_zoo_models() {
        let dir = std::env::temp_dir()
            .join(format!("alt_plan_nonzoo_{}", std::process::id()));
        let mut plan = sample_plan();
        plan.model = "not_a_zoo_member".into();
        let g = models::case_study();
        let err = save_plan(&dir, &plan, &g).unwrap_err();
        assert!(format!("{err}").contains("model zoo"), "{err}");
        assert!(!dir.exists(), "nothing must be written on rejection");
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = std::env::temp_dir()
            .join(format!("alt_plan_test_{}", std::process::id()));
        let g = models::case_study();
        let plan = sample_plan();
        save_plan(&dir, &plan, &g).unwrap();
        let (loaded, graph) = load_plan(&dir).unwrap();
        assert_eq!(loaded, plan);
        assert_eq!(graph.name, "case_study");
        // no temp build directory is left behind by the atomic publish
        let parent = dir.parent().unwrap();
        let stem = format!("{}.tmp.", dir.file_name().unwrap().to_str().unwrap());
        let leftover = std::fs::read_dir(parent).unwrap().any(|e| {
            e.unwrap().file_name().to_str().is_some_and(|n| n.starts_with(&stem))
        });
        assert!(!leftover, "temp plan directory survived the rename");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_replace() {
        let dir = std::env::temp_dir()
            .join(format!("alt_plan_replace_{}", std::process::id()));
        let g = models::case_study();
        let mut plan = sample_plan();
        save_plan(&dir, &plan, &g).unwrap();
        plan.weight_seed = 99;
        save_plan(&dir, &plan, &g).unwrap();
        let (loaded, _) = load_plan(&dir).unwrap();
        assert_eq!(loaded.weight_seed, 99, "second save replaced the first");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_refuses_version_skew() {
        let dir = std::env::temp_dir()
            .join(format!("alt_plan_skew_{}", std::process::id()));
        let g = models::case_study();
        save_plan(&dir, &sample_plan(), &g).unwrap();
        let mpath = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&mpath).unwrap();
        // a v1-era manifest: no version line at all
        let stripped: String = text
            .lines()
            .filter(|l| !l.starts_with('#'))
            .map(|l| format!("{l}\n"))
            .collect();
        std::fs::write(&mpath, stripped).unwrap();
        let err = load_plan(&dir).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Plan(PlanError::VersionSkew), "{err}");
        // ...and a future version this build does not speak
        let future = text.replacen("v2", "v99", 1);
        std::fs::write(&mpath, future).unwrap();
        let err = load_plan(&dir).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Plan(PlanError::VersionSkew), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_refuses_truncated_plan_with_checksum_mismatch() {
        let dir = std::env::temp_dir()
            .join(format!("alt_plan_torn_{}", std::process::id()));
        let g = models::case_study();
        save_plan(&dir, &sample_plan(), &g).unwrap();
        let ppath = dir.join(PLAN_FILE);
        let bytes = std::fs::read(&ppath).unwrap();
        std::fs::write(&ppath, &bytes[..bytes.len() / 2]).unwrap();
        let err = load_plan(&dir).unwrap_err();
        assert_eq!(
            err.kind(),
            ErrorKind::Plan(PlanError::ChecksumMismatch),
            "{err}"
        );
        // single-bit corruption is caught too, not just truncation
        let mut flipped = bytes.clone();
        flipped[bytes.len() / 2] ^= 0x01;
        std::fs::write(&ppath, &flipped).unwrap();
        let err = load_plan(&dir).unwrap_err();
        assert_eq!(
            err.kind(),
            ErrorKind::Plan(PlanError::ChecksumMismatch),
            "{err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_types_malformed_and_io_failures() {
        let dir = std::env::temp_dir()
            .join(format!("alt_plan_malformed_{}", std::process::id()));
        // missing directory → Plan(Io)
        let err = load_plan(&dir).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Plan(PlanError::Io), "{err}");
        let g = models::case_study();
        save_plan(&dir, &sample_plan(), &g).unwrap();
        // garbage checksum hex → Plan(Malformed)
        let mpath = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&mpath).unwrap();
        let bad = text.replace("# checksum plan.txt ", "# checksum plan.txt zz");
        std::fs::write(&mpath, bad).unwrap();
        let err = load_plan(&dir).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Plan(PlanError::Malformed), "{err}");
        // a plan file that no longer parses (checksum updated to match
        // the corrupted bytes, so parsing is reached) → Plan(Malformed)
        let garbage = b"model = \nnot a plan".to_vec();
        std::fs::write(dir.join(PLAN_FILE), &garbage).unwrap();
        let sum = crate::util::fnv1a64(&garbage);
        let patched: String = text
            .lines()
            .map(|l| {
                if l.starts_with("# checksum ") {
                    format!("# checksum {PLAN_FILE} {sum:016x}\n")
                } else {
                    format!("{l}\n")
                }
            })
            .collect();
        std::fs::write(&mpath, patched).unwrap();
        let err = load_plan(&dir).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Plan(PlanError::Malformed), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
