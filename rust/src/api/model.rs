//! Whole-model compilation and native execution.
//!
//! [`CompiledModel`] turns a tuned plan into a topological execution
//! plan for the native backend and runs the *entire* graph on host
//! `f32` buffers — the multi-op successor of the single-op
//! [`NativeExecutable`] path:
//!
//! * every complex operator (+ its fused elementwise tail) is lowered
//!   once, at compile time, with its tuned layout decision and loop
//!   schedule;
//! * constant weights are generated from the plan's `weight_seed` and
//!   packed into their tuned storage layouts **once at compile time**
//!   (the paper's free offline weight transform);
//! * inter-op buffers stay in their producers' storage layouts and are
//!   fed straight into downstream nests — a layout repack (Fig. 5a
//!   conversion) is materialized only on edges where the consumer's
//!   read layout disagrees with the allocation layout, and simple
//!   (non-complex) operators absorb their output layouts in their own
//!   write pass (Fig. 5b);
//! * freed intermediate buffers return to a capacity pool and are
//!   recycled by later steps, so a run's allocation churn is bounded
//!   by the live set, not the node count.
//!
//! Execution is deterministic: complex nests inherit the interpreter's
//! bit-identical-across-thread-counts guarantee, and every simple
//! operator (pooling, softmax, layer-norm, padding, reductions,
//! element-wise) is evaluated in a fixed serial order.

// Same audit as runtime/native.rs: address arithmetic mixes i64
// expression values with usize indexing (the PR 6 u32-truncation bug
// class), so every narrowing cast is either checked or locally
// allowed with a justification.
#![warn(clippy::cast_possible_truncation)]

use std::borrow::Cow;
use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::time::Instant;

use crate::analysis::{self, Diagnostic, ProofKind, Severity};
use crate::error::{panic_error, Error, ErrorKind, Result};
use crate::graph::{EltKind, Graph, NodeId, OpKind, PoolKind};
use crate::layout::{LayoutSeq, LayoutTransform};
use crate::loops::LoopSchedule;
use crate::propagate::propagate;
use crate::rewrite::{self, RewriteKind};
use crate::runtime::{
    random_input, seeded_inputs, DegradeReason, ExecMode, ExecScratch,
    NativeExecutable, OperandView, RunStats, TensorSpec,
};
use crate::sim::HwProfile;
use crate::tensor::{Role, TensorId};
use crate::{bail, err};

use super::plan::{input_specs_of, output_spec_of, save_plan, TunedPlan};

/// How a complex step's operand slot is fed.
enum Operand {
    /// A live buffer in its allocation layout (the producer wrote it
    /// in exactly the layout this nest reads — no repack).
    Tensor(TensorId),
    /// The output of a preceding [`Step::Convert`] (Fig. 5a).
    Converted(usize),
    /// A compile-time constant (packed weight).
    Const(usize),
}

/// A Fig. 5a layout conversion on one edge. In [`ExecMode::Fast`] the
/// edge is *fused*: the consumer nest reads the producer's buffer
/// through the precompiled gather map and this step is skipped; in
/// [`ExecMode::Bytecode`] the repack materializes here element by
/// element (the pre-fusion reference path).
struct ConvertStep {
    tensor: TensorId,
    slot: usize,
    logical_shape: Vec<i64>,
    /// `None` when the source buffer is already logical row-major.
    from: Option<LayoutTransform>,
    to: LayoutTransform,
    /// Set on a folded-pad edge ([`RewriteKind::FoldPad`]): the source
    /// is the *pre-pad* tensor, and this is the logical embed map
    /// (`map[padded logical] = source logical`, `-1` = pad fill) plus
    /// the source's logical shape. `from` then unpacks the source
    /// shape, and the map slots between unpack and `to`.
    embed: Option<(Vec<i64>, Vec<i64>)>,
}

/// A boundary unpack/pack edge at a simple operator: the
/// expression-level transform (the bytecode reference path) plus its
/// index map precompiled at model-compile time (the fast path's
/// straight indexed copy; `-1` entries read/fill `0.0`).
struct BoundaryMap {
    tf: LayoutTransform,
    map: Vec<i64>,
}

impl BoundaryMap {
    /// Storage → logical edge (`map[logical] = storage addr`).
    fn unpack_edge(shape: &[i64], tf: LayoutTransform) -> Self {
        let map = tf.unpack_map(shape);
        Self { tf, map }
    }

    /// Logical → storage edge (`map[storage] = logical addr`).
    fn pack_edge(shape: &[i64], tf: LayoutTransform) -> Self {
        let map = tf.pack_map(shape);
        Self { tf, map }
    }
}

/// Indexed copy through a boundary map into a pooled buffer.
// Map entries are validated against the source length when the map is
// composed at compile time, so the per-element narrowing is safe.
#[allow(clippy::cast_possible_truncation)]
fn apply_map(map: &[i64], src: &[f32], mut out: Vec<f32>) -> Vec<f32> {
    out.clear();
    out.extend(
        map.iter().map(|&m| if m < 0 { 0.0 } else { src[m as usize] }),
    );
    out
}

/// A fused-in rewrite epilogue applied to a nest's finished output
/// buffer ([`RewriteKind::FuseEpilogue`] / [`RewriteKind::FoldBatchNorm`]).
/// Anchored rewrites require the identity output layout, so the buffer
/// is logical row-major and the line math applies in place of the
/// folded node's own interpreted step — same scalar code, same order.
enum EpiKind {
    Softmax { axis: usize },
    LayerNorm { axis: usize },
    /// The BN residual: `out[i] += consts[slot][i % channels]` (the
    /// multiplicative part folded into the packed weights).
    ChannelShift { slot: usize },
}

struct EpilogueStep {
    /// The folded graph node (for reporting; its simple step is gone).
    node: NodeId,
    kind: EpiKind,
}

/// One lowered complex nest (+ fused tail).
struct ComplexStep {
    node: NodeId,
    exe: NativeExecutable,
    operands: Vec<Operand>,
    /// Rewrite epilogue applied to the output buffer before commit.
    epilogue: Option<EpilogueStep>,
    /// Tensor whose storage buffer the nest writes (the folded
    /// epilogue node's output when one is fused).
    out: TensorId,
}

/// Where a simple (interpreted) operator reads one input.
enum SimpleSrc {
    /// Live buffer; unpacked to logical through the boundary map when
    /// the allocation layout is non-identity.
    Tensor(TensorId, Option<BoundaryMap>),
    /// Compile-time constant held in logical row-major form.
    Const(usize),
}

/// One interpreted operator (everything that is not a complex nest).
struct SimpleStep {
    node: NodeId,
    srcs: Vec<SimpleSrc>,
    out: TensorId,
    /// Pack the logical result into the output's allocation layout in
    /// the same write pass (an absorbed conversion, Fig. 5b).
    pack: Option<BoundaryMap>,
}

enum Step {
    Convert(ConvertStep),
    // boxed: a lowered executable is much larger than the other
    // variants, and plans hold one Step per node
    Complex(Box<ComplexStep>),
    Simple(SimpleStep),
}

/// A whole model compiled for the native backend.
pub struct CompiledModel {
    graph: Graph,
    plan: TunedPlan,
    steps: Vec<Step>,
    /// Compile-time constants: packed weights (complex operands) and
    /// logical weights (simple-op operands).
    consts: Vec<Vec<f32>>,
    n_conv_slots: usize,
    /// Per conversion slot: the source tensor the fused gather reads.
    conv_tensor: Vec<TensorId>,
    /// Per conversion slot: composed gather map (consumer storage index
    /// → producer storage index, `-1` → `0.0`), built once at compile.
    conv_gathers: Vec<Vec<i64>>,
    /// Per conversion slot: `true` when the composed gather map failed
    /// validation and the edge must materialize even in Fast mode (the
    /// consumer nest degraded with [`DegradeReason::GatherCompose`]).
    conv_forced: Vec<bool>,
    input_ids: Vec<TensorId>,
    output_id: TensorId,
    output_unpack: Option<BoundaryMap>,
    mode: ExecMode,
    /// Tensor buffers whose last use is step `i` (recycled after it).
    dies: Vec<Vec<TensorId>>,
    /// Conversion slots whose last use is step `i`.
    conv_dies: Vec<Vec<usize>>,
    /// Dataflow wavefronts over plan steps (step indices grouped by
    /// depth): steps in one wave read only buffers written by earlier
    /// waves, so they are mutually data-independent — the step-level
    /// projection of [`crate::graph::shard::exec_waves`], computed from
    /// each step's *actual* operand reads so fused tails (a nest
    /// reading a residual branch) are accounted for.
    step_waves: Vec<Vec<usize>>,
    complex_steps: usize,
    simple_steps: usize,
    conversions: usize,
    /// Conversion edges pinned to materialization (invalid composed
    /// gather map); excluded from Fast mode's fused-repack count.
    forced_convs: usize,
    boundary_repacks: usize,
    weights_total: usize,
    weights_packed: usize,
    packing_ms: f64,
    compile_ms: f64,
    /// Graph rewrites baked into this plan (== `plan.rewrites.len()`).
    rewrites_applied: usize,
    /// Matched-but-unapplied rewrite candidates (dead opportunities the
    /// linter surfaces).
    dead_rewrites: Vec<rewrite::Candidate>,
}

/// Deterministic logical weight data for tensor `t` (shared convention
/// with the runtime's seeded inputs: one stream per tensor id).
// Dims are validated ≥ 1 at graph construction; they fit usize.
#[allow(clippy::cast_possible_truncation)]
pub fn weight_data(graph: &Graph, t: TensorId, weight_seed: u64) -> Vec<f32> {
    let ten = graph.tensor(t);
    let spec = TensorSpec {
        dtype: "float32".into(),
        shape: ten.shape.iter().map(|&d| d as usize).collect(),
    };
    random_input(&spec, weight_seed.wrapping_add(t as u64))
}

/// Compile-time finiteness audit on a materialized constant: a NaN or
/// infinity baked into the weights would silently poison every
/// inference, so it is a typed [`ErrorKind::Compile`] refusal instead.
fn audit_weight(data: &[f32], graph: &Graph, t: TensorId) -> Result<()> {
    match data.iter().position(|v| !v.is_finite()) {
        None => Ok(()),
        Some(i) => Err(Error::with_kind(
            ErrorKind::Compile,
            format!(
                "{}: weight {} has non-finite element {} ({})",
                graph.name,
                graph.tensor(t).name,
                i,
                data[i]
            ),
        )),
    }
}

pub(crate) fn compile_model(
    graph: &Graph,
    hw: &HwProfile,
    plan: &TunedPlan,
) -> Result<CompiledModel> {
    let t0 = Instant::now();
    plan.validate_against(graph)?;
    let decisions = plan.decisions();
    // Every rewrite in the plan must match a candidate a fresh analysis
    // of this graph produces (typed Compile refusal otherwise), so a
    // loaded plan re-derives exactly the rewritten execution plan the
    // tuner chose — rewrites are plan annotations, never graph edits.
    let analysis = rewrite::validate(graph, &plan.rewrites, &decisions)?;
    let scheds = plan.scheds();
    let prop = propagate(graph, &decisions, plan.mode);

    let input_ids: Vec<TensorId> = graph
        .tensors
        .iter()
        .filter(|t| t.role == Role::Input)
        .map(|t| t.id)
        .collect();
    for &t in &input_ids {
        if !prop.layouts.get(t).is_identity() {
            bail!(
                "graph input {} carries a non-identity allocation layout",
                graph.tensor(t).name
            );
        }
    }
    let output_id = graph
        .nodes
        .last()
        .ok_or_else(|| err!("{}: empty graph", graph.name))?
        .output;

    let mut steps: Vec<Step> = Vec::new();
    let mut consts: Vec<Vec<f32>> = Vec::new();
    let mut const_key: HashMap<(TensorId, LayoutSeq), usize> = HashMap::new();
    let mut n_conv_slots = 0usize;
    let mut conv_tensor: Vec<TensorId> = Vec::new();
    let mut conv_gathers: Vec<Vec<i64>> = Vec::new();
    let mut conv_forced: Vec<bool> = Vec::new();
    let (mut conversions, mut boundary_repacks) = (0usize, 0usize);
    let mut forced_convs = 0usize;
    let (mut weights_total, mut weights_packed) = (0usize, 0usize);
    let mut packing_ms = 0.0f64;

    // Fusion groups may overlap at residual joins: two complex ops'
    // chains share the `add → …` suffix (the propagation pass — and
    // the simulator, which merely double-counts the cheap tail —
    // tolerate this). Execution must compute every fused node exactly
    // once, so the LAST claimant in topological order owns each node:
    // chains that merge walk identically afterwards, so the owned
    // nodes of any chain form a prefix, earlier claimants truncate
    // their tails before the shared suffix, and their nests then
    // materialize exactly the tensor the owner's join reads.
    let mut tail_owner: HashMap<NodeId, NodeId> = HashMap::new();
    for node in &graph.nodes {
        if let Some(tail) = prop.fused_tails.get(&node.id) {
            for &t in tail {
                tail_owner.insert(t, node.id);
            }
        }
    }

    // ---- partition the plan's rewrites by execution mechanism ----
    // skip: nodes whose own step disappears (computed elsewhere);
    // pad_fold_src: padded tensor → folded PadOp node (the consumer
    // nest's operand edge becomes an embedding conversion);
    // epi_of: anchor nest → folded epilogue/BN node.
    let mut skip: HashSet<NodeId> = HashSet::new();
    let mut const_fold_nodes: Vec<NodeId> = Vec::new();
    let mut pad_fold_src: HashMap<TensorId, NodeId> = HashMap::new();
    let mut epi_of: HashMap<NodeId, NodeId> = HashMap::new();
    for r in &plan.rewrites {
        skip.insert(r.node);
        match r.kind {
            RewriteKind::FoldConstant => const_fold_nodes.push(r.node),
            RewriteKind::FoldPad => {
                pad_fold_src.insert(graph.node(r.node).output, r.node);
            }
            RewriteKind::FoldBatchNorm | RewriteKind::FuseEpilogue => {
                if epi_of.insert(r.anchor, r.node).is_some() {
                    bail!(
                        "{}: two rewrites fuse into anchor node {}",
                        graph.name,
                        r.anchor
                    );
                }
            }
        }
    }

    // ---- constant folding: evaluate folded nodes at compile time ----
    // Topological (node-id) order lets folds cascade; results land in
    // the const table exactly like weights, so consumers read them as
    // compile-time constants and the folded steps never execute.
    let mut folded_const: HashMap<TensorId, usize> = HashMap::new();
    const_fold_nodes.sort_unstable();
    {
        let mut ws = WorkScratch::default();
        for &nid in &const_fold_nodes {
            let node = graph.node(nid);
            let mut owned: Vec<Vec<f32>> = Vec::with_capacity(node.inputs.len());
            for &t in &node.inputs {
                owned.push(match folded_const.get(&t) {
                    Some(&slot) => consts[slot].clone(),
                    None => weight_data(graph, t, plan.weight_seed),
                });
            }
            let slices: Vec<&[f32]> = owned.iter().map(|v| v.as_slice()).collect();
            let data = interp_simple(graph, nid, &slices, &mut ws)
                .map_err(|e| {
                    e.context(format!(
                        "constant-folding node {} ({}) of {}",
                        nid, node.name, graph.name
                    ))
                })?;
            audit_weight(&data, graph, node.output)?;
            consts.push(data);
            folded_const.insert(node.output, consts.len() - 1);
        }
    }

    for node in &graph.nodes {
        if prop.fused_nodes.contains(&node.id) {
            continue; // computed inside the owning complex nest
        }
        if skip.contains(&node.id) {
            continue; // folded or fused away by a plan rewrite
        }
        match &node.kind {
            OpKind::Conv { .. } | OpKind::Matmul | OpKind::Dense => {
                let mut tail = prop
                    .fused_tails
                    .get(&node.id)
                    .cloned()
                    .unwrap_or_default();
                if let Some(cut) = tail
                    .iter()
                    .position(|t| tail_owner.get(t) != Some(&node.id))
                {
                    tail.truncate(cut);
                }
                let sched = scheds.get(&node.id).cloned().unwrap_or_else(|| {
                    let (sp, rd) =
                        crate::autotune::tuner::nest_dims(graph, node.id, &prop);
                    LoopSchedule::identity(&sp, &rd)
                });
                let mut exe = NativeExecutable::compile(
                    &node.name,
                    graph,
                    node.id,
                    &tail,
                    &prop.layouts,
                    &sched,
                    hw.simd_lanes,
                    plan.threads,
                )
                .map_err(|e| {
                    e.context(format!(
                        "compiling node {} ({}) of {}",
                        node.id, node.name, graph.name
                    ))
                })?;
                let out = exe.written_tensor();
                // A fused epilogue / folded BN on this anchor: resolve
                // it before the operand loop, because BN folding scales
                // the weight operand as it is packed.
                let mut epilogue: Option<EpilogueStep> = None;
                let mut bn_scale: Option<Vec<f32>> = None;
                if let Some(&en) = epi_of.get(&node.id) {
                    let enode = graph.node(en);
                    if enode.inputs[0] != out {
                        bail!(
                            "{}: rewrite fuses node {} into {}, which \
                             writes t{} not t{}",
                            graph.name,
                            en,
                            node.name,
                            out,
                            enode.inputs[0]
                        );
                    }
                    let kind = match &enode.kind {
                        OpKind::Softmax { axis } => {
                            EpiKind::Softmax { axis: *axis }
                        }
                        OpKind::LayerNorm { axis } => {
                            EpiKind::LayerNorm { axis: *axis }
                        }
                        OpKind::BatchNorm => {
                            // scale = gamma / sqrt(var + eps) folds into
                            // the packed weights; shift = beta - mean *
                            // scale survives as a per-channel epilogue
                            let s = plan.weight_seed;
                            let gamma =
                                weight_data(graph, enode.inputs[1], s);
                            let beta =
                                weight_data(graph, enode.inputs[2], s);
                            let mean =
                                weight_data(graph, enode.inputs[3], s);
                            let var = weight_data(graph, enode.inputs[4], s);
                            let scale: Vec<f32> = gamma
                                .iter()
                                .zip(&var)
                                .map(|(g, v)| g / (v + 1e-5).sqrt())
                                .collect();
                            let shift: Vec<f32> = beta
                                .iter()
                                .zip(&mean)
                                .zip(&scale)
                                .map(|((b, m), sc)| b - m * sc)
                                .collect();
                            audit_weight(&scale, graph, enode.inputs[1])?;
                            audit_weight(&shift, graph, enode.inputs[2])?;
                            consts.push(shift);
                            bn_scale = Some(scale);
                            EpiKind::ChannelShift { slot: consts.len() - 1 }
                        }
                        other => bail!(
                            "{}: node {} ({other:?}) cannot fuse as an \
                             epilogue",
                            graph.name,
                            en
                        ),
                    };
                    epilogue = Some(EpilogueStep { node: en, kind });
                }
                let mut operands = Vec::new();
                for (i, &t) in exe.operand_tensors().iter().enumerate() {
                    let ten = graph.tensor(t);
                    let read = prop.layouts.get_for(node.id, t);
                    if ten.role == Role::Weight {
                        if let (Some(scale), true) =
                            (&bn_scale, t == node.inputs[1])
                        {
                            // BN-scaled weight: unique to this anchor,
                            // so it bypasses the shared const cache
                            let tp = Instant::now();
                            let mut data =
                                weight_data(graph, t, plan.weight_seed);
                            let o = scale.len();
                            for (j, v) in data.iter_mut().enumerate() {
                                *v *= scale[j % o];
                            }
                            let packed = exe.pack_operand(i, &data)?;
                            audit_weight(&packed, graph, t)?;
                            packing_ms += tp.elapsed().as_secs_f64() * 1e3;
                            weights_total += 1;
                            if !read.is_identity() {
                                weights_packed += 1;
                            }
                            consts.push(packed);
                            operands.push(Operand::Const(consts.len() - 1));
                            continue;
                        }
                        let key = (t, read.clone());
                        let slot = match const_key.get(&key) {
                            Some(&s) => s,
                            None => {
                                let tp = Instant::now();
                                #[allow(unused_mut)]
                                let mut data =
                                    weight_data(graph, t, plan.weight_seed);
                                #[cfg(feature = "fault-inject")]
                                if crate::faults::fire(
                                    crate::faults::FaultSite::NanWeight,
                                ) {
                                    if let Some(v) = data.first_mut() {
                                        *v = f32::NAN;
                                    }
                                }
                                let packed = exe.pack_operand(i, &data)?;
                                audit_weight(&packed, graph, t)?;
                                packing_ms += tp.elapsed().as_secs_f64() * 1e3;
                                // both counters count unique constants,
                                // so packed/total is a true ratio
                                weights_total += 1;
                                if !read.is_identity() {
                                    weights_packed += 1;
                                }
                                consts.push(packed);
                                const_key.insert(key, consts.len() - 1);
                                consts.len() - 1
                            }
                        };
                        operands.push(Operand::Const(slot));
                    } else if let Some(&ls) = folded_const.get(&t) {
                        // constant-folded producer: the nest reads a
                        // packed compile-time constant instead of a
                        // live buffer
                        let key = (t, read.clone());
                        let slot = match const_key.get(&key) {
                            Some(&s) => s,
                            None => {
                                let data = consts[ls].clone();
                                let packed = exe.pack_operand(i, &data)?;
                                consts.push(packed);
                                const_key.insert(key, consts.len() - 1);
                                consts.len() - 1
                            }
                        };
                        operands.push(Operand::Const(slot));
                    } else if let Some(&pad_id) = pad_fold_src.get(&t) {
                        // folded pad (FoldPad): the PadOp step is gone;
                        // this edge reads the *pre-pad* tensor through
                        // an embedding conversion whose `-1` slots fill
                        // 0.0 — bit-for-bit the zeros the PadOp would
                        // have written, in the same nest read order.
                        let pad = graph.node(pad_id);
                        let OpKind::PadOp { before, .. } = &pad.kind else {
                            bail!(
                                "{}: fold_pad names non-pad node {}",
                                graph.name,
                                pad_id
                            );
                        };
                        let t_src = pad.inputs[0];
                        let src_shape = graph.tensor(t_src).shape.clone();
                        let src_alloc = prop.layouts.get(t_src);
                        let slot = n_conv_slots;
                        n_conv_slots += 1;
                        conversions += 1;
                        let from = (!src_alloc.is_identity()).then(|| {
                            LayoutTransform::new(src_shape.clone(), &src_alloc)
                        });
                        let to = LayoutTransform::new(ten.shape.clone(), &read);
                        // logical embed map: padded idx → source idx|-1
                        let sstr = strides_of(&src_shape);
                        let padded_len: i64 = ten.shape.iter().product();
                        let mut embed_map = Vec::with_capacity(
                            usize::try_from(padded_len).unwrap_or(0),
                        );
                        for_each_index(&ten.shape, |idx| {
                            let mut off = 0i64;
                            let mut inside = true;
                            for (d, &iv) in idx.iter().enumerate() {
                                let s = iv - before[d];
                                if s < 0 || s >= src_shape[d] {
                                    inside = false;
                                    break;
                                }
                                off += s * sstr[d];
                            }
                            embed_map.push(if inside { off } else { -1 });
                        });
                        // compose consumer pack ∘ embed ∘ source unpack
                        let pm = to.pack_map(&ten.shape);
                        let um =
                            from.as_ref().map(|f| f.unpack_map(&src_shape));
                        let gather: Vec<i64> = pm
                            .iter()
                            .map(|&l| match usize::try_from(l) {
                                Err(_) => -1,
                                Ok(lp) => {
                                    match usize::try_from(embed_map[lp]) {
                                        Err(_) => -1,
                                        Ok(lsrc) => um
                                            .as_ref()
                                            .map_or(embed_map[lp], |m| {
                                                m[lsrc]
                                            }),
                                    }
                                }
                            })
                            .collect();
                        let src_len = match &from {
                            None => src_shape.iter().product::<i64>(),
                            Some(f) => f.pack_map(&src_shape).len() as i64,
                        };
                        let forced = gather.iter().any(|&g| g >= src_len);
                        if forced {
                            forced_convs += 1;
                            exe.degrade(DegradeReason::GatherCompose);
                        }
                        conv_forced.push(forced);
                        conv_tensor.push(t_src);
                        conv_gathers.push(gather);
                        steps.push(Step::Convert(ConvertStep {
                            tensor: t_src,
                            slot,
                            logical_shape: ten.shape.clone(),
                            from,
                            to,
                            embed: Some((embed_map, src_shape)),
                        }));
                        operands.push(Operand::Converted(slot));
                    } else {
                        let alloc = prop.layouts.get(t);
                        if read == alloc {
                            operands.push(Operand::Tensor(t));
                        } else {
                            // a conversion operator sits on this edge
                            let slot = n_conv_slots;
                            n_conv_slots += 1;
                            conversions += 1;
                            let from = (!alloc.is_identity()).then(|| {
                                LayoutTransform::new(ten.shape.clone(), &alloc)
                            });
                            let to =
                                LayoutTransform::new(ten.shape.clone(), &read);
                            // Compose unpack∘pack into one gather map:
                            // consumer-read storage index → producer
                            // storage index (-1 reads as the repack's
                            // 0.0 fill). The consumer nest reads the
                            // producer buffer through it directly, so
                            // the Fig. 5a copy disappears in Fast mode.
                            let pm = to.pack_map(&ten.shape);
                            let gather: Vec<i64> = match &from {
                                None => pm,
                                Some(f) => {
                                    let um = f.unpack_map(&ten.shape);
                                    // -1 (pad fill) passes through; any
                                    // in-range index is re-looked-up in
                                    // the producer's unpack map
                                    pm.iter()
                                        .map(|&l| {
                                            usize::try_from(l)
                                                .map_or(-1, |i| um[i])
                                        })
                                        .collect()
                                }
                            };
                            // Validate the composition against the
                            // producer's actual storage length. A
                            // composed index past the source buffer
                            // can't be fused (either executor would
                            // read out of bounds through the map), so
                            // the edge pins to materialization and the
                            // consumer nest records the degrade.
                            let src_len = match &from {
                                None => ten.shape.iter().product::<i64>(),
                                Some(f) => {
                                    f.pack_map(&ten.shape).len() as i64
                                }
                            };
                            let forced =
                                gather.iter().any(|&g| g >= src_len);
                            if forced {
                                forced_convs += 1;
                                exe.degrade(DegradeReason::GatherCompose);
                            }
                            conv_forced.push(forced);
                            conv_tensor.push(t);
                            conv_gathers.push(gather);
                            steps.push(Step::Convert(ConvertStep {
                                tensor: t,
                                slot,
                                logical_shape: ten.shape.clone(),
                                from,
                                to,
                                embed: None,
                            }));
                            operands.push(Operand::Converted(slot));
                        }
                    }
                }
                let step_out =
                    epilogue.as_ref().map_or(out, |e| graph.node(e.node).output);
                steps.push(Step::Complex(Box::new(ComplexStep {
                    node: node.id,
                    exe,
                    operands,
                    epilogue,
                    out: step_out,
                })));
            }
            OpKind::LayoutConvert => {
                bail!("{}: standalone LayoutConvert nodes are unsupported", node.name)
            }
            _ => {
                let mut srcs = Vec::new();
                for &t in &node.inputs {
                    let ten = graph.tensor(t);
                    if let Some(&slot) = folded_const.get(&t) {
                        // constant-folded producer, held logical
                        srcs.push(SimpleSrc::Const(slot));
                        continue;
                    }
                    if ten.role == Role::Weight {
                        let key = (t, LayoutSeq::new());
                        let slot = match const_key.get(&key) {
                            Some(&s) => s,
                            None => {
                                // logical (identity-layout) constant
                                weights_total += 1;
                                #[allow(unused_mut)]
                                let mut data =
                                    weight_data(graph, t, plan.weight_seed);
                                #[cfg(feature = "fault-inject")]
                                if crate::faults::fire(
                                    crate::faults::FaultSite::NanWeight,
                                ) {
                                    if let Some(v) = data.first_mut() {
                                        *v = f32::NAN;
                                    }
                                }
                                audit_weight(&data, graph, t)?;
                                consts.push(data);
                                const_key.insert(key, consts.len() - 1);
                                consts.len() - 1
                            }
                        };
                        srcs.push(SimpleSrc::Const(slot));
                    } else {
                        let alloc = prop.layouts.get(t);
                        let tf = if alloc.is_identity() {
                            None
                        } else {
                            boundary_repacks += 1;
                            Some(BoundaryMap::unpack_edge(
                                &ten.shape,
                                LayoutTransform::new(ten.shape.clone(), &alloc),
                            ))
                        };
                        srcs.push(SimpleSrc::Tensor(t, tf));
                    }
                }
                let oalloc = prop.layouts.get(node.output);
                let pack = if oalloc.is_identity() {
                    None
                } else {
                    boundary_repacks += 1;
                    let oshape = graph.tensor(node.output).shape.clone();
                    Some(BoundaryMap::pack_edge(
                        &oshape,
                        LayoutTransform::new(oshape.clone(), &oalloc),
                    ))
                };
                steps.push(Step::Simple(SimpleStep {
                    node: node.id,
                    srcs,
                    out: node.output,
                    pack,
                }));
            }
        }
    }

    // ---- liveness: recycle buffers after their last reading step ----
    let mut last_use: HashMap<TensorId, usize> = HashMap::new();
    let mut conv_last: HashMap<usize, usize> = HashMap::new();
    for (si, step) in steps.iter().enumerate() {
        match step {
            Step::Convert(c) => {
                last_use.insert(c.tensor, si);
            }
            Step::Complex(cs) => {
                for o in &cs.operands {
                    match o {
                        Operand::Tensor(t) => {
                            last_use.insert(*t, si);
                        }
                        Operand::Converted(s) => {
                            conv_last.insert(*s, si);
                            // In Fast mode the conversion is fused: the
                            // nest reads the *source* buffer through
                            // the gather map here, so the source must
                            // stay live through this step (covers both
                            // modes — this index is past the Convert
                            // step's).
                            last_use.insert(conv_tensor[*s], si);
                        }
                        Operand::Const(_) => {}
                    }
                }
            }
            Step::Simple(ss) => {
                for s in &ss.srcs {
                    if let SimpleSrc::Tensor(t, _) = s {
                        last_use.insert(*t, si);
                    }
                }
            }
        }
    }
    let mut dies = vec![Vec::new(); steps.len()];
    for (&t, &si) in &last_use {
        if t != output_id {
            dies[si].push(t);
        }
    }
    for d in dies.iter_mut() {
        d.sort_unstable();
    }
    let mut conv_dies = vec![Vec::new(); steps.len()];
    for (&s, &si) in &conv_last {
        conv_dies[si].push(s);
    }
    for d in conv_dies.iter_mut() {
        d.sort_unstable();
    }

    // ---- dataflow wavefronts over steps (intra-request pipelining) ----
    // wave(step) = max over its reads of (writer's wave + 1); graph
    // inputs and constants are ready at wave 0. A Complex step's reads
    // include both a conversion slot and its source tensor, covering
    // the fused (Fast) and materialized (Bytecode) read paths with one
    // mode-independent structure.
    let mut step_waves: Vec<Vec<usize>> = Vec::new();
    {
        let mut tensor_ready: HashMap<TensorId, usize> = HashMap::new();
        let mut conv_ready: HashMap<usize, usize> = HashMap::new();
        for (si, step) in steps.iter().enumerate() {
            let mut w = 0usize;
            {
                let mut need_t = |t: TensorId, w: &mut usize| {
                    *w = (*w).max(tensor_ready.get(&t).copied().unwrap_or(0));
                };
                match step {
                    Step::Convert(c) => need_t(c.tensor, &mut w),
                    Step::Complex(cs) => {
                        for o in &cs.operands {
                            match o {
                                Operand::Tensor(t) => need_t(*t, &mut w),
                                Operand::Converted(s) => {
                                    w = w.max(
                                        conv_ready
                                            .get(s)
                                            .copied()
                                            .unwrap_or(0),
                                    );
                                    need_t(conv_tensor[*s], &mut w);
                                }
                                Operand::Const(_) => {}
                            }
                        }
                    }
                    Step::Simple(ss) => {
                        for s in &ss.srcs {
                            if let SimpleSrc::Tensor(t, _) = s {
                                need_t(*t, &mut w);
                            }
                        }
                    }
                }
            }
            if step_waves.len() <= w {
                step_waves.resize_with(w + 1, Vec::new);
            }
            step_waves[w].push(si);
            match step {
                Step::Convert(c) => {
                    conv_ready.insert(c.slot, w + 1);
                }
                Step::Complex(cs) => {
                    tensor_ready.insert(cs.out, w + 1);
                }
                Step::Simple(ss) => {
                    tensor_ready.insert(ss.out, w + 1);
                }
            }
        }
    }

    let out_seq = prop.layouts.get(output_id);
    let output_unpack = (!out_seq.is_identity()).then(|| {
        let shape = graph.tensor(output_id).shape.clone();
        BoundaryMap::unpack_edge(
            &shape,
            LayoutTransform::new(shape.clone(), &out_seq),
        )
    });

    let complex_steps =
        steps.iter().filter(|s| matches!(s, Step::Complex(_))).count();
    let simple_steps =
        steps.iter().filter(|s| matches!(s, Step::Simple(_))).count();

    // candidates the plan left on the table — the `alt check` linter's
    // dead-rewrite-opportunity findings
    let dead_rewrites: Vec<rewrite::Candidate> = analysis
        .candidates
        .iter()
        .filter(|c| !plan.rewrites.iter().any(|r| *r == c.decision()))
        .copied()
        .collect();

    Ok(CompiledModel {
        graph: graph.clone(),
        plan: plan.clone(),
        steps,
        consts,
        n_conv_slots,
        conv_tensor,
        conv_gathers,
        conv_forced,
        input_ids,
        output_id,
        output_unpack,
        mode: ExecMode::Fast,
        dies,
        conv_dies,
        step_waves,
        complex_steps,
        simple_steps,
        conversions,
        forced_convs,
        boundary_repacks,
        weights_total,
        weights_packed,
        packing_ms,
        compile_ms: t0.elapsed().as_secs_f64() * 1e3,
        rewrites_applied: plan.rewrites.len(),
        dead_rewrites,
    })
}

/// Take a zeroed buffer of `n` elements, recycling pooled capacity.
fn take(pool: &mut Vec<Vec<f32>>, n: usize) -> Vec<f32> {
    let mut b = pool.pop().unwrap_or_default();
    b.clear();
    b.resize(n, 0f32);
    b
}

/// Worker-local compute scratch: every vector one step's *computation*
/// would otherwise allocate per call (nest env/stack, pooling
/// coordinates, line-op line/result buffers) plus the recycled-capacity
/// buffer pool. Holds no per-tensor state, so pipelined execution can
/// hand each core its own `WorkScratch` against one shared buffer set.
#[derive(Default)]
struct WorkScratch {
    exec: ExecScratch,
    pool: Vec<Vec<f32>>,
    idx: Vec<i64>,
    line: Vec<f32>,
    res: Vec<f32>,
}

/// Reusable per-run execution state: the live tensor/conversion buffer
/// sets plus a [`WorkScratch`]. A fresh default works for any model;
/// reusing one across runs (the [`CompiledModel::run_in`] family) keeps
/// every `f32` buffer in the pool, so steady-state serving stops
/// allocating. One scratch serves one request at a time — servers keep
/// one per worker.
#[derive(Default)]
pub struct RunScratch {
    work: WorkScratch,
    bufs: Vec<Option<Vec<f32>>>,
    convs: Vec<Option<Vec<f32>>>,
}

/// Per-request scratch set for [`CompiledModel::run_batch_in`]: one
/// [`RunScratch`] per batch lane, grown on demand and reused across
/// batches.
#[derive(Default)]
pub struct BatchScratch {
    per: Vec<RunScratch>,
}

/// Worker-local scratches for [`CompiledModel::run_pipelined_in`]: one
/// [`WorkScratch`] per pipeline core, grown on demand.
#[derive(Default)]
pub struct PipeScratch {
    workers: Vec<WorkScratch>,
}

/// Per-phase wall-clock breakdown of one inference (milliseconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseBreakdown {
    /// Complex nest execution (including fused gather reads).
    pub nest_ms: f64,
    /// Materialized Fig. 5a conversion steps (zero in Fast mode, where
    /// conversions fuse into consumer read streams).
    pub repack_ms: f64,
    /// Simple-op boundary unpack/pack passes + the final output unpack.
    pub boundary_ms: f64,
    /// Simple-op compute (interpreted, logical row-major).
    pub simple_ms: f64,
    /// Portion of `nest_ms` spent in nests running degraded (their
    /// fast plan failed to compile or was revoked) — zero on a fully
    /// healthy model.
    pub degraded_ms: f64,
    /// Time the request waited in a serving queue before a worker
    /// picked it up (zero outside the [`crate::api::serve`] layer —
    /// direct `run*` calls never queue).
    pub queue_ms: f64,
}

impl PhaseBreakdown {
    fn accum(&mut self, o: &PhaseBreakdown) {
        self.nest_ms += o.nest_ms;
        self.repack_ms += o.repack_ms;
        self.boundary_ms += o.boundary_ms;
        self.simple_ms += o.simple_ms;
        self.degraded_ms += o.degraded_ms;
        self.queue_ms += o.queue_ms;
    }
}

/// One completed inference: run stats, per-phase breakdown, and the
/// logical row-major output.
pub type RunOutput = (RunStats, PhaseBreakdown, Vec<f32>);

/// Where one computed step result lands when committed.
enum StepTarget {
    /// A tensor's storage buffer.
    Tensor(TensorId),
    /// A Fig. 5a conversion slot.
    Conv(usize),
}

/// Health of one complex nest in a compiled model.
#[derive(Clone, Debug)]
pub struct NestHealth {
    /// Graph node the nest lowers.
    pub node: NodeId,
    pub name: String,
    /// Whether a strided fast plan is live for this nest.
    pub fast: bool,
    /// Whether parallel workers write the shared output directly
    /// (write map proven injective) rather than staging scatters.
    pub writes_direct: bool,
    /// How the write-map certificate was obtained: symbolically by the
    /// analyzer, by fallback enumeration under the 2^22 cap, or not at
    /// all.
    pub write_proof: ProofKind,
    /// Data-race-freedom certificate: the nest either runs on one
    /// worker or its parallel workers write disjoint output slices
    /// (write map proven injective + in-bounds at compile time).
    pub race_free: bool,
    /// Every read stream proven in-bounds over the full iteration box
    /// (the runtime checks guarding them are dead weight).
    pub reads_bounded: bool,
    /// Whether the nest runs on more than one worker.
    pub parallel: bool,
    /// Why the fast plan is absent (`None` when `fast`).
    pub degraded: Option<DegradeReason>,
}

/// Per-nest degradation report for a whole compiled model — the
/// serving-side view of the degradation ladder. A model is fully
/// healthy iff `degraded_nests == 0` and `forced_repacks == 0`.
#[derive(Clone, Debug, Default)]
pub struct HealthReport {
    /// One entry per complex nest, plan order.
    pub nests: Vec<NestHealth>,
    /// Nests currently running on the bytecode interpreter.
    pub degraded_nests: usize,
    /// Conversion edges pinned to materialization because their
    /// composed gather map failed validation.
    pub forced_repacks: usize,
    /// Graph rewrites baked into the compiled plan.
    pub rewrites_applied: usize,
    /// Rewrite candidates the matcher found on this graph (applied +
    /// dead opportunities).
    pub rewrites_available: usize,
}

/// Row-major strides of a shape.
fn strides_of(shape: &[i64]) -> Vec<i64> {
    let mut s = vec![1i64; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

/// Visit every multi-index of `extents` in row-major order.
fn for_each_index(extents: &[i64], mut f: impl FnMut(&[i64])) {
    let total: i64 = extents.iter().product();
    let mut idx = vec![0i64; extents.len()];
    for _ in 0..total {
        f(&idx);
        for d in (0..extents.len()).rev() {
            idx[d] += 1;
            if idx[d] < extents[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

/// Unary elementwise scalar — the same definitions the native nest's
/// fused tail uses, so fused and unfused evaluation agree.
fn elt_unary(kind: EltKind, x: f32) -> f32 {
    match kind {
        EltKind::Relu => x.max(0.0),
        EltKind::Relu6 => x.clamp(0.0, 6.0),
        EltKind::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        EltKind::Gelu => {
            0.5 * x * (1.0 + (0.797_884_6_f32 * (x + 0.044_715 * x * x * x)).tanh())
        }
        EltKind::Tanh => x.tanh(),
        EltKind::Identity => x,
        EltKind::Add | EltKind::Mul => x,
    }
}

/// Softmax over one line — shared by the interpreted `Softmax` step and
/// the fused-epilogue path, so fused and unfused outputs are
/// bit-identical.
fn softmax_line(line: &[f32], out: &mut [f32]) {
    let mut m = f32::NEG_INFINITY;
    for &v in line.iter() {
        m = m.max(v);
    }
    let mut sum = 0.0f32;
    for (o, &v) in out.iter_mut().zip(line.iter()) {
        *o = (v - m).exp();
        sum += *o;
    }
    for o in out.iter_mut() {
        *o /= sum;
    }
}

/// LayerNorm over one line — shared between the interpreted step and
/// the fused-epilogue path (same scalar order, bit-identical).
fn layernorm_line(line: &[f32], out: &mut [f32]) {
    let m = line.len() as f32;
    let mean = line.iter().sum::<f32>() / m;
    let var = line.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / m;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for (o, &v) in out.iter_mut().zip(line.iter()) {
        *o = (v - mean) * inv;
    }
}

/// Evaluate one simple operator on logical row-major inputs.
// Per-element offsets are products of validated positive dims bounded
// by the (usize-sized) output/input buffer lengths by construction.
#[allow(clippy::cast_possible_truncation)]
fn interp_simple(
    graph: &Graph,
    node: NodeId,
    ins: &[&[f32]],
    ws: &mut WorkScratch,
) -> Result<Vec<f32>> {
    let WorkScratch { pool, idx, line, res, .. } = ws;
    let n = graph.node(node);
    let out_shape = graph.tensor(n.output).shape.clone();
    let out_len: i64 = out_shape.iter().product();
    match &n.kind {
        OpKind::Eltwise { kind, arity } => {
            if ins.len() != *arity {
                bail!("{}: arity {} vs {} inputs", n.name, arity, ins.len());
            }
            let mut out = take(pool, out_len as usize);
            match kind {
                EltKind::Add => {
                    out.copy_from_slice(ins[0]);
                    for src in &ins[1..] {
                        for (o, v) in out.iter_mut().zip(*src) {
                            *o += v;
                        }
                    }
                }
                EltKind::Mul => {
                    out.copy_from_slice(ins[0]);
                    for src in &ins[1..] {
                        for (o, v) in out.iter_mut().zip(*src) {
                            *o *= v;
                        }
                    }
                }
                k => {
                    for (o, &v) in out.iter_mut().zip(ins[0]) {
                        *o = elt_unary(*k, v);
                    }
                }
            }
            Ok(out)
        }
        OpKind::BiasAdd => {
            let Some(&last) = out_shape.last() else {
                bail!("{}: bias-add on a scalar output", n.name);
            };
            let c = last as usize;
            let mut out = take(pool, out_len as usize);
            for (i, (o, &v)) in out.iter_mut().zip(ins[0]).enumerate() {
                *o = v + ins[1][i % c];
            }
            Ok(out)
        }
        OpKind::PadOp { before, .. } => {
            let in_shape = &graph.tensor(n.inputs[0]).shape;
            let ostr = strides_of(&out_shape);
            let mut out = take(pool, out_len as usize);
            let x = ins[0];
            let mut flat = 0usize;
            for_each_index(in_shape, |idx| {
                let mut off = 0i64;
                for (d, &i) in idx.iter().enumerate() {
                    off += (i + before[d]) * ostr[d];
                }
                out[off as usize] = x[flat];
                flat += 1;
            });
            Ok(out)
        }
        OpKind::Pool { kind, kernel, stride } => {
            let in_shape = &graph.tensor(n.inputs[0]).shape;
            let sp = kernel.len();
            let xstr = strides_of(in_shape);
            let rank = out_shape.len();
            let mut out = take(pool, out_len as usize);
            let x = ins[0];
            let oc = &mut *idx;
            oc.clear();
            oc.resize(rank, 0);
            let kelems = kernel.iter().product::<i64>() as f32;
            for (flat, slot) in out.iter_mut().enumerate() {
                let mut rem = flat as i64;
                for d in (0..rank).rev() {
                    oc[d] = rem % out_shape[d];
                    rem /= out_shape[d];
                }
                let base = oc[0] * xstr[0] + oc[rank - 1] * xstr[rank - 1];
                let mut acc = match kind {
                    PoolKind::Max => f32::NEG_INFINITY,
                    PoolKind::Avg => 0.0,
                };
                for_each_index(kernel, |k| {
                    let mut off = base;
                    for d in 0..sp {
                        off += (oc[1 + d] * stride[d] + k[d]) * xstr[1 + d];
                    }
                    let v = x[off as usize];
                    match kind {
                        PoolKind::Max => acc = acc.max(v),
                        PoolKind::Avg => acc += v,
                    }
                });
                *slot = match kind {
                    PoolKind::Max => acc,
                    PoolKind::Avg => acc / kelems,
                };
            }
            Ok(out)
        }
        OpKind::Softmax { axis } => {
            line_op(ins[0], &out_shape, *axis, pool, line, res, softmax_line)
        }
        OpKind::LayerNorm { axis } => {
            line_op(ins[0], &out_shape, *axis, pool, line, res, layernorm_line)
        }
        OpKind::BatchNorm => {
            // inference-mode BN: out = x * scale[c] + shift[c] with
            // scale = gamma / sqrt(var + eps), shift = beta - mean *
            // scale — the same per-channel scalars the FoldBatchNorm
            // rewrite bakes into packed weights + a channel shift, in
            // the same arithmetic form (fold differs only by applying
            // the scale per-MAC instead of post-sum: reassociation).
            let Some(&last) = out_shape.last() else {
                bail!("{}: batchnorm on a scalar output", n.name);
            };
            let c = last as usize;
            let (gamma, beta, mean, var) = (ins[1], ins[2], ins[3], ins[4]);
            let mut out = take(pool, out_len as usize);
            for (i, (o, &x)) in out.iter_mut().zip(ins[0]).enumerate() {
                let ch = i % c;
                let scale = gamma[ch] / (var[ch] + 1e-5).sqrt();
                *o = x * scale + (beta[ch] - mean[ch] * scale);
            }
            Ok(out)
        }
        OpKind::Reduce { keep_last } => {
            let in_shape = &graph.tensor(n.inputs[0]).shape;
            let batch = in_shape[0] as usize;
            let Some(&last) = in_shape.last() else {
                bail!("{}: reduce on a scalar input", n.name);
            };
            let c = last as usize;
            let per_batch = ins[0].len() / batch;
            let mut out = take(pool, out_len as usize);
            if *keep_last {
                let mid = (per_batch / c) as f32;
                for (i, &v) in ins[0].iter().enumerate() {
                    out[(i / per_batch) * c + i % c] += v;
                }
                for o in out.iter_mut() {
                    *o /= mid;
                }
            } else {
                for (i, &v) in ins[0].iter().enumerate() {
                    out[i / per_batch] += v;
                }
                for o in out.iter_mut() {
                    *o /= per_batch as f32;
                }
            }
            Ok(out)
        }
        OpKind::Reshape { .. } => {
            let mut out = take(pool, out_len as usize);
            out.copy_from_slice(ins[0]);
            Ok(out)
        }
        other => bail!("{}: unsupported simple op {other:?}", n.name),
    }
}

/// Apply `f` to every 1-d line along `axis` of a row-major tensor.
/// `line`/`res` are caller-provided scratch (resized here) so repeated
/// runs allocate nothing per call.
// Line bases/strides are bounded by `x.len()` by construction.
#[allow(clippy::cast_possible_truncation)]
fn line_op(
    x: &[f32],
    shape: &[i64],
    axis: usize,
    pool: &mut Vec<Vec<f32>>,
    line: &mut Vec<f32>,
    res: &mut Vec<f32>,
    mut f: impl FnMut(&[f32], &mut [f32]),
) -> Result<Vec<f32>> {
    if axis >= shape.len() {
        bail!("axis {axis} out of range for shape {shape:?}");
    }
    let strides = strides_of(shape);
    let m = shape[axis] as usize;
    let sa = strides[axis] as usize;
    let mut out = take(pool, x.len());
    let mut outer_shape = shape.to_vec();
    outer_shape.remove(axis);
    let mut outer_strides = strides.clone();
    outer_strides.remove(axis);
    line.clear();
    line.resize(m, 0f32);
    res.clear();
    res.resize(m, 0f32);
    for_each_index(&outer_shape, |idx| {
        let mut base = 0i64;
        for (d, &i) in idx.iter().enumerate() {
            base += i * outer_strides[d];
        }
        let base = base as usize;
        for (j, l) in line.iter_mut().enumerate() {
            *l = x[base + j * sa];
        }
        f(line, res);
        for (j, &r) in res.iter().enumerate() {
            out[base + j * sa] = r;
        }
    });
    Ok(out)
}

impl CompiledModel {
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The durable plan this model was compiled from.
    pub fn plan(&self) -> &TunedPlan {
        &self.plan
    }

    /// Logical input specs (the graph's `Role::Input` tensors, id
    /// order) — what [`run`](Self::run) expects.
    pub fn input_specs(&self) -> Vec<TensorSpec> {
        input_specs_of(&self.graph)
    }

    /// Logical output spec (the final node's tensor).
    pub fn output_spec(&self) -> TensorSpec {
        output_spec_of(&self.graph)
    }

    /// Deterministic seeded inputs matching [`input_specs`](Self::input_specs).
    pub fn seeded_inputs(&self, seed: u64) -> Vec<Vec<f32>> {
        seeded_inputs(&self.input_specs(), seed)
    }

    /// Persist the plan + extended manifest into `dir`
    /// (`Session::load` restores it without re-tuning).
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        save_plan(dir.as_ref(), &self.plan, &self.graph)
    }

    /// Execute the whole model; returns stats only.
    pub fn run(&self, inputs: &[Vec<f32>]) -> Result<RunStats> {
        self.run_with_output(inputs).map(|(s, _)| s)
    }

    /// Execute the whole model, returning the logical row-major output.
    pub fn run_with_output(
        &self,
        inputs: &[Vec<f32>],
    ) -> Result<(RunStats, Vec<f32>)> {
        self.run_profiled(inputs).map(|(s, _, o)| (s, o))
    }

    /// [`run_with_output`](Self::run_with_output) that also reports the
    /// per-phase wall-clock breakdown of the inference.
    pub fn run_profiled(
        &self,
        inputs: &[Vec<f32>],
    ) -> Result<(RunStats, PhaseBreakdown, Vec<f32>)> {
        self.run_profiled_in(&mut RunScratch::default(), inputs)
    }

    /// [`run_with_output`](Self::run_with_output) against a caller-held
    /// [`RunScratch`]: after warmup every intermediate buffer comes out
    /// of the scratch's pool, so a serving worker that keeps its
    /// scratch across requests runs the f32 hot path allocation-free.
    /// One scratch serves one request at a time.
    pub fn run_in(
        &self,
        scratch: &mut RunScratch,
        inputs: &[Vec<f32>],
    ) -> Result<(RunStats, Vec<f32>)> {
        self.run_profiled_in(scratch, inputs).map(|(s, _, o)| (s, o))
    }

    /// Validate request inputs against the graph's input specs — typed
    /// [`ErrorKind::Input`] refusals for count, length, and finiteness.
    fn validate_inputs(&self, inputs: &[Vec<f32>]) -> Result<()> {
        let specs = self.input_specs();
        if inputs.len() != specs.len() {
            return Err(Error::with_kind(
                ErrorKind::Input,
                format!(
                    "{}: want {} inputs, got {}",
                    self.graph.name,
                    specs.len(),
                    inputs.len()
                ),
            ));
        }
        for ((data, spec), &t) in
            inputs.iter().zip(&specs).zip(&self.input_ids)
        {
            if data.len() != spec.elements() {
                return Err(Error::with_kind(
                    ErrorKind::Input,
                    format!(
                        "{}: input {} has {} elements, want {}",
                        self.graph.name,
                        self.graph.tensor(t).name,
                        data.len(),
                        spec.elements()
                    ),
                ));
            }
            if let Some(i) = data.iter().position(|v| !v.is_finite()) {
                return Err(Error::with_kind(
                    ErrorKind::Input,
                    format!(
                        "{}: input {} has non-finite element {} ({})",
                        self.graph.name,
                        self.graph.tensor(t).name,
                        i,
                        data[i]
                    ),
                ));
            }
        }
        Ok(())
    }

    /// Reclaim whatever a previous (possibly failed) run left live in
    /// `scratch` and seed the graph inputs from pooled buffers.
    fn seed_scratch(&self, scratch: &mut RunScratch, inputs: &[Vec<f32>]) {
        let RunScratch { work, bufs, convs } = scratch;
        for b in bufs.iter_mut().chain(convs.iter_mut()) {
            if let Some(v) = b.take() {
                work.pool.push(v);
            }
        }
        bufs.resize_with(self.graph.tensors.len(), || None);
        convs.resize_with(self.n_conv_slots, || None);
        for (&t, data) in self.input_ids.iter().zip(inputs) {
            let mut b = work.pool.pop().unwrap_or_default();
            b.clear();
            b.extend_from_slice(data);
            bufs[t] = Some(b);
        }
    }

    /// Compute step `si` without touching shared state: read the live
    /// buffer sets, return the produced buffer (if any) for the caller
    /// to commit. Safe to call from several threads of one request as
    /// long as the steps are data-independent (see `step_waves`).
    fn compute_step(
        &self,
        si: usize,
        fast: bool,
        bufs: &[Option<Vec<f32>>],
        convs: &[Option<Vec<f32>>],
        ws: &mut WorkScratch,
    ) -> Result<(Option<(StepTarget, Vec<f32>)>, PhaseBreakdown)> {
        let mut phases = PhaseBreakdown::default();
        let produced = match &self.steps[si] {
            Step::Convert(c) => {
                // Fast mode fuses this edge: the consumer nest reads
                // the source buffer through the precompiled gather
                // map, so nothing materializes here — unless the
                // composed map failed validation, in which case the
                // edge stays materialized.
                if !fast || self.conv_forced[c.slot] {
                    let tp = Instant::now();
                    let src = bufs[c.tensor].as_deref().ok_or_else(
                        || err!("convert: t{} not live", c.tensor),
                    )?;
                    // on a folded-pad edge the source's own logical
                    // shape differs from the (padded) edge shape
                    let src_shape: &[i64] = c
                        .embed
                        .as_ref()
                        .map_or(&c.logical_shape, |(_, s)| s);
                    let logical_owned;
                    let logical: &[f32] = match &c.from {
                        None => src,
                        Some(tf) => {
                            logical_owned = tf.unpack(src, src_shape);
                            &logical_owned
                        }
                    };
                    let embedded_owned;
                    let logical: &[f32] = match &c.embed {
                        None => logical,
                        Some((map, _)) => {
                            // materialize the pad: -1 slots fill 0.0
                            embedded_owned =
                                apply_map(map, logical, Vec::new());
                            &embedded_owned
                        }
                    };
                    let buf = c.to.repack(logical, &c.logical_shape, 0.0);
                    phases.repack_ms += tp.elapsed().as_secs_f64() * 1e3;
                    Some((StepTarget::Conv(c.slot), buf))
                } else {
                    None
                }
            }
            Step::Complex(cs) => {
                let tp = Instant::now();
                let mut out_buf = ws.pool.pop().unwrap_or_default();
                {
                    // liveness is computed from these very steps,
                    // so a missing buffer is a plan-construction
                    // bug — surfaced as a typed error, not a panic
                    let dead = |what: &str, id: usize| {
                        err!(
                            "{}: nest {} read a dead {} buffer ({id})",
                            self.graph.name,
                            cs.exe.name(),
                            what
                        )
                    };
                    let mut views: Vec<OperandView> =
                        Vec::with_capacity(cs.operands.len());
                    for o in &cs.operands {
                        views.push(match o {
                            Operand::Tensor(t) => OperandView::direct(
                                bufs[*t]
                                    .as_deref()
                                    .ok_or_else(|| dead("operand", *t))?,
                            ),
                            Operand::Converted(s) => {
                                if fast && !self.conv_forced[*s] {
                                    OperandView {
                                        data: bufs[self.conv_tensor[*s]]
                                            .as_deref()
                                            .ok_or_else(|| {
                                                dead("conversion source", *s)
                                            })?,
                                        gather: Some(&self.conv_gathers[*s]),
                                    }
                                } else {
                                    OperandView::direct(
                                        convs[*s].as_deref().ok_or_else(
                                            || dead("conversion", *s),
                                        )?,
                                    )
                                }
                            }
                            Operand::Const(k) => OperandView::direct(
                                self.consts[*k].as_slice(),
                            ),
                        });
                    }
                    cs.exe.run_storage_views_into(
                        &views,
                        &mut out_buf,
                        &mut ws.exec,
                    )?;
                }
                if let Some(epi) = &cs.epilogue {
                    // anchored rewrites require the identity output
                    // layout, so the buffer is logical row-major and
                    // the folded node's line math applies in place —
                    // the same scalar routines the interpreted step
                    // would run (bit-identical to unfused execution)
                    let WorkScratch { pool, line, res, .. } = &mut *ws;
                    let shape = &self.graph.tensor(cs.out).shape;
                    match &epi.kind {
                        EpiKind::Softmax { axis } => {
                            let prev = out_buf;
                            out_buf = line_op(
                                &prev,
                                shape,
                                *axis,
                                pool,
                                line,
                                res,
                                softmax_line,
                            )?;
                            pool.push(prev);
                        }
                        EpiKind::LayerNorm { axis } => {
                            let prev = out_buf;
                            out_buf = line_op(
                                &prev,
                                shape,
                                *axis,
                                pool,
                                line,
                                res,
                                layernorm_line,
                            )?;
                            pool.push(prev);
                        }
                        EpiKind::ChannelShift { slot } => {
                            let shift = &self.consts[*slot];
                            let c = shift.len();
                            for (i, o) in out_buf.iter_mut().enumerate() {
                                *o += shift[i % c];
                            }
                        }
                    }
                }
                let dt = tp.elapsed().as_secs_f64() * 1e3;
                phases.nest_ms += dt;
                if cs.exe.degrade_reason().is_some() {
                    phases.degraded_ms += dt;
                }
                Some((StepTarget::Tensor(cs.out), out_buf))
            }
            Step::Simple(ss) => {
                let tb = Instant::now();
                let mut ins: Vec<Cow<[f32]>> =
                    Vec::with_capacity(ss.srcs.len());
                for s in &ss.srcs {
                    ins.push(match s {
                        SimpleSrc::Const(k) => {
                            Cow::Borrowed(self.consts[*k].as_slice())
                        }
                        SimpleSrc::Tensor(t, tf) => {
                            let buf =
                                bufs[*t].as_deref().ok_or_else(|| {
                                    err!(
                                        "{}: simple op read a dead \
                                         buffer (t{})",
                                        self.graph.name,
                                        t
                                    )
                                })?;
                            match tf {
                                None => Cow::Borrowed(buf),
                                Some(bm) => Cow::Owned(if fast {
                                    apply_map(
                                        &bm.map,
                                        buf,
                                        ws.pool.pop().unwrap_or_default(),
                                    )
                                } else {
                                    bm.tf.unpack(
                                        buf,
                                        &self.graph.tensor(*t).shape,
                                    )
                                }),
                            }
                        }
                    });
                }
                phases.boundary_ms += tb.elapsed().as_secs_f64() * 1e3;
                let ti = Instant::now();
                let logical = {
                    let slices: Vec<&[f32]> =
                        ins.iter().map(|c| c.as_ref()).collect();
                    interp_simple(&self.graph, ss.node, &slices, ws)?
                };
                phases.simple_ms += ti.elapsed().as_secs_f64() * 1e3;
                for c in ins {
                    if let Cow::Owned(v) = c {
                        ws.pool.push(v);
                    }
                }
                let tb = Instant::now();
                let stored = match &ss.pack {
                    None => logical,
                    Some(bm) => {
                        let packed = if fast {
                            apply_map(
                                &bm.map,
                                &logical,
                                ws.pool.pop().unwrap_or_default(),
                            )
                        } else {
                            bm.tf.repack(
                                &logical,
                                &self.graph.tensor(ss.out).shape,
                                0.0,
                            )
                        };
                        ws.pool.push(logical);
                        packed
                    }
                };
                phases.boundary_ms += tb.elapsed().as_secs_f64() * 1e3;
                Some((StepTarget::Tensor(ss.out), stored))
            }
        };
        Ok((produced, phases))
    }

    /// Commit one computed step: land the produced buffer in the shared
    /// buffer sets and recycle everything whose last reader was this
    /// step. Callers invoke commits strictly in plan order, which keeps
    /// every execution mode bit-identical to the serial path.
    fn commit_step(
        &self,
        si: usize,
        produced: Option<(StepTarget, Vec<f32>)>,
        bufs: &mut [Option<Vec<f32>>],
        convs: &mut [Option<Vec<f32>>],
        pool: &mut Vec<Vec<f32>>,
    ) {
        if let Some((target, buf)) = produced {
            let old = match target {
                StepTarget::Tensor(t) => bufs[t].replace(buf),
                StepTarget::Conv(s) => convs[s].replace(buf),
            };
            if let Some(old) = old {
                pool.push(old);
            }
        }
        for &d in &self.dies[si] {
            if let Some(b) = bufs[d].take() {
                pool.push(b);
            }
        }
        for &s in &self.conv_dies[si] {
            if let Some(b) = convs[s].take() {
                pool.push(b);
            }
        }
    }

    /// Take the finished output buffer out of the live set and unpack
    /// it to logical row-major.
    fn finish_output(
        &self,
        bufs: &mut [Option<Vec<f32>>],
        pool: &mut Vec<Vec<f32>>,
        fast: bool,
        phases: &mut PhaseBreakdown,
    ) -> Result<Vec<f32>> {
        let storage = bufs[self.output_id]
            .take()
            .ok_or_else(|| err!("{}: output never produced", self.graph.name))?;
        let tb = Instant::now();
        let out = match &self.output_unpack {
            None => storage,
            Some(bm) => {
                let unpacked = if fast {
                    apply_map(&bm.map, &storage, pool.pop().unwrap_or_default())
                } else {
                    bm.tf.unpack(
                        &storage,
                        &self.graph.tensor(self.output_id).shape,
                    )
                };
                pool.push(storage);
                unpacked
            }
        };
        phases.boundary_ms += tb.elapsed().as_secs_f64() * 1e3;
        Ok(out)
    }

    /// The reusable-scratch core: run the whole model against `scratch`.
    pub fn run_profiled_in(
        &self,
        scratch: &mut RunScratch,
        inputs: &[Vec<f32>],
    ) -> Result<(RunStats, PhaseBreakdown, Vec<f32>)> {
        self.validate_inputs(inputs)?;
        let fast = self.mode == ExecMode::Fast;
        let t0 = Instant::now();
        self.seed_scratch(scratch, inputs);
        let mut phases = PhaseBreakdown::default();
        let RunScratch { work, bufs, convs } = scratch;
        for si in 0..self.steps.len() {
            let (produced, ph) = self.compute_step(si, fast, bufs, convs, work)?;
            phases.accum(&ph);
            self.commit_step(si, produced, bufs, convs, &mut work.pool);
        }
        let out = self.finish_output(bufs, &mut work.pool, fast, &mut phases)?;
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        let sample = out.iter().take(8).copied().collect();
        Ok((RunStats { latency_ms, output_elems: out.len(), sample }, phases, out))
    }

    /// Dynamic-batching core: run `requests` as one batch-dim-aware
    /// execution. The plan's step sequence becomes the outer loop and
    /// the batch lanes the inner one, so each step's strided address
    /// streams, gather maps, and packed weights are read once per batch
    /// while per-request activations stay in per-lane buffer sets —
    /// outputs are bit-identical to running the requests sequentially.
    /// A request that fails (validation, execution, or a caught panic)
    /// gets its own typed `Err` and is skipped for the remaining steps;
    /// the rest of the batch completes. Per-request `latency_ms` is the
    /// whole batch's wall time (queue wait is reported separately via
    /// [`PhaseBreakdown::queue_ms`]).
    pub fn run_batch_in(
        &self,
        batch: &mut BatchScratch,
        requests: &[&[Vec<f32>]],
    ) -> Vec<Result<RunOutput>> {
        if batch.per.len() < requests.len() {
            batch.per.resize_with(requests.len(), RunScratch::default);
        }
        let fast = self.mode == ExecMode::Fast;
        let t0 = Instant::now();
        let mut state: Vec<Result<PhaseBreakdown>> =
            Vec::with_capacity(requests.len());
        for (r, req) in requests.iter().enumerate() {
            state.push(self.validate_inputs(req).map(|()| {
                self.seed_scratch(&mut batch.per[r], req);
                PhaseBreakdown::default()
            }));
        }
        for si in 0..self.steps.len() {
            for r in 0..requests.len() {
                if state[r].is_err() {
                    continue;
                }
                let outcome = {
                    let RunScratch { work, bufs, convs } = &mut batch.per[r];
                    match catch_unwind(AssertUnwindSafe(|| {
                        self.compute_step(si, fast, bufs, convs, work)
                    })) {
                        Ok(Ok((produced, ph))) => {
                            self.commit_step(
                                si,
                                produced,
                                bufs,
                                convs,
                                &mut work.pool,
                            );
                            Ok(ph)
                        }
                        Ok(Err(e)) => Err(e),
                        Err(p) => Err(panic_error(p, "batched model step")),
                    }
                };
                match outcome {
                    Ok(ph) => {
                        if let Ok(phases) = &mut state[r] {
                            phases.accum(&ph);
                        }
                    }
                    Err(e) => {
                        // a panicked lane's scratch may be mid-mutation:
                        // discard it wholesale; the lane stays failed
                        // while the rest of the batch keeps stepping
                        batch.per[r] = RunScratch::default();
                        state[r] = Err(e);
                    }
                }
            }
        }
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        state
            .into_iter()
            .enumerate()
            .map(|(r, st)| {
                let mut phases = st?;
                let RunScratch { work, bufs, .. } = &mut batch.per[r];
                let out =
                    self.finish_output(bufs, &mut work.pool, fast, &mut phases)?;
                let sample = out.iter().take(8).copied().collect();
                Ok((
                    RunStats { latency_ms, output_elems: out.len(), sample },
                    phases,
                    out,
                ))
            })
            .collect()
    }

    /// Intra-request pipelining core: execute one request with the
    /// data-independent plan steps of each dataflow wave fanned out
    /// across up to `width` cores (the step-level projection of
    /// [`crate::graph::shard::exec_waves`]). Workers only *compute*
    /// against the shared buffer sets; results are committed in plan
    /// order on the calling thread, so the output is bit-identical to
    /// the serial path for every `width`. `width <= 1` (or a
    /// single-step wave) runs serially with zero spawn overhead.
    pub fn run_pipelined_in(
        &self,
        scratch: &mut RunScratch,
        pipe: &mut PipeScratch,
        width: usize,
        inputs: &[Vec<f32>],
    ) -> Result<(RunStats, PhaseBreakdown, Vec<f32>)> {
        self.validate_inputs(inputs)?;
        let fast = self.mode == ExecMode::Fast;
        let t0 = Instant::now();
        self.seed_scratch(scratch, inputs);
        let mut phases = PhaseBreakdown::default();
        let RunScratch { work, bufs, convs } = scratch;
        for wave in &self.step_waves {
            if width <= 1 || wave.len() <= 1 {
                for &si in wave {
                    let (produced, ph) =
                        self.compute_step(si, fast, bufs, convs, work)?;
                    phases.accum(&ph);
                    self.commit_step(si, produced, bufs, convs, &mut work.pool);
                }
                continue;
            }
            let nw = width.min(wave.len());
            if pipe.workers.len() < nw {
                pipe.workers.resize_with(nw, WorkScratch::default);
            }
            // keep worker pools primed out of the main pool: committed
            // buffers die back into the main pool, so without this the
            // workers would allocate fresh capacity every wave
            for wsc in pipe.workers.iter_mut().take(nw) {
                while wsc.pool.len() < 4 {
                    match work.pool.pop() {
                        Some(b) => wsc.pool.push(b),
                        None => break,
                    }
                }
            }
            let bufs_r: &[Option<Vec<f32>>] = bufs;
            let convs_r: &[Option<Vec<f32>>] = convs;
            type Computed = (Option<(StepTarget, Vec<f32>)>, PhaseBreakdown);
            let mut results: Vec<(usize, Result<Computed>)> =
                std::thread::scope(|s| {
                    let mut handles = Vec::with_capacity(nw);
                    for (k, wsc) in
                        pipe.workers.iter_mut().take(nw).enumerate()
                    {
                        let mine: Vec<usize> =
                            wave.iter().copied().skip(k).step_by(nw).collect();
                        handles.push(s.spawn(move || {
                            let mut done = Vec::with_capacity(mine.len());
                            for si in mine {
                                let r = catch_unwind(AssertUnwindSafe(|| {
                                    self.compute_step(
                                        si, fast, bufs_r, convs_r, wsc,
                                    )
                                }))
                                .unwrap_or_else(|p| {
                                    Err(panic_error(p, "pipelined model step"))
                                });
                                done.push((si, r));
                            }
                            done
                        }));
                    }
                    handles
                        .into_iter()
                        .flat_map(|h| h.join().unwrap_or_default())
                        .collect()
                });
            // commit in plan order — bit-identical to serial execution
            results.sort_unstable_by_key(|&(si, _)| si);
            for (si, r) in results {
                let (produced, ph) = r?;
                phases.accum(&ph);
                self.commit_step(si, produced, bufs, convs, &mut work.pool);
            }
        }
        let out = self.finish_output(bufs, &mut work.pool, fast, &mut phases)?;
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        let sample = out.iter().take(8).copied().collect();
        Ok((RunStats { latency_ms, output_elems: out.len(), sample }, phases, out))
    }

    /// Shape of the pipelining wavefronts: `(waves, widest)` — how many
    /// dataflow waves the plan has and the step count of the widest one
    /// (`widest > 1` means intra-request pipelining has work to fan
    /// out).
    pub fn wave_shape(&self) -> (usize, usize) {
        (
            self.step_waves.len(),
            self.step_waves.iter().map(|w| w.len()).max().unwrap_or(0),
        )
    }

    /// Select the executor for every step of the plan. `Fast` (the
    /// default) runs strided address streams, fuses Fig. 5a conversion
    /// edges into consumer gather reads, and applies boundary edges as
    /// precompiled index maps; `Bytecode` forces the reference
    /// interpreter with materialized repacks everywhere — the genuine
    /// pre-fast-path execution, used as the within-run baseline.
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
        for step in self.steps.iter_mut() {
            if let Step::Complex(cs) = step {
                cs.exe.set_exec_mode(mode);
            }
        }
    }

    pub fn exec_mode(&self) -> ExecMode {
        self.mode
    }

    /// Whether every complex nest in the plan compiled a strided fast
    /// plan (none fell back to bytecode).
    pub fn all_fast_paths(&self) -> bool {
        self.steps.iter().all(|s| match s {
            Step::Complex(cs) => cs.exe.has_fast_path(),
            _ => true,
        })
    }

    /// Per-nest degradation report: which nests hold a live fast plan,
    /// which fell down the ladder and why. Outputs stay bit-identical
    /// either way; this reports *throughput* health.
    pub fn health(&self) -> HealthReport {
        let mut report = HealthReport {
            forced_repacks: self.forced_convs,
            rewrites_applied: self.rewrites_applied,
            rewrites_available: self.rewrites_available(),
            ..HealthReport::default()
        };
        for step in &self.steps {
            if let Step::Complex(cs) = step {
                let degraded = cs.exe.degrade_reason();
                if degraded.is_some() {
                    report.degraded_nests += 1;
                }
                report.nests.push(NestHealth {
                    node: cs.node,
                    name: cs.exe.name().to_string(),
                    fast: cs.exe.has_fast_path(),
                    writes_direct: cs.exe.writes_direct(),
                    write_proof: cs.exe.write_proof(),
                    race_free: !cs.exe.is_parallel()
                        || cs.exe.writes_direct(),
                    reads_bounded: cs.exe.reads_bounded(),
                    parallel: cs.exe.is_parallel(),
                    degraded,
                });
            }
        }
        report
    }

    /// Static plan lints: everything the analyzer can say about this
    /// compiled model without running it. Returns per-nest access-level
    /// findings (zero-trip loops, dead pad clamps) plus model-level
    /// ones (never-firing `-1` gather slots, non-stride-1 innermost
    /// reads, degraded nests with their proof status). Severity
    /// [`Severity::Error`]/[`Severity::Warning`] findings fail
    /// `alt check`; [`Severity::Perf`] ones are advisory.
    pub fn diagnostics(&self) -> Vec<Diagnostic> {
        let mut out = Vec::new();
        for step in &self.steps {
            let Step::Complex(cs) = step else { continue };
            out.extend(analysis::lint_nest(cs.exe.program()));
            if let Some((sl, sr)) = cs.exe.innermost_strides() {
                if sl != 1 || sr != 1 {
                    out.push(Diagnostic::nest_scoped(
                        Severity::Perf,
                        cs.node,
                        "non-unit-innermost-read",
                        format!(
                            "{}: innermost MAC strides ({sl}, {sr}) — no \
                             contiguous run for the unrolled dot kernel",
                            cs.exe.name()
                        ),
                    ));
                }
            }
            if let Some(reason) = cs.exe.degrade_reason() {
                out.push(Diagnostic::nest_scoped(
                    Severity::Warning,
                    cs.node,
                    "degraded-nest",
                    format!(
                        "{}: off the fast plan ({reason}); write proof: {}",
                        cs.exe.name(),
                        cs.exe.write_proof()
                    ),
                ));
            }
            if let Some(reason) = cs.exe.write_degrade() {
                out.push(Diagnostic::nest_scoped(
                    Severity::Warning,
                    cs.node,
                    "staged-scatter-write",
                    format!(
                        "{}: parallel nest stages scatter writes \
                         ({reason}); write proof: {}",
                        cs.exe.name(),
                        cs.exe.write_proof()
                    ),
                ));
            }
            if !cs.exe.reads_bounded() {
                out.push(Diagnostic::nest_scoped(
                    Severity::Perf,
                    cs.node,
                    "unproven-read-bounds",
                    format!(
                        "{}: a read stream's bounds were not proven \
                         symbolically; runtime checks stay live",
                        cs.exe.name()
                    ),
                ));
            }
        }
        for c in &self.dead_rewrites {
            out.push(Diagnostic {
                severity: Severity::Perf,
                nest: None,
                code: "dead-rewrite-opportunity",
                message: format!(
                    "{} matched node {} (anchor {}) but the plan leaves \
                     it unapplied — tune with rewrite=on or rewrite=joint",
                    c.kind.name(),
                    c.node,
                    c.anchor
                ),
            });
        }
        for (slot, gather) in self.conv_gathers.iter().enumerate() {
            if self.conv_forced[slot] {
                continue; // already surfaced via the consumer's degrade
            }
            if !gather.iter().any(|&g| g < 0) {
                out.push(Diagnostic {
                    severity: Severity::Perf,
                    nest: None,
                    code: "dead-gather-fill",
                    message: format!(
                        "conversion slot {slot} (t{}): gather map has no \
                         -1 entries; the zero-fill branch never fires",
                        self.conv_tensor[slot]
                    ),
                });
            }
        }
        out.sort_by_key(|d| d.severity);
        out
    }

    /// Nests currently running on the bytecode interpreter.
    pub fn degraded_nests(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| {
                matches!(s, Step::Complex(cs) if cs.exe.degrade_reason().is_some())
            })
            .count()
    }

    /// Force the nest lowering `node` down the ladder: its fast plan
    /// is revoked and it runs on the bytecode interpreter from the
    /// next request on, bit-identically. Returns `false` when `node`
    /// is not a complex nest of this plan. This is the operational
    /// "quarantine one operator" lever (and the degradation-overhead
    /// bench's probe); compile-time failures take the same path
    /// automatically.
    pub fn degrade_nest(&mut self, node: NodeId, reason: DegradeReason) -> bool {
        for step in self.steps.iter_mut() {
            if let Step::Complex(cs) = step {
                if cs.node == node {
                    cs.exe.degrade(reason);
                    return true;
                }
            }
        }
        false
    }

    /// Median-of-`n` timed runs (first run excluded as warmup).
    pub fn bench(&self, inputs: &[Vec<f32>], n: usize) -> Result<f64> {
        let _ = self.run(inputs)?;
        let mut times = Vec::with_capacity(n.max(1));
        for _ in 0..n.max(1) {
            times.push(self.run(inputs)?.latency_ms);
        }
        Ok(crate::util::stats::median(&mut times))
    }

    // ---- compile-time accounting (the serving bench's metrics) ----

    /// Complex nests in the execution plan.
    pub fn complex_steps(&self) -> usize {
        self.complex_steps
    }

    /// Interpreted simple operators in the execution plan.
    pub fn simple_steps(&self) -> usize {
        self.simple_steps
    }

    /// Fig. 5a conversion steps executed per inference.
    pub fn conversions(&self) -> usize {
        self.conversions
    }

    /// Non-identity unpack/pack passes at simple-op boundaries per
    /// inference (absorbed conversions, Fig. 5b).
    pub fn boundary_repacks(&self) -> usize {
        self.boundary_repacks
    }

    /// Total runtime layout repack edges per inference (fused or not).
    pub fn repacks_per_run(&self) -> usize {
        self.conversions + self.boundary_repacks
    }

    /// Fig. 5a conversion edges eliminated by read-side fusion in the
    /// current execution mode (every conversion edge has exactly one
    /// complex consumer by construction, so Fast mode fuses them all).
    pub fn fused_repacks(&self) -> usize {
        if self.mode == ExecMode::Fast {
            self.conversions - self.forced_convs
        } else {
            0
        }
    }

    /// Repack edges still materialized as buffer copies per inference.
    pub fn materialized_repacks(&self) -> usize {
        self.repacks_per_run() - self.fused_repacks()
    }

    /// Unique constant weight buffers materialized at compile time,
    /// and how many of those were packed into a non-identity layout
    /// (the amortized offline transform).
    pub fn weights_total(&self) -> usize {
        self.weights_total
    }

    pub fn weights_packed(&self) -> usize {
        self.weights_packed
    }

    /// Graph rewrites baked into this compiled plan.
    pub fn rewrites_applied(&self) -> usize {
        self.rewrites_applied
    }

    /// Rewrite candidates the matcher found on this graph (applied
    /// plus dead opportunities).
    pub fn rewrites_available(&self) -> usize {
        self.rewrites_applied + self.dead_rewrites.len()
    }

    /// Wall-clock spent packing weights at compile time.
    pub fn packing_ms(&self) -> f64 {
        self.packing_ms
    }

    /// Total compile wall-clock (lowering + weight packing).
    pub fn compile_ms(&self) -> f64 {
        self.compile_ms
    }
}
