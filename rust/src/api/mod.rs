//! The unified staged-pipeline API: tune → compile → run one graph
//! end-to-end on the native backend, with durable artifacts.
//!
//! ```text
//!   Session::new(graph)            builder: profile, TuneOptions,
//!     │                            execution threads, weight seed
//!     ▼ .tune()
//!   TunedGraph                     serializable tuned plan: per-op
//!     │                            layout decision + loop schedule
//!     ▼ .compile()
//!   CompiledModel                  lowered nests, weights packed once,
//!     │                            repacks only where layouts disagree
//!     ▼ .run(inputs)               whole-model native execution
//!   (RunStats, output)
//!
//!   CompiledModel::save(dir)  ⇄  Session::load(dir)
//! ```
//!
//! The stages correspond to ALT's architecture: `tune` runs the joint
//! layout/loop search (the sharded graph orchestrator), `compile`
//! lowers every complex operator with its chosen decisions and builds
//! a topological multi-op execution plan for the native backend, and
//! `run` executes the whole model on host buffers. `save`/`load`
//! round-trip the plan (plus an extended artifact manifest) through a
//! directory, so tuning results survive the process: a loaded session
//! compiles to a model producing bit-identical outputs without
//! spending a single new measurement.

pub mod model;
pub mod plan;
pub mod serve;

use std::collections::HashMap;
use std::path::Path;

use crate::autotune::{tune_graph, GraphTuneResult, TuneOptions};
use crate::error::Result;
use crate::graph::{models, Graph, NodeId};
use crate::loops::LoopSchedule;
use crate::propagate::ComplexDecision;
use crate::rewrite::{self, RewriteMode};
use crate::sim::netsim::GraphReport;
use crate::sim::HwProfile;
use crate::{bail, err};

pub use model::{
    BatchScratch, CompiledModel, PhaseBreakdown, PipeScratch, RunOutput,
    RunScratch,
};
pub use plan::{OpPlan, TunedPlan};
pub use serve::{Pending, ServeOptions, ServeReply, Server, ServerStats};

/// Default seed the compiled model's constant weights are drawn from.
pub const DEFAULT_WEIGHT_SEED: u64 = 1000;

/// The pipeline entry point: one graph plus everything `tune` needs.
pub struct Session {
    graph: Graph,
    hw: HwProfile,
    opts: TuneOptions,
    exec_threads: usize,
    weight_seed: u64,
}

impl Session {
    /// A session over `graph` with the default Intel profile and
    /// default [`TuneOptions`].
    pub fn new(graph: Graph) -> Self {
        Self {
            graph,
            hw: HwProfile::intel(),
            opts: TuneOptions::default(),
            exec_threads: 0,
            weight_seed: DEFAULT_WEIGHT_SEED,
        }
    }

    /// A session over a model-zoo workload
    /// ([`crate::graph::models::by_name`]).
    pub fn for_model(name: &str) -> Result<Self> {
        let graph = models::by_name(name)
            .ok_or_else(|| err!("unknown workload '{name}'"))?;
        Ok(Self::new(graph))
    }

    /// Tune on this simulated hardware profile.
    pub fn with_profile(mut self, hw: HwProfile) -> Self {
        self.hw = hw;
        self
    }

    /// Tune with these options (budget, seed, shards, mode, …).
    pub fn with_options(mut self, opts: TuneOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Native-execution threads for the compiled model (0 = all cores;
    /// a pure throughput knob — outputs are bit-identical at any
    /// value).
    pub fn with_exec_threads(mut self, threads: usize) -> Self {
        self.exec_threads = threads;
        self
    }

    /// Seed the compiled model's constant weights are drawn from.
    pub fn with_weight_seed(mut self, seed: u64) -> Self {
        self.weight_seed = seed;
        self
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    fn plan_from(&self, ops: Vec<OpPlan>) -> TunedPlan {
        // Rewrite selection is re-derived from the final decisions, so
        // every path into a plan (tune / baseline / plan_with) agrees
        // with what the joint stage actually settled on. `Off` skips
        // the analysis entirely — zero added work on today's path.
        let rewrites = if self.opts.rewrite == RewriteMode::Off {
            Vec::new()
        } else {
            let decisions: Vec<ComplexDecision> =
                ops.iter().map(|o| o.decision.clone()).collect();
            rewrite::select(
                &rewrite::analyze(&self.graph),
                self.opts.rewrite,
                self.opts.mode,
                &decisions,
            )
        };
        TunedPlan {
            model: self.graph.name.clone(),
            hw: self.hw.name.to_string(),
            mode: self.opts.mode,
            seed: self.opts.seed,
            weight_seed: self.weight_seed,
            threads: self.exec_threads,
            rewrites,
            ops,
        }
    }

    /// Stage 1: run the joint layout/loop search over the whole graph
    /// (the sharded orchestrator) and wrap the result as a durable
    /// tuned plan.
    pub fn tune(&self) -> TunedGraph {
        let result = tune_graph(&self.graph, &self.hw, &self.opts);
        let ops = result
            .ops
            .iter()
            .map(|o| OpPlan {
                node: o.node,
                decision: o.decision.clone(),
                sched: o.sched.clone(),
            })
            .collect();
        TunedGraph {
            graph: self.graph.clone(),
            hw: self.hw.clone(),
            plan: self.plan_from(ops),
            result: Some(result),
        }
    }

    /// An untuned plan: identity layouts, identity schedules — the
    /// vendor-style baseline, and the cheapest way to exercise
    /// `compile`/`run` without spending measurements.
    pub fn baseline(&self) -> TunedGraph {
        TunedGraph {
            graph: self.graph.clone(),
            hw: self.hw.clone(),
            plan: self.plan_from(Vec::new()),
            result: None,
        }
    }

    /// A hand-authored plan from explicit per-op decisions and/or loop
    /// schedules (ops absent from both fall back to identity at
    /// compile time) — the layout-lab path.
    pub fn plan_with(
        &self,
        decisions: Vec<ComplexDecision>,
        scheds: HashMap<NodeId, LoopSchedule>,
    ) -> Result<TunedGraph> {
        let complex = self.graph.complex_nodes();
        let mut by_node: HashMap<NodeId, ComplexDecision> =
            decisions.into_iter().map(|d| (d.node, d)).collect();
        let mut scheds = scheds;
        // one propagation over every provided decision (topo order) —
        // the same pass compile_model will run; a node's nest dims
        // depend only on its own output layout, so fallback identity
        // schedules computed here match the compile-time fallbacks
        let ordered: Vec<ComplexDecision> = complex
            .iter()
            .filter_map(|n| by_node.get(n).cloned())
            .collect();
        let prop =
            crate::propagate::propagate(&self.graph, &ordered, self.opts.mode);
        let mut ops = Vec::new();
        for node in &complex {
            let dec = by_node.remove(node);
            let sched = scheds.remove(node);
            if dec.is_none() && sched.is_none() {
                continue;
            }
            ops.push(OpPlan {
                node: *node,
                decision: dec.unwrap_or_else(|| ComplexDecision {
                    node: *node,
                    ..Default::default()
                }),
                sched: sched.unwrap_or_else(|| {
                    let (sp, rd) = crate::autotune::tuner::nest_dims(
                        &self.graph,
                        *node,
                        &prop,
                    );
                    LoopSchedule::identity(&sp, &rd)
                }),
            });
        }
        if let Some((&node, _)) = by_node.iter().next() {
            bail!("decision for node {node}, which is not a complex op");
        }
        if let Some((&node, _)) = scheds.iter().next() {
            bail!("schedule for node {node}, which is not a complex op");
        }
        let plan = self.plan_from(ops);
        plan.validate_against(&self.graph)?;
        Ok(TunedGraph {
            graph: self.graph.clone(),
            hw: self.hw.clone(),
            plan,
            result: None,
        })
    }

    /// Restore a tuned graph from a directory written by
    /// [`CompiledModel::save`] — the graph is rebuilt from the model
    /// zoo, the plan is parsed and spec-checked against the manifest,
    /// and no re-tuning happens.
    pub fn load(dir: impl AsRef<Path>) -> Result<TunedGraph> {
        let (plan, graph) = plan::load_plan(dir.as_ref())?;
        let hw = HwProfile::by_name(&plan.hw)
            .ok_or_else(|| err!("unknown hw profile '{}' in plan", plan.hw))?;
        Ok(TunedGraph { graph, hw, plan, result: None })
    }
}

/// Stage-2 input: a graph plus its (possibly loaded) tuned plan.
pub struct TunedGraph {
    graph: Graph,
    hw: HwProfile,
    plan: TunedPlan,
    result: Option<GraphTuneResult>,
}

impl TunedGraph {
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn hw(&self) -> &HwProfile {
        &self.hw
    }

    /// The serializable tuned plan.
    pub fn plan(&self) -> &TunedPlan {
        &self.plan
    }

    /// The full tuning result (None when the plan was loaded or
    /// hand-authored).
    pub fn result(&self) -> Option<&GraphTuneResult> {
        self.result.as_ref()
    }

    /// The simulated end-to-end report, when tuning ran.
    pub fn report(&self) -> Option<&GraphReport> {
        self.result.as_ref().map(|r| &r.report)
    }

    /// Override the native execution thread count (pure throughput).
    pub fn with_exec_threads(mut self, threads: usize) -> Self {
        self.plan.threads = threads;
        self
    }

    /// Stage 2: lower every complex op with its tuned decisions, pack
    /// the constant weights once, and build the topological multi-op
    /// execution plan for the native backend.
    pub fn compile(&self) -> Result<CompiledModel> {
        model::compile_model(&self.graph, &self.hw, &self.plan)
    }

    /// Persist the plan without compiling first (equivalent to
    /// [`CompiledModel::save`]).
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        plan::save_plan(dir.as_ref(), &self.plan, &self.graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::layout::{LayoutSeq, Primitive};

    /// Tiny conv+bias+relu graph (pre-padded input) for fast compile
    /// tests.
    fn tiny_graph() -> Graph {
        let mut b = GraphBuilder::new("tiny");
        let x = b.input("x", &["N", "H", "W", "I"], &[1, 6, 6, 2]);
        b.conv_bias_relu("c", x, 3, 3, 1, 0);
        b.finish()
    }

    #[test]
    fn baseline_compiles_and_runs() {
        let s = Session::new(tiny_graph()).with_exec_threads(1);
        let model = s.baseline().compile().unwrap();
        assert_eq!(model.complex_steps(), 1);
        assert_eq!(model.conversions(), 0);
        let inputs = model.seeded_inputs(3);
        let (stats, out) = model.run_with_output(&inputs).unwrap();
        assert_eq!(stats.output_elems, 4 * 4 * 3);
        assert!(out.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn plan_with_accepts_layouts_and_rejects_non_complex() {
        let s = Session::new(tiny_graph());
        let mut out_seq = LayoutSeq::new();
        out_seq
            .push(Primitive::split(3, &[1, 3]))
            .push(Primitive::reorder(&[0, 3, 1, 2, 4]));
        let conv = s.graph().complex_nodes()[0];
        let dec = ComplexDecision { node: conv, out_seq, ..Default::default() };
        let tuned = s.plan_with(vec![dec.clone()], HashMap::new()).unwrap();
        assert_eq!(tuned.plan().ops.len(), 1);
        let model = tuned.compile().unwrap();
        // identity-plan output must match the laid-out plan's output
        let base = s.baseline().compile().unwrap();
        let inputs = model.seeded_inputs(5);
        let a = model.run_with_output(&inputs).unwrap().1;
        let b = base.run_with_output(&inputs).unwrap().1;
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "layouts must be pure storage transforms"
        );

        // node 1 is the bias op — not complex, so the plan is rejected
        let bad = ComplexDecision { node: 1, ..Default::default() };
        assert!(s.plan_with(vec![bad], HashMap::new()).is_err());
    }

    #[test]
    fn for_model_resolves_zoo_names() {
        assert!(Session::for_model("case_study").is_ok());
        assert!(Session::for_model("nope").is_err());
    }
}
