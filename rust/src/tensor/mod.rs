//! Tensor descriptors.
//!
//! A tensor in ALT is a logical multi-dimensional value; its *storage
//! layout* is the composition of the layout-primitive sequence attached
//! to it by the tuner (see [`crate::layout`]). The descriptor here keeps
//! the logical shape plus bookkeeping the graph and propagation passes
//! need: role (input/weight/intermediate/output) and the producing node.

use std::fmt;

/// Element types we model. Sizes feed the cache simulator.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    BF16,
    I8,
}

impl DType {
    pub fn bytes(self) -> i64 {
        match self {
            DType::F32 => 4,
            DType::BF16 => 2,
            DType::I8 => 1,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::F32 => write!(f, "f32"),
            DType::BF16 => write!(f, "bf16"),
            DType::I8 => write!(f, "i8"),
        }
    }
}

/// Role of a tensor in the graph; drives layout-tuning decisions
/// (weights transform offline for free; intermediates need propagation
/// or conversion ops — paper §4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Graph input (activations arriving from outside).
    Input,
    /// Constant parameter — layout changes are free (offline repack).
    Weight,
    /// Produced and consumed inside the graph.
    Intermediate,
    /// Graph output.
    Output,
}

/// Unique tensor id within a [`crate::graph::Graph`].
pub type TensorId = usize;

/// A logical tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub id: TensorId,
    pub name: String,
    /// Logical dimension names, e.g. `["N", "H", "W", "O"]`. Layout
    /// primitives operate on *storage* dims derived from these.
    pub dim_names: Vec<String>,
    /// Logical extents (same order as `dim_names`).
    pub shape: Vec<i64>,
    pub dtype: DType,
    pub role: Role,
    /// Producing node id (None for inputs/weights).
    pub producer: Option<usize>,
}

impl Tensor {
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn elements(&self) -> i64 {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> i64 {
        self.elements() * self.dtype.bytes()
    }

    /// Human-readable layout string, e.g. `NHWO`.
    pub fn layout_string(&self) -> String {
        self.dim_names.join("")
    }
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: {}[{}] ({:?})",
            self.name,
            self.dtype,
            self.shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("x"),
            self.role
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Tensor {
        Tensor {
            id: 0,
            name: "conv".into(),
            dim_names: vec!["N".into(), "H".into(), "W".into(), "O".into()],
            shape: vec![1, 112, 112, 64],
            dtype: DType::F32,
            role: Role::Intermediate,
            producer: Some(3),
        }
    }

    #[test]
    fn sizes() {
        let x = t();
        assert_eq!(x.rank(), 4);
        assert_eq!(x.elements(), 112 * 112 * 64);
        assert_eq!(x.bytes(), 112 * 112 * 64 * 4);
        assert_eq!(x.layout_string(), "NHWO");
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::BF16.bytes(), 2);
        assert_eq!(DType::I8.bytes(), 1);
    }
}
