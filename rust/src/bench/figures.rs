//! One generator per paper table/figure (§7). Each returns [`Table`]s
//! whose rows mirror what the paper reports; `cargo bench` targets and
//! the `figures` binary print them. Budgets are scaled (DESIGN.md) but
//! keep the paper's stage ratios.

use std::collections::HashMap;

use crate::autotune::tuner::{
    tune_graph, tune_graphs, tune_loops, tune_op, TuneOptions,
};
use crate::baselines;
use crate::bench::harness::Table;
use crate::graph::{models, Graph};
use crate::layout::{LayoutSeq, Primitive};
use crate::propagate::{propagate, ComplexDecision, PropMode};
use crate::sim::netsim::simulate_graph;
use crate::sim::{cache, HwProfile};
use crate::util::geomean;

/// Scaled budget presets. `quick` keeps `cargo bench` minutes-fast;
/// `full` is the figures-binary default.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub op_budget: usize,
    pub graph_budget: usize,
    pub configs_per_family: usize,
    pub seed: u64,
}

impl Scale {
    pub fn quick() -> Self {
        Self { op_budget: 160, graph_budget: 960, configs_per_family: 2, seed: 42 }
    }

    pub fn full() -> Self {
        Self { op_budget: 400, graph_budget: 3200, configs_per_family: 4, seed: 42 }
    }
}

fn opts(budget: usize, seed: u64, mode: PropMode) -> TuneOptions {
    TuneOptions {
        budget,
        batch: 16,
        top_k: 4,
        seed,
        mode,
        ..Default::default()
    }
}

/// Fixed whole-tensor layout sequences for a 4-d NHWO logical tensor.
fn fixed_layout(name: &str) -> LayoutSeq {
    let mut s = LayoutSeq::new();
    match name {
        "NHWO" => {}
        "NOHW" => {
            s.push(Primitive::reorder(&[0, 3, 1, 2]));
        }
        "HWON" => {
            s.push(Primitive::reorder(&[1, 2, 3, 0]));
        }
        other => panic!("unknown fixed layout {other}"),
    }
    s
}

/// The NeoCPU-style packed layout `N (O/ot) H W ot`.
fn packed_layout(o: i64, ot: i64) -> LayoutSeq {
    let mut s = LayoutSeq::new();
    s.push(Primitive::split(3, &[o / ot, ot]));
    s.push(Primitive::reorder(&[0, 3, 1, 2, 4]));
    s
}

/// The §7.3.3 searched tiled layout `N (H/ht)(W/wt)(O/ot) ht wt ot`.
fn tiled_layout(h: i64, w: i64, o: i64, ht: i64, wt: i64, ot: i64) -> LayoutSeq {
    let mut s = LayoutSeq::new();
    s.push(Primitive::split(1, &[h / ht, ht]));
    s.push(Primitive::split(3, &[w / wt, wt]));
    s.push(Primitive::split(5, &[o / ot, ot]));
    s.push(Primitive::reorder(&[0, 1, 3, 5, 2, 4, 6]));
    s
}

/// C2D configs for Fig. 1 (varied channels/strides like the paper).
fn fig1_configs() -> Vec<(String, Graph)> {
    let mut out = Vec::new();
    for (i, (ci, co, k, stride, hw)) in [
        (3i64, 64i64, 7i64, 2i64, 224i64),
        (64, 64, 3, 1, 56),
        (64, 128, 3, 2, 56),
        (128, 128, 3, 1, 28),
        (256, 256, 3, 1, 14),
        (512, 512, 3, 1, 7),
        (16, 32, 5, 1, 28),
        (32, 16, 1, 1, 28),
    ]
    .iter()
    .enumerate()
    {
        let mut b = crate::graph::GraphBuilder::new(&format!("c2d{i}"));
        let x = b.input("x", &["N", "H", "W", "I"], &[1, *hw, *hw, *ci]);
        b.conv2d(&format!("c{i}"), x, *co, *k, *stride, *k / 2);
        out.push((format!("I{ci}-O{co}-k{k}-s{stride}-{hw}"), b.finish()));
    }
    out
}

/// Fig. 1: loop-tuned latency of C2D under NOHW / NHWO / HWON on each
/// hardware profile.
pub fn fig1(scale: &Scale) -> Vec<Table> {
    let layouts = ["NOHW", "NHWO", "HWON"];
    let mut tables = Vec::new();
    for hw in HwProfile::all() {
        let mut t = Table::new(
            &format!("Fig 1 ({}): C2D latency (ms) per fixed layout", hw.name),
            &["config", "NOHW", "NHWO", "HWON", "best/worst"],
        );
        for (name, g) in fig1_configs() {
            let conv = g.complex_nodes()[0];
            let mut row = vec![name.clone()];
            let mut vals = Vec::new();
            for lay in layouts {
                let dec = ComplexDecision {
                    node: conv,
                    out_seq: fixed_layout(lay),
                    ..Default::default()
                };
                let r = tune_loops(
                    &g,
                    conv,
                    &dec,
                    &hw,
                    &opts(scale.op_budget, scale.seed, PropMode::Alt),
                );
                vals.push(r.best_ms);
                row.push(format!("{:.4}", r.best_ms));
            }
            let best = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            let worst = vals.iter().cloned().fold(0.0, f64::max);
            row.push(format!("{:.2}x", worst / best));
            t.row(&row);
        }
        tables.push(t);
    }
    tables
}

/// §2 motivating example: the overlapped-tiled layout vs the NeoCPU
/// packed layout `N (O/ot) H W ot` on the R18 first layer.
pub fn motivating(scale: &Scale) -> Table {
    let g = models::case_study();
    let conv = g.complex_nodes()[0];
    let hw = HwProfile::intel();
    let o = opts(scale.op_budget, scale.seed, PropMode::Alt);

    let packed = ComplexDecision {
        node: conv,
        out_seq: packed_layout(64, 16),
        ..Default::default()
    };
    let r_packed = tune_loops(&g, conv, &packed, &hw, &o);

    // overlapped tiled layout + matching input unfold (paper Fig. 2/3)
    let (ht, wt, ot) = (4, 16, 16);
    let mut in_seq = LayoutSeq::new();
    in_seq.push(Primitive::unfold(1, 2 * (ht - 1) + 7, 2 * ht));
    in_seq.push(Primitive::unfold(3, 2 * (wt - 1) + 7, 2 * wt));
    let tiled = ComplexDecision {
        node: conv,
        out_seq: tiled_layout(112, 112, 64, ht, wt, ot),
        in_seq,
        ..Default::default()
    };
    let r_tiled = tune_loops(&g, conv, &tiled, &hw, &o);

    // The same comparison under a constrained loop-tuning budget —
    // the §2 setting where schedules are not yet fully optimized and
    // the layout's intrinsic locality dominates.
    let mut o_small = o.clone();
    o_small.budget = (scale.op_budget / 4).max(24);
    let rp_small = tune_loops(&g, conv, &packed, &hw, &o_small);
    let rt_small = tune_loops(&g, conv, &tiled, &hw, &o_small);

    let mut t = Table::new(
        "Motivating example (paper: tiled layout +32.4% over N(O/ot)HWot)",
        &["layout", "budget", "latency (ms)", "improvement"],
    );
    t.row(&[
        "N(O/ot)HWot".into(),
        o_small.budget.to_string(),
        format!("{:.4}", rp_small.best_ms),
        "-".into(),
    ]);
    t.row(&[
        "tiled+unfold".into(),
        o_small.budget.to_string(),
        format!("{:.4}", rt_small.best_ms),
        format!("{:+.1}%", (rp_small.best_ms / rt_small.best_ms - 1.0) * 100.0),
    ]);
    t.row(&[
        "N(O/ot)HWot".into(),
        o.budget.to_string(),
        format!("{:.4}", r_packed.best_ms),
        "-".into(),
    ]);
    t.row(&[
        "tiled+unfold".into(),
        o.budget.to_string(),
        format!("{:.4}", r_tiled.best_ms),
        format!("{:+.1}%", (r_packed.best_ms / r_tiled.best_ms - 1.0) * 100.0),
    ]);
    t
}

/// Table 2: L1 demand misses, layout tiling vs prediction vs loop tiling
/// on the Cortex-A76-like exact cache simulator.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2: L1 misses (Cortex-A76-like, 64B lines, 4-line prefetch)",
        &["tile", "#L1-mis (layout)", "pred.", "#L1-mis (loop)"],
    );
    for cols in [4u64, 16, 64, 256] {
        t.row(&[
            format!("512 x {cols}"),
            cache::table2_layout_tiled(512, cols).to_string(),
            cache::table2_prediction(512, cols).to_string(),
            cache::table2_loop_tiled(512, cols, 512).to_string(),
        ]);
    }
    t
}

/// Fig. 9: single-operator benchmark across the nine families, five
/// systems, three platforms. Reports per-family geomean speedup over
/// the worst performer (the paper's normalization).
pub fn fig9(scale: &Scale) -> Vec<Table> {
    let systems = ["torch", "autotvm", "flextensor", "ansor", "ALT"];
    let mut tables = Vec::new();
    for hw in HwProfile::all() {
        let mut t = Table::new(
            &format!("Fig 9 ({}): single-op speedup over worst (geomean)", hw.name),
            &["op", "torch", "autotvm", "flextensor", "ansor", "ALT"],
        );
        let mut geo_alt_vs_ansor = Vec::new();
        for fam in models::OP_FAMILIES {
            let mut speedups: Vec<Vec<f64>> = vec![Vec::new(); systems.len()];
            let mut rng = crate::util::Rng::new(scale.seed ^ fam.len() as u64);
            for _ in 0..scale.configs_per_family {
                let cfg = models::random_op_config(fam, &mut rng);
                let node = cfg.graph.complex_nodes()[0];
                let b = scale.op_budget;
                let lat = [
                    baselines::vendor(&cfg.graph, node, &hw).best_ms,
                    baselines::autotvm_like(&cfg.graph, node, &hw, b, scale.seed)
                        .best_ms,
                    baselines::flextensor_like(&cfg.graph, node, &hw, b, scale.seed)
                        .best_ms,
                    baselines::ansor_like(&cfg.graph, node, &hw, b, scale.seed)
                        .best_ms,
                    tune_op(
                        &cfg.graph,
                        node,
                        &hw,
                        &opts(b, scale.seed, PropMode::Alt),
                    )
                    .best_ms,
                ];
                let worst = lat.iter().cloned().fold(0.0, f64::max);
                for (s, &l) in lat.iter().enumerate() {
                    speedups[s].push(worst / l);
                }
                geo_alt_vs_ansor.push(lat[3] / lat[4]);
            }
            let mut row = vec![fam.to_string()];
            for s in &speedups {
                row.push(format!("{:.2}", geomean(s)));
            }
            t.row(&row);
        }
        tables.push(t);
        let mut s = Table::new(
            &format!("Fig 9 ({}): ALT speedup over Ansor", hw.name),
            &["metric", "value"],
        );
        s.row(&["geomean ALT/ansor".into(), format!("{:.2}x", geomean(&geo_alt_vs_ansor))]);
        tables.push(s);
    }
    tables
}

/// The five end-to-end networks (scaled variants used when `quick`),
/// resolved through the shared model zoo.
fn fig10_networks(quick: bool) -> Vec<Graph> {
    let names: &[&str] = if quick {
        &["resnet18", "mobilenet_v2", "bert_tiny"]
    } else {
        // "resnet18-b16" is the paper's b16 row (intel/gpu)
        &[
            "resnet18",
            "resnet18-b16",
            "mobilenet_v2",
            "bert_base",
            "bert_tiny",
            "resnet3d_18",
        ]
    };
    names
        .iter()
        .map(|n| models::by_name(n).expect("zoo workload"))
        .collect()
}

/// Fig. 10: end-to-end latency + speedup over the vendor (Torch-like)
/// build, for Ansor-like / ALT-OL / ALT-WP / ALT. The whole network
/// fleet of each mode goes through the multi-workload front end
/// ([`tune_graphs`], auto-sharded with adaptive budget reallocation),
/// so every graph's independent shards tune concurrently over one
/// shared engine instead of walking ops one at a time.
pub fn fig10(scale: &Scale, quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for hw in HwProfile::all() {
        let mut t = Table::new(
            &format!(
                "Fig 10 ({}): end-to-end latency ms (speedup over vendor)",
                hw.name
            ),
            &["network", "vendor", "ansor", "ALT-OL", "ALT-WP", "ALT"],
        );
        let nets = fig10_networks(quick);
        // vendor: fixed heuristic schedules, no tuning
        let vendor: Vec<f64> = nets
            .iter()
            .map(|g| {
                let prop = propagate(g, &[], PropMode::Alt);
                let mut scheds = HashMap::new();
                for &c in &g.complex_nodes() {
                    let out = g.tensor(g.node(c).output).shape.clone();
                    let mut s = crate::loops::LoopSchedule::identity(&out, &[1]);
                    for (i, tl) in s.spatial_tiles.iter_mut().enumerate() {
                        *tl = crate::util::round_to_divisor(
                            out[i],
                            if i + 1 == out.len() { hw.simd_lanes as f64 } else { 4.0 },
                        );
                    }
                    s.vectorize = true;
                    s.parallel = 2;
                    scheds.insert(c, s);
                }
                simulate_graph(g, &prop, &scheds, &hw).latency_ms()
            })
            .collect();
        // one fleet-scale multi-workload run per distinct mode; the
        // ansor-like column *is* ALT-OL (loop-only, default layouts),
        // so that fleet is tuned once and reported twice
        let fleet = |mode: PropMode| -> Vec<f64> {
            let mut o = opts(scale.graph_budget, scale.seed, mode);
            o.shards = 0; // auto-shard each network
            tune_graphs(&nets, &hw, &o)
                .iter()
                .map(|r| r.report.latency_ms())
                .collect()
        };
        let loop_only = fleet(PropMode::LoopOnly);
        let per_mode: Vec<Vec<f64>> = vec![
            loop_only.clone(), // ansor-like
            loop_only,         // ALT-OL
            fleet(PropMode::WithoutFusionProp),
            fleet(PropMode::Alt),
        ];
        for (i, g) in nets.iter().enumerate() {
            let mut row = vec![g.name.clone(), format!("{:.3}", vendor[i])];
            for mode_lat in &per_mode {
                row.push(format!(
                    "{:.3} ({:.2}x)",
                    mode_lat[i],
                    vendor[i] / mode_lat[i]
                ));
            }
            t.row(&row);
        }
        tables.push(t);
        if quick {
            break; // one platform in quick mode
        }
    }
    tables
}

/// Fig. 11: layout-propagation overhead ablation on the two §7.3.1
/// subgraphs (Ansor / ALT-FP / ALT-BP / ALT).
pub fn fig11(scale: &Scale) -> Table {
    let mut t = Table::new(
        "Fig 11: propagation-overhead ablation, latency ms",
        &["subgraph", "ansor", "ALT-FP", "ALT-BP", "ALT"],
    );
    let hw = HwProfile::intel();
    for hwsz in [7, 14] {
        let g = models::prop_subgraph(hwsz);
        let mut row = vec![g.name.clone()];
        for mode in [
            PropMode::LoopOnly,
            PropMode::ForwardShare,
            PropMode::BackwardShare,
            PropMode::Alt,
        ] {
            let r = tune_graph(&g, &hw, &opts(scale.graph_budget / 2, scale.seed, mode));
            row.push(format!("{:.4}", r.report.latency_ms()));
        }
        t.row(&row);
    }
    t
}

/// Fig. 12: parameter sensitivity — template levels × budget.
pub fn fig12(scale: &Scale) -> Table {
    let mut t = Table::new(
        "Fig 12: template levels x budget (end-to-end latency ms)",
        &["network", "1-level@B", "2-level@B", "2-level@1.5B"],
    );
    let hw = HwProfile::intel();
    for g in [models::case_study(), models::prop_subgraph(14)] {
        let mut row = vec![g.name.clone()];
        for (levels, budget) in [
            (1usize, scale.graph_budget),
            (2, scale.graph_budget),
            (2, scale.graph_budget * 3 / 2),
        ] {
            let mut o = opts(budget, scale.seed, PropMode::Alt);
            o.levels = levels;
            let r = tune_graph(&g, &hw, &o);
            row.push(format!("{:.4}", r.report.latency_ms()));
        }
        t.row(&row);
    }
    t
}

/// Table 3: profiled counters of the case-study subgraph under the four
/// §7.3.3 layouts (counts in 1e6, latency ms).
pub fn table3(scale: &Scale) -> Table {
    let g = models::case_study();
    let conv = g.complex_nodes()[0];
    let hw = HwProfile::intel();
    let o = opts(scale.op_budget, scale.seed, PropMode::Alt);
    let mut t = Table::new(
        "Table 3: counters per layout (1e6; latency ms)",
        &["layout", "#Inst", "#L1-lds", "#L1-mis", "#L1-sts", "Lat."],
    );

    let mk_unfold = |ht: i64, wt: i64| -> LayoutSeq {
        let mut s = LayoutSeq::new();
        s.push(Primitive::unfold(1, 2 * (ht - 1) + 7, 2 * ht));
        s.push(Primitive::unfold(3, 2 * (wt - 1) + 7, 2 * wt));
        s
    };
    let cases: Vec<(&str, ComplexDecision)> = vec![
        (
            "NHWO & rsIO",
            ComplexDecision { node: conv, ..Default::default() },
        ),
        (
            "NOHW & OIrs",
            ComplexDecision {
                node: conv,
                out_seq: fixed_layout("NOHW"),
                w_seq: {
                    let mut s = LayoutSeq::new();
                    s.push(Primitive::reorder(&[3, 2, 0, 1]));
                    s
                },
                ..Default::default()
            },
        ),
        (
            "N(O/ot)HWot",
            ComplexDecision {
                node: conv,
                out_seq: packed_layout(64, 16),
                ..Default::default()
            },
        ),
        (
            "tiled+unfold (searched)",
            ComplexDecision {
                node: conv,
                out_seq: tiled_layout(112, 112, 64, 4, 16, 16),
                in_seq: mk_unfold(4, 16),
                ..Default::default()
            },
        ),
    ];
    for (name, dec) in cases {
        let r = tune_loops(&g, conv, &dec, &hw, &o);
        // re-simulate the winner to read its counters
        let prop = propagate(&g, std::slice::from_ref(&dec), PropMode::Alt);
        let (_, rep) = crate::sim::netsim::simulate_single_op(
            &g, conv, &prop, &r.sched, &hw,
        );
        t.row(&[
            name.into(),
            format!("{:.1}", rep.instructions / 1e6),
            format!("{:.1}", rep.l1_loads / 1e6),
            format!("{:.1}", rep.l1_misses / 1e6),
            format!("{:.1}", rep.l1_stores / 1e6),
            format!("{:.3}", r.best_ms),
        ]);
    }
    t
}

/// Design-choice ablations (DESIGN.md): how the cross-exploration
/// hyper-parameters shape the result on the case study — joint-stage
/// share, loop rounds per layout candidate, and the cost-model's
/// measurement economy (Ansor-like vs FlexTensor-like contrast).
pub fn ablations(scale: &Scale) -> Vec<Table> {
    let g = models::case_study();
    let conv = g.complex_nodes()[0];
    let hw = HwProfile::intel();
    let budget = scale.op_budget * 4;

    let mut t1 = Table::new(
        "Ablation: joint-stage budget share (case study)",
        &["joint_frac", "best ms"],
    );
    for jf in [0.0, 0.15, 0.3, 0.6] {
        let mut o = opts(budget, scale.seed, PropMode::Alt);
        o.joint_frac = jf;
        let r = tune_op(&g, conv, &hw, &o);
        t1.row(&[format!("{jf:.2}"), format!("{:.4}", r.best_ms)]);
    }

    let mut t2 = Table::new(
        "Ablation: loop rounds per layout candidate (cross-exploration depth)",
        &["rounds", "best ms"],
    );
    for rpl in [1usize, 2, 4] {
        let mut o = opts(budget, scale.seed, PropMode::Alt);
        o.rounds_per_layout = rpl;
        let r = tune_op(&g, conv, &hw, &o);
        t2.row(&[rpl.to_string(), format!("{:.4}", r.best_ms)]);
    }

    let mut t3 = Table::new(
        "Ablation: cost-model measurement economy (same budget)",
        &["tuner", "best ms"],
    );
    let with_cm = baselines::ansor_like(&g, conv, &hw, budget, scale.seed);
    let without = baselines::flextensor_like(&g, conv, &hw, budget, scale.seed);
    t3.row(&["with cost model (top-k measured)".into(), format!("{:.4}", with_cm.best_ms)]);
    t3.row(&["without (every candidate measured)".into(), format!("{:.4}", without.best_ms)]);

    vec![t1, t2, t3]
}

/// §7.3.4 observation: distribution of the tuned `ot` (channel tile).
pub fn observations(scale: &Scale) -> Table {
    let mut t = Table::new(
        "§7.3.4: tuned channel-tile (ot) statistics per platform",
        &["platform", "lanes", "median ot", "ot == 2x lanes?"],
    );
    for hw in HwProfile::all() {
        let mut ots = Vec::new();
        let mut rng = crate::util::Rng::new(scale.seed);
        for _ in 0..scale.configs_per_family.max(3) {
            let cfg = models::random_op_config("C2D", &mut rng);
            let node = cfg.graph.complex_nodes()[0];
            let r = tune_op(
                &cfg.graph,
                node,
                &hw,
                &opts(scale.op_budget, scale.seed, PropMode::Alt),
            );
            // ot = last split factor of the output sequence
            if let Some(Primitive::Split { factors, .. }) = r
                .decision
                .out_seq
                .prims
                .iter()
                .filter(|p| matches!(p, Primitive::Split { .. }))
                .last()
            {
                ots.push(*factors.last().unwrap());
            }
        }
        ots.sort();
        let med = ots.get(ots.len() / 2).copied().unwrap_or(0);
        t.row(&[
            hw.name.into(),
            hw.simd_lanes.to_string(),
            med.to_string(),
            format!("{}", med == 2 * hw.simd_lanes),
        ]);
    }
    t
}
