//! Benchmark harness shared by `cargo bench` targets and the `figures`
//! binary.
//!
//! criterion is not available offline, so this module provides the
//! timing/reporting scaffolding (median-of-n wall-clock, Markdown-ish
//! tables) and, in [`figures`], one generator function per paper table
//! and figure. Bench targets are thin `harness = false` mains calling
//! into here.

pub mod figures;
pub mod harness;

pub use harness::{time_fn, BenchTimer, Table};
