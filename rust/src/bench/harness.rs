//! Minimal timing + table-report harness (criterion replacement).

use std::time::Instant;

/// Median-of-n wall-clock timing of a closure, with one warmup call.
/// Returns milliseconds.
pub fn time_fn<F: FnMut()>(mut f: F, n: usize) -> f64 {
    f(); // warmup
    let mut times = Vec::with_capacity(n.max(1));
    for _ in 0..n.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    crate::util::stats::median(&mut times)
}

/// Wall-clock stopwatch with named laps (profiling aid for §Perf).
pub struct BenchTimer {
    start: Instant,
    laps: Vec<(String, f64)>,
    last: Instant,
}

impl Default for BenchTimer {
    fn default() -> Self {
        Self::new()
    }
}

impl BenchTimer {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, laps: Vec::new(), last: now }
    }

    pub fn lap(&mut self, name: &str) {
        let now = Instant::now();
        self.laps
            .push((name.to_string(), (now - self.last).as_secs_f64() * 1e3));
        self.last = now;
    }

    pub fn total_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, ms) in &self.laps {
            s.push_str(&format!("{name}: {ms:.1} ms\n"));
        }
        s.push_str(&format!("total: {:.1} ms\n", self.total_ms()));
        s
    }
}

/// Plain-text aligned table for figure/table reproductions.
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table arity");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_positive() {
        let ms = time_fn(
            || {
                let mut s = 0u64;
                for i in 0..10_000 {
                    s = s.wrapping_add(i);
                }
                std::hint::black_box(s);
            },
            3,
        );
        assert!(ms >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("333"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "table arity")]
    fn table_arity_checked() {
        let mut t = Table::new("demo", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn timer_laps() {
        let mut t = BenchTimer::new();
        t.lap("one");
        t.lap("two");
        assert!(t.report().contains("one"));
        assert!(t.total_ms() >= 0.0);
    }
}
