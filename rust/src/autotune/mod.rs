//! The auto-tuning module (paper §5): layout templates, PPO agents, the
//! loop space, the two-stage cross-exploration tuner, and the sharded
//! graph-tuning orchestrator with adaptive budget reallocation.

pub mod orchestrator;
pub mod ppo;
pub mod space;
pub mod template;
pub mod tuner;

pub use orchestrator::{
    tune_graph, tune_graph_with, tune_graphs, tune_graphs_with,
    GraphTuneResult,
};
pub use space::LoopSpace;
pub use tuner::{tune_op, OpTuneResult, OpTuner, TuneOptions};
