//! The auto-tuning module (paper §5): layout templates, PPO agents, the
//! loop space, and the two-stage cross-exploration tuner.

pub mod ppo;
pub mod space;
pub mod template;
pub mod tuner;

pub use space::LoopSpace;
pub use tuner::{tune_graph, tune_op, GraphTuneResult, OpTuneResult, TuneOptions};
