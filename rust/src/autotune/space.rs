//! Loop-tuning space: the per-operator option grid the loop agents walk
//! (random-walk exploration as in FlexTensor/§5.2.2).
//!
//! A point indexes into per-dimension option lists: tile factors
//! (divisors) per spatial/reduction storage dim, vectorize, parallel
//! depth, unroll limit, and which spatial dim rotates innermost. The
//! space is rebuilt whenever the output layout changes (the loop-nest
//! reconstruction of §5.2 that motivates the two-stage design).

use crate::loops::LoopSchedule;
use crate::util::{divisors, Rng};

/// A point in loop space: one option index per dimension.
pub type Point = Vec<usize>;

/// The loop space for one operator under a fixed output layout.
#[derive(Clone, Debug)]
pub struct LoopSpace {
    pub spatial: Vec<i64>,
    pub reduction: Vec<i64>,
    /// Option lists per point dimension (values are opaque codes).
    options: Vec<Vec<i64>>,
}

impl LoopSpace {
    pub fn new(spatial: &[i64], reduction: &[i64]) -> Self {
        let mut options: Vec<Vec<i64>> = Vec::new();
        for &e in spatial {
            options.push(divisors(e));
        }
        for &e in reduction {
            options.push(divisors(e));
        }
        options.push(vec![0, 1]); // vectorize
        options.push(vec![0, 1, 2, 3]); // parallel depth
        options.push(vec![0, 4, 16]); // unroll
        // innermost rotation: which spatial dim moves innermost
        options.push((0..spatial.len() as i64).collect());
        Self { spatial: spatial.to_vec(), reduction: reduction.to_vec(), options }
    }

    /// Number of point dimensions.
    pub fn n_dims(&self) -> usize {
        self.options.len()
    }

    /// Total number of points (the paper's `O(10^7)` for C2D).
    pub fn size(&self) -> f64 {
        self.options.iter().map(|o| o.len() as f64).product()
    }

    pub fn random_point(&self, rng: &mut Rng) -> Point {
        self.options.iter().map(|o| rng.below(o.len())).collect()
    }

    /// The identity/default point (no tiling, no annotations).
    pub fn default_point(&self) -> Point {
        let mut p: Vec<usize> = Vec::with_capacity(self.n_dims());
        for (d, o) in self.options.iter().enumerate() {
            if d < self.spatial.len() + self.reduction.len() {
                p.push(o.len() - 1); // full extent (single tile)
            } else {
                p.push(0);
            }
        }
        p
    }

    /// A structured starting point (Ansor-sketch-style): tile spatial
    /// dims to ~4 (last dim to the SIMD width), tile reductions fully,
    /// vectorize, parallelize two outer loops, light unroll.
    pub fn heuristic_point(&self, simd_lanes: i64) -> Point {
        let ns = self.spatial.len();
        let nr = self.reduction.len();
        let mut p = Vec::with_capacity(self.n_dims());
        for (d, o) in self.options.iter().enumerate().take(ns) {
            let want = if d + 1 == ns { simd_lanes } else { 4 };
            p.push(nearest_idx(o, want));
        }
        for o in self.options.iter().skip(ns).take(nr) {
            p.push(nearest_idx(o, 4));
        }
        p.push(1); // vectorize on
        p.push(2); // parallel depth 2
        p.push(1); // unroll 4
        p.push((ns - 1).min(self.options[ns + nr + 3].len() - 1)); // rotate last dim innermost
        p
    }

    /// A random *sketch* point (Ansor-style structured candidate):
    /// canonical tile shapes — spatial tiles from {1, ~4, ~lanes,
    /// full}, the channel-most dim biased to {lanes, 2·lanes, full},
    /// reductions from {1, full}, vectorized, parallel 2–3. These
    /// include the archetypal good schedules, cutting the variance of
    /// pure random-walk exploration.
    pub fn sketch_point(&self, simd_lanes: i64, rng: &mut Rng) -> Point {
        let ns = self.spatial.len();
        let nr = self.reduction.len();
        let mut p = Vec::with_capacity(self.n_dims());
        for (d, o) in self.options.iter().enumerate().take(ns) {
            let choices: [i64; 4] = if d + 1 == ns {
                [simd_lanes, 2 * simd_lanes, self.spatial[d], 1]
            } else {
                [1, 4, simd_lanes, self.spatial[d]]
            };
            p.push(nearest_idx(o, choices[rng.below(choices.len())]));
        }
        for (r, o) in self.options.iter().skip(ns).take(nr).enumerate() {
            let full = self.reduction[r];
            p.push(nearest_idx(o, if rng.uniform() < 0.5 { 1 } else { full }));
        }
        p.push(1); // vectorize
        p.push(2 + rng.below(2)); // parallel 2..=3
        p.push(rng.below(2)); // unroll 0 or 4
        p.push((ns - 1).min(self.options[ns + nr + 3].len() - 1));
        p
    }

    /// Walk one step along `dim` in direction `dir` (±1), clamped.
    pub fn neighbor(&self, p: &Point, dim: usize, dir: i64) -> Point {
        let mut q = p.clone();
        let len = self.options[dim].len() as i64;
        let cur = q[dim] as i64;
        q[dim] = (cur + dir).clamp(0, len - 1) as usize;
        q
    }

    /// Decode a point into a concrete schedule.
    pub fn decode(&self, p: &Point) -> LoopSchedule {
        let ns = self.spatial.len();
        let nr = self.reduction.len();
        assert_eq!(p.len(), self.n_dims(), "point arity");
        let spatial_tiles: Vec<i64> =
            (0..ns).map(|d| self.options[d][p[d]]).collect();
        let reduction_tiles: Vec<i64> =
            (0..nr).map(|d| self.options[ns + d][p[ns + d]]).collect();
        let vectorize = self.options[ns + nr][p[ns + nr]] == 1;
        let parallel = self.options[ns + nr + 1][p[ns + nr + 1]] as usize;
        let unroll = self.options[ns + nr + 2][p[ns + nr + 2]];
        let rot = self.options[ns + nr + 3][p[ns + nr + 3]] as usize;
        // inner perm: rotate `rot` to the last position
        let mut perm: Vec<usize> = (0..ns).filter(|&d| d != rot).collect();
        perm.push(rot);
        let mut s = LoopSchedule {
            spatial_tiles,
            reduction_tiles,
            inner_perm: perm,
            vectorize,
            parallel,
            unroll,
            fuse_eltwise: true,
        };
        s.repair(&self.spatial, &self.reduction);
        s
    }

    /// Decode a whole candidate batch (the engine evaluates rounds as
    /// batches; decoding up front keeps the parallel stage pure).
    pub fn decode_batch<'a>(
        &self,
        points: impl IntoIterator<Item = &'a Point>,
    ) -> Vec<LoopSchedule> {
        points.into_iter().map(|p| self.decode(p)).collect()
    }

    /// Total option count for a point dimension.
    pub fn n_options(&self, dim: usize) -> usize {
        self.options[dim].len()
    }

    /// State vector for the PPO agents: normalized option indices.
    pub fn state(&self, p: &Point) -> Vec<f64> {
        p.iter()
            .zip(&self.options)
            .map(|(&i, o)| (i as f64 + 0.5) / o.len() as f64)
            .collect()
    }
}

fn nearest_idx(options: &[i64], want: i64) -> usize {
    options
        .iter()
        .enumerate()
        .min_by_key(|(_, &v)| (v - want).abs())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heuristic_point_is_vectorized() {
        let s = LoopSpace::new(&[1, 112, 112, 64], &[3, 7, 7]);
        let p = s.heuristic_point(16);
        let d = s.decode(&p);
        assert!(d.vectorize);
        assert_eq!(d.parallel, 2);
        assert_eq!(*d.spatial_tiles.last().unwrap(), 16);
    }

    #[test]
    fn c2d_space_is_big() {
        // 7 storage dims (tiled layout) + 3 reductions
        let s = LoopSpace::new(&[1, 28, 7, 4, 4, 16, 16], &[3, 7, 7]);
        assert!(s.size() > 1e5, "space {}", s.size());
    }

    #[test]
    fn decode_default_is_identity_tiles() {
        let s = LoopSpace::new(&[8, 16], &[4]);
        let d = s.decode(&s.default_point());
        assert_eq!(d.spatial_tiles, vec![8, 16]);
        assert_eq!(d.reduction_tiles, vec![4]);
        assert!(!d.vectorize);
    }

    #[test]
    fn neighbor_clamps() {
        let s = LoopSpace::new(&[8], &[]);
        let p = s.default_point();
        let up = s.neighbor(&p, 0, 1);
        assert_eq!(up[0], p[0], "already at max");
        let down = s.neighbor(&p, 0, -1);
        assert_eq!(down[0], p[0] - 1);
    }

    #[test]
    fn decode_random_points_are_feasible() {
        let mut rng = Rng::new(3);
        let s = LoopSpace::new(&[1, 28, 7, 4, 4, 16, 16], &[3, 7, 7]);
        for _ in 0..50 {
            let p = s.random_point(&mut rng);
            let d = s.decode(&p);
            for (t, e) in d.spatial_tiles.iter().zip(&s.spatial) {
                assert_eq!(e % t, 0);
            }
            for (t, e) in d.reduction_tiles.iter().zip(&s.reduction) {
                assert_eq!(e % t, 0);
            }
        }
    }

    #[test]
    fn decode_batch_matches_single_decode() {
        let mut rng = Rng::new(9);
        let s = LoopSpace::new(&[8, 16], &[4]);
        let pts: Vec<Point> = (0..8).map(|_| s.random_point(&mut rng)).collect();
        let batch = s.decode_batch(pts.iter());
        for (p, d) in pts.iter().zip(&batch) {
            assert_eq!(*d, s.decode(p));
        }
    }

    #[test]
    fn state_normalized() {
        let s = LoopSpace::new(&[8, 16], &[4]);
        let p = s.default_point();
        for v in s.state(&p) {
            assert!((0.0..=1.0).contains(&v));
        }
    }
}
