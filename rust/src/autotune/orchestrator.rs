//! Sharded graph-tuning orchestrator with adaptive budget
//! reallocation (ROADMAP: multi-graph sharding).
//!
//! The historical `tune_graph` walked a network's complex operators
//! strictly sequentially with a one-off `budget / n_ops` split — the
//! "one-off workflow" rigidity the paper argues against at the
//! graph/operator boundary. This module replaces that walk with a
//! three-part orchestration:
//!
//! * **Shard analysis** ([`crate::graph::shard`]) partitions the
//!   complex ops into independently tunable shards along the §4.2
//!   propagation-reachability structure. Ops coupled through an
//!   element-wise chain stay sequential inside one shard (the §6
//!   topological order); ops separated by a non-propagatable boundary
//!   tune concurrently.
//! * **Shard scheduling** runs the shards over one shared
//!   [`Engine`], each holding a *fair-share* handle
//!   ([`Engine::fair_handles`]) so no shard's candidate batches can
//!   starve another's. Per-op work is driven through the resumable
//!   [`OpTuner`], and every op carries its own engine tally, so
//!   per-op → per-shard → per-graph stats compose exactly.
//! * **Adaptive budget reallocation** (`TuneOptions::budget_realloc`)
//!   starts every op at the per-op floor and then feeds the remaining
//!   graph budget, phase by phase, to the ops whose best-so-far
//!   history is still improving — plateaued shards stop consuming
//!   budget instead of burning their fixed share. With
//!   `budget_realloc = false` every op receives the historical fixed
//!   split, and a sharded run reproduces the sequential results
//!   bit-for-bit (sharding is then a pure throughput knob).
//!
//! ## Determinism contract
//!
//! For a fixed `(seed, shards)` the outcome — decisions, schedules,
//! latencies, histories, measurement counts — is bit-identical at any
//! `threads` value: per-op trajectories never depend on engine cache
//! state (property-tested by the eviction suite), shard membership is
//! a pure function of the graph, phase barriers make every
//! reallocation decision from completed, deterministic state, and
//! results are folded in topological order. `shards = 1` (the
//! default) takes the sequential legacy path and reproduces the
//! pre-orchestrator `tune_graph` bit-for-bit. Engine *counters* in
//! sharded runs are deterministic as long as the memo cap does not
//! bind (the same caveat the engine has always documented).
//!
//! ## Multi-workload front end
//!
//! [`tune_graphs`] shards several networks across one scheduler and
//! one engine — the figure harness tunes whole workload fleets this
//! way. Budgets are per-graph ledgers; shards of all graphs share the
//! fair-handle pool, so a small graph's shards fill the cores a big
//! graph's plateaued shards stopped using.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::autotune::tuner::{
    engine_for, measured_per_round, tune_op_with, OpTuneResult, OpTuner,
    TuneOptions,
};
use crate::engine::{Engine, EngineStats};
use crate::graph::{shard, Graph, NodeId};
use crate::loops::LoopSchedule;
use crate::propagate::{propagate, ComplexDecision};
use crate::sim::netsim::{simulate_graph_with, GraphReport};
use crate::sim::HwProfile;

/// Per-op measurement floor: below ~128 measurements the joint stage
/// cannot act, so graph tuning guarantees each op a meaningful slice
/// (total measurements may exceed the graph budget on very deep nets —
/// surfaced as [`GraphTuneResult::budget_overshoot`]).
pub const PER_OP_FLOOR: usize = 128;

/// Best-so-far window (measurements) the adaptive scheduler inspects:
/// an op is "improving" while its global best dropped by more than
/// [`REALLOC_EPS`] over its last window.
const REALLOC_WINDOW: usize = 16;
const REALLOC_EPS: f64 = 0.003;

/// Hard cap on reallocation phases — a backstop far above what any
/// real budget reaches (each phase spends at least one grant quantum).
const MAX_REALLOC_ROUNDS: usize = 64;

/// End-to-end tuning result for a graph.
#[derive(Clone, Debug)]
pub struct GraphTuneResult {
    pub decisions: Vec<ComplexDecision>,
    pub scheds: HashMap<NodeId, LoopSchedule>,
    pub report: GraphReport,
    pub measurements: usize,
    /// cumulative PPO rounds across all ops
    pub rounds: usize,
    /// Engine counters attributable to *this* graph's run: the sum of
    /// the per-op tallies plus the final whole-graph simulation —
    /// delta-based, so results compose when many runs share an engine
    /// (equal to a global before/after snapshot when the engine is
    /// held exclusively).
    pub engine: EngineStats,
    /// Measurements spent beyond `opts.budget`. The per-op floor can
    /// force this on deep nets (`n_ops * floor > budget`); the
    /// adaptive scheduler never grants past the budget, so any
    /// overshoot is the floor's (plus at most one in-flight
    /// round/proposal per op).
    pub budget_overshoot: usize,
    /// Scheduling units the run used (1 = the sequential legacy path).
    pub shards: usize,
    /// Per-op results in topological order (decisions/scheds above are
    /// projections of these).
    pub ops: Vec<OpTuneResult>,
}

/// Tune every complex operator of a graph, then simulate the whole
/// network under the propagated layouts. One engine (and memo cache)
/// spans the entire run, so the final graph simulation re-uses
/// programs the per-op tuning already lowered. `opts.shards == 1`
/// walks ops sequentially in topological order exactly as the
/// pre-orchestrator tuner did; other values shard (see module docs).
pub fn tune_graph(
    graph: &Graph,
    hw: &HwProfile,
    opts: &TuneOptions,
) -> GraphTuneResult {
    let engine = engine_for(opts);
    tune_graph_with(graph, hw, opts, &engine)
}

/// [`tune_graph`] against a caller-provided engine (shared memo cache
/// across whole fleets of runs; stats stay delta-based).
pub fn tune_graph_with(
    graph: &Graph,
    hw: &HwProfile,
    opts: &TuneOptions,
    engine: &Engine,
) -> GraphTuneResult {
    let complex = graph.complex_nodes();
    if opts.shards == 1 || complex.len() <= 1 {
        // ---- sequential legacy path (bit-for-bit the historical
        // serial loop; a single op cannot shard, and realloc of a
        // one-op graph is a no-op by construction) ----
        let per_op = fixed_split(opts.budget, complex.len());
        let mut o = opts.clone();
        o.budget = per_op;
        let ops: Vec<OpTuneResult> = complex
            .iter()
            .map(|&node| tune_op_with(graph, node, hw, &o, engine))
            .collect();
        return assemble(graph, hw, opts, ops, engine, 1);
    }
    let (mut per_graph, mut shards_used) =
        tune_ops_sharded(&[graph], hw, opts, engine);
    assemble(
        graph,
        hw,
        opts,
        per_graph.pop().expect("one graph in, one result out"),
        engine,
        shards_used.pop().unwrap_or(1),
    )
}

/// Multi-workload front end: tune several networks over one scheduler
/// and one shared engine. With `shards == 1` this is a sequential
/// fold of [`tune_graph_with`]; otherwise every graph's shards join
/// one fair-share pool and each graph keeps its own budget ledger.
/// Results come back in input order.
pub fn tune_graphs(
    graphs: &[Graph],
    hw: &HwProfile,
    opts: &TuneOptions,
) -> Vec<GraphTuneResult> {
    let engine = engine_for(opts);
    tune_graphs_with(graphs, hw, opts, &engine)
}

/// [`tune_graphs`] against a caller-provided engine.
pub fn tune_graphs_with(
    graphs: &[Graph],
    hw: &HwProfile,
    opts: &TuneOptions,
    engine: &Engine,
) -> Vec<GraphTuneResult> {
    if opts.shards == 1 || graphs.len() <= 1 {
        return graphs
            .iter()
            .map(|g| tune_graph_with(g, hw, opts, engine))
            .collect();
    }
    let refs: Vec<&Graph> = graphs.iter().collect();
    let (results, shards_used) = tune_ops_sharded(&refs, hw, opts, engine);
    results
        .into_iter()
        .zip(graphs)
        .zip(shards_used)
        .map(|((ops, g), s)| assemble(g, hw, opts, ops, engine, s))
        .collect()
}

/// The historical one-off split: every op gets the same share, floored.
fn fixed_split(budget: usize, n_ops: usize) -> usize {
    (budget / n_ops.max(1)).max(PER_OP_FLOOR)
}

/// One scheduling unit: a shard of one graph's complex ops, tuned
/// sequentially in topological order on a fair-share engine handle.
struct Unit<'a> {
    graph_idx: usize,
    tuners: Vec<OpTuner<'a>>,
}

/// The sharded core: build units for every graph, drive them through
/// the floor phase and the adaptive reallocation phases, return per-op
/// results grouped per graph in topological order (plus each graph's
/// unit count).
fn tune_ops_sharded<'a>(
    graphs: &[&'a Graph],
    hw: &'a HwProfile,
    opts: &TuneOptions,
    engine: &Engine,
) -> (Vec<Vec<OpTuneResult>>, Vec<usize>) {
    let mut units: Vec<Unit<'a>> = Vec::new();
    let mut shards_per_graph = vec![0usize; graphs.len()];
    for (gi, g) in graphs.iter().enumerate() {
        let n_ops = g.complex_nodes().len();
        // Every op keeps the historical per-op budget basis (it fixes
        // the joint-stage layout-exploration share). Adaptive mode
        // additionally lowers the *initial target* to the floor: the
        // scheduler hands out the rest by improvement, and a floor
        // below the joint share just pauses the joint stage until a
        // grant resumes it. Fixed mode is exactly the legacy split.
        let mut o = opts.clone();
        o.budget = fixed_split(opts.budget, n_ops);
        let plan = shard::analyze(g);
        for nodes in shard::pack(&plan, opts.shards) {
            shards_per_graph[gi] += 1;
            units.push(Unit {
                graph_idx: gi,
                tuners: nodes
                    .iter()
                    .map(|&node| {
                        let mut t = OpTuner::new(g, node, hw, &o);
                        if opts.budget_realloc {
                            t.set_target(PER_OP_FLOOR.min(t.target()));
                        }
                        t
                    })
                    .collect(),
            });
        }
    }
    let n = units.len();
    let slots: Vec<Mutex<Unit<'a>>> = units.into_iter().map(Mutex::new).collect();
    // Fair shares are recomputed per phase over the *active* units, so
    // a late reallocation phase with one improving shard gets the whole
    // pool instead of the floor phase's 1/n sliver. Widths never affect
    // results (only throughput), so this cannot touch the determinism
    // contract.
    let run_phase = |active: &[bool]| {
        let n_active = active.iter().filter(|&&a| a).count();
        if n_active == 0 {
            return;
        }
        let handles = engine.fair_handles(n_active);
        let mut handle_of = vec![0usize; n];
        let mut next = 0usize;
        for (i, &a) in active.iter().enumerate() {
            if a {
                handle_of[i] = next;
                next += 1;
            }
        }
        let inflight = n_active.min(engine.threads()).max(1);
        engine.run_with(inflight, n, |i| {
            if !active[i] {
                return;
            }
            let mut unit = slots[i].lock().expect("unit lock");
            for t in unit.tuners.iter_mut() {
                t.advance(handles[handle_of[i]]);
            }
        });
    };

    // ---- phase 0: every op runs to its floor ----
    run_phase(&vec![true; n]);

    // ---- adaptive phases: feed remaining budget to improving ops ----
    if opts.budget_realloc {
        let quantum = measured_per_round(opts).max(1) * 2;
        for _ in 0..MAX_REALLOC_ROUNDS {
            // barrier state: spent per graph + improving ops, all read
            // from completed (deterministic) tuner state
            let mut spent = vec![0usize; graphs.len()];
            let mut improving: Vec<(usize, usize, usize)> = Vec::new();
            for (i, slot) in slots.iter().enumerate() {
                let unit = slot.lock().expect("unit lock");
                for (j, t) in unit.tuners.iter().enumerate() {
                    spent[unit.graph_idx] += t.used();
                    if t.recent_gain(REALLOC_WINDOW) > REALLOC_EPS {
                        improving.push((i, j, unit.graph_idx));
                    }
                }
            }
            let pool: Vec<usize> = spent
                .iter()
                .map(|&s| opts.budget.saturating_sub(s))
                .collect();
            improving.retain(|&(_, _, gi)| pool[gi] >= quantum);
            if improving.is_empty() {
                break;
            }
            let mut counts = vec![0usize; graphs.len()];
            for &(_, _, gi) in &improving {
                counts[gi] += 1;
            }
            // geometric split per graph among its improving ops: each
            // phase hands out a quarter of the per-op share of the
            // remaining ledger (at least one round's worth), so grants
            // stay adaptive — improvement is re-checked between phases
            // — yet the pool drains within the phase cap. Deterministic
            // order: unit index, then op index; clamped to the ledger.
            let mut left = pool.clone();
            let mut active = vec![false; n];
            let mut granted_any = false;
            for &(i, j, gi) in &improving {
                let share =
                    (pool[gi] / (4 * counts[gi].max(1))).max(quantum);
                let grant = share.min(left[gi]);
                if grant < quantum {
                    continue;
                }
                left[gi] -= grant;
                slots[i].lock().expect("unit lock").tuners[j].grant(grant);
                active[i] = true;
                granted_any = true;
            }
            if !granted_any {
                break;
            }
            run_phase(&active);
        }
    }

    // ---- drain, regrouping per graph in topological order ----
    let mut by_node: Vec<HashMap<NodeId, OpTuneResult>> =
        graphs.iter().map(|_| HashMap::new()).collect();
    for slot in slots {
        let unit = slot.into_inner().expect("unit lock");
        let gi = unit.graph_idx;
        for t in unit.tuners {
            let r = t.finish();
            by_node[gi].insert(r.node, r);
        }
    }
    let results = graphs
        .iter()
        .enumerate()
        .map(|(gi, g)| {
            g.complex_nodes()
                .iter()
                .map(|node| {
                    by_node[gi].remove(node).expect("every complex op tuned")
                })
                .collect()
        })
        .collect();
    (results, shards_per_graph)
}

/// Fold per-op results into the graph result: propagate the winning
/// decisions, simulate the whole network on the shared engine, and
/// compose the delta-based stats (op tallies + final-sim delta).
fn assemble(
    graph: &Graph,
    hw: &HwProfile,
    opts: &TuneOptions,
    ops: Vec<OpTuneResult>,
    engine: &Engine,
    shards: usize,
) -> GraphTuneResult {
    let decisions: Vec<ComplexDecision> =
        ops.iter().map(|r| r.decision.clone()).collect();
    let scheds: HashMap<NodeId, LoopSchedule> =
        ops.iter().map(|r| (r.node, r.sched.clone())).collect();
    let measurements: usize = ops.iter().map(|r| r.measurements).sum();
    let rounds: usize = ops.iter().map(|r| r.rounds).sum();
    let prop = propagate(graph, &decisions, opts.mode);
    let sim0 = engine.stats();
    let report = simulate_graph_with(graph, &prop, &scheds, hw, engine);
    let sim_delta = engine.stats().since(&sim0);
    let engine_stats =
        ops.iter().fold(sim_delta, |acc, r| acc.merged(&r.engine));
    GraphTuneResult {
        decisions,
        scheds,
        report,
        measurements,
        rounds,
        engine: engine_stats,
        budget_overshoot: measurements.saturating_sub(opts.budget),
        shards,
        ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::propagate::PropMode;

    fn opts(budget: usize, shards: usize, realloc: bool) -> TuneOptions {
        TuneOptions {
            budget,
            seed: 7,
            shards,
            budget_realloc: realloc,
            ..Default::default()
        }
    }

    #[test]
    fn sequential_graph_tuning_still_works() {
        let g = models::prop_subgraph(7);
        let r = tune_graph(&g, &HwProfile::intel(), &opts(40, 1, true));
        assert_eq!(r.decisions.len(), 2);
        assert_eq!(r.shards, 1);
        assert_eq!(r.ops.len(), 2);
        // floor forces 2 * 128 measurements against a budget of 40
        assert_eq!(r.budget_overshoot, r.measurements - 40);
        assert!(r.report.latency_ms() > 0.0);
    }

    #[test]
    fn sharded_graph_tuning_runs_and_respects_the_ledger() {
        let g = models::prop_subgraph(14);
        let budget = 480;
        let r = tune_graph(&g, &HwProfile::intel(), &opts(budget, 0, true));
        assert_eq!(r.shards, 2, "two independent convs, two shards");
        assert!(r.measurements >= 2 * PER_OP_FLOOR, "floors guaranteed");
        // adaptive grants never push past the budget by more than one
        // in-flight round per op
        let slack = 2 * measured_per_round(&opts(budget, 0, true));
        assert!(
            r.measurements <= budget + slack,
            "overshot: {} > {budget} + {slack}",
            r.measurements
        );
        assert_eq!(
            r.budget_overshoot,
            r.measurements.saturating_sub(budget)
        );
    }

    #[test]
    fn mode_is_respected_in_sharded_runs() {
        let g = models::prop_subgraph(7);
        let mut o = opts(300, 0, true);
        o.mode = PropMode::LoopOnly;
        let r = tune_graph(&g, &HwProfile::arm(), &o);
        assert!(r.decisions.iter().all(|d| d.out_seq.is_identity()));
    }
}
