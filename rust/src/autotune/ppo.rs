//! PPO (proximal policy optimization) from scratch (paper §5.2).
//!
//! Two network heads as in the paper: *actors* propose primitive
//! parameters (a generic continuous split actor mapping actions into
//! `(0,1)`, and categorical direction actors for the loop random walk);
//! a single **global shared critic** fits the rewards of every agent to
//! model interference among sub-spaces (§5.2.2).

use crate::util::Rng;

/// A small dense MLP with tanh hidden activations.
#[derive(Clone, Debug)]
pub struct Mlp {
    // per layer: weights [out][in], biases [out]
    ws: Vec<Vec<Vec<f64>>>,
    bs: Vec<Vec<f64>>,
    // Adam state
    mw: Vec<Vec<Vec<f64>>>,
    vw: Vec<Vec<Vec<f64>>>,
    mb: Vec<Vec<f64>>,
    vb: Vec<Vec<f64>>,
    t: i32,
}

impl Mlp {
    pub fn new(sizes: &[usize], rng: &mut Rng) -> Self {
        let mut ws: Vec<Vec<Vec<f64>>> = Vec::new();
        let mut bs: Vec<Vec<f64>> = Vec::new();
        for w in sizes.windows(2) {
            let (nin, nout) = (w[0], w[1]);
            let scale = (2.0 / (nin + nout) as f64).sqrt();
            ws.push(
                (0..nout)
                    .map(|_| (0..nin).map(|_| rng.normal() * scale).collect())
                    .collect(),
            );
            bs.push(vec![0.0; nout]);
        }
        let mw = ws
            .iter()
            .map(|l| l.iter().map(|r| vec![0.0; r.len()]).collect())
            .collect();
        let vw = ws
            .iter()
            .map(|l: &Vec<Vec<f64>>| {
                l.iter().map(|r| vec![0.0; r.len()]).collect()
            })
            .collect();
        let mb = bs.iter().map(|b| vec![0.0; b.len()]).collect();
        let vb = bs.iter().map(|b| vec![0.0; b.len()]).collect();
        Self { ws, bs, mw, vw, mb, vb, t: 0 }
    }

    /// Forward pass; returns activations of every layer (input first).
    fn forward_full(&self, x: &[f64]) -> Vec<Vec<f64>> {
        let mut acts = vec![x.to_vec()];
        let last = self.ws.len() - 1;
        for (li, (w, b)) in self.ws.iter().zip(&self.bs).enumerate() {
            let prev = acts.last().unwrap().clone();
            let mut out = vec![0.0; b.len()];
            for (o, row) in w.iter().enumerate() {
                let mut s = b[o];
                for (i, wi) in row.iter().enumerate() {
                    s += wi * prev[i];
                }
                out[o] = if li == last { s } else { s.tanh() };
            }
            acts.push(out);
        }
        acts
    }

    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        self.forward_full(x).pop().unwrap()
    }

    /// Shift the output-layer biases (used to start a squashed policy
    /// off-center, e.g. toward small tile factors).
    pub fn add_output_bias(&mut self, b: f64) {
        if let Some(last) = self.bs.last_mut() {
            for v in last.iter_mut() {
                *v += b;
            }
        }
    }

    /// Backprop `dout` (gradient at the linear output) and apply one
    /// Adam step with learning rate `lr`.
    pub fn backward_step(&mut self, x: &[f64], dout: &[f64], lr: f64) {
        let acts = self.forward_full(x);
        let n_layers = self.ws.len();
        let mut grad = dout.to_vec();
        // accumulate gradients layer by layer, updating in place
        let mut gws: Vec<Vec<Vec<f64>>> = Vec::with_capacity(n_layers);
        let mut gbs: Vec<Vec<f64>> = Vec::with_capacity(n_layers);
        for li in (0..n_layers).rev() {
            let a_in = &acts[li];
            let gw: Vec<Vec<f64>> = (0..self.bs[li].len())
                .map(|o| a_in.iter().map(|ai| grad[o] * ai).collect())
                .collect();
            let gb = grad.clone();
            if li > 0 {
                // propagate through weights then tanh'
                let mut gin = vec![0.0; a_in.len()];
                for (o, row) in self.ws[li].iter().enumerate() {
                    for (i, wi) in row.iter().enumerate() {
                        gin[i] += grad[o] * wi;
                    }
                }
                for (i, g) in gin.iter_mut().enumerate() {
                    let a = acts[li][i];
                    *g *= 1.0 - a * a; // tanh'
                }
                grad = gin;
            }
            gws.push(gw);
            gbs.push(gb);
        }
        gws.reverse();
        gbs.reverse();
        // Adam
        self.t += 1;
        let (b1, b2, eps): (f64, f64, f64) = (0.9, 0.999, 1e-8);
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        for li in 0..n_layers {
            for o in 0..self.bs[li].len() {
                for i in 0..self.ws[li][o].len() {
                    let g = gws[li][o][i];
                    let m = &mut self.mw[li][o][i];
                    *m = b1 * *m + (1.0 - b1) * g;
                    let v = &mut self.vw[li][o][i];
                    *v = b2 * *v + (1.0 - b2) * g * g;
                    self.ws[li][o][i] -=
                        lr * (self.mw[li][o][i] / bc1)
                            / ((self.vw[li][o][i] / bc2).sqrt() + eps);
                }
                let g = gbs[li][o];
                self.mb[li][o] = b1 * self.mb[li][o] + (1.0 - b1) * g;
                self.vb[li][o] = b2 * self.vb[li][o] + (1.0 - b2) * g * g;
                self.bs[li][o] -= lr * (self.mb[li][o] / bc1)
                    / ((self.vb[li][o] / bc2).sqrt() + eps);
            }
        }
    }
}

/// One transition in a PPO rollout.
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f64>,
    /// For the Gaussian actor: raw (pre-squash) action vector.
    /// For categorical: one-hot-ish (index stored in `action_idx`).
    pub action: Vec<f64>,
    pub action_idx: usize,
    pub logp: f64,
    pub reward: f64,
    pub value: f64,
}

/// Shared critic: fits state -> expected reward (the global critic of
/// §5.2.2 shared by all actors).
pub struct Critic {
    net: Mlp,
    lr: f64,
}

impl Critic {
    pub fn new(state_dim: usize, rng: &mut Rng) -> Self {
        Self { net: Mlp::new(&[state_dim, 32, 1], rng), lr: 3e-3 }
    }

    pub fn value(&self, state: &[f64]) -> f64 {
        self.net.forward(state)[0]
    }

    pub fn update(&mut self, batch: &[(Vec<f64>, f64)]) {
        for (s, target) in batch {
            let v = self.value(s);
            // d/dv of 0.5*(v - target)^2
            self.net.backward_step(s, &[v - target], self.lr);
        }
    }
}

/// Continuous actor: diagonal Gaussian over `dim` raw actions, squashed
/// through a sigmoid to `(0,1)` (the paper's split-actor mapping, Eq. 2).
pub struct GaussianActor {
    net: Mlp,
    log_std: f64,
    dim: usize,
    lr: f64,
    clip: f64,
}

impl GaussianActor {
    pub fn new(state_dim: usize, dim: usize, rng: &mut Rng) -> Self {
        let mut net = Mlp::new(&[state_dim, 32, dim], rng);
        // start the squashed mean near 0.18: good tile factors live in
        // the small-fraction region (paper §7.3.4: ot ≈ 2x SIMD lanes,
        // a small fraction of the channel extent)
        net.add_output_bias(-1.5);
        Self { net, log_std: -0.7, dim, lr: 3e-3, clip: 0.2 }
    }

    /// Sample raw actions + log-prob; squashed values in (0,1).
    pub fn sample(&self, state: &[f64], rng: &mut Rng) -> (Vec<f64>, Vec<f64>, f64) {
        let mean = self.net.forward(state);
        let std = self.log_std.exp();
        let raw: Vec<f64> =
            mean.iter().map(|m| m + std * rng.normal()).collect();
        let logp = self.log_prob(&mean, &raw);
        let squashed: Vec<f64> =
            raw.iter().map(|r| 1.0 / (1.0 + (-r).exp())).collect();
        (raw, squashed, logp)
    }

    fn log_prob(&self, mean: &[f64], raw: &[f64]) -> f64 {
        let std = self.log_std.exp();
        raw.iter()
            .zip(mean)
            .map(|(a, m)| {
                let z = (a - m) / std;
                -0.5 * z * z
                    - self.log_std
                    - 0.5 * (2.0 * std::f64::consts::PI).ln()
            })
            .sum()
    }

    /// Clipped-surrogate PPO update over a rollout (advantages already
    /// computed by the caller via the shared critic).
    pub fn update(&mut self, batch: &[Transition], advantages: &[f64]) {
        for (tr, &adv) in batch.iter().zip(advantages) {
            let mean = self.net.forward(&tr.state);
            let logp = self.log_prob(&mean, &tr.action);
            let ratio = (logp - tr.logp).exp();
            let clipped = ratio.clamp(1.0 - self.clip, 1.0 + self.clip);
            // d surrogate / d mean: only when the unclipped branch is
            // active does the gradient flow
            let use_grad = if adv >= 0.0 {
                ratio <= 1.0 + self.clip
            } else {
                ratio >= 1.0 - self.clip
            };
            let _ = clipped;
            if !use_grad {
                continue;
            }
            let std = self.log_std.exp();
            // d logp / d mean_i = (a_i - m_i)/std^2 ; surrogate = ratio*adv
            let dmean: Vec<f64> = mean
                .iter()
                .zip(&tr.action)
                .map(|(m, a)| {
                    // gradient ASCENT on ratio*adv -> descent on -that
                    -(adv * ratio) * ((a - m) / (std * std))
                })
                .collect();
            self.net.backward_step(&tr.state, &dmean, self.lr);
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

/// Categorical actor over `n_actions` discrete choices (loop random-walk
/// directions, §5.2.2).
pub struct CategoricalActor {
    net: Mlp,
    n_actions: usize,
    lr: f64,
    clip: f64,
}

impl CategoricalActor {
    pub fn new(state_dim: usize, n_actions: usize, rng: &mut Rng) -> Self {
        Self {
            net: Mlp::new(&[state_dim, 32, n_actions], rng),
            n_actions,
            lr: 3e-3,
            clip: 0.2,
        }
    }

    fn probs(&self, state: &[f64]) -> Vec<f64> {
        let logits = self.net.forward(state);
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
        let z: f64 = exps.iter().sum();
        exps.into_iter().map(|e| e / z).collect()
    }

    pub fn sample(&self, state: &[f64], rng: &mut Rng) -> (usize, f64) {
        let p = self.probs(state);
        let mut u = rng.uniform();
        for (i, pi) in p.iter().enumerate() {
            if u < *pi {
                return (i, pi.max(1e-12).ln());
            }
            u -= pi;
        }
        (self.n_actions - 1, p[self.n_actions - 1].max(1e-12).ln())
    }

    pub fn update(&mut self, batch: &[Transition], advantages: &[f64]) {
        for (tr, &adv) in batch.iter().zip(advantages) {
            let p = self.probs(&tr.state);
            let logp = p[tr.action_idx].max(1e-12).ln();
            let ratio = (logp - tr.logp).exp();
            let use_grad = if adv >= 0.0 {
                ratio <= 1.0 + self.clip
            } else {
                ratio >= 1.0 - self.clip
            };
            if !use_grad {
                continue;
            }
            // d/d logits of -(ratio*adv*logp): softmax cross-entropy form
            let mut dlogits: Vec<f64> = p.clone();
            for (i, d) in dlogits.iter_mut().enumerate() {
                let ind = if i == tr.action_idx { 1.0 } else { 0.0 };
                *d = -(adv * ratio) * (ind - *d);
            }
            self.net.backward_step(&tr.state, &dlogits, self.lr);
        }
    }

    pub fn n_actions(&self) -> usize {
        self.n_actions
    }
}

/// Generalized advantage estimation over a rollout of rewards/values
/// (episodic, no bootstrapping past the end).
pub fn gae(rewards: &[f64], values: &[f64], gamma: f64, lambda: f64) -> Vec<f64> {
    let n = rewards.len();
    let mut adv = vec![0.0; n];
    let mut acc = 0.0;
    for t in (0..n).rev() {
        let next_v = if t + 1 < n { values[t + 1] } else { 0.0 };
        let delta = rewards[t] + gamma * next_v - values[t];
        acc = delta + gamma * lambda * acc;
        adv[t] = acc;
    }
    // normalize (standard PPO practice; keeps the toy nets stable)
    let mean = adv.iter().sum::<f64>() / n as f64;
    let var = adv.iter().map(|a| (a - mean).powi(2)).sum::<f64>() / n as f64;
    let sd = var.sqrt().max(1e-8);
    adv.iter().map(|a| (a - mean) / sd).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_fits_xor_ish() {
        let mut rng = Rng::new(3);
        let mut net = Mlp::new(&[2, 16, 1], &mut rng);
        let data = [
            ([0.0, 0.0], 0.0),
            ([0.0, 1.0], 1.0),
            ([1.0, 0.0], 1.0),
            ([1.0, 1.0], 0.0),
        ];
        for _ in 0..3000 {
            for (x, y) in &data {
                let out = net.forward(x)[0];
                net.backward_step(x, &[out - y], 0.01);
            }
        }
        for (x, y) in &data {
            let out = net.forward(x)[0];
            assert!((out - y).abs() < 0.25, "xor({x:?}) = {out}, want {y}");
        }
    }

    #[test]
    fn gaussian_actor_learns_target() {
        // reward = -(a - 0.8)^2 on the squashed action; the actor should
        // move its mean toward 0.8
        let mut rng = Rng::new(5);
        let mut actor = GaussianActor::new(2, 1, &mut rng);
        let mut critic = Critic::new(2, &mut rng);
        let state = vec![0.5, -0.5];
        let mut last_mean = 0.0;
        for _ in 0..60 {
            let mut batch = Vec::new();
            for _ in 0..16 {
                let (raw, squashed, logp) = actor.sample(&state, &mut rng);
                let reward = -(squashed[0] - 0.8).powi(2);
                batch.push(Transition {
                    state: state.clone(),
                    action: raw,
                    action_idx: 0,
                    logp,
                    reward,
                    value: critic.value(&state),
                });
            }
            let rewards: Vec<f64> = batch.iter().map(|t| t.reward).collect();
            let values: Vec<f64> = batch.iter().map(|t| t.value).collect();
            let adv = gae(&rewards, &values, 0.99, 0.95);
            actor.update(&batch, &adv);
            critic.update(
                &batch
                    .iter()
                    .map(|t| (t.state.clone(), t.reward))
                    .collect::<Vec<_>>(),
            );
            last_mean = 1.0 / (1.0 + (-actor.net.forward(&state)[0]).exp());
        }
        assert!(
            (last_mean - 0.8).abs() < 0.2,
            "actor mean {last_mean}, want ~0.8"
        );
    }

    #[test]
    fn categorical_actor_prefers_best_arm() {
        let mut rng = Rng::new(7);
        let mut actor = CategoricalActor::new(1, 3, &mut rng);
        let state = vec![1.0];
        let arm_reward = [0.1, 0.9, 0.3];
        for _ in 0..80 {
            let mut batch = Vec::new();
            for _ in 0..16 {
                let (a, logp) = actor.sample(&state, &mut rng);
                batch.push(Transition {
                    state: state.clone(),
                    action: vec![],
                    action_idx: a,
                    logp,
                    reward: arm_reward[a],
                    value: 0.0,
                });
            }
            let rewards: Vec<f64> = batch.iter().map(|t| t.reward).collect();
            let values = vec![0.4; batch.len()];
            let adv = gae(&rewards, &values, 0.99, 0.95);
            actor.update(&batch, &adv);
        }
        let p = actor.probs(&state);
        assert!(
            p[1] > 0.5,
            "best arm probability {p:?} did not dominate"
        );
    }

    #[test]
    fn gae_normalized() {
        let adv = gae(&[1.0, 2.0, 3.0, 4.0], &[0.0; 4], 0.99, 0.95);
        let mean: f64 = adv.iter().sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-9);
    }
}
